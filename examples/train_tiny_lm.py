"""End-to-end training driver with CORE-protected fault tolerance.

Trains a small decoder LM (reduced qwen2 wiring; --big trains a ~100M
variant) on the synthetic pipeline with CORE-encoded checkpoints, then
demonstrates the paper's value proposition *inside a training job*:

  1. train N steps, checkpointing every K;
  2. KILL storage nodes (simulated host loss) so checkpoint blocks die;
  3. DEGRADED RESTORE straight through the failures (vertical XOR path);
  4. verify the restored train state bit-for-bit (paper §7.3's MD5
     check, done with sha256 here);
  5. background-repair the lost blocks (RGS schedule) and keep training.

    PYTHONPATH=src python examples/train_tiny_lm.py [--big] [--steps 300]
"""

import argparse
import hashlib

import jax
import numpy as np

from repro.configs import get_config
from repro.train.loop import LoopConfig, Trainer
from repro.train import optimizer as opt


def state_digest(state) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(jax.tree.map(np.asarray, state)):
        h.update(np.ascontiguousarray(leaf).tobytes())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (slow on CPU; the deliverable profile)")
    ap.add_argument("--kill-nodes", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config("qwen2_72b").reduced()
    if args.big:
        cfg = cfg.reduced(num_layers=8, d_model=768, num_heads=12, head_dim=64,
                          d_ff=2048, vocab_size=32768)

    lc = LoopConfig(steps=args.steps, ckpt_every=max(args.steps // 3, 10),
                    log_every=10, seq_len=128, global_batch=8)
    oc = opt.OptConfig(lr=1e-3, warmup_steps=10, decay_steps=args.steps)
    tr = Trainer(cfg, lc, oc)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
        jax.eval_shape(lambda: tr.api.init(cfg, jax.random.PRNGKey(0)))))
    print(f"arch={cfg.name} (reduced) params={n_params/1e6:.1f}M "
          f"core_code=({tr.ckpt.code.n},{tr.ckpt.code.k},{tr.ckpt.code.t})")

    # phase 1: train with periodic CORE checkpoints
    state = tr.run()
    d0 = state_digest(state)
    first, last = tr.metrics_log[0]["loss"], tr.metrics_log[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'LEARNING' if last < first else 'no improvement?'})")
    print(f"final-state digest {d0}")

    # phase 2: kill storage nodes; checkpoint blocks on them are gone
    victims = list(range(args.kill_nodes))
    tr.store.fail_nodes(victims)
    lost = sum(1 for k, n in tr.store.placement.items() if n in victims)
    print(f"\nkilled nodes {victims} -> {lost} checkpoint blocks unavailable")

    # phase 3+4: degraded restore through the failures, verify digest
    restored = tr.restore_latest()
    rep = tr.last_restore_report
    d1 = state_digest(restored)
    print(f"degraded restore: fetched {rep.blocks_fetched} blocks "
          f"({rep.bytes_fetched/1e6:.1f} MB), digest {d1} "
          f"{'== OK' if d1 == d0 else '!= CORRUPT'}")
    assert d1 == d0

    # phase 5: background repair regenerates the lost blocks onto the
    # surviving nodes while the victims are still dead, then train on
    fix = tr.ckpt.repair(int(np.asarray(restored.step)))
    print(f"background repair: {fix.blocks_repaired} blocks regenerated "
          f"(schedules [{fix.schedule[:60]}…]), fetched {fix.blocks_fetched} blocks")
    for n in victims:
        tr.store.heal_node(n)  # replacement hosts may rejoin later

    tr.lc.steps = args.steps + 30
    state = tr.run(state=restored, until=args.steps + 30)
    print(f"\nresumed to step {int(np.asarray(state.step))}; "
          f"loss {tr.metrics_log[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
