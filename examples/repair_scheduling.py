"""Repair scheduling live: the paper's Step and Plus failure patterns
(§6.3, Table 1) scheduled with row-first / column-first / RGS, printing
each schedule and its cost.

    PYTHONPATH=src python examples/repair_scheduling.py
"""

import numpy as np

from repro.core.failure_matrix import (
    independent_clusters,
    plus_pattern,
    step_pattern,
)
from repro.core.product_code import CoreCode
from repro.core.recoverability import (
    irrecoverability_lower_bound,
    is_recoverable,
    recoverability_upper_bound,
)
from repro.core.scheduling import SCHEDULERS


def show(code: CoreCode, name: str, fm: np.ndarray):
    print(f"--- {name} pattern ({int(fm.sum())} failures) ---")
    for r in range(fm.shape[0]):
        print("   ", "".join("X" if x else "." for x in fm[r]))
    print(f"  clusters: {len(independent_clusters(fm))}, "
          f"recoverable: {is_recoverable(code, fm)}")
    for sched_name, fn in SCHEDULERS.items():
        s = fn(code, fm)
        print(f"  {sched_name:13s} cost {s.traffic:3d} blocks   plan: {s.describe()}")
    print()


def main():
    code = CoreCode(14, 12, 5)
    print(f"code ({code.n},{code.k},{code.t}); irrecoverability bounds "
          f"L={irrecoverability_lower_bound(code)}, "
          f"U={recoverability_upper_bound(code)}\n")
    show(code, "Step", step_pattern(code.rows, code.n))
    show(code, "Plus", plus_pattern(code.rows, code.n))

    # a random heavy pattern: partial recovery via independent clusters
    rng = np.random.default_rng(7)
    fm = (rng.random((code.rows, code.n)) < 0.12)
    show(code, "random p=0.12", fm)


if __name__ == "__main__":
    main()
