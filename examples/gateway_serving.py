"""Quickstart: serve live PUT/GET traffic from the CORE cluster.

The gateway is the client-facing layer over the simulated block store:
a Zipf/Poisson request trace is planned per-request against the live
failure set (vertical XOR at t blocks vs horizontal RS at k — the
paper's Table 1), and each window's reconstructions — however mixed
their shapes — are staged as fixed-width descriptor tiles and decoded
by the ragged MEGAKERNEL (GatewayConfig.coalesce="ragged", the
default): one descriptor-driven Pallas launch set per kind, <= 2 live
jit signatures per kind, tile widths autotuned per backend and the
winners persisted across processes. A small rebuild-cost-aware cache
absorbs hot reconstructions, and background repair contends with
foreground reads on the same simulated fabric — preemptively shared in
fixed quanta, so a repair transfer cannot head-of-line-block a read.
The serve path is the pipelined dataplane: window N+1's fetches overlap
window N's decode launches on the simulated decode-engine pool, which
spreads one megakernel launch across engines by tile ranges.

Multi-tenant QoS (--tenants): every request carries a tenant tag; each
tenant's fabric traffic is shaped by its weighted-fair quantum ratio
(repair is just the "repair" tenant), tenants may declare a p99 latency
SLO, and the admission controller rejects (or degrades to the
latency-cheapest plan) any GET whose estimated queue + decode time
would bust its tenant's target. The demo runs a premium tenant with an
SLO against a throttled batch tenant and prints per-tenant latency,
rejection, and starvation accounting.

Fault-injection scenarios (--scenario): a ScenarioTrace drives the
gateway over simulated time — a correlated rack failure under a load
surge, plus a flapping node — while SLO-aware closed-loop repair pacing
modulates the "repair" tenant's fabric weight and engine share from
observed foreground pressure. The demo replays the same trace with
fixed full-weight repair and with pacing, and prints p99-under-failure,
MTTR, the pacer's share decisions, negative-cache activity, and the
final durability audit.

Gray-failure hardening (--graybox): the failures real clusters fear
most are the ones that don't announce themselves — silent bit rot and
fail-slow nodes. The demo runs two experiments. First, a fail-slow
race: one node serving a twentieth of its healthy bandwidth, replayed
without and with hedged degraded reads (a speculative reconstruction
launched when a fetch overshoots the deadline priced off the request's
LEAST-backlogged source — the cross-source differential is the gray
signal), under a per-tenant 5% speculative-byte budget. Second, a
corruption + fail-slow + crash scenario bounded at the code's
tolerance: silent bitflips are caught by fetch-time checksum verifies
(read path) and the paced background scrubber, reclassified as
erasures (quarantine + tombstone + repair), and every GET still
returns digest-verified bytes — the demo prints detection split, MTTD,
hedge accounting, and the wrong-bytes-served count (always 0).

Code-family bake-off (--bakeoff): the paper's comparison, live — the
SAME objects, workload, and Weibull-interarrival fault trace served
three times with the per-namespace code family switched between RS
(the traditional-EC baseline), CORE (the product code), and LRC
(Azure-style local reconstruction groups). The demo prints per-family
repair traffic (fetch blocks per repaired block: CORE verticals at t,
RS at k, LRC local groups at k/2), repair time, degraded p99, storage
overhead, and the CORE-vs-RS repair ratio the paper claims at ~0.5x.

Write dataplane (--writes): mixed read/write churn — full-row
overwrite PUTs, small sealed PUTs, deletes — served twice through the
same trace: once with write_coalesce="sync" (one billed encode launch
pair per PUT) and once with "ragged" (the window's RS parity
generations in ONE ragged EH launch, its vertical-parity XOR-delta
folds in ONE EV launch, both billed on the shared engine pool before
any client transfer starts). The demo prints PUT throughput/latency,
encode launch counts and live jit signatures per kind, stripe-sealing
volume, and both end-to-end consistency audits (zero stale parity,
every sealed extent byte-identical).

Sharded scale-out (--shards N): the namespace metadata plane splits
from the data path — N shard gateways (each its own engine pool, block
cache, and admission state) serve one consistent-hash-routed namespace
over ONE shared block store and fabric. The demo serves the same
decode-bound trace at 1 shard and at N, prints the throughput speedup
and verifies the two runs returned byte-identical payloads per request
(sharding changes WHERE a request decodes, never WHAT it returns),
then replays the N-shard run with a whole shard killed mid-trace: the
dead shard's directory arcs hand over to survivors, every request
still completes, and the durability audit stays clean — the store is
shared, so shard death is a serving event, not a durability event.

Sim-time tracing (--trace out.json): the same serve with the
observability plane on — every request becomes a trace of spans over
the SIMULATED clock, exported as chrome-tracing JSON that opens
directly in https://ui.perfetto.dev (or chrome://tracing).

How to read a gateway trace
---------------------------
Each subsystem is one process row, each row's threads are its members:

  * ``tenant``  — one thread per tenant. The ``request`` span is the
    whole GET (arrival to delivery); nested under it: ``plan`` (the
    degraded-read plan against the live failure set), one ``fetch`` per
    source block (fabric queueing + transfer, as the request saw it),
    ``cache.hit`` instants for blocks served from the rebuild cache,
    ``decode`` attribution spans (args carry kernel kind, launch id,
    megakernel fraction and tile count), and ``verify`` at delivery.
  * ``engine``  — one thread per decode engine: ``engine.launch`` spans
    are the physical launches occupying it; several requests' decodes
    may share one launch (same ``launch_id``).
  * ``fabric``  — one thread per send port: ``xfer`` spans are the
    individual block transfers with their queueing delay in args.
  * ``repair``  — background repair: ``repair.run`` per repair sweep,
    ``repair.group`` per repaired group, ``repair.fetch`` per step, and
    ``repair.heal``/``repair.pacing`` instants (MTTR, share decisions).

Because timestamps are simulated seconds (rendered as microseconds), a
request whose latency is 30 ms shows a 30 ms span — what you see is
the modeled contention, not host jitter. To attribute a slow request,
find its ``request`` span, then look at whichever child ends last:
that dependency (a queued ``fetch``, a shared ``engine.launch``, a
paced repair transfer in the way) is the critical path — the same
decomposition ``repro.obs.critical_path`` computes, whose fleet-level
stage shares the gateway_obs benchmark reports.

    PYTHONPATH=src python examples/gateway_serving.py
    PYTHONPATH=src python examples/gateway_serving.py --tenants
    PYTHONPATH=src python examples/gateway_serving.py --scenario
    PYTHONPATH=src python examples/gateway_serving.py --graybox
    PYTHONPATH=src python examples/gateway_serving.py --bakeoff
    PYTHONPATH=src python examples/gateway_serving.py --writes
    PYTHONPATH=src python examples/gateway_serving.py --shards 4
    PYTHONPATH=src python examples/gateway_serving.py --trace out.json
"""

import argparse

import numpy as np

from repro.core.product_code import CoreCode
from repro.gateway import (
    GatewayConfig,
    ObjectGateway,
    ShardedGateway,
    ShardFailEvent,
    SlowNodeEvent,
    TenantProfile,
    WorkloadConfig,
    generate_requests,
    generate_tenant_requests,
    plan_failures,
    tenant_slo_map,
    tenant_weight_map,
)
from repro.scenario import (
    ScenarioConfig,
    correlated_surge_setup,
    flapping_node,
    generate_scenario,
    run_scenario,
)
from repro.storage.netmodel import REPAIR_TENANT, ClusterProfile


def main(trace_out: str | None = None):
    code = CoreCode(9, 6, 3)
    num_objects, q, num_nodes = 30, 1 << 14, 60
    rng = np.random.default_rng(0)

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {num_nodes} nodes, "
          f"{num_objects} objects of {code.k} x {q // 1024} KiB blocks")

    cfg = GatewayConfig(
        batch_window=0.02,          # 20 ms arrival coalescing
        cache_bytes=24 * q,         # small hot-block cache
        repair_on_failure=True,     # BlockFixer runs in the background
        repair_delay=0.5,           # failure-detection lag
        background_share=0.5,       # repair gets half a link
        tracing=trace_out is not None,  # sim-time spans (see --trace)
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), num_nodes, cfg)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))

    wl = WorkloadConfig(
        num_objects=num_objects,
        num_requests=1200,
        arrival_rate=1000.0,        # Poisson arrivals
        zipf_s=1.1,                 # popularity skew
        put_fraction=0.05,
        seed=1,
    )
    failures = plan_failures(2, num_nodes, at_time=0.15, spacing=0.25, seed=4)
    print(f"trace: {wl.num_requests} requests @ {wl.arrival_rate:.0f}/s, "
          f"node failures at t=" + ", ".join(f"{f.time:.2f}s" for f in failures))

    report = gw.serve(generate_requests(wl), failures)

    deg = report.degraded_gets
    st = gw.coalescer.stats
    print(f"\nserved {len(report.completed)}/{len(report.records)} requests "
          f"(every GET verified against ground truth)")
    print(f"  throughput      {report.throughput:8.1f} req/s")
    print(f"  latency p50/p99 {report.latency_percentile(50)*1e3:8.2f} / "
          f"{report.latency_percentile(99)*1e3:.2f} ms")
    print(f"  degraded GETs   {len(deg):8d} "
          f"({report.reconstruction_blocks_per_degraded_get:.1f} reconstruction "
          f"blocks each; vertical costs t={code.t}, horizontal k={code.k})")
    print(f"  ragged decode   {st.decode_ops:8d} reconstructions in "
          f"{st.decode_calls} megakernel launches (max batch "
          f"{st.max_batch}, {st.jit_entries} live jit entries, "
          f"{st.launches_per_window:.1f} launches/window, "
          f"{st.padded_byte_ratio:.0%} tile filler)")
    print(f"  block cache     {gw.cache.stats.hits:8d} hits / "
          f"{gw.cache.stats.misses} misses ({gw.cache.stats.hit_rate:.0%})")
    fg_mb = sum(
        v for k, v in gw.sim.class_bytes.items() if k != REPAIR_TENANT
    ) / 1e6
    print(f"  fabric          {fg_mb:8.1f} MB foreground, "
          f"{gw.sim.class_bytes.get(REPAIR_TENANT, 0)/1e6:.1f} MB "
          f"background repair ({len(report.repair_reports)} repair runs)")

    if trace_out is not None:
        from repro.obs import stage_shares, write_chrome_trace

        write_chrome_trace(trace_out, gw.tracer.spans)
        shares = stage_shares(gw.tracer)
        dominant = max(shares["shares"], key=shares["shares"].get)
        print(f"\n  trace           {len(gw.tracer.spans):8d} spans over "
              f"{gw.tracer.traces_kept} traces -> {trace_out}")
        print(f"  critical path   {dominant:>8s} dominates "
              f"({shares['shares'][dominant]:.0%} of total latency; "
              "open the file in https://ui.perfetto.dev)")


def main_tenants():
    """Two-tenant QoS demo: a premium tenant with a latency SLO shares
    the fabric with a heavily throttled batch tenant."""
    code = CoreCode(9, 6, 3)
    num_objects, q, num_nodes = 30, 1 << 14, 60
    rng = np.random.default_rng(0)
    profiles = [
        TenantProfile("premium", arrival_rate=400.0, weight=1.0, slo_p99=0.1),
        TenantProfile("batch", arrival_rate=400.0, weight=0.25),
    ]
    cfg = GatewayConfig(
        batch_window=0.02,
        tenant_weights=tenant_weight_map(profiles),
        tenant_slo_p99=tenant_slo_map(profiles),
        admission="reject",
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), num_nodes, cfg)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, two tenants: "
          + ", ".join(f"{p.name} (weight {p.weight}"
                      + (f", SLO p99 {p.slo_p99*1e3:.0f} ms)" if p.slo_p99 else ")")
                      for p in profiles))
    reqs = generate_tenant_requests(profiles, num_objects, 300, seed=1)
    failures = plan_failures(1, num_nodes, at_time=0.1, seed=4)
    report = gw.serve(reqs, failures)

    for p in profiles:
        done = report.tenant_completed(p.name)
        print(f"\n  {p.name}:")
        print(f"    completed       {len(done):6d} / "
              f"{sum(1 for r in reqs if r.tenant == p.name)}"
              f"  (rejected {report.rejections.get(p.name, 0)})")
        print(f"    latency p50/p99 {report.tenant_latency_percentile(p.name, 50)*1e3:8.2f}"
              f" / {report.tenant_latency_percentile(p.name, 99)*1e3:.2f} ms")
        if p.slo_p99:
            print(f"    SLO violations  "
                  f"{report.slo_violation_rate(p.name, p.slo_p99):8.1%} of admitted"
                  f"  (fabric deadline misses "
                  f"{gw.sim.deadline_miss_rate(p.name):.1%})")
        print(f"    worst fabric queueing "
              f"{gw.sim.tenant_wait_max.get(p.name, 0.0)*1e3:.2f} ms")


def main_scenario():
    """Fault-injection demo: the canonical correlated-failure + surge
    scenario (repro.scenario.correlated_surge_setup — the same setup the
    benchmark gate and regression test validate), replayed with fixed
    full-weight repair and with SLO-paced repair, plus a flapping node
    after the surge. The repair backlog (one rack's worth of every
    group) is far too large to finish inside the surge even at full
    weight — the regime where pacing is a real decision — and p99 is
    measured over requests arriving in the failure + surge window, the
    requests the SLO protects."""
    code = CoreCode(9, 6, 3)
    setup = correlated_surge_setup(code, num_requests=300)
    fail_at, surge_end, slo = setup["fail_at"], setup["surge_end"], setup["slo"]
    trace = flapping_node(setup["trace"], node=0, start=0.7, period=0.1, count=3)

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {setup['num_nodes']} "
          f"nodes in racks of {code.n - code.k}")
    print(f"trace: rack 2 lost at t={fail_at:.2f}s, node 0 flapping from "
          f"t=0.70s, 1.5x load surge for {surge_end - fail_at:.1f}s; "
          f"SLO p99 {slo * 1e3:.0f} ms")

    for label, pacing in (("fixed full-weight repair", False),
                          ("SLO-paced repair", True)):
        cfg = GatewayConfig(repair_pacing=pacing, **setup["gateway_kwargs"])
        gw = ObjectGateway(
            code, ClusterProfile.network_critical(), setup["num_nodes"], cfg
        )
        rng = np.random.default_rng(setup["seed"])
        gw.load_objects(rng.integers(
            0, 256,
            (setup["num_objects"], code.k, setup["block_bytes"]),
            dtype=np.uint8,
        ))
        res = run_scenario(gw, trace, setup["workload"])
        rep = res.report
        print(f"\n  {label}:")
        print(f"    p99 in surge      {res.p99_window(fail_at, surge_end)*1e3:8.1f} ms"
              f"   (whole trace p99 {rep.latency_percentile(99)*1e3:.1f} ms)")
        print(f"    MTTR mean/max     {res.mttr_mean:8.3f} / {res.mttr_max:.3f} s"
              f"   ({sum(r.blocks_repaired for r in rep.repair_reports)} blocks repaired)")
        print(f"    degraded GETs     {len(rep.degraded_gets):8d}"
              f"   (negative-cache probes skipped: {gw.cache.stats.negative_hits})")
        if pacing:
            shares = [s for _, s in rep.pacing]
            print(f"    pacing shares     {' '.join(f'{s:.2f}' for s in shares)}")
        audit = res.durability
        print(f"    durability        {audit['blocks_lost']} blocks lost, "
              f"{audit['unreadable_objects']} unreadable, "
              f"{audit['missing_blocks']} still missing")


def main_graybox():
    """Gray-failure demo: hedged degraded reads racing a fail-slow node,
    then a corruption + fail-slow + crash scenario exercising the
    corruption-as-erasure integrity plane end to end (the same two
    setups the gateway_integrity benchmark rows gate)."""
    code = CoreCode(9, 6, 3)
    q, num_objects = 4096, 30

    # --- experiment 1: fail-slow node, unhedged vs hedged -------------
    # A sparse cluster with uniform popularity keeps the slow-hit
    # fraction structural (~10% of GETs touch the slow node), the regime
    # a 5% speculative byte budget is meant to cover.
    num_nodes = 120
    wl = WorkloadConfig(
        num_objects=num_objects,
        num_requests=300,
        arrival_rate=200.0,
        zipf_s=0.0,
        seed=53,
    )
    reqs = generate_requests(wl)
    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {num_nodes} nodes; "
          f"one node fail-slow at 5% of healthy bandwidth from t=0")
    for label, hedge in (("unhedged", False), ("hedged", True)):
        cfg = GatewayConfig(
            batch_window=0.005, decode_cost=0.0005, hedge=hedge,
        )
        gw = ObjectGateway(
            code, ClusterProfile.network_critical(), num_nodes, cfg
        )
        rng = np.random.default_rng(53)
        gw.load_objects(
            rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8)
        )
        # degrade a node hosting object 0's first data column (placement
        # is seed-deterministic: both runs race the same slow node)
        slow = gw.store.node_of((*gw._objects[0], 0))
        rep = gw.serve(
            reqs, [SlowNodeEvent(time=0.0, node=slow, rate_factor=0.05)]
        )
        m = rep.metrics
        print(f"\n  {label}:")
        print(f"    latency p50/p99 {rep.latency_percentile(50)*1e3:8.2f} / "
              f"{rep.latency_percentile(99)*1e3:.2f} ms")
        if hedge:
            extra = m.counter_total("hedge_bytes") / max(
                sum(gw._fetch_bytes.values()), 1
            )
            print(f"    hedges          {int(m.counter_total('hedge_launched')):8d}"
                  f" launched, {int(m.counter_total('hedge_wins'))} won, "
                  f"{int(m.counter_total('hedge_losses'))} lost, "
                  f"{int(m.counter_total('hedge_budget_denied'))} budget-denied")
            print(f"    extra fabric    {extra:8.1%} speculative bytes "
                  f"(budget {cfg.hedge_budget:.0%})")

    # --- experiment 2: corruption-as-erasure under a gray trace -------
    scfg = ScenarioConfig(
        duration=0.6,
        num_nodes=60,
        nodes_per_rack=3,
        max_concurrent_failures=code.n - code.k,
        crash_rate=4.0,
        mean_downtime=0.08,
        transient_fraction=0.5,
        corruption_rate=10.0,
        corruption_blocks=2,
        slow_rate=5.0,
        slow_factor=0.2,
        mean_slow_time=0.1,
        seed=47,
    )
    trace = generate_scenario(scfg)
    cfg = GatewayConfig(
        batch_window=0.01,
        cache_bytes=8 * q,
        repair_on_failure=True,
        repair_delay=0.03,
        scrub_interval=0.1,
        scrub_blocks_per_run=48,
        decode_cost=0.002,
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), 60, cfg)
    rng = np.random.default_rng(47)
    gw.load_objects(
        rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8)
    )
    print(f"\ngray trace: {len(trace.fault_events())} fault events over "
          f"{scfg.duration:.1f}s — silent bitflips + fail-slow nodes + "
          f"transient crashes, bounded at n-k={code.n - code.k}")
    res = run_scenario(
        gw,
        trace,
        WorkloadConfig(
            num_objects=num_objects,
            num_requests=300,
            arrival_rate=400.0,
            seed=47,
        ),
    )
    rep = res.report
    m = rep.metrics
    mttd = list(rep.corruption_latency)
    gets_done = sum(1 for r in rep.completed if r.kind == "get")
    wrong = gets_done - int(m.counter_total("verified_gets"))
    print(f"\n  corruption      {int(m.counter_total('blocks_corrupted')):8d}"
          f" blocks silently damaged, "
          f"{int(m.counter_total('corruption_detected'))} detected "
          f"({int(m.counter_total('corruption_detected', source='read'))} by "
          f"fetch verify, "
          f"{int(m.counter_total('corruption_detected', source='scrub'))} by "
          f"scrub)")
    if mttd:
        print(f"    MTTD mean/max {np.mean(mttd)*1e3:8.1f} / "
              f"{np.max(mttd)*1e3:.1f} ms (injection -> checksum detection)")
    print(f"    fail-slow       {int(m.counter_total('slow_events')):8d}"
          f" rate-change events applied to the fabric")
    print(f"    degraded GETs   {len(rep.degraded_gets):8d} of {gets_done} "
          f"(every payload digest-verified; {wrong} wrong bytes served)")
    audit = res.durability
    print(f"    durability      {res.blocks_lost:8d} blocks lost, "
          f"{audit['unreadable_objects']} unreadable, "
          f"{audit['missing_blocks']} still missing after repair")


def main_bakeoff():
    """Code-family bake-off demo: RS vs CORE vs LRC through the same
    gateway, objects, workload, and Weibull fault trace (the same setup
    the gateway_bakeoff benchmark block gates)."""
    code = CoreCode(9, 6, 3)  # even k, n >= k+2: valid for all 3 families
    q, num_objects, num_nodes = 4096, 30, 60

    scfg = ScenarioConfig(
        duration=0.5,
        num_nodes=num_nodes,
        nodes_per_rack=3,
        max_concurrent_failures=1,  # the paper's single-node-failure regime
        crash_rate=10.0,
        mean_downtime=0.08,
        transient_fraction=0.75,
        interarrival="weibull",     # bursty warehouse-cluster churn
        interarrival_shape=0.7,
        seed=29,
    )
    trace = generate_scenario(scfg)
    wl = WorkloadConfig(
        num_objects=num_objects, num_requests=240, arrival_rate=400.0, seed=29
    )
    print(f"shared shape ({code.n},{code.k},{code.t}), {num_nodes} nodes, "
          f"{len(trace.fault_events())} fault events (Weibull shape "
          f"{scfg.interarrival_shape}, never >1 node down), same workload "
          f"for every family")
    print(f"\n  {'family':>8s} {'fetch/blk':>10s} {'repair ms/blk':>14s} "
          f"{'p99 ms':>8s} {'overhead':>9s} {'tolerance':>10s}")
    fetch_per = {}
    for fam in ("rs", "core", "lrc"):
        cfg = GatewayConfig(
            code_family=fam, batch_window=0.01,
            repair_on_failure=True, repair_delay=0.02,
        )
        gw = ObjectGateway(
            code, ClusterProfile.network_critical(), num_nodes, cfg
        )
        rng = np.random.default_rng(29)
        gw.load_objects(
            rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8)
        )
        res = run_scenario(gw, trace, wl)
        rep = res.report
        fetched = sum(r.blocks_fetched for r in rep.repair_reports)
        repaired = max(sum(r.blocks_repaired for r in rep.repair_reports), 1)
        rtime = sum(r.total_time for r in rep.repair_reports)
        fetch_per[fam] = fetched / repaired
        print(f"  {fam:>8s} {fetch_per[fam]:10.2f} "
              f"{rtime / repaired * 1e3:14.2f} "
              f"{rep.latency_percentile(99) * 1e3:8.2f} "
              f"{gw.family.storage_overhead:9.2f} "
              f"{gw.family.tolerance:10d}")
    print(f"\n  CORE repair traffic = {fetch_per['core'] / fetch_per['rs']:.2f}x "
          f"RS (paper claims ~0.5x); LRC = "
          f"{fetch_per['lrc'] / fetch_per['rs']:.2f}x")


def main_writes():
    """Write-dataplane demo: the same mixed read/write churn trace
    served through the per-PUT sync baseline and the ragged ENCODE
    megakernel (the setup the gateway_writes benchmark block gates),
    ending with the end-to-end consistency audits."""
    code = CoreCode(9, 6, 3)
    q, num_objects, num_nodes = 4096, 24, 60

    wl = WorkloadConfig(
        num_objects=num_objects,
        num_requests=300,
        arrival_rate=1500.0,
        zipf_s=0.4,
        put_fraction=0.8,           # PUT-heavy: windows hold real batches
        small_put_fraction=0.2,     # a fifth of PUTs are small sealed writes
        small_put_bytes=3000,
        delete_fraction=0.04,
        seed=61,
    )
    reqs = generate_requests(wl)
    n_puts = sum(1 for r in reqs if r.kind == "put")
    n_small = sum(1 for r in reqs if r.kind == "put" and r.nbytes)
    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {num_nodes} nodes; "
          f"{len(reqs)} requests: {n_puts} PUTs ({n_small} small, sealed), "
          f"{sum(1 for r in reqs if r.kind == 'delete')} deletes")
    for mode in ("sync", "ragged"):
        cfg = GatewayConfig(
            batch_window=0.01,
            write_coalesce=mode,
            encode_cost=0.002,      # modeled launch billing (deterministic)
            decode_cost=0.002,
        )
        gw = ObjectGateway(
            code, ClusterProfile.computation_critical(), num_nodes, cfg
        )
        rng = np.random.default_rng(61)
        gw.load_objects(
            rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8)
        )
        rep = gw.serve(list(reqs))
        gw.seal_flush(reqs[-1].time + 1.0)
        puts = [r for r in rep.records
                if r.kind == "put" and r.latency is not None]
        lats = sorted(r.latency for r in puts)
        span = (max(r.time + r.latency for r in puts)
                - min(r.time for r in puts))
        st = gw.coalescer.stats
        by_kind = gw.coalescer.jit_entries_by_kind()
        parity = gw.audit_parity()
        sealed = gw.audit_sealed_stripes()
        print(f"\n  write_coalesce={mode}:")
        print(f"    PUT throughput  {len(puts) / max(span, 1e-9):8.1f} put/s "
              f"(p50 {lats[len(lats) // 2] * 1e3:.1f} ms, "
              f"p99 {lats[int(len(lats) * 0.99)] * 1e3:.1f} ms)")
        print(f"    ragged encode   {st.encode_ops:8d} encode ops in "
              f"{st.encode_calls} billed launches over {st.encode_windows} "
              f"windows (live jit: EH {by_kind.get('EH', 0)}, "
              f"EV {by_kind.get('EV', 0)})")
        print(f"    stripes sealed  {sealed['rows_checked']:8d} rows "
              f"({sealed['extents_checked']} small extents; "
              f"{int(rep.metrics.counter_total('stripes_sealed'))} sealed "
              f"mid-trace, the rest at drain)")
        print(f"    parity audit    {parity['blocks_checked']:8d} blocks: "
              f"{parity['stale_blocks']} stale, "
              f"{parity['corrupt_blocks']} corrupt")
        print(f"    sealed audit    {sealed['rows_checked']:8d} rows "
              f"decoded: {sealed['extents_wrong']} wrong extents, "
              f"{sealed['rows_unreadable']} unreadable")


def main_shards(num_shards: int):
    """Sharded scale-out demo: the same decode-bound trace at 1 shard
    and at N over one shared store (the setup the gateway_shards
    benchmark block gates), then the N-shard run with a whole shard
    killed mid-trace."""
    code = CoreCode(9, 6, 3)
    q, num_objects, num_nodes = 4096, 60, 60
    tenants = [
        TenantProfile("gold", arrival_rate=8000.0, weight=1.0, zipf_s=0.4)
    ]

    def build(shards):
        cfg = GatewayConfig(
            batch_window=0.005,
            decode_cost_per_tile=0.002,  # deterministic per-tile billing
            record_payloads=True,
            tenant_weights=tenant_weight_map(tenants),
            tenant_slo_p99=tenant_slo_map(tenants),
        )
        gw = ShardedGateway(
            code,
            ClusterProfile.computation_critical(),
            num_nodes,
            shards,
            cfg,
            vnodes=256,
        )
        rng = np.random.default_rng(11)
        gw.load_objects(
            rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8)
        )
        return gw

    reqs = generate_tenant_requests(tenants, num_objects, 1200, seed=11)
    failures = plan_failures(3, num_nodes, at_time=0.01, spacing=0.0, seed=11)
    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {num_nodes} nodes, "
          f"{len(reqs)} requests, {len(failures)} node failures; "
          f"one shared store under 1 vs {num_shards} shard gateways")

    digests = {}
    rps = {}
    for shards in (1, num_shards):
        gw = build(shards)
        rep = gw.serve(list(reqs), list(failures))
        rps[shards] = rep.throughput
        digests[shards] = {
            (r.time, r.object_id): r.payload_digest
            for r in rep.completed if r.kind == "get"
        }
        print(f"\n  {shards} shard{'s' if shards > 1 else ' '}:")
        print(f"    completed       {len(rep.completed):8d} / {len(reqs)}")
        print(f"    throughput      {rep.throughput:8.1f} req/s")
        print(f"    latency p50/p99 {rep.latency_percentile(50)*1e3:8.2f} / "
              f"{rep.latency_percentile(99)*1e3:.2f} ms")
    match = digests[1] == digests[num_shards]
    print(f"\n  shards speedup    {rps[num_shards] / rps[1]:8.2f}x over "
          f"1 shard on the same store")
    print(f"  routing identity  {len(digests[1]):8d} payload digests "
          f"compared: {'byte-identical' if match else 'MISMATCH'}")

    if num_shards < 2:
        return
    victim = num_shards // 2
    span = max(r.time for r in reqs)
    gw = build(num_shards)
    rep = gw.serve(
        list(reqs),
        list(failures) + [ShardFailEvent(time=span * 0.5, shard=victim)],
    )
    aud = gw.audit_durability()
    print(f"\n  shard {victim} killed at t={span * 0.5:.3f}s:")
    print(f"    survivors       {gw.live_shards()!r} serve the dead "
          f"shard's arcs (minimal movement)")
    print(f"    completed       {len(rep.completed):8d} / {len(reqs)}")
    print(f"    durability      {aud['blocks_lost']:8d} blocks lost, "
          f"{aud['unreadable_objects']} unreadable (store is shared: "
          f"shard death is a serving event)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", action="store_true",
                    help="two-tenant QoS demo (weights + SLO admission)")
    ap.add_argument("--scenario", action="store_true",
                    help="fault-injection demo (paced vs fixed repair)")
    ap.add_argument("--graybox", action="store_true",
                    help="gray-failure demo (corruption-as-erasure, "
                         "fail-slow injection, hedged degraded reads)")
    ap.add_argument("--bakeoff", action="store_true",
                    help="code-family bake-off demo (RS vs CORE vs LRC "
                         "under the same workload and fault trace)")
    ap.add_argument("--writes", action="store_true",
                    help="write-dataplane demo (ragged ENCODE megakernel "
                         "vs per-PUT sync baseline + consistency audits)")
    ap.add_argument("--shards", metavar="N", type=int, default=None,
                    help="sharded scale-out demo: N shard gateways over "
                         "one shared store (speedup vs 1 shard, "
                         "byte-identical routing, shard-death failover)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="run the default demo with sim-time tracing and "
                         "export a Perfetto/chrome-tracing JSON file")
    args = ap.parse_args()
    if args.shards is not None:
        main_shards(args.shards)
    elif args.writes:
        main_writes()
    elif args.bakeoff:
        main_bakeoff()
    elif args.graybox:
        main_graybox()
    elif args.scenario:
        main_scenario()
    elif args.tenants:
        main_tenants()
    else:
        main(trace_out=args.trace)
