"""Quickstart: serve live PUT/GET traffic from the CORE cluster.

The gateway is the client-facing layer over the simulated block store:
a Zipf/Poisson request trace is planned per-request against the live
failure set (vertical XOR at t blocks vs horizontal RS at k — the
paper's Table 1), concurrent degraded reads sharing a decode shape are
coalesced into single batched Pallas GF(256) launches (batch sizes
padded up a fixed ladder so the jit cache stays bounded, kernel
parameters autotuned per backend), a small rebuild-cost-aware cache
absorbs hot reconstructions, and background repair contends with
foreground reads on the same simulated fabric — preemptively shared in
fixed quanta, so a repair transfer cannot head-of-line-block a read.
The serve path is the pipelined dataplane: window N+1's fetches overlap
window N's decode launches on the simulated decode-engine pool.

Multi-tenant QoS (--tenants): every request carries a tenant tag; each
tenant's fabric traffic is shaped by its weighted-fair quantum ratio
(repair is just the "repair" tenant), tenants may declare a p99 latency
SLO, and the admission controller rejects (or degrades to the
latency-cheapest plan) any GET whose estimated queue + decode time
would bust its tenant's target. The demo runs a premium tenant with an
SLO against a throttled batch tenant and prints per-tenant latency,
rejection, and starvation accounting.

    PYTHONPATH=src python examples/gateway_serving.py
    PYTHONPATH=src python examples/gateway_serving.py --tenants
"""

import argparse

import numpy as np

from repro.core.product_code import CoreCode
from repro.gateway import (
    GatewayConfig,
    ObjectGateway,
    TenantProfile,
    WorkloadConfig,
    generate_requests,
    generate_tenant_requests,
    plan_failures,
    tenant_slo_map,
    tenant_weight_map,
)
from repro.storage.netmodel import REPAIR_TENANT, ClusterProfile


def main():
    code = CoreCode(9, 6, 3)
    num_objects, q, num_nodes = 30, 1 << 14, 60
    rng = np.random.default_rng(0)

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {num_nodes} nodes, "
          f"{num_objects} objects of {code.k} x {q // 1024} KiB blocks")

    cfg = GatewayConfig(
        batch_window=0.02,          # 20 ms arrival coalescing
        cache_bytes=24 * q,         # small hot-block cache
        repair_on_failure=True,     # BlockFixer runs in the background
        repair_delay=0.5,           # failure-detection lag
        background_share=0.5,       # repair gets half a link
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), num_nodes, cfg)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))

    wl = WorkloadConfig(
        num_objects=num_objects,
        num_requests=1200,
        arrival_rate=1000.0,        # Poisson arrivals
        zipf_s=1.1,                 # popularity skew
        put_fraction=0.05,
        seed=1,
    )
    failures = plan_failures(2, num_nodes, at_time=0.15, spacing=0.25, seed=4)
    print(f"trace: {wl.num_requests} requests @ {wl.arrival_rate:.0f}/s, "
          f"node failures at t=" + ", ".join(f"{f.time:.2f}s" for f in failures))

    report = gw.serve(generate_requests(wl), failures)

    deg = report.degraded_gets
    st = gw.coalescer.stats
    print(f"\nserved {len(report.completed)}/{len(report.records)} requests "
          f"(every GET verified against ground truth)")
    print(f"  throughput      {report.throughput:8.1f} req/s")
    print(f"  latency p50/p99 {report.latency_percentile(50)*1e3:8.2f} / "
          f"{report.latency_percentile(99)*1e3:.2f} ms")
    print(f"  degraded GETs   {len(deg):8d} "
          f"({report.reconstruction_blocks_per_degraded_get:.1f} reconstruction "
          f"blocks each; vertical costs t={code.t}, horizontal k={code.k})")
    print(f"  batched decode  {st.decode_ops:8d} reconstructions in "
          f"{st.decode_calls} kernel launches (max batch {st.max_batch}, "
          f"{st.jit_entries} jit entries)")
    print(f"  block cache     {gw.cache.stats.hits:8d} hits / "
          f"{gw.cache.stats.misses} misses ({gw.cache.stats.hit_rate:.0%})")
    fg_mb = sum(
        v for k, v in gw.sim.class_bytes.items() if k != REPAIR_TENANT
    ) / 1e6
    print(f"  fabric          {fg_mb:8.1f} MB foreground, "
          f"{gw.sim.class_bytes.get(REPAIR_TENANT, 0)/1e6:.1f} MB "
          f"background repair ({len(report.repair_reports)} repair runs)")


def main_tenants():
    """Two-tenant QoS demo: a premium tenant with a latency SLO shares
    the fabric with a heavily throttled batch tenant."""
    code = CoreCode(9, 6, 3)
    num_objects, q, num_nodes = 30, 1 << 14, 60
    rng = np.random.default_rng(0)
    profiles = [
        TenantProfile("premium", arrival_rate=400.0, weight=1.0, slo_p99=0.1),
        TenantProfile("batch", arrival_rate=400.0, weight=0.25),
    ]
    cfg = GatewayConfig(
        batch_window=0.02,
        tenant_weights=tenant_weight_map(profiles),
        tenant_slo_p99=tenant_slo_map(profiles),
        admission="reject",
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), num_nodes, cfg)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, two tenants: "
          + ", ".join(f"{p.name} (weight {p.weight}"
                      + (f", SLO p99 {p.slo_p99*1e3:.0f} ms)" if p.slo_p99 else ")")
                      for p in profiles))
    reqs = generate_tenant_requests(profiles, num_objects, 300, seed=1)
    failures = plan_failures(1, num_nodes, at_time=0.1, seed=4)
    report = gw.serve(reqs, failures)

    for p in profiles:
        done = report.tenant_completed(p.name)
        print(f"\n  {p.name}:")
        print(f"    completed       {len(done):6d} / "
              f"{sum(1 for r in reqs if r.tenant == p.name)}"
              f"  (rejected {report.rejections.get(p.name, 0)})")
        print(f"    latency p50/p99 {report.tenant_latency_percentile(p.name, 50)*1e3:8.2f}"
              f" / {report.tenant_latency_percentile(p.name, 99)*1e3:.2f} ms")
        if p.slo_p99:
            print(f"    SLO violations  "
                  f"{report.slo_violation_rate(p.name, p.slo_p99):8.1%} of admitted"
                  f"  (fabric deadline misses "
                  f"{gw.sim.deadline_miss_rate(p.name):.1%})")
        print(f"    worst fabric queueing "
              f"{gw.sim.tenant_wait_max.get(p.name, 0.0)*1e3:.2f} ms")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", action="store_true",
                    help="two-tenant QoS demo (weights + SLO admission)")
    if ap.parse_args().tenants:
        main_tenants()
    else:
        main()
