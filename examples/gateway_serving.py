"""Quickstart: serve live PUT/GET traffic from the CORE cluster.

The gateway is the client-facing layer over the simulated block store:
a Zipf/Poisson request trace is planned per-request against the live
failure set (vertical XOR at t blocks vs horizontal RS at k — the
paper's Table 1), concurrent degraded reads sharing a decode shape are
coalesced into single batched Pallas GF(256) launches (batch sizes
padded up a fixed ladder so the jit cache stays bounded, kernel
parameters autotuned per backend), a small rebuild-cost-aware cache
absorbs hot reconstructions, and background repair contends with
foreground reads on the same simulated fabric — preemptively shared in
fixed quanta, so a repair transfer cannot head-of-line-block a read.
The serve path is the pipelined dataplane: window N+1's fetches overlap
window N's decode launches on the simulated decode engine.

    PYTHONPATH=src python examples/gateway_serving.py
"""

import numpy as np

from repro.core.product_code import CoreCode
from repro.gateway import (
    GatewayConfig,
    ObjectGateway,
    WorkloadConfig,
    generate_requests,
    plan_failures,
)
from repro.storage.netmodel import ClusterProfile


def main():
    code = CoreCode(9, 6, 3)
    num_objects, q, num_nodes = 30, 1 << 14, 60
    rng = np.random.default_rng(0)

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {num_nodes} nodes, "
          f"{num_objects} objects of {code.k} x {q // 1024} KiB blocks")

    cfg = GatewayConfig(
        batch_window=0.02,          # 20 ms arrival coalescing
        cache_bytes=24 * q,         # small hot-block cache
        repair_on_failure=True,     # BlockFixer runs in the background
        repair_delay=0.5,           # failure-detection lag
        background_share=0.5,       # repair gets half a link
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), num_nodes, cfg)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))

    wl = WorkloadConfig(
        num_objects=num_objects,
        num_requests=1200,
        arrival_rate=1000.0,        # Poisson arrivals
        zipf_s=1.1,                 # popularity skew
        put_fraction=0.05,
        seed=1,
    )
    failures = plan_failures(2, num_nodes, at_time=0.15, spacing=0.25, seed=4)
    print(f"trace: {wl.num_requests} requests @ {wl.arrival_rate:.0f}/s, "
          f"node failures at t=" + ", ".join(f"{f.time:.2f}s" for f in failures))

    report = gw.serve(generate_requests(wl), failures)

    deg = report.degraded_gets
    st = gw.coalescer.stats
    print(f"\nserved {len(report.completed)}/{len(report.records)} requests "
          f"(every GET verified against ground truth)")
    print(f"  throughput      {report.throughput:8.1f} req/s")
    print(f"  latency p50/p99 {report.latency_percentile(50)*1e3:8.2f} / "
          f"{report.latency_percentile(99)*1e3:.2f} ms")
    print(f"  degraded GETs   {len(deg):8d} "
          f"({report.reconstruction_blocks_per_degraded_get:.1f} reconstruction "
          f"blocks each; vertical costs t={code.t}, horizontal k={code.k})")
    print(f"  batched decode  {st.decode_ops:8d} reconstructions in "
          f"{st.decode_calls} kernel launches (max batch {st.max_batch}, "
          f"{st.jit_entries} jit entries)")
    print(f"  block cache     {gw.cache.stats.hits:8d} hits / "
          f"{gw.cache.stats.misses} misses ({gw.cache.stats.hit_rate:.0%})")
    print(f"  fabric          {gw.sim.class_bytes.get(0, 0)/1e6:8.1f} MB "
          f"foreground, {gw.sim.class_bytes.get(1, 0)/1e6:.1f} MB background "
          f"repair ({len(report.repair_reports)} repair runs)")


if __name__ == "__main__":
    main()
