"""Quickstart: serve live PUT/GET traffic from the CORE cluster.

The gateway is the client-facing layer over the simulated block store:
a Zipf/Poisson request trace is planned per-request against the live
failure set (vertical XOR at t blocks vs horizontal RS at k — the
paper's Table 1), and each window's reconstructions — however mixed
their shapes — are staged as fixed-width descriptor tiles and decoded
by the ragged MEGAKERNEL (GatewayConfig.coalesce="ragged", the
default): one descriptor-driven Pallas launch set per kind, <= 2 live
jit signatures per kind, tile widths autotuned per backend and the
winners persisted across processes. A small rebuild-cost-aware cache
absorbs hot reconstructions, and background repair contends with
foreground reads on the same simulated fabric — preemptively shared in
fixed quanta, so a repair transfer cannot head-of-line-block a read.
The serve path is the pipelined dataplane: window N+1's fetches overlap
window N's decode launches on the simulated decode-engine pool, which
spreads one megakernel launch across engines by tile ranges.

Multi-tenant QoS (--tenants): every request carries a tenant tag; each
tenant's fabric traffic is shaped by its weighted-fair quantum ratio
(repair is just the "repair" tenant), tenants may declare a p99 latency
SLO, and the admission controller rejects (or degrades to the
latency-cheapest plan) any GET whose estimated queue + decode time
would bust its tenant's target. The demo runs a premium tenant with an
SLO against a throttled batch tenant and prints per-tenant latency,
rejection, and starvation accounting.

Fault-injection scenarios (--scenario): a ScenarioTrace drives the
gateway over simulated time — a correlated rack failure under a load
surge, plus a flapping node — while SLO-aware closed-loop repair pacing
modulates the "repair" tenant's fabric weight and engine share from
observed foreground pressure. The demo replays the same trace with
fixed full-weight repair and with pacing, and prints p99-under-failure,
MTTR, the pacer's share decisions, negative-cache activity, and the
final durability audit.

Sim-time tracing (--trace out.json): the same serve with the
observability plane on — every request becomes a trace of spans over
the SIMULATED clock, exported as chrome-tracing JSON that opens
directly in https://ui.perfetto.dev (or chrome://tracing).

How to read a gateway trace
---------------------------
Each subsystem is one process row, each row's threads are its members:

  * ``tenant``  — one thread per tenant. The ``request`` span is the
    whole GET (arrival to delivery); nested under it: ``plan`` (the
    degraded-read plan against the live failure set), one ``fetch`` per
    source block (fabric queueing + transfer, as the request saw it),
    ``cache.hit`` instants for blocks served from the rebuild cache,
    ``decode`` attribution spans (args carry kernel kind, launch id,
    megakernel fraction and tile count), and ``verify`` at delivery.
  * ``engine``  — one thread per decode engine: ``engine.launch`` spans
    are the physical launches occupying it; several requests' decodes
    may share one launch (same ``launch_id``).
  * ``fabric``  — one thread per send port: ``xfer`` spans are the
    individual block transfers with their queueing delay in args.
  * ``repair``  — background repair: ``repair.run`` per repair sweep,
    ``repair.group`` per repaired group, ``repair.fetch`` per step, and
    ``repair.heal``/``repair.pacing`` instants (MTTR, share decisions).

Because timestamps are simulated seconds (rendered as microseconds), a
request whose latency is 30 ms shows a 30 ms span — what you see is
the modeled contention, not host jitter. To attribute a slow request,
find its ``request`` span, then look at whichever child ends last:
that dependency (a queued ``fetch``, a shared ``engine.launch``, a
paced repair transfer in the way) is the critical path — the same
decomposition ``repro.obs.critical_path`` computes, whose fleet-level
stage shares the gateway_obs benchmark reports.

    PYTHONPATH=src python examples/gateway_serving.py
    PYTHONPATH=src python examples/gateway_serving.py --tenants
    PYTHONPATH=src python examples/gateway_serving.py --scenario
    PYTHONPATH=src python examples/gateway_serving.py --trace out.json
"""

import argparse

import numpy as np

from repro.core.product_code import CoreCode
from repro.gateway import (
    GatewayConfig,
    ObjectGateway,
    TenantProfile,
    WorkloadConfig,
    generate_requests,
    generate_tenant_requests,
    plan_failures,
    tenant_slo_map,
    tenant_weight_map,
)
from repro.scenario import (
    correlated_surge_setup,
    flapping_node,
    run_scenario,
)
from repro.storage.netmodel import REPAIR_TENANT, ClusterProfile


def main(trace_out: str | None = None):
    code = CoreCode(9, 6, 3)
    num_objects, q, num_nodes = 30, 1 << 14, 60
    rng = np.random.default_rng(0)

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {num_nodes} nodes, "
          f"{num_objects} objects of {code.k} x {q // 1024} KiB blocks")

    cfg = GatewayConfig(
        batch_window=0.02,          # 20 ms arrival coalescing
        cache_bytes=24 * q,         # small hot-block cache
        repair_on_failure=True,     # BlockFixer runs in the background
        repair_delay=0.5,           # failure-detection lag
        background_share=0.5,       # repair gets half a link
        tracing=trace_out is not None,  # sim-time spans (see --trace)
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), num_nodes, cfg)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))

    wl = WorkloadConfig(
        num_objects=num_objects,
        num_requests=1200,
        arrival_rate=1000.0,        # Poisson arrivals
        zipf_s=1.1,                 # popularity skew
        put_fraction=0.05,
        seed=1,
    )
    failures = plan_failures(2, num_nodes, at_time=0.15, spacing=0.25, seed=4)
    print(f"trace: {wl.num_requests} requests @ {wl.arrival_rate:.0f}/s, "
          f"node failures at t=" + ", ".join(f"{f.time:.2f}s" for f in failures))

    report = gw.serve(generate_requests(wl), failures)

    deg = report.degraded_gets
    st = gw.coalescer.stats
    print(f"\nserved {len(report.completed)}/{len(report.records)} requests "
          f"(every GET verified against ground truth)")
    print(f"  throughput      {report.throughput:8.1f} req/s")
    print(f"  latency p50/p99 {report.latency_percentile(50)*1e3:8.2f} / "
          f"{report.latency_percentile(99)*1e3:.2f} ms")
    print(f"  degraded GETs   {len(deg):8d} "
          f"({report.reconstruction_blocks_per_degraded_get:.1f} reconstruction "
          f"blocks each; vertical costs t={code.t}, horizontal k={code.k})")
    print(f"  ragged decode   {st.decode_ops:8d} reconstructions in "
          f"{st.decode_calls} megakernel launches (max batch "
          f"{st.max_batch}, {st.jit_entries} live jit entries, "
          f"{st.launches_per_window:.1f} launches/window, "
          f"{st.padded_byte_ratio:.0%} tile filler)")
    print(f"  block cache     {gw.cache.stats.hits:8d} hits / "
          f"{gw.cache.stats.misses} misses ({gw.cache.stats.hit_rate:.0%})")
    fg_mb = sum(
        v for k, v in gw.sim.class_bytes.items() if k != REPAIR_TENANT
    ) / 1e6
    print(f"  fabric          {fg_mb:8.1f} MB foreground, "
          f"{gw.sim.class_bytes.get(REPAIR_TENANT, 0)/1e6:.1f} MB "
          f"background repair ({len(report.repair_reports)} repair runs)")

    if trace_out is not None:
        from repro.obs import stage_shares, write_chrome_trace

        write_chrome_trace(trace_out, gw.tracer.spans)
        shares = stage_shares(gw.tracer)
        dominant = max(shares["shares"], key=shares["shares"].get)
        print(f"\n  trace           {len(gw.tracer.spans):8d} spans over "
              f"{gw.tracer.traces_kept} traces -> {trace_out}")
        print(f"  critical path   {dominant:>8s} dominates "
              f"({shares['shares'][dominant]:.0%} of total latency; "
              "open the file in https://ui.perfetto.dev)")


def main_tenants():
    """Two-tenant QoS demo: a premium tenant with a latency SLO shares
    the fabric with a heavily throttled batch tenant."""
    code = CoreCode(9, 6, 3)
    num_objects, q, num_nodes = 30, 1 << 14, 60
    rng = np.random.default_rng(0)
    profiles = [
        TenantProfile("premium", arrival_rate=400.0, weight=1.0, slo_p99=0.1),
        TenantProfile("batch", arrival_rate=400.0, weight=0.25),
    ]
    cfg = GatewayConfig(
        batch_window=0.02,
        tenant_weights=tenant_weight_map(profiles),
        tenant_slo_p99=tenant_slo_map(profiles),
        admission="reject",
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), num_nodes, cfg)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, two tenants: "
          + ", ".join(f"{p.name} (weight {p.weight}"
                      + (f", SLO p99 {p.slo_p99*1e3:.0f} ms)" if p.slo_p99 else ")")
                      for p in profiles))
    reqs = generate_tenant_requests(profiles, num_objects, 300, seed=1)
    failures = plan_failures(1, num_nodes, at_time=0.1, seed=4)
    report = gw.serve(reqs, failures)

    for p in profiles:
        done = report.tenant_completed(p.name)
        print(f"\n  {p.name}:")
        print(f"    completed       {len(done):6d} / "
              f"{sum(1 for r in reqs if r.tenant == p.name)}"
              f"  (rejected {report.rejections.get(p.name, 0)})")
        print(f"    latency p50/p99 {report.tenant_latency_percentile(p.name, 50)*1e3:8.2f}"
              f" / {report.tenant_latency_percentile(p.name, 99)*1e3:.2f} ms")
        if p.slo_p99:
            print(f"    SLO violations  "
                  f"{report.slo_violation_rate(p.name, p.slo_p99):8.1%} of admitted"
                  f"  (fabric deadline misses "
                  f"{gw.sim.deadline_miss_rate(p.name):.1%})")
        print(f"    worst fabric queueing "
              f"{gw.sim.tenant_wait_max.get(p.name, 0.0)*1e3:.2f} ms")


def main_scenario():
    """Fault-injection demo: the canonical correlated-failure + surge
    scenario (repro.scenario.correlated_surge_setup — the same setup the
    benchmark gate and regression test validate), replayed with fixed
    full-weight repair and with SLO-paced repair, plus a flapping node
    after the surge. The repair backlog (one rack's worth of every
    group) is far too large to finish inside the surge even at full
    weight — the regime where pacing is a real decision — and p99 is
    measured over requests arriving in the failure + surge window, the
    requests the SLO protects."""
    code = CoreCode(9, 6, 3)
    setup = correlated_surge_setup(code, num_requests=300)
    fail_at, surge_end, slo = setup["fail_at"], setup["surge_end"], setup["slo"]
    trace = flapping_node(setup["trace"], node=0, start=0.7, period=0.1, count=3)

    print(f"CORE ({code.n},{code.k},{code.t}) cluster, {setup['num_nodes']} "
          f"nodes in racks of {code.n - code.k}")
    print(f"trace: rack 2 lost at t={fail_at:.2f}s, node 0 flapping from "
          f"t=0.70s, 1.5x load surge for {surge_end - fail_at:.1f}s; "
          f"SLO p99 {slo * 1e3:.0f} ms")

    for label, pacing in (("fixed full-weight repair", False),
                          ("SLO-paced repair", True)):
        cfg = GatewayConfig(repair_pacing=pacing, **setup["gateway_kwargs"])
        gw = ObjectGateway(
            code, ClusterProfile.network_critical(), setup["num_nodes"], cfg
        )
        rng = np.random.default_rng(setup["seed"])
        gw.load_objects(rng.integers(
            0, 256,
            (setup["num_objects"], code.k, setup["block_bytes"]),
            dtype=np.uint8,
        ))
        res = run_scenario(gw, trace, setup["workload"])
        rep = res.report
        print(f"\n  {label}:")
        print(f"    p99 in surge      {res.p99_window(fail_at, surge_end)*1e3:8.1f} ms"
              f"   (whole trace p99 {rep.latency_percentile(99)*1e3:.1f} ms)")
        print(f"    MTTR mean/max     {res.mttr_mean:8.3f} / {res.mttr_max:.3f} s"
              f"   ({sum(r.blocks_repaired for r in rep.repair_reports)} blocks repaired)")
        print(f"    degraded GETs     {len(rep.degraded_gets):8d}"
              f"   (negative-cache probes skipped: {gw.cache.stats.negative_hits})")
        if pacing:
            shares = [s for _, s in rep.pacing]
            print(f"    pacing shares     {' '.join(f'{s:.2f}' for s in shares)}")
        audit = res.durability
        print(f"    durability        {audit['blocks_lost']} blocks lost, "
              f"{audit['unreadable_objects']} unreadable, "
              f"{audit['missing_blocks']} still missing")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", action="store_true",
                    help="two-tenant QoS demo (weights + SLO admission)")
    ap.add_argument("--scenario", action="store_true",
                    help="fault-injection demo (paced vs fixed repair)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="run the default demo with sim-time tracing and "
                         "export a Perfetto/chrome-tracing JSON file")
    args = ap.parse_args()
    if args.scenario:
        main_scenario()
    elif args.tenants:
        main_tenants()
    else:
        main(trace_out=args.trace)
