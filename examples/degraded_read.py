"""Degraded reads under node failures: CORE vs classic RS on the
simulated cluster — the paper's §5.3 trade-offs, live:

  * single-BLOCK degraded access: CORE pulls t blocks (vertical XOR),
    RS pulls k (decode) — the paper's headline win;
  * whole-OBJECT centralized read with one failure: CORE pays
    (k-1) + t vs RS's k — the honest Fig-7 overhead at low stretch;
  * three failures in one row: (14,12) RS is DEAD (> n-k), CORE
    reads through via the vertical parities.

    PYTHONPATH=src python examples/degraded_read.py
"""

import numpy as np

from repro.core.product_code import CoreCode, CoreCodec
from repro.storage.blockstore import BlockStore
from repro.storage.netmodel import ClusterProfile
from repro.storage.repair import BlockFixer, UnrecoverableError


def fresh(code, matrix, drop):
    store = BlockStore(num_nodes=20)
    store.put_group("obj", matrix)
    for cell in drop:
        store.drop_block(("obj", *cell))
    return store


def main():
    code = CoreCode(14, 12, 5)
    codec = CoreCodec(code)
    rng = np.random.default_rng(1)
    block = 1 << 18
    objects = rng.integers(0, 256, (code.t, code.k, block), dtype=np.uint8)
    matrix = np.asarray(codec.encode(objects))
    prof = ClusterProfile.network_critical()

    print("1) single-BLOCK degraded access (block (0,0) missing)")
    for mode in ("hdfs_raid", "core"):
        store = fresh(code, matrix, [(0, 0)])
        fixer = BlockFixer(store, code, prof, mode=mode)
        rep = fixer.fix_group("obj")  # regenerate just the missing block
        print(f"   {mode:10s} fetched {rep.blocks_fetched:2d} blocks "
              f"({rep.bytes_fetched/1e6:5.1f} MB) t={rep.total_time:5.2f}s")
    print(f"   -> CORE: t={code.t} blocks vs RS: k={code.k} (paper's 50%+ save)\n")

    print("2) whole-OBJECT centralized read, one block missing "
          "(paper Fig 7: CORE pays extra at low stretch)")
    for mode in ("hdfs_raid", "core"):
        store = fresh(code, matrix, [(0, 0)])
        fixer = BlockFixer(store, code, prof, mode=mode)
        data, rep = fixer.degraded_read("obj", row=0)
        ok = np.array_equal(data, matrix[0, : code.k])
        print(f"   {mode:10s} fetched {rep.blocks_fetched:2d} blocks "
              f"({rep.bytes_fetched/1e6:5.1f} MB) t={rep.total_time:5.2f}s ok={ok}")
    print()

    print("3) three failures in row 0 (> n-k = 2): RS cannot read at all")
    for mode in ("hdfs_raid", "core"):
        store = fresh(code, matrix, [(0, 0), (0, 1), (0, 2)])
        fixer = BlockFixer(store, code, prof, mode=mode)
        try:
            data, rep = fixer.degraded_read("obj", row=0)
            ok = np.array_equal(data, matrix[0, : code.k])
            print(f"   {mode:10s} fetched {rep.blocks_fetched:2d} blocks, ok={ok}")
        except UnrecoverableError as e:
            print(f"   {mode:10s} UNRECOVERABLE ({e})")


if __name__ == "__main__":
    main()
