"""Quickstart: the CORE primitive in 60 seconds.

Encodes t objects with the (n,k,t) product code, kills blocks, repairs
them three ways (classic HDFS-RAID RS, optimized RS, CORE vertical/RGS),
and prints the paper's headline numbers live.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.product_code import CoreCode, CoreCodec
from repro.storage.blockstore import BlockStore
from repro.storage.netmodel import ClusterProfile
from repro.storage.repair import BlockFixer


def main():
    code = CoreCode(n=9, k=6, t=3)
    codec = CoreCodec(code)
    rng = np.random.default_rng(0)
    block = 1 << 18  # 256 KiB

    print(f"CORE ({code.n},{code.k},{code.t}): stretch {code.stretch:.2f}x")
    objects = rng.integers(0, 256, (code.t, code.k, block), dtype=np.uint8)
    matrix = np.asarray(codec.encode(objects))
    print(f"encoded {code.t} objects -> {code.rows}x{code.n} block matrix "
          f"({matrix.nbytes / 1e6:.1f} MB)")
    assert codec.verify(matrix), "product-code consistency"

    for mode in ("hdfs_raid", "hdfs_raid_opt", "core"):
        store = BlockStore(num_nodes=20)
        store.put_group("demo", matrix)
        store.drop_block(("demo", 0, 0))  # single failure
        fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode=mode)
        rep = fixer.fix_group("demo")
        ok = np.array_equal(store.get(("demo", 0, 0)), matrix[0, 0])
        print(f"  {mode:15s} fetched {rep.blocks_fetched:2d} blocks "
              f"({rep.bytes_fetched/1e6:5.1f} MB), "
              f"t_net {rep.network_time:6.2f}s + t_cpu {rep.compute_time:5.3f}s "
              f"verified={ok}")

    # a failure pattern classic RS cannot recover at all: 4 failures in one row
    store = BlockStore(num_nodes=20)
    store.put_group("demo", matrix)
    for c in range(4):
        store.drop_block(("demo", 1, c))  # > n-k = 3 failures in the row
    fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode="core")
    rep = fixer.fix_group("demo")
    ok = all(np.array_equal(store.get(("demo", 1, c)), matrix[1, c]) for c in range(4))
    print(f"4 failures in one row (unrecoverable for a row-only (9,6) RS): "
          f"CORE repairs via vertical parity, verified={ok}, "
          f"schedule [{rep.schedule}]")


if __name__ == "__main__":
    main()
