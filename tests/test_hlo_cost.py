"""Validate the trip-count-aware HLO cost analyzer against analytic
counts on known programs (scan-of-matmul, psum'd shard_map) — this is
the oracle behind every §Roofline number."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import analyze_hlo_text, builtin_cost_dict, parse_module


def _cost(fn, *args):
    co = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(co.as_text()), co


def test_scan_matmul_flops_trip_scaled():
    L, B, D = 5, 8, 64

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), ()

        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    cost, co = _cost(
        f,
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
    )
    expect = 2 * B * D * D * L
    assert cost.flops == pytest.approx(expect, rel=0.02), (cost.flops, expect)
    # builtin cost_analysis counts the body once -> must be ~L x smaller
    builtin = builtin_cost_dict(co).get("flops", 0.0)
    assert builtin < expect / 2


def test_nested_scan_flops():
    L, M, B, D = 4, 3, 2, 16

    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), ()

            c2, _ = jax.lax.scan(inner, c, None, length=M)
            return c2, ()

        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    cost, _ = _cost(
        f,
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
    )
    expect = 2 * B * D * D * L * M
    assert cost.flops == pytest.approx(expect, rel=0.05)


def test_dot_general_batched_flops():
    B, H, S, D = 2, 4, 32, 16

    def f(q, k):
        return jnp.einsum("bhsd,bhtd->bhst", q, k)

    cost, _ = _cost(
        f,
        jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
        jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
    )
    expect = 2 * B * H * S * S * D
    assert cost.flops == pytest.approx(expect, rel=0.02)


def test_bytes_reasonable_for_elementwise():
    N = 1 << 20

    def f(x):
        return x * 2.0 + 1.0

    cost, _ = _cost(f, jax.ShapeDtypeStruct((N,), jnp.float32))
    # one read + one write = 8 MiB; allow fusion-boundary slack
    assert 0.5 * 8e6 < cost.hbm_bytes < 3 * 8e6


def test_parse_module_roundtrip_smoke():
    def f(x):
        return jnp.sin(x).sum()

    co = jax.jit(f).lower(jax.ShapeDtypeStruct((128,), jnp.float32)).compile()
    comps, entry = parse_module(co.as_text())
    assert entry is not None and entry in comps
    assert comps[entry].instrs
