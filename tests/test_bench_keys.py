"""Schema guard for BENCH_gateway.json — the machine-readable perf
snapshot benchmarks/run.py --fast rewrites on every run.

The ROADMAP's standing rule is that these keys are STABLE: extended,
never renamed, so the perf trajectory stays comparable across PRs. This
test pins the key set from PR 2 (throughput / latency / amplification /
pipelined-vs-serial / p99-under-repair), the PR 3 multi-tenant block
(gateway_tenants), the PR 4 fault-scenario block (gateway_scenario:
paced-vs-fixed repair p99/MTTR plus durability counters), the PR 5
megakernel block, the PR 6 observability block (gateway_obs: tracing
overhead + stage attribution + bounded long-trace), and the PR 7
gray-failure block (gateway_integrity: hedged-vs-unhedged p99 under
fail-slow, the structural extra-byte budget, and corruption-as-erasure
detection/repair counters), the PR 8 code-family bake-off block
(gateway_bakeoff: per-family repair bandwidth / repair time / degraded
p99 / storage overhead under the shared Weibull fault trace plus the
CORE-vs-RS repair ratio and clean-path byte identity), and the PR 9
write-dataplane block (gateway_writes: ragged-vs-sync PUT throughput
under modeled encode billing, jit signatures per encode kind, stripe
sealing, and the churn-audit consistency counters), the PR 10 sharded
scale-out block (gateway_shards: multi-shard speedup over one shared
store/fabric, the shard-death failover trace, routing identity) plus
the double-failure blend subkeys under gateway_bakeoff, and skips
cleanly
when the snapshot has not been generated in this checkout (e.g. a
fresh clone running only the unit suite).
"""

from __future__ import annotations

import json
import pathlib

import pytest

BENCH_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_gateway.json"

# PR-2 top-level keys + the PR-3 gateway_tenants block. New keys may be
# ADDED next to these; removing or renaming any of them is a break.
TOP_LEVEL_KEYS = {
    "schema",
    "bench",
    "throughput_rps",
    "p50_ms",
    "p99_ms",
    "degraded_read_amplification",
    "pipelined_vs_serial",
    "p99_under_repair_ms",
    "jit_cache_entries",
    "autotune",
    "gateway_tenants",
    "gateway_scenario",
    "gateway_megakernel",
    "gateway_obs",
    "gateway_integrity",
    "gateway_bakeoff",
    "gateway_writes",
    "gateway_shards",
}

PIPELINE_KEYS = {
    "serial_rps",
    "pipelined_rps",
    "speedup",
    "serial_p99_ms",
    "pipelined_p99_ms",
}

REPAIR_KEYS = {"fifo", "quantum", "improvement"}

TENANT_KEYS = {
    "tenant_weights",
    "tenant_p99_ms",
    "tenant_wait_max_ms",
    "slo_violation_rate",
    "slo_rejected",
    "engines_speedup",
}

TIER_NAMES = {"gold", "silver", "bronze"}

SCENARIO_KEYS = {
    "p99_under_failure_ms",
    "mttr_s",
    "durability_events",
    "blocks_lost",
    "pacing_updates",
}

# PR-5 ragged megakernel block: one descriptor-driven launch set per
# window vs the shape-bucketed baseline.
MEGAKERNEL_KEYS = {
    "launches_per_window",
    "padded_byte_ratio",
    "ragged_rps",
    "bucketed_rps",
    "speedup",
    "jit_entries",
    "decode_shapes",
}

# PR-6 observability block: tracing overhead + critical-path stage
# attribution on the canonical scenario, plus the bounded-memory
# long-trace run.
OBS_KEYS = {
    "overhead_ratio",
    "stage_shares",
    "shares_sum",
    "traces_kept",
    "spans",
    "launch_amortization",
    "jit_retraces",
    "autotune_sweeps",
    "long_trace",
}

OBS_STAGES = {
    "admission",
    "fetch",
    "batch_wait",
    "engine_wait",
    "decode",
    "deliver",
}

# PR-7 gray-failure block: hedged degraded reads under fail-slow plus
# the corruption-as-erasure integrity plane.
INTEGRITY_KEYS = {
    "p99_fail_slow_ms",
    "hedge_launched",
    "hedge_wins",
    "hedge_losses",
    "extra_fabric_ratio",
    "corruption_injected",
    "corruption_detected",
    "detected_by_read",
    "detected_by_scrub",
    "mttd_s",
    "corrupt_blocks_repaired",
    "wrong_bytes_served",
}

# PR-8 code-family bake-off block: RS vs CORE vs LRC through the same
# gateway, workload and shared Weibull fault trace.
BAKEOFF_KEYS = {
    "families",
    "fault_events",
    "repair_blocks_per_lost",
    "repair_bytes",
    "repair_time_per_block_ms",
    "degraded_p99_ms",
    "storage_overhead",
    "tolerance",
    "core_vs_rs_repair_ratio",
    "lrc_vs_rs_repair_ratio",
    "core_vs_rs_repair_time_ratio",
    "clean_path_identical",
    "blocks_lost",
    "double_failure",
}

FAMILY_NAMES = {"core", "rs", "lrc"}

# PR-10 double-failure blend subkeys (gateway_bakeoff.double_failure):
# 85% single / 15% same-column double erasures, CORE-vs-RS blended
# degraded traffic between the t/k and 1.0 endpoints.
DOUBLE_FAILURE_KEYS = {
    "double_fraction",
    "degraded_gets",
    "recon_blocks_per_degraded_get",
    "core_vs_rs_degraded_ratio",
    "vertical_endpoint_ratio",
}

# PR-10 sharded scale-out block: near-linear multi-shard speedup over
# one shared store/fabric, the shard-death failover trace, and the
# routing-identity bit.
SHARDS_KEYS = {
    "shard_counts",
    "throughput_rps",
    "speedup",
    "p99_ms",
    "shard_death",
    "routing",
}

SHARD_DEATH_KEYS = {
    "shards",
    "dead_shards",
    "requests",
    "completed",
    "p99_pre_ms",
    "p99_post_ms",
    "p99_failover_ratio",
    "blocks_lost",
    "unreadable_objects",
}

# PR-9 write-dataplane block: ragged ENCODE megakernel vs the per-PUT
# sync baseline plus the churn consistency audit.
WRITES_KEYS = {
    "put_rps",
    "speedup",
    "put_p50_ms",
    "put_p99_ms",
    "encode_launches",
    "encode_ops",
    "jit_per_encode_kind",
    "stripes_sealed",
    "deletes",
    "churn_audit",
}

CHURN_AUDIT_KEYS = {
    "fault_events",
    "blocks_checked",
    "stale_blocks",
    "extents_checked",
    "extents_wrong",
    "blocks_lost",
    "replay_identical",
}


@pytest.fixture(scope="module")
def bench() -> dict:
    if not BENCH_PATH.exists():
        pytest.skip(f"{BENCH_PATH.name} not generated in this checkout")
    with open(BENCH_PATH) as f:
        return json.load(f)


def test_top_level_keys_stable(bench):
    missing = TOP_LEVEL_KEYS - set(bench)
    assert not missing, f"BENCH_gateway.json lost stable keys: {sorted(missing)}"
    assert bench["bench"] == "gateway"
    assert bench["schema"] == 1


def test_load_and_pipeline_keys(bench):
    for section in ("throughput_rps", "p50_ms", "p99_ms"):
        assert {"f0", "f1", "f2"} <= set(bench[section]), section
    assert {"f1", "f2"} <= set(bench["degraded_read_amplification"])
    assert PIPELINE_KEYS <= set(bench["pipelined_vs_serial"])
    assert REPAIR_KEYS <= set(bench["p99_under_repair_ms"])


def test_gateway_tenants_keys(bench):
    ten = bench["gateway_tenants"]
    missing = TENANT_KEYS - set(ten)
    assert not missing, f"gateway_tenants lost stable keys: {sorted(missing)}"
    for section in ("tenant_weights", "tenant_p99_ms", "tenant_wait_max_ms"):
        assert TIER_NAMES <= set(ten[section]), section
    assert {"off", "reject"} <= set(ten["slo_violation_rate"])
    assert {"rps_1", "rps_4", "speedup"} <= set(ten["engines_speedup"])


def test_gateway_scenario_keys(bench):
    sc = bench["gateway_scenario"]
    missing = SCENARIO_KEYS - set(sc)
    assert not missing, f"gateway_scenario lost stable keys: {sorted(missing)}"
    for section in ("p99_under_failure_ms", "mttr_s"):
        assert {"fixed", "paced"} <= set(sc[section]), section
    assert "improvement" in sc["p99_under_failure_ms"]
    assert "ratio" in sc["mttr_s"]


def test_gateway_megakernel_keys(bench):
    mk = bench["gateway_megakernel"]
    missing = MEGAKERNEL_KEYS - set(mk)
    assert not missing, f"gateway_megakernel lost stable keys: {sorted(missing)}"
    for section in ("launches_per_window", "padded_byte_ratio", "jit_entries"):
        assert {"ragged", "bucketed"} <= set(mk[section]), section


def test_gateway_megakernel_values_sane(bench):
    """Light sanity (the real acceptance gates live in
    benchmarks/gateway_load.py check()): both dataplanes ran, the
    mixed-shape workload exercised >= 3 decode shapes, and the ragged
    path's live jit set stays O(1)."""
    mk = bench["gateway_megakernel"]
    assert mk["ragged_rps"] > 0 and mk["bucketed_rps"] > 0
    assert mk["decode_shapes"] >= 3
    assert 0 < mk["jit_entries"]["ragged"] <= 4  # <= 2 rungs x 2 kinds
    assert 0.0 <= mk["padded_byte_ratio"]["ragged"] < 1.0


def test_gateway_scenario_values_sane(bench):
    """Light sanity on the scenario block (the real acceptance gates live
    in benchmarks/gateway_load.py check()): within-tolerance traces lose
    nothing, both repair modes actually repaired, and pacing decisions
    were recorded."""
    sc = bench["gateway_scenario"]
    assert sc["blocks_lost"] == 0
    assert sc["durability_events"] > 0
    assert sc["mttr_s"]["fixed"] > 0 and sc["mttr_s"]["paced"] > 0
    assert sc["pacing_updates"] > 0


def test_gateway_obs_keys(bench):
    obs = bench["gateway_obs"]
    missing = OBS_KEYS - set(obs)
    assert not missing, f"gateway_obs lost stable keys: {sorted(missing)}"
    assert OBS_STAGES <= set(obs["stage_shares"])
    assert {"launches", "ops_per_launch", "tiles_per_launch"} <= set(
        obs["launch_amortization"]
    )
    assert {
        "requests",
        "records_resident",
        "resident_samples",
        "spans_resident",
        "traces_kept",
    } <= set(obs["long_trace"])


def test_gateway_obs_values_sane(bench):
    """Light sanity (the real acceptance gates live in
    benchmarks/gateway_load.py check()): the tracer plane costs a few
    percent at most, the additive critical-path shares cover the whole
    latency, and the long-trace run kept resident state bounded."""
    obs = bench["gateway_obs"]
    assert 1.0 <= obs["overhead_ratio"] <= 1.05
    assert obs["shares_sum"] == pytest.approx(1.0, abs=0.01)
    assert obs["traces_kept"] > 0 and obs["spans"] > 0
    lt = obs["long_trace"]
    assert lt["requests"] >= 2000
    assert lt["records_resident"] == 0
    assert lt["resident_samples"] < 50_000


def test_gateway_integrity_keys(bench):
    integ = bench["gateway_integrity"]
    missing = INTEGRITY_KEYS - set(integ)
    assert not missing, f"gateway_integrity lost stable keys: {sorted(missing)}"
    assert {"unhedged", "hedged", "improvement"} <= set(
        integ["p99_fail_slow_ms"]
    )


def test_gateway_integrity_values_sane(bench):
    """Light sanity (the real acceptance gates live in
    benchmarks/gateway_load.py check()): zero wrong bytes ever served,
    hedging beats the unhedged baseline inside the structural 5%
    extra-byte budget, and every detected corruption was repaired."""
    integ = bench["gateway_integrity"]
    assert integ["wrong_bytes_served"] == 0
    p99 = integ["p99_fail_slow_ms"]
    assert p99["hedged"] < p99["unhedged"]
    assert integ["hedge_wins"] > 0
    assert 0.0 <= integ["extra_fabric_ratio"] <= 0.05
    assert integ["corruption_detected"] > 0
    assert integ["corrupt_blocks_repaired"] == integ["corruption_detected"]
    assert integ["mttd_s"] >= 0.0


def test_gateway_bakeoff_keys(bench):
    bak = bench["gateway_bakeoff"]
    missing = BAKEOFF_KEYS - set(bak)
    assert not missing, f"gateway_bakeoff lost stable keys: {sorted(missing)}"
    assert set(bak["families"]) == FAMILY_NAMES
    for section in (
        "repair_blocks_per_lost",
        "repair_bytes",
        "repair_time_per_block_ms",
        "degraded_p99_ms",
        "storage_overhead",
        "tolerance",
    ):
        assert FAMILY_NAMES <= set(bak[section]), section


def test_gateway_bakeoff_values_sane(bench):
    """Light sanity (the real acceptance gates live in
    benchmarks/gateway_load.py check()): the paper's headline claim —
    CORE repair bandwidth <= 0.55x RS on single-node failure — holds in
    our fabric, LRC's local groups beat the RS k-block re-decode, all
    three families served byte-identical payloads on the clean path,
    and nothing was lost under the within-tolerance trace."""
    bak = bench["gateway_bakeoff"]
    assert bak["fault_events"] > 0
    assert 0 < bak["core_vs_rs_repair_ratio"] <= 0.55
    assert bak["lrc_vs_rs_repair_ratio"] < 1.0
    blk = bak["repair_blocks_per_lost"]
    assert blk["core"] < blk["rs"] and blk["lrc"] < blk["rs"]
    assert bak["clean_path_identical"] is True
    assert bak["blocks_lost"] == 0
    # storage price of the repair savings: CORE's stretch exceeds the
    # shared-row n/k of RS and LRC
    ovh = bak["storage_overhead"]
    assert ovh["core"] > ovh["rs"] == ovh["lrc"]
    assert all(v > 0 for v in bak["degraded_p99_ms"].values())


def test_gateway_double_failure_keys(bench):
    df = bench["gateway_bakeoff"]["double_failure"]
    missing = DOUBLE_FAILURE_KEYS - set(df)
    assert not missing, f"double_failure lost stable keys: {sorted(missing)}"
    for section in ("degraded_gets", "recon_blocks_per_degraded_get"):
        assert {"core", "rs"} <= set(df[section]), section


def test_gateway_double_failure_values_sane(bench):
    """Light sanity (the real acceptance gates live in
    benchmarks/gateway_load.py check()): the blended CORE-vs-RS degraded
    traffic ratio under 85% single / 15% same-column double erasures
    sits strictly between the vertical endpoint (t/k) and the
    all-horizontal 1.0 — the paper's double-failure regime."""
    df = bench["gateway_bakeoff"]["double_failure"]
    assert 0.0 < df["double_fraction"] < 0.5
    assert df["vertical_endpoint_ratio"] < df["core_vs_rs_degraded_ratio"] < 1.0
    assert df["degraded_gets"]["core"] > 0
    assert df["degraded_gets"]["core"] == df["degraded_gets"]["rs"]


def test_gateway_shards_keys(bench):
    sh = bench["gateway_shards"]
    missing = SHARDS_KEYS - set(sh)
    assert not missing, f"gateway_shards lost stable keys: {sorted(missing)}"
    for section in ("throughput_rps", "speedup", "p99_ms"):
        assert {"s1", "s2", "s4", "s8"} <= set(sh[section]), section
    assert SHARD_DEATH_KEYS <= set(sh["shard_death"])
    assert {"digests_compared", "digest_match"} <= set(sh["routing"])


def test_gateway_shards_values_sane(bench):
    """Light sanity (the real acceptance gates live in
    benchmarks/gateway_load.py check()): near-linear scale-out (>= 3x at
    4 shards), zero-loss whole-shard-death failover with bounded
    survivor p99, and routing identity between 1 and 4 shards."""
    sh = bench["gateway_shards"]
    sp = sh["speedup"]
    assert sp["s1"] == 1.0
    assert 1.0 < sp["s2"] < sp["s4"] < sp["s8"]
    assert sp["s4"] >= 3.0
    dth = sh["shard_death"]
    assert dth["blocks_lost"] == 0
    assert dth["unreadable_objects"] == 0
    assert dth["completed"] == dth["requests"]
    assert 0 < dth["p99_failover_ratio"] <= 1.5
    rt = sh["routing"]
    assert rt["digest_match"] is True and rt["digests_compared"] > 0


def test_gateway_writes_keys(bench):
    wr = bench["gateway_writes"]
    missing = WRITES_KEYS - set(wr)
    assert not missing, f"gateway_writes lost stable keys: {sorted(missing)}"
    for section in ("put_rps", "put_p50_ms", "put_p99_ms", "encode_launches"):
        assert {"sync", "ragged"} <= set(wr[section]), section
    assert {"EH", "EV"} <= set(wr["jit_per_encode_kind"])
    assert CHURN_AUDIT_KEYS <= set(wr["churn_audit"])


def test_gateway_writes_values_sane(bench):
    """Light sanity (the real acceptance gates live in
    benchmarks/gateway_load.py check()): PUT latency is billed sim time
    (> 0 — encode launches and transfers are never free), the ragged
    encode path beats the sync baseline >= 1.5x, the live jit set stays
    <= 2 signatures per encode kind, and the churn audit is clean."""
    wr = bench["gateway_writes"]
    assert wr["put_rps"]["sync"] > 0 and wr["put_rps"]["ragged"] > 0
    assert wr["speedup"] >= 1.5
    assert wr["put_p50_ms"]["ragged"] > 0 and wr["put_p99_ms"]["ragged"] > 0
    jit = wr["jit_per_encode_kind"]
    assert 0 < jit["EH"] <= 2 and 0 < jit["EV"] <= 2
    assert wr["stripes_sealed"] > 0
    ca = wr["churn_audit"]
    assert ca["fault_events"] > 0 and ca["extents_checked"] > 0
    assert ca["stale_blocks"] == 0
    assert ca["extents_wrong"] == 0
    assert ca["blocks_lost"] == 0
    assert ca["replay_identical"] is True


def test_gateway_tenants_values_sane(bench):
    """Light sanity on the recorded values (the real acceptance gates
    live in benchmarks/gateway_load.py check()): weights map to the tier
    scheme and the recorded numbers are positive."""
    ten = bench["gateway_tenants"]
    assert ten["tenant_weights"] == {"gold": 1.0, "silver": 0.5, "bronze": 0.2}
    assert all(v > 0 for v in ten["tenant_p99_ms"].values())
    assert ten["engines_speedup"]["rps_1"] > 0
    assert ten["engines_speedup"]["rps_4"] > 0
