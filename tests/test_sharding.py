"""Sharded multi-gateway scale-out tests: consistent-hash directory
properties (coverage, balance, minimal movement on shard death),
cross-shard cache coherence through the metadata plane, routing
identity (sharding changes WHERE a request decodes, never WHAT it
returns), deterministic replay, whole-shard-death failover with zero
loss, and the per-tile decode billing model's config validation."""

import numpy as np
import pytest

from repro.core import CoreCode
from repro.gateway import (
    GatewayConfig,
    LRUBlockCache,
    MetadataPlane,
    ShardDirectory,
    ShardedGateway,
    ShardFailEvent,
    TenantProfile,
    generate_tenant_requests,
    plan_failures,
    tenant_slo_map,
    tenant_weight_map,
)
from repro.storage import ClusterProfile
from repro.storage.netmodel import base_tenant, shard_tenant

CODE = CoreCode(9, 6, 3)
NUM_NODES = 60


def _mk_sharded(num_shards, num_objects=60, q=4096, seed=5, **cfg_kw):
    """A small decode-bound sharded cluster + matching request trace."""
    tenants = [
        TenantProfile("gold", arrival_rate=3000.0, weight=1.0, zipf_s=0.4)
    ]
    cfg = GatewayConfig(
        batch_window=0.005,
        decode_cost_per_tile=0.002,
        record_payloads=True,
        tenant_weights=tenant_weight_map(tenants),
        tenant_slo_p99=tenant_slo_map(tenants),
        **cfg_kw,
    )
    gw = ShardedGateway(
        CODE,
        ClusterProfile.computation_critical(),
        NUM_NODES,
        num_shards,
        cfg,
        vnodes=256,
    )
    rng = np.random.default_rng(seed)
    gw.load_objects(
        rng.integers(0, 256, (num_objects, CODE.k, q), dtype=np.uint8)
    )
    reqs = generate_tenant_requests(tenants, num_objects, 300, seed=seed)
    return gw, reqs


def _digests(rep):
    return {
        (r.time, r.object_id): r.payload_digest
        for r in rep.completed
        if r.kind == "get"
    }


# -- consistent-hash directory ------------------------------------------------


def test_directory_covers_and_balances():
    d = ShardDirectory(range(4), vnodes=256)
    owners = [d.shard_for(oid) for oid in range(2000)]
    counts = {sid: owners.count(sid) for sid in d.shards}
    assert set(counts) == {0, 1, 2, 3}
    assert all(c > 0 for c in counts.values())
    # the murmur-mixed ring keeps arcs sane: no shard owns a majority
    assert max(counts.values()) < 0.5 * len(owners)


def test_directory_minimal_movement_on_shard_death():
    d = ShardDirectory(range(4), vnodes=256)
    before = {oid: d.shard_for(oid) for oid in range(2000)}
    d.remove_shard(2)
    moved = 0
    for oid, owner in before.items():
        if owner == 2:
            moved += 1
            assert d.shard_for(oid) in {0, 1, 3}
        else:
            # survivors keep every object they already owned
            assert d.shard_for(oid) == owner
    assert moved > 0


def test_directory_refuses_to_remove_last_shard():
    d = ShardDirectory([0], vnodes=16)
    with pytest.raises(ValueError):
        d.remove_shard(0)


def test_group_ownership_partitions_repair_work():
    meta = MetadataPlane(shard_ids=range(4), vnodes=256)
    gids = [f"g{g}" for g in range(80)]
    for gid in gids:
        owners = [s for s in range(4) if meta.owns_group(s, gid)]
        assert len(owners) == 1  # exactly one live shard owns each group
    # the unsharded gateway (shard_id None) owns everything
    assert all(meta.owns_group(None, gid) for gid in gids)


# -- fabric tenant tagging ----------------------------------------------------


def test_shard_tenant_roundtrip():
    assert shard_tenant("gold", 2) == "gold@s2"
    assert base_tenant("gold@s2") == "gold"
    assert shard_tenant("gold", None) == "gold"
    assert base_tenant("gold") == "gold"
    # legacy int class ids pass through untouched
    assert shard_tenant(1, 2) == 1
    assert base_tenant(1) == 1


# -- cross-shard cache coherence ----------------------------------------------


def test_metadata_plane_fans_out_cache_coherence():
    meta = MetadataPlane(shard_ids=range(2), vnodes=16)
    c0, c1 = LRUBlockCache(1 << 20), LRUBlockCache(1 << 20)
    meta.register_cache(c0)
    meta.register_cache(c1)
    key = ("g0", 0, 0)
    blk = np.zeros(64, dtype=np.uint8)
    c0.put(key, blk)
    c1.put(key, blk)
    # a PUT overwrite / repair heal invalidates EVERY shard's copy
    meta.invalidate(key)
    assert c0.get(key) is None and c1.get(key) is None
    # a node failure tombstones the block in EVERY negative cache
    meta.put_negative(key, now=1.0, ttl=10.0)
    assert c0.is_negative(key, now=2.0) and c1.is_negative(key, now=2.0)
    # recovery purges both
    assert meta.purge_negative([key]) == 2
    assert not c0.is_negative(key, now=2.0)
    # an unregistered (dead) shard's cache drops out of the fan-out
    meta.unregister_cache(c1)
    meta.put_negative(key, now=3.0, ttl=10.0)
    assert c0.is_negative(key, now=3.5) and not c1.is_negative(key, now=3.5)


# -- routing identity + determinism -------------------------------------------


def test_sharded_serve_matches_unsharded_bytes():
    """1 shard vs 3 shards on the same trace + failures: byte-identical
    payloads per (time, object) — the tentpole's correctness gate."""
    failures = plan_failures(4, NUM_NODES, at_time=0.01, spacing=0.0, seed=5)
    gw1, reqs = _mk_sharded(1)
    rep1 = gw1.serve(reqs, failures)
    gw3, _ = _mk_sharded(3)
    rep3 = gw3.serve(reqs, failures)
    assert len(rep1.completed) == len(reqs)
    assert len(rep3.completed) == len(reqs)
    d1, d3 = _digests(rep1), _digests(rep3)
    assert d1 and d1 == d3


def test_sharded_serve_deterministic_replay():
    """Two fresh 3-shard runs of the same trace are bit-identical under
    per-tile decode billing (no measured-kernel wall-clock noise)."""
    failures = plan_failures(4, NUM_NODES, at_time=0.01, spacing=0.0, seed=5)

    def outcome():
        gw, reqs = _mk_sharded(3)
        rep = gw.serve(reqs, failures)
        return [
            (r.time, r.object_id, r.kind, r.latency, r.payload_digest)
            for r in rep.records
        ]

    assert outcome() == outcome()


# -- whole-shard death --------------------------------------------------------


def test_shard_death_failover_zero_loss():
    gw, reqs = _mk_sharded(3)
    span = max(r.time for r in reqs)
    before = {oid: gw.shard_of(oid) for oid in range(60)}
    failures = plan_failures(2, NUM_NODES, at_time=0.01, spacing=0.0, seed=5)
    rep = gw.serve(
        reqs, failures + [ShardFailEvent(time=span * 0.5, shard=1)]
    )
    assert gw.dead_shards == {1}
    assert gw.live_shards() == [0, 2]
    # every request still completes; storage was untouched so the
    # namespace stays fully durable
    assert len(rep.completed) == len(reqs)
    aud = gw.audit_durability()
    assert aud["blocks_lost"] == 0
    assert aud["unreadable_objects"] == 0
    # minimal movement: only the dead shard's objects re-route
    for oid, owner in before.items():
        if owner == 1:
            assert gw.shard_of(oid) in {0, 2}
        else:
            assert gw.shard_of(oid) == owner


def test_shard_death_events_validate():
    gw, reqs = _mk_sharded(1, num_objects=6)
    span = max(r.time for r in reqs)
    with pytest.raises(RuntimeError):
        gw.serve(list(reqs), [ShardFailEvent(time=span * 0.5, shard=0)])
    gw2, reqs2 = _mk_sharded(2, num_objects=6)
    with pytest.raises(ValueError):
        gw2.serve(list(reqs2), [ShardFailEvent(time=0.01, shard=7)])


# -- config validation --------------------------------------------------------


def test_sharded_gateway_rejects_zero_shards():
    with pytest.raises(ValueError):
        ShardedGateway(
            CODE, ClusterProfile.computation_critical(), NUM_NODES, 0
        )


def test_decode_cost_per_tile_validation():
    from repro.gateway import ObjectGateway

    def build(**cfg_kw):
        return ObjectGateway(
            CODE,
            ClusterProfile.computation_critical(),
            NUM_NODES,
            GatewayConfig(**cfg_kw),
        )

    with pytest.raises(ValueError):
        build(decode_cost_per_tile=-0.1)
    with pytest.raises(ValueError):
        build(decode_cost=0.01, decode_cost_per_tile=0.01)
    with pytest.raises(ValueError):
        build(decode_cost_per_tile=0.01, coalesce="bucketed")
    gw = build(decode_cost_per_tile=0.01)
    assert gw.config.decode_cost_per_tile == 0.01
