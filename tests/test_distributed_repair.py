"""distributed_xor_repair: butterfly XOR across mesh shards == oracle.
Runs in a subprocess with 8 fake devices (the main session keeps 1)."""

from __future__ import annotations

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh, mesh_context
from repro.core.distributed import distributed_xor_repair

for t, q in [(8, 4096), (5, 1000), (3, 257)]:
    n_axis = 8
    mesh = make_mesh((n_axis,), ("data",))
    rng = np.random.default_rng(t)
    blocks = rng.integers(0, 256, (t, q), dtype=np.uint8)
    want = np.bitwise_xor.reduce(blocks, axis=0)
    with mesh_context(mesh):
        got = np.asarray(jax.jit(
            lambda b: distributed_xor_repair(b, mesh, "data")
        )(jnp.asarray(blocks)))
    assert np.array_equal(got, want), (t, q)
print("DISTRIBUTED_XOR_OK")
"""


def test_distributed_xor_repair_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO, timeout=600, capture_output=True, text=True,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "DISTRIBUTED_XOR_OK" in r.stdout


def test_critical_path_model():
    from repro.core.distributed import xor_repair_critical_path

    bfly, cent = xor_repair_critical_path(5, 64 << 20, 50e9, 12e6)
    assert bfly < cent / 100  # mesh repair crushes 2013-Ethernet repair
    b2, c2 = xor_repair_critical_path(5, 4 << 20, 50e9, 50e9)
    assert b2 == pytest.approx(3 * (4 << 20) / 50e9)
    assert c2 == pytest.approx(5 * (4 << 20) / 50e9)
