"""Gateway serving-layer tests: degraded-read planner (Table 1 costs),
decode coalescer, LRU cache, priority fabric sharing, and an end-to-end
trace with injected failures."""

import numpy as np
import pytest

from repro.core.product_code import CoreCode, CoreCodec
from repro.gateway import (
    DecodeCoalescer,
    DegradedReadPlanner,
    GatewayConfig,
    LRUBlockCache,
    ObjectGateway,
    TenantProfile,
    UnreadableObjectError,
    WorkloadConfig,
    generate_requests,
    generate_tenant_requests,
    plan_failures,
    tenant_slo_map,
    tenant_weight_map,
)
from repro.gateway.workload import FailureEvent, Request, zipf_probs
from repro.storage.blockstore import BlockStore
from repro.storage.netmodel import (
    BACKGROUND,
    REPAIR_TENANT,
    ClusterProfile,
    NetSimulator,
    Transfer,
)


def make_group(code, store, group_id="g0", q=1024, seed=0):
    rng = np.random.default_rng(seed)
    objects = rng.integers(0, 256, size=(code.t, code.k, q), dtype=np.uint8)
    matrix = np.asarray(CoreCodec(code).encode(objects))
    store.put_group(group_id, matrix)
    return objects, matrix


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_planner_healthy_object_needs_no_decode():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    make_group(code, store)
    plan = DegradedReadPlanner(store, code).plan("g0", 0)
    assert not plan.degraded
    assert len(plan.direct) == code.k
    assert plan.reconstruction_blocks == 0


def test_planner_prefers_vertical_at_t_blocks():
    """Table 1: one missing block, intact column => t sources via XOR."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    make_group(code, store)
    store.fail_nodes([store.node_of(("g0", 0, 2))])
    plan = DegradedReadPlanner(store, code).plan("g0", 0)
    assert plan.degraded
    (op,) = plan.decodes
    assert op.kind == "V" and op.targets == (2,)
    assert len(op.sources) == code.t
    assert plan.reconstruction_blocks == code.t


def test_planner_horizontal_on_broken_column():
    """Table 1: broken column forces the k-block RS decode."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    make_group(code, store)
    # (0,2) missing and its column broken elsewhere too
    store.fail_nodes([store.node_of(("g0", 0, 2)), store.node_of(("g0", 2, 2))])
    plan = DegradedReadPlanner(store, code).plan("g0", 0)
    (op,) = plan.decodes
    assert op.kind == "H" and op.targets == (2,)
    assert len(op.sources) == code.k
    assert plan.reconstruction_blocks == code.k
    # distinct blocks touched stays at k: avail data cols double as sources
    assert len(plan.source_keys) == code.k


def test_planner_vertical_wins_ties_and_loses_when_costlier():
    """(9,6,3): 2 missing => 2t = 6 <= k = 6, vertical; 3 missing =>
    3t = 9 > k = 6, one horizontal decode covers all three."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    make_group(code, store)
    store.fail_nodes([store.node_of(("g0", 0, 1)), store.node_of(("g0", 0, 4))])
    plan = DegradedReadPlanner(store, code).plan("g0", 0)
    assert [op.kind for op in plan.decodes] == ["V", "V"]
    store.fail_nodes([store.node_of(("g0", 0, 5))])
    plan = DegradedReadPlanner(store, code).plan("g0", 0)
    (op,) = plan.decodes
    assert op.kind == "H" and set(op.targets) == {1, 4, 5}
    assert plan.reconstruction_blocks == code.k


def test_planner_unreadable_raises():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    make_group(code, store)
    # kill column 2 entirely and m+1 blocks of row 0
    for r in range(code.rows):
        store.drop_block(("g0", r, 2))
    for c in (0, 1, 3):
        store.drop_block(("g0", 0, c))
    with pytest.raises(UnreadableObjectError):
        DegradedReadPlanner(store, code).plan("g0", 0)


# ---------------------------------------------------------------------------
# coalescer
# ---------------------------------------------------------------------------

def test_coalescer_batches_same_shape_and_matches_reference():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    _, matrix = make_group(code, store, q=512)
    planner = DegradedReadPlanner(store, code)
    # one failure in each of three rows (distinct columns): three
    # concurrent degraded reads produce three identical-shape V ops
    cells = [(0, 0), (1, 2), (2, 4)]
    for r, c in cells:
        store.fail_nodes([store.node_of(("g0", r, c))])
    plans = [planner.plan("g0", r) for r, _ in cells]
    ops = [op for p in plans for op in p.decodes]
    assert len(ops) == 3 and all(op.shape_key == ops[0].shape_key for op in ops)
    co = DecodeCoalescer()
    results, _ = co.execute(ops, lambda key: store.get(key))
    assert co.stats.decode_calls == 1  # ONE launch for all three
    assert co.stats.decode_ops == 3
    assert co.stats.max_batch == 3
    for op, res in zip(ops, results):
        np.testing.assert_array_equal(
            res[op.targets[0]], matrix[op.row, op.targets[0]]
        )


def test_coalescer_mixed_shapes_get_separate_launches():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=80)
    _, matrix = make_group(code, store, q=512)
    planner = DegradedReadPlanner(store, code)
    # vertical on row 1 col 0; horizontal on row 0 (column 3 broken)
    store.fail_nodes([store.node_of(("g0", 1, 0))])
    store.fail_nodes([store.node_of(("g0", 0, 3)), store.node_of(("g0", 2, 3))])
    v_plan = planner.plan("g0", 1)
    h_plan = planner.plan("g0", 0)
    ops = list(v_plan.decodes) + list(h_plan.decodes)
    kinds = sorted(op.kind for op in ops)
    assert kinds == ["H", "V"]
    co = DecodeCoalescer()
    results, _ = co.execute(ops, lambda key: store.get(key))
    assert co.stats.decode_calls == 2  # shapes differ: one launch each
    for op, res in zip(ops, results):
        for col in op.targets:
            np.testing.assert_array_equal(res[col], matrix[op.row, col])


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_lru_cache_eviction_and_stats():
    blk = lambda i: np.full(100, i, dtype=np.uint8)
    cache = LRUBlockCache(capacity_bytes=250)  # fits two 100-byte blocks
    cache.put(("g", 0, 0), blk(1))
    cache.put(("g", 0, 1), blk(2))
    assert cache.get(("g", 0, 0)) is not None  # refresh 0's recency
    cache.put(("g", 0, 2), blk(3))  # evicts ("g",0,1) (LRU)
    assert cache.get(("g", 0, 1)) is None
    assert cache.get(("g", 0, 0)) is not None
    assert cache.get(("g", 0, 2)) is not None
    assert cache.stats.evictions == 1
    assert cache.stats.hits == 3 and cache.stats.misses == 1
    assert ("g", 0, 0) in cache and ("g", 0, 1) not in cache


def test_cache_rejects_oversized_and_invalidates():
    cache = LRUBlockCache(capacity_bytes=50)
    cache.put(("g", 0, 0), np.zeros(100, dtype=np.uint8))  # larger than cache
    assert len(cache) == 0
    cache.put(("g", 0, 1), np.zeros(40, dtype=np.uint8))
    cache.invalidate(("g", 0, 1))
    assert len(cache) == 0 and cache.nbytes == 0


def test_cost_aware_cache_keeps_expensive_reconstructions():
    """A k-cost horizontal reconstruction outlives cheap 1-cost fetches
    under pressure, even when it is the oldest entry."""
    blk = lambda: np.zeros(100, dtype=np.uint8)
    cache = LRUBlockCache(capacity_bytes=250, policy="cost")  # two blocks
    cache.put(("g", 0, 0), blk(), cost=6.0)  # horizontal decode, k=6
    cache.put(("g", 0, 1), blk(), cost=1.0)  # plain fetch
    cache.put(("g", 0, 2), blk(), cost=1.0)  # evicts the cheap fetch
    assert ("g", 0, 0) in cache  # expensive entry survives despite age
    assert ("g", 0, 1) not in cache
    # vertical (t=3) beats plain fetch but loses to horizontal (k=6)
    cache.put(("g", 0, 3), blk(), cost=3.0)
    assert ("g", 0, 0) in cache and ("g", 0, 2) not in cache


def test_cost_aware_cache_uniform_costs_degenerate_to_lru():
    blk = lambda: np.zeros(100, dtype=np.uint8)
    cache = LRUBlockCache(capacity_bytes=250, policy="cost")
    cache.put(("g", 0, 0), blk())
    cache.put(("g", 0, 1), blk())
    assert cache.get(("g", 0, 0)) is not None  # refresh 0's recency
    cache.put(("g", 0, 2), blk())  # must evict ("g",0,1), the LRU
    assert ("g", 0, 1) not in cache
    assert ("g", 0, 0) in cache and ("g", 0, 2) in cache


def test_cost_aware_cache_refresh_demotes_repaired_blocks():
    """After BlockFixer repairs the underlying block it is a cheap store
    read again; refresh_cost drops its eviction priority in place."""
    blk = lambda: np.zeros(100, dtype=np.uint8)
    cache = LRUBlockCache(capacity_bytes=250, policy="cost")
    cache.put(("g", 0, 0), blk(), cost=6.0)
    cache.put(("g", 0, 1), blk(), cost=3.0)
    cache.refresh_cost(("g", 0, 0), 1.0)  # repaired: now the cheapest
    cache.put(("g", 0, 2), blk(), cost=1.0)
    assert ("g", 0, 0) not in cache  # demoted entry is the victim
    assert ("g", 0, 1) in cache and ("g", 0, 2) in cache


def test_cost_aware_cache_clock_never_rolls_back():
    """Evicting an entry whose score was demoted below the inflation
    clock (via refresh_cost) must not deflate the clock — otherwise
    fresh insertions get stale scores and are evicted before older
    entries (recency inversion)."""
    blk = lambda: np.zeros(100, dtype=np.uint8)
    cache = LRUBlockCache(capacity_bytes=250, policy="cost")
    cache.put(("g", 0, 0), blk(), cost=5.0)
    cache.put(("g", 0, 1), blk(), cost=5.0)
    cache.put(("g", 0, 2), blk(), cost=5.0)  # evicts 0, clock -> 5
    cache.refresh_cost(("g", 0, 1), 0.1)  # score drops below the clock
    cache.put(("g", 0, 3), blk(), cost=5.0)  # evicts 1; clock must hold
    cache.put(("g", 0, 4), blk(), cost=5.0)  # must evict the OLDER 2
    assert ("g", 0, 2) not in cache
    assert ("g", 0, 3) in cache and ("g", 0, 4) in cache


def test_gateway_repair_refreshes_cache_costs():
    """End-to-end: a cached reconstruction keeps its rebuild cost while
    the repair write-back is in flight, and is re-priced to 1.0 once the
    heal completes in simulated time (the BlockFixer hook, deferred)."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code,
        cache_bytes=4 * 1024 * 1024,
        batch_window=0.02,
        repair_on_failure=True,
        repair_delay=0.05,
        background_share=0.5,
    )
    victim = gw.store.node_of(("g0", 0, 0))
    key = ("g0", 0, 0)
    reqs = [Request(time=0.03 + 0.001 * i, object_id=0) for i in range(5)]
    report = gw.serve(reqs, [FailureEvent(time=0.01, node=victim)])
    assert report.repair_reports
    # the decoded block is cached at its vertical rebuild cost (t), and
    # stays there while the write-back transfers are still in flight —
    # it is the only copy pre-heal reads can use
    assert key in gw.cache
    assert gw.cache._cost[key] == code.t
    assert key in gw._reprice_on_heal
    # a read dated long after the heal completes triggers the re-price
    report2 = gw.serve([Request(time=50.0, object_id=0)])
    assert len(report2.completed) == 1
    assert key in gw.cache
    assert gw.cache._cost[key] == 1.0
    assert key not in gw._reprice_on_heal


# ---------------------------------------------------------------------------
# workload + fabric sharing
# ---------------------------------------------------------------------------

def test_workload_is_reproducible_and_zipf_skewed():
    cfg = WorkloadConfig(num_objects=50, num_requests=2000, zipf_s=1.2, seed=3)
    a, b = generate_requests(cfg), generate_requests(cfg)
    assert [(r.time, r.object_id) for r in a] == [(r.time, r.object_id) for r in b]
    probs = zipf_probs(50, 1.2)
    assert probs[0] > 10 * probs[-1]  # heavy head
    counts = np.bincount([r.object_id for r in a], minlength=50)
    assert counts.max() > 3 * np.median(counts[counts > 0])


def test_netsim_rejects_zero_background_share():
    with pytest.raises(ValueError):
        NetSimulator(ClusterProfile.network_critical(), background_share=0.0)
    with pytest.raises(ValueError):
        NetSimulator(ClusterProfile.network_critical(), background_share=1.5)


def test_netsim_priority_classes_share_ports_and_account_separately():
    # fifo mode: the PR-1 hold-until-done model with rate-throttled
    # background; quantum (preemptive) sharing is covered in
    # tests/test_netmodel.py
    sim = NetSimulator(
        ClusterProfile.network_critical(), background_share=0.5, mode="fifo"
    )
    end_fg = sim.transfer(Transfer(0, 1, 12_000_000))  # 1s at 12 MB/s
    assert end_fg == pytest.approx(1.0)
    # background transfer on the same ports: waits, then runs at half rate
    end_bg = sim.transfer(Transfer(0, 1, 12_000_000, priority=BACKGROUND))
    assert end_bg == pytest.approx(3.0)
    assert sim.class_bytes == {0: 12_000_000, 1: 12_000_000}
    assert sim.class_makespan[0] == pytest.approx(1.0)
    assert sim.class_makespan[1] == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# end-to-end
# ---------------------------------------------------------------------------

def _gateway(code, num_nodes=60, q=2048, num_objects=12, **cfg_kw):
    gw = ObjectGateway(
        code, ClusterProfile.network_critical(), num_nodes, GatewayConfig(**cfg_kw)
    )
    rng = np.random.default_rng(9)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))
    return gw


def test_gateway_end_to_end_with_failures_verifies_and_coalesces():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, batch_window=0.05)
    # degrade three DISTINCT objects (rows of two groups), then storm
    # them with interleaved concurrent GETs
    victims = {gw.store.node_of(("g0", 0, 0)),
               gw.store.node_of(("g0", 1, 3)),
               gw.store.node_of(("g1", 0, 5))}
    failures = [FailureEvent(time=0.001, node=n) for n in victims]
    degraded_objects = (0, 1, 3)  # g0 row 0, g0 row 1, g1 row 0
    reqs = [
        Request(time=0.01 + 0.001 * i, object_id=degraded_objects[i % 3])
        for i in range(30)
    ]
    report = gw.serve(reqs, failures)  # verify=True checks every GET
    assert len(report.completed) == 30
    deg = report.degraded_gets
    assert len(deg) == 30
    st = gw.coalescer.stats
    # window dedup + shape batching: far fewer launches than degraded GETs
    assert st.decode_calls < len(deg)
    assert st.decode_ops <= len(deg)  # dedup collapses same-object decodes
    assert st.max_batch > 1  # distinct objects share one V launch
    # Table 1 traffic: a vertical plan with j missing blocks reads
    # (k - j) direct + j*t sources; a horizontal fallback (victim also
    # broke a column) reads exactly k distinct blocks
    q = 2048
    for r in deg:
        rb = r.reconstruction_blocks
        j = rb // code.t
        vertical = rb == j * code.t and r.bytes_read == (code.k - j + rb) * q
        horizontal = rb == code.k and r.bytes_read == code.k * q
        assert vertical or horizontal, (rb, r.bytes_read)
    if st.ops_by_kind.get("V"):
        assert st.sources_per_op("V") == pytest.approx(code.t)


def test_gateway_cache_absorbs_repeat_degraded_reads():
    code = CoreCode(9, 6, 3)
    q = 2048
    gw = _gateway(code, q=q, cache_bytes=4 * 1024 * 1024, batch_window=0.05)
    reqs = generate_requests(
        WorkloadConfig(num_objects=12, num_requests=300, arrival_rate=2000.0, seed=8)
    )
    failures = plan_failures(2, 60, at_time=0.01, spacing=0.01, seed=8)
    report = gw.serve(reqs, failures)
    assert len(report.completed) == 300
    # with an ample cache each object decodes at most once; the rest hit
    assert gw.cache.stats.hits > 0
    assert gw.coalescer.stats.decode_ops <= 12
    deg_fabric = [r for r in report.degraded_gets if r.bytes_read > 0]
    assert len(deg_fabric) <= gw.coalescer.stats.decode_ops + 12


def test_gateway_puts_update_objects_and_keep_parity_consistent():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, batch_window=0.05)
    # interleave puts and gets; then fail a node and read degraded — the
    # vertical XOR only works if PUT kept the parity row consistent
    reqs = [Request(time=0.001 * i, object_id=i % 6, kind="put") for i in range(6)]
    reqs += [Request(time=0.1 + 0.001 * i, object_id=i % 12, kind="get") for i in range(24)]
    report = gw.serve(reqs, [])
    assert all(r.latency is not None for r in report.records)
    victim = gw.store.node_of(("g0", 0, 1))
    reqs2 = [Request(time=10.0 + 0.001 * i, object_id=i % 3, kind="get") for i in range(9)]
    report2 = gw.serve(reqs2, [FailureEvent(time=9.0, node=victim)])
    assert len(report2.completed) == 9  # verify=True validated contents
    assert any(r.degraded for r in report2.records)


def test_gateway_background_repair_restores_health():
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code,
        batch_window=0.02,
        repair_on_failure=True,
        repair_delay=0.05,
        background_share=0.5,
    )
    reqs = generate_requests(
        WorkloadConfig(num_objects=12, num_requests=200, arrival_rate=500.0, seed=5)
    )
    # fail a node that provably holds a data block of a real object
    victim = gw.store.node_of(("g0", 0, 0))
    report = gw.serve(reqs, [FailureEvent(time=0.02, node=victim)])
    assert report.repair_reports, "repair must have run"
    assert all(r.recovered for r in report.repair_reports)
    # shared-fabric repair, accounted under the "repair" tenant
    assert gw.sim.class_bytes.get(REPAIR_TENANT, 0) > 0
    # after repair, the failure set no longer degrades the store
    for gid in gw._groups:
        fm = gw.store.failure_matrix(gid, code.rows, code.n)
        assert not fm.any()


def test_gateway_window_dedups_same_object_decodes():
    """N concurrent GETs for the same degraded object in one window must
    execute ONE reconstruction, fanned out to all of them."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, batch_window=1.0)
    victim = gw.store.node_of(("g0", 0, 0))
    gw.store.fail_nodes([victim])
    reqs = [Request(time=0.001 * i, object_id=0, kind="get") for i in range(10)]
    report = gw.serve(reqs, [])
    assert len(report.completed) == 10
    assert all(r.degraded for r in report.records)
    st = gw.coalescer.stats
    assert st.decode_ops == 1 and st.decode_calls == 1


def test_gateway_repair_visible_only_after_transfers_complete():
    """Blocks written back by repair must not serve reads dated before
    the repair's fabric transfers finish."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, q=1 << 18, batch_window=0.0001, repair_on_failure=True,
                  repair_delay=0.01, background_share=0.5)
    victim = gw.store.node_of(("g0", 0, 0))
    # repair fires at t=0.03; moving t x 256 KiB at the throttled 6 MB/s
    # takes ~0.13s, so a GET right after detection is still degraded
    reqs = [Request(time=0.032, object_id=0, kind="get"),
            Request(time=100.0, object_id=0, kind="get")]
    report = gw.serve(reqs, [FailureEvent(time=0.02, node=victim)])
    early, late = report.records
    assert early.degraded  # write-back still in flight at t=0.032
    assert not late.degraded  # long after completion: healed
    assert len(report.completed) == 2


@pytest.mark.parametrize("num_failures", [0, 1, 2])
def test_pipelined_and_serial_paths_serve_identical_bytes(num_failures):
    """Property: the pipelined dataplane changes WHEN things happen in
    simulated time, never WHAT is served. Over a seeded Zipf workload
    with 0/1/2 node failures, pipelined and serial runs must produce
    byte-identical GET payloads (sha256) and identical verification /
    degradation outcomes per request."""
    code = CoreCode(9, 6, 3)
    q = 1024
    wl = WorkloadConfig(
        num_objects=12, num_requests=150, arrival_rate=3000.0, seed=num_failures
    )
    reports = {}
    for pipeline in ("pipelined", "serial"):
        gw = _gateway(
            code,
            q=q,
            batch_window=0.01,
            pipeline=pipeline,
            record_payloads=True,  # verify=True is the config default
        )
        # fail nodes that provably hold data blocks of live objects
        # (placement is process-stable, so both runs fail the same nodes)
        victims = [gw.store.node_of(("g0", 0, 0)), gw.store.node_of(("g1", 0, 2))]
        failures = [
            FailureEvent(time=0.01 + 0.015 * i, node=victims[i])
            for i in range(num_failures)
        ]
        reports[pipeline] = gw.serve(generate_requests(wl), failures)
    pipe, ser = reports["pipelined"].records, reports["serial"].records
    assert len(pipe) == len(ser) == 150
    for a, b in zip(pipe, ser):
        assert (a.time, a.object_id, a.kind) == (b.time, b.object_id, b.kind)
        assert a.degraded == b.degraded
        assert (a.latency is None) == (b.latency is None)
        assert a.payload_digest == b.payload_digest  # byte-identical GET
        if a.latency is not None:
            assert a.payload_digest is not None
    if num_failures:
        assert any(r.degraded for r in pipe)


def test_pipelined_cache_hit_waits_for_decode_completion():
    """Causality: a reconstruction is cached at host flush time, but a
    later request hitting it in cache may not be served before the
    decode's simulated completion."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, q=1 << 16, cache_bytes=32 * 1024 * 1024, batch_window=0.0001
    )
    gw.store.fail_nodes([gw.store.node_of(("g0", 0, 0))])
    r1 = Request(time=0.001, object_id=0)  # decodes, caches the block
    r2 = Request(time=0.0015, object_id=0)  # next window: cache hit
    report = gw.serve([r1, r2])
    key = ("g0", 0, 0)
    ready = gw._cache_ready[key]  # simulated decode completion
    rec1, rec2 = report.records
    assert rec1.degraded and not rec2.degraded  # r2 planned off the cache
    assert rec2.cache_hits >= 1
    # fetching t=3 64 KiB source blocks takes ~5.5 ms simulated, so the
    # decode finishes well after r2's arrival — r2 must wait for it
    assert ready > r2.time
    assert rec2.latency >= ready - r2.time - 1e-9


def test_jit_cache_entries_bounded_over_500_requests():
    """The coalescer's pad ladder caps distinct traced signatures: over a
    500-request degraded run with organically varying batch sizes, the
    jit-cache-entry counter stays within the ladder."""
    from repro.gateway.coalescer import PAD_LADDER

    code = CoreCode(9, 6, 3)
    gw = _gateway(code, q=512, batch_window=0.01)
    victim = gw.store.node_of(("g0", 0, 0))
    reqs = generate_requests(
        WorkloadConfig(num_objects=12, num_requests=500, arrival_rate=4000.0, seed=13)
    )
    report = gw.serve(reqs, [FailureEvent(time=0.005, node=victim)])
    assert len(report.completed) == 500
    st = gw.coalescer.stats
    assert st.decode_calls > len(PAD_LADDER)  # plenty of traffic...
    assert 0 < report.jit_cache_entries <= len(PAD_LADDER)  # ...few traces


# ---------------------------------------------------------------------------
# multi-tenant QoS: tenant workloads, SLO admission, multi-engine decode
# ---------------------------------------------------------------------------

def test_tenant_requests_merged_sorted_and_tagged():
    profs = [
        TenantProfile("gold", arrival_rate=500.0, weight=1.0, slo_p99=0.1),
        TenantProfile("bronze", arrival_rate=250.0, weight=0.25),
    ]
    reqs = generate_tenant_requests(profs, num_objects=12,
                                    num_requests_per_tenant=100, seed=4)
    assert len(reqs) == 200
    assert all(a.time <= b.time for a, b in zip(reqs, reqs[1:]))
    by_tenant = {t: [r for r in reqs if r.tenant == t] for t in ("gold", "bronze")}
    assert len(by_tenant["gold"]) == len(by_tenant["bronze"]) == 100
    # reproducible
    again = generate_tenant_requests(profs, 12, 100, seed=4)
    assert reqs == again
    assert tenant_weight_map(profs) == {"gold": 1.0, "bronze": 0.25}
    assert tenant_slo_map(profs) == {"gold": 0.1}  # best-effort has no SLO


def test_planner_candidates_table1_cheapest_first():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    make_group(code, store, q=512)
    planner = DegradedReadPlanner(store, code)
    # healthy: single all-direct candidate
    (only,) = planner.candidates("g0", 0)
    assert not only.degraded
    # one missing data block, column intact: vertical (t=3) beats
    # horizontal (k=6); both viable
    store.fail_nodes([store.node_of(("g0", 0, 0))])
    cands = planner.candidates("g0", 0)
    assert len(cands) == 2
    assert cands[0].decodes[0].kind == "V"
    assert cands[1].decodes[0].kind == "H"
    assert cands[0].reconstruction_blocks <= cands[1].reconstruction_blocks
    assert planner.plan("g0", 0) == cands[0]


def test_gateway_admission_reject_cuts_slo_violations():
    """Decode-bound degraded load vs a tight SLO: with admission off most
    GETs bust the target; with admission="reject" the controller sheds
    load and the admitted survivors' violation rate drops; "degrade"
    first re-ranks the planner's candidates by estimated time and only
    rejects when even the cheapest plan busts the target."""
    code = CoreCode(9, 6, 3)
    slo = 0.05
    rates = {}
    for admission in ("off", "reject", "degrade"):
        # modeled decode billing: the backlog this test needs must not
        # depend on how fast the host happens to run the real kernels —
        # payload bytes still come from the real decode path (verify)
        cfg_kw = dict(
            batch_window=0.003,
            admission=admission,
            decode_cost=0.01,
            tenant_slo_p99={"foreground": slo},
        )
        gw = ObjectGateway(
            code,
            ClusterProfile.computation_critical(),
            60,
            GatewayConfig(**cfg_kw),
        )
        rng = np.random.default_rng(9)
        gw.load_objects(
            rng.integers(0, 256, (12, code.k, 1 << 16), dtype=np.uint8)
        )
        reqs = generate_requests(
            WorkloadConfig(
                num_objects=12, num_requests=250, arrival_rate=2000.0, seed=6
            )
        )
        failures = plan_failures(6, 60, at_time=0.005, spacing=0.0, seed=6)
        rep = gw.serve(reqs, failures)
        rates[admission] = rep.slo_violation_rate("foreground", slo)
        if admission == "off":
            assert rep.rejections == {}
            assert len(rep.completed) == 250
            assert rates["off"] > 0.2  # the backlog really bites
        else:
            rejected = rep.rejections.get("foreground", 0)
            assert rejected > 0
            assert len(rep.completed) == 250 - rejected
            recs = rep.rejected
            assert len(recs) == rejected
            assert all(r.latency is None and r.rejected for r in recs)
            # every admitted GET is still verified against ground truth
            # (degrade mode may swap plans, never payloads)
            assert any(r.degraded for r in rep.completed)
    assert rates["reject"] < rates["off"]
    assert rates["degrade"] < rates["off"]


def test_gateway_multi_engine_serves_identical_bytes():
    """num_engines changes WHEN decodes run, never WHAT is served: the
    4-engine run is byte-identical to the 1-engine run per request, with
    identical degradation outcomes. (The engine pool's throughput win is
    gated in benchmarks/gateway_load.py — latencies are built on
    per-run measured kernel times, so cross-run latency comparisons
    would be asserting on wall-clock noise.)"""
    code = CoreCode(9, 6, 3)
    reports = {}
    for ne in (1, 4):
        gw = ObjectGateway(
            code,
            ClusterProfile.computation_critical(),
            60,
            GatewayConfig(
                batch_window=0.005, num_engines=ne, record_payloads=True
            ),
        )
        rng = np.random.default_rng(9)
        gw.load_objects(rng.integers(0, 256, (12, code.k, 2048), dtype=np.uint8))
        reqs = generate_requests(
            WorkloadConfig(
                num_objects=12, num_requests=200, arrival_rate=3000.0, seed=8
            )
        )
        failures = plan_failures(4, 60, at_time=0.005, spacing=0.0, seed=8)
        reports[ne] = gw.serve(reqs, failures)
    one, four = reports[1].records, reports[4].records
    assert len(one) == len(four) == 200
    for a, b in zip(one, four):
        assert (a.time, a.object_id, a.kind, a.degraded) == (
            b.time, b.object_id, b.kind, b.degraded,
        )
        assert a.payload_digest == b.payload_digest
    assert any(r.degraded for r in one)


def test_gateway_config_validation():
    code = CoreCode(9, 6, 3)
    profile = ClusterProfile.network_critical()
    with pytest.raises(ValueError):
        ObjectGateway(code, profile, 60, GatewayConfig(admission="maybe"))
    with pytest.raises(ValueError):
        ObjectGateway(code, profile, 60, GatewayConfig(num_engines=0))
    with pytest.raises(ValueError):
        # the serial baseline models a single-engine synchronous loop
        ObjectGateway(
            code, profile, 60, GatewayConfig(pipeline="serial", num_engines=4)
        )


def test_gateway_unrecoverable_object_reported_not_crashing():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, batch_window=0.01)
    for r in range(code.rows):
        gw.store.drop_block(("g0", r, 0))
    for c in (1, 2, 3):
        gw.store.drop_block(("g0", 0, c))
    reqs = [Request(time=0.0, object_id=0, kind="get"),
            Request(time=0.0005, object_id=3, kind="get")]
    report = gw.serve(reqs, [])
    rec0 = next(r for r in report.records if r.object_id == 0)
    rec3 = next(r for r in report.records if r.object_id == 3)
    assert rec0.latency is None  # unreadable, reported
    assert rec3.latency is not None  # other group unaffected
