"""Measured kernel autotuning (kernels/autotune.py): the sweep must pick
a real candidate, cache it per backend, and every candidate configuration
it can pick must be numerically correct (the packed u32 variant and every
block_n rung are swept on the interpret path too, so this runs on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref


def test_tuned_gf256_picks_candidate_and_caches():
    tuned = autotune.tuned_gf256(True)
    assert tuned.block_n in autotune.GF_BLOCK_CANDIDATES
    assert isinstance(tuned.packed, bool)
    assert tuned.elapsed > 0
    assert autotune.tuned_gf256(True) is tuned  # process-lifetime cache
    assert "gf256/interpret" in autotune.report()


def test_tuned_xor_picks_candidate_and_caches():
    tuned = autotune.tuned_xor(True)
    assert tuned.block_n in autotune.XOR_BLOCK_CANDIDATES
    assert tuned.packed is False
    assert autotune.tuned_xor(True) is tuned
    assert "xor/interpret" in autotune.report()


def test_block_n_capped_to_payload_size():
    """Ladder padding must never multiply kernel work: the tuned tile is
    capped to the next power of two of the actual byte length."""
    t = autotune.TunedKernel(block_n=32768, packed=False, elapsed=0.0)
    assert t.block_n_for(1000) == 1024
    assert t.block_n_for(128) == 128
    assert t.block_n_for(50) == 128  # kernel minimum tile
    assert t.block_n_for(1 << 20) == 32768  # never above the tuned value


@pytest.mark.parametrize("block_n", autotune.GF_BLOCK_CANDIDATES)
@pytest.mark.parametrize("packed", [False, True])
def test_every_gf256_candidate_config_is_correct(block_n, packed):
    """Whatever the sweep picks must match the reference bit-for-bit."""
    rng = np.random.default_rng(block_n + packed)
    b, m, k, n = 3, 2, 6, 4096
    coefs = rng.integers(0, 256, size=(b, m, k), dtype=np.uint8)
    data = rng.integers(0, 256, size=(b, k, n), dtype=np.uint8)
    got = np.asarray(
        ops.gf256_matmul_batched(
            coefs, jnp.asarray(data), block_n=min(block_n, n),
            interpret=True, packed=packed,
        )
    )
    for i in range(b):
        want = np.asarray(ref.gf256_matmul(jnp.asarray(coefs[i]), jnp.asarray(data[i])))
        np.testing.assert_array_equal(got[i], want)
