"""Measured kernel autotuning (kernels/autotune.py): the sweep must pick
a real candidate, cache it per backend — in process AND on disk, so the
winners survive across processes — and every candidate configuration it
can pick must be numerically correct (the packed u32 variant and every
block_n rung are swept on the interpret path too, so this runs on CPU)."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref


def test_tuned_gf256_picks_candidate_and_caches():
    tuned = autotune.tuned_gf256(True)
    assert tuned.block_n in autotune.GF_BLOCK_CANDIDATES
    assert isinstance(tuned.packed, bool)
    assert tuned.elapsed > 0
    assert autotune.tuned_gf256(True) is tuned  # process-lifetime cache
    assert "gf256/interpret" in autotune.report()


def test_tuned_xor_picks_candidate_and_caches():
    tuned = autotune.tuned_xor(True)
    assert tuned.block_n in autotune.XOR_BLOCK_CANDIDATES
    assert tuned.packed is False
    assert autotune.tuned_xor(True) is tuned
    assert "xor/interpret" in autotune.report()


def test_block_n_capped_to_payload_size():
    """Ladder padding must never multiply kernel work: the tuned tile is
    capped to the next power of two of the actual byte length."""
    t = autotune.TunedKernel(block_n=32768, packed=False, elapsed=0.0)
    assert t.block_n_for(1000) == 1024
    assert t.block_n_for(128) == 128
    assert t.block_n_for(50) == 128  # kernel minimum tile
    assert t.block_n_for(1 << 20) == 32768  # never above the tuned value


def test_tuned_ragged_kernels_pick_candidates():
    gf = autotune.tuned_ragged_gf256(True)
    assert gf.block_n in autotune.RAGGED_GF_TILE_CANDIDATES
    assert isinstance(gf.packed, bool)
    xor = autotune.tuned_ragged_xor(True)
    assert xor.block_n in autotune.RAGGED_XOR_TILE_CANDIDATES
    assert xor.packed is False
    assert "ragged_gf256/interpret" in autotune.report()
    assert "ragged_xor/interpret" in autotune.report()


# ---------------------------------------------------------------------------
# cross-process persistence (the disk cache)
# ---------------------------------------------------------------------------

@pytest.fixture
def disk_cache(tmp_path, monkeypatch):
    """Isolated disk cache + empty in-process cache for each test."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    saved = dict(autotune._CACHE)
    autotune._CACHE.clear()
    yield path
    autotune._CACHE.clear()
    autotune._CACHE.update(saved)


def test_sweep_persists_winner_to_disk(disk_cache):
    tuned = autotune.tuned_xor(True)
    assert disk_cache.exists()
    doc = json.loads(disk_cache.read_text())
    entry = doc["entries"][autotune._disk_key("xor", True)]
    assert entry["block_n"] == tuned.block_n
    assert entry["packed"] == tuned.packed


def test_persisted_winner_loads_without_sweeping(disk_cache, monkeypatch):
    """A fresh process (cleared in-memory cache) must take the disk
    winner instead of re-running the measurement sweep."""
    autotune.tuned_xor(True)
    autotune._CACHE.clear()  # simulate a new process

    def boom(*a, **kw):  # the sweep must NOT run
        raise AssertionError("sweep ran despite a persisted winner")

    monkeypatch.setattr(autotune, "_best", boom)
    tuned = autotune.tuned_xor(True)
    assert tuned.block_n in autotune.XOR_BLOCK_CANDIDATES


def test_stale_disk_entry_is_ignored(disk_cache):
    """An entry whose block_n is no longer a candidate (retired config)
    must not be loaded — the sweep re-runs instead."""
    disk_cache.write_text(json.dumps({
        "schema": 1,
        "entries": {
            autotune._disk_key("xor", True): {
                "block_n": 12345, "packed": False, "elapsed": 0.001,
            }
        },
    }))
    tuned = autotune.tuned_xor(True)
    assert tuned.block_n in autotune.XOR_BLOCK_CANDIDATES


def test_clear_cache_clears_disk_too(disk_cache):
    autotune.tuned_xor(True)
    assert disk_cache.exists()
    autotune.clear_cache()
    assert not disk_cache.exists()
    assert autotune.report() == {}


def test_cache_disabled_via_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "off")
    assert autotune.cache_path() is None
    saved = dict(autotune._CACHE)
    autotune._CACHE.clear()
    try:
        autotune.tuned_xor(True)  # must not raise without a disk path
    finally:
        autotune._CACHE.clear()
        autotune._CACHE.update(saved)
    assert not (tmp_path / "autotune.json").exists()


def test_corrupt_disk_cache_is_nonfatal(disk_cache):
    disk_cache.write_text("{not json")
    tuned = autotune.tuned_xor(True)  # falls back to the sweep
    assert tuned.block_n in autotune.XOR_BLOCK_CANDIDATES


@pytest.mark.parametrize("block_n", autotune.GF_BLOCK_CANDIDATES)
@pytest.mark.parametrize("packed", [False, True])
def test_every_gf256_candidate_config_is_correct(block_n, packed):
    """Whatever the sweep picks must match the reference bit-for-bit."""
    rng = np.random.default_rng(block_n + packed)
    b, m, k, n = 3, 2, 6, 4096
    coefs = rng.integers(0, 256, size=(b, m, k), dtype=np.uint8)
    data = rng.integers(0, 256, size=(b, k, n), dtype=np.uint8)
    got = np.asarray(
        ops.gf256_matmul_batched(
            coefs, jnp.asarray(data), block_n=min(block_n, n),
            interpret=True, packed=packed,
        )
    )
    for i in range(b):
        want = np.asarray(ref.gf256_matmul(jnp.asarray(coefs[i]), jnp.asarray(data[i])))
        np.testing.assert_array_equal(got[i], want)
