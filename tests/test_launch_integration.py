"""Subprocess integration tests: the launchers on small multi-device
meshes (fake CPU devices). These exercise the REAL pjit path — sharded
train steps and an actual dry-run lower+compile — end-to-end, in
isolated processes so the main test session keeps its 1-device view."""

from __future__ import annotations

import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=900):
    return subprocess.run(
        [sys.executable, *args], env=ENV, cwd=REPO, timeout=timeout,
        capture_output=True, text=True,
    )


def test_sharded_training_on_2x2_mesh():
    r = _run([
        "-m", "repro.launch.train", "--arch", "qwen2_72b", "--reduced",
        "--steps", "3", "--devices", "4", "--mesh", "2x2",
        "--seq-len", "32", "--global-batch", "4", "--ckpt-every", "2",
    ])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "done at step 3" in r.stdout, r.stdout


def test_dryrun_cell_on_debug_mesh():
    r = _run([
        "-m", "repro.launch.dryrun", "--arch", "falcon_mamba_7b",
        "--shape", "decode_32k", "--mesh", "2x2", "--devices", "4",
    ])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "bound=" in r.stdout and "CompiledMemoryStats" in r.stdout


def test_serve_loop_reduced():
    r = _run([
        "-m", "repro.launch.serve", "--arch", "olmoe_1b_7b", "--reduced",
        "--requests", "3", "--batch", "2", "--prompt-len", "8",
        "--max-new", "4", "--cache-len", "32",
    ])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "served 3 requests" in r.stdout, r.stdout
