"""Quantized preemptive fabric sharing (NetSimulator mode="quantum").

The PR-1 fifo fabric lets a long background repair transfer hold a port
until done, head-of-line-blocking any foreground read that arrives
mid-way — the repair-vs-read contention production studies flag as the
dominant cost of erasure-coded serving. Quantum mode schedules transfers
in fixed full-rate quanta with weighted-fair spacing, so foreground
traffic preempts into the holes a throttled background class leaves.
"""

import pytest

from repro.storage.netmodel import (
    BACKGROUND,
    FOREGROUND,
    ClusterProfile,
    NetSimulator,
    Transfer,
    _PortTimeline,
)

PROFILE = ClusterProfile.network_critical()  # 12 MB/s links
MB = 1_000_000


def test_foreground_read_bounded_under_long_background_transfer():
    """A foreground read issued mid-way through a long background
    transfer completes in roughly its own transmission time, not after
    the whole background transfer."""
    long_bg = 24 * MB  # 2 s alone at full rate, 4 s at share 0.5
    fg = 512 * 1024  # ~43 ms at full rate

    fifo = NetSimulator(PROFILE, background_share=0.5, mode="fifo")
    fifo.transfer(Transfer(0, 1, long_bg, priority=BACKGROUND))
    fifo_fg_end = fifo.transfer(Transfer(0, 1, fg, not_before=1.0))

    quant = NetSimulator(PROFILE, background_share=0.5, mode="quantum")
    bg_end = quant.transfer(Transfer(0, 1, long_bg, priority=BACKGROUND))
    quant_fg_end = quant.transfer(Transfer(0, 1, fg, not_before=1.0))

    # fifo: the read waits out the entire 4 s background transfer
    assert fifo_fg_end > 4.0
    # quantum: the read lands in the background class's holes — bounded
    # by its own duration over the foreground share (1 - 0.5), plus one
    # quantum of slack for the in-flight granule
    fg_alone = fg / PROFILE.node_bandwidth
    slack = quant.quantum_bytes / PROFILE.node_bandwidth
    assert quant_fg_end - 1.0 <= fg_alone / 0.5 + 2 * slack
    # waiting time shrinks by an order of magnitude vs head-of-line fifo
    assert (quant_fg_end - 1.0) < (fifo_fg_end - 1.0) / 10
    # the background transfer still respects its share when alone
    assert bg_end == pytest.approx(long_bg / (0.5 * PROFILE.node_bandwidth), rel=0.02)


def test_quantum_bytes_conserved_vs_fifo():
    """Same transfer schedule, both modes: byte accounting identical."""
    schedule = [
        Transfer(0, 1, 3 * MB, priority=BACKGROUND),
        Transfer(0, 2, 1 * MB, not_before=0.05),
        Transfer(3, 1, 2 * MB, not_before=0.1, priority=BACKGROUND),
        Transfer(0, 1, 512 * 1024, not_before=0.12),
    ]
    sims = {
        mode: NetSimulator(PROFILE, background_share=0.25, mode=mode)
        for mode in ("fifo", "quantum")
    }
    for sim in sims.values():
        for t in schedule:
            sim.transfer(Transfer(t.src_node, t.dst_node, t.nbytes, t.not_before, t.priority))
    assert sims["fifo"].total_bytes == sims["quantum"].total_bytes
    assert sims["fifo"].class_bytes == sims["quantum"].class_bytes
    assert sims["quantum"].class_bytes == {
        FOREGROUND: 1 * MB + 512 * 1024,
        BACKGROUND: 5 * MB,
    }


def test_quantum_stream_of_small_background_transfers_respects_share():
    """Repair issues one transfer per block; the quantum ratio must hold
    across the stream (per-port class cursors), not just within one big
    transfer — otherwise small-block repair dodges the throttle."""
    sim = NetSimulator(PROFILE, background_share=0.5, mode="quantum")
    block = 64 * 1024  # == one quantum
    end = 0.0
    for _ in range(32):
        end = sim.transfer(Transfer(0, 1, block, priority=BACKGROUND))
    # 32 quanta at share 0.5: ~31 full periods + the final transmission
    alone = 32 * block / PROFILE.node_bandwidth
    assert end == pytest.approx(2 * alone, rel=0.05)
    # and a foreground read still fits in the holes left between them
    fg_end = sim.transfer(Transfer(0, 1, block, not_before=0.0))
    assert fg_end < end / 4


def test_quantum_foreground_is_fifo_within_class():
    """share-1.0 classes schedule contiguously and in call order on a
    port, matching the fifo model when uncontended."""
    fifo = NetSimulator(PROFILE, mode="fifo")
    quant = NetSimulator(PROFILE, mode="quantum")
    for sim in (fifo, quant):
        a = sim.transfer(Transfer(0, 1, 6 * MB))
        b = sim.transfer(Transfer(0, 1, 6 * MB))
        assert a == pytest.approx(0.5)
        assert b == pytest.approx(1.0)


def test_quantum_respects_not_before_dependency():
    sim = NetSimulator(PROFILE, mode="quantum")
    end = sim.transfer(Transfer(0, 1, MB, not_before=3.0))
    assert end == pytest.approx(3.0 + MB / PROFILE.node_bandwidth)


def test_mode_and_quantum_validation():
    with pytest.raises(ValueError):
        NetSimulator(PROFILE, mode="wfq")
    with pytest.raises(ValueError):
        NetSimulator(PROFILE, quantum_bytes=0)
    with pytest.raises(ValueError):
        NetSimulator(PROFILE, background_share=0.0)


def test_port_timeline_first_fit_and_merge():
    tl = _PortTimeline()
    tl.occupy(1.0, 2.0)
    tl.occupy(3.0, 4.0)
    assert tl.next_fit(0.0, 1.0) == 0.0  # fits before the first interval
    assert tl.next_fit(0.5, 1.0) == 2.0  # hole [2, 3] found
    assert tl.next_fit(0.5, 2.0) == 4.0  # too big for the hole
    tl.occupy(2.0, 3.0)  # bridges [1,2] and [3,4]
    assert tl.starts == [1.0] and tl.ends == [4.0]
    assert tl.next_fit(0.0, 0.5) == 0.0
    assert tl.next_fit(1.5, 0.5) == 4.0
