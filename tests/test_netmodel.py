"""Quantized preemptive fabric sharing (NetSimulator mode="quantum").

The PR-1 fifo fabric lets a long background repair transfer hold a port
until done, head-of-line-blocking any foreground read that arrives
mid-way — the repair-vs-read contention production studies flag as the
dominant cost of erasure-coded serving. Quantum mode schedules transfers
in fixed full-rate quanta with weighted-fair spacing, so foreground
traffic preempts into the holes a throttled background class leaves.
"""

import pytest

from repro.storage.netmodel import (
    BACKGROUND,
    FOREGROUND,
    FOREGROUND_TENANT,
    REPAIR_TENANT,
    ClusterProfile,
    NetSimulator,
    Transfer,
    _PortTimeline,
)

PROFILE = ClusterProfile.network_critical()  # 12 MB/s links
MB = 1_000_000


def test_foreground_read_bounded_under_long_background_transfer():
    """A foreground read issued mid-way through a long background
    transfer completes in roughly its own transmission time, not after
    the whole background transfer."""
    long_bg = 24 * MB  # 2 s alone at full rate, 4 s at share 0.5
    fg = 512 * 1024  # ~43 ms at full rate

    fifo = NetSimulator(PROFILE, background_share=0.5, mode="fifo")
    fifo.transfer(Transfer(0, 1, long_bg, priority=BACKGROUND))
    fifo_fg_end = fifo.transfer(Transfer(0, 1, fg, not_before=1.0))

    quant = NetSimulator(PROFILE, background_share=0.5, mode="quantum")
    bg_end = quant.transfer(Transfer(0, 1, long_bg, priority=BACKGROUND))
    quant_fg_end = quant.transfer(Transfer(0, 1, fg, not_before=1.0))

    # fifo: the read waits out the entire 4 s background transfer
    assert fifo_fg_end > 4.0
    # quantum: the read lands in the background class's holes — bounded
    # by its own duration over the foreground share (1 - 0.5), plus one
    # quantum of slack for the in-flight granule
    fg_alone = fg / PROFILE.node_bandwidth
    slack = quant.quantum_bytes / PROFILE.node_bandwidth
    assert quant_fg_end - 1.0 <= fg_alone / 0.5 + 2 * slack
    # waiting time shrinks by an order of magnitude vs head-of-line fifo
    assert (quant_fg_end - 1.0) < (fifo_fg_end - 1.0) / 10
    # the background transfer still respects its share when alone
    assert bg_end == pytest.approx(long_bg / (0.5 * PROFILE.node_bandwidth), rel=0.02)


def test_quantum_bytes_conserved_vs_fifo():
    """Same transfer schedule, both modes: byte accounting identical."""
    schedule = [
        Transfer(0, 1, 3 * MB, priority=BACKGROUND),
        Transfer(0, 2, 1 * MB, not_before=0.05),
        Transfer(3, 1, 2 * MB, not_before=0.1, priority=BACKGROUND),
        Transfer(0, 1, 512 * 1024, not_before=0.12),
    ]
    sims = {
        mode: NetSimulator(PROFILE, background_share=0.25, mode=mode)
        for mode in ("fifo", "quantum")
    }
    for sim in sims.values():
        for t in schedule:
            sim.transfer(Transfer(t.src_node, t.dst_node, t.nbytes, t.not_before, t.priority))
    assert sims["fifo"].total_bytes == sims["quantum"].total_bytes
    assert sims["fifo"].class_bytes == sims["quantum"].class_bytes
    assert sims["quantum"].class_bytes == {
        FOREGROUND: 1 * MB + 512 * 1024,
        BACKGROUND: 5 * MB,
    }


def test_quantum_stream_of_small_background_transfers_respects_share():
    """Repair issues one transfer per block; the quantum ratio must hold
    across the stream (per-port class cursors), not just within one big
    transfer — otherwise small-block repair dodges the throttle."""
    sim = NetSimulator(PROFILE, background_share=0.5, mode="quantum")
    block = 64 * 1024  # == one quantum
    end = 0.0
    for _ in range(32):
        end = sim.transfer(Transfer(0, 1, block, priority=BACKGROUND))
    # 32 quanta at share 0.5: ~31 full periods + the final transmission
    alone = 32 * block / PROFILE.node_bandwidth
    assert end == pytest.approx(2 * alone, rel=0.05)
    # and a foreground read still fits in the holes left between them
    fg_end = sim.transfer(Transfer(0, 1, block, not_before=0.0))
    assert fg_end < end / 4


def test_quantum_foreground_is_fifo_within_class():
    """share-1.0 classes schedule contiguously and in call order on a
    port, matching the fifo model when uncontended."""
    fifo = NetSimulator(PROFILE, mode="fifo")
    quant = NetSimulator(PROFILE, mode="quantum")
    for sim in (fifo, quant):
        a = sim.transfer(Transfer(0, 1, 6 * MB))
        b = sim.transfer(Transfer(0, 1, 6 * MB))
        assert a == pytest.approx(0.5)
        assert b == pytest.approx(1.0)


def test_quantum_respects_not_before_dependency():
    sim = NetSimulator(PROFILE, mode="quantum")
    end = sim.transfer(Transfer(0, 1, MB, not_before=3.0))
    assert end == pytest.approx(3.0 + MB / PROFILE.node_bandwidth)


def test_mode_and_quantum_validation():
    with pytest.raises(ValueError):
        NetSimulator(PROFILE, mode="wfq")
    with pytest.raises(ValueError):
        NetSimulator(PROFILE, quantum_bytes=0)
    with pytest.raises(ValueError):
        NetSimulator(PROFILE, background_share=0.0)


# ---------------------------------------------------------------------------
# multi-tenant weighted-fair sharing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "weights",
    [
        {"a": 0.5, "b": 0.25, "c": 0.25},
        {"a": 0.5, "b": 0.3, "c": 0.2},
        {"a": 0.4, "b": 0.4, "c": 0.2},
        {"a": 0.6, "b": 0.2, "c": 0.1},  # undersubscribed link
    ],
)
def test_tenant_weights_deliver_proportional_bytes_when_saturated(weights):
    """The fairness property: N tenants streaming concurrently on one
    saturated port pair each see exactly their weighted-fair rate — a
    weight-w tenant's stream of bytes completes at nbytes/(w * bw),
    within one quantum of slack per transfer. Delivered bytes over any
    saturated window therefore match ``tenant_weights`` to quantum
    granularity. (Weights are guaranteed fractions, so the property
    requires sum(weights) <= 1 — an oversubscribed link cannot honor
    every tenant's self-clocked cap at once.)"""
    sim = NetSimulator(PROFILE, mode="quantum", tenant_weights=weights)
    quanta = 48
    nbytes = quanta * sim.quantum_bytes
    slack = sim.quantum_bytes / PROFILE.node_bandwidth
    ends = {t: sim.transfer(Transfer(0, 1, nbytes, tenant=t)) for t in weights}
    for t, w in weights.items():
        expected = nbytes / (w * PROFILE.node_bandwidth)
        # early side: the final quantum needs no trailing (1-w) gap, so a
        # weight-w stream may finish up to (1/w - 1) quanta early; late
        # side: a competing tenant may hold the final hole for a couple
        # of quanta. Never later than that is the fairness guarantee.
        assert ends[t] >= expected - slack / w - 1e-9, (t, w)
        assert ends[t] <= expected + 2 * slack + 1e-9, (t, w)
    # byte conservation across tenants
    assert sim.total_bytes == len(weights) * nbytes
    assert sim.class_bytes == {t: nbytes for t in weights}
    # delivered *rate* orders with the weights
    ordered = sorted(weights, key=weights.get, reverse=True)
    rates = {t: nbytes / ends[t] for t in weights}
    for hi, lo in zip(ordered, ordered[1:]):
        assert rates[hi] >= rates[lo] - 1e-9


def test_background_share_shim_reproduces_two_class_schedule():
    """background_share is now just the seed weight of the "repair"
    tenant: an explicit tenant_weights map with the same ratio must
    reproduce the PR-2 two-class schedule transfer for transfer."""
    schedule = [
        (24 * MB, 0.0, "bg"),
        (512 * 1024, 1.0, "fg"),
        (3 * MB, 1.2, "bg"),
        (2 * MB, 1.3, "fg"),
    ]
    legacy = NetSimulator(PROFILE, background_share=0.5, mode="quantum")
    named = NetSimulator(
        PROFILE,
        mode="quantum",
        tenant_weights={FOREGROUND_TENANT: 1.0, REPAIR_TENANT: 0.5},
    )
    for nbytes, t0, cls in schedule:
        leg_end = legacy.transfer(
            Transfer(
                0, 1, nbytes, not_before=t0,
                priority=BACKGROUND if cls == "bg" else FOREGROUND,
            )
        )
        named_end = named.transfer(
            Transfer(
                0, 1, nbytes, not_before=t0,
                tenant=REPAIR_TENANT if cls == "bg" else FOREGROUND_TENANT,
            )
        )
        assert named_end == pytest.approx(leg_end, abs=1e-12)
    assert legacy.total_bytes == named.total_bytes
    # legacy accounting keys are the int classes, named keys the tenants
    assert legacy.class_bytes[BACKGROUND] == named.class_bytes[REPAIR_TENANT]
    assert legacy.class_bytes[FOREGROUND] == named.class_bytes[FOREGROUND_TENANT]


def test_unknown_tenant_defaults_to_full_weight():
    sim = NetSimulator(PROFILE, mode="quantum", tenant_weights={"slow": 0.25})
    end = sim.transfer(Transfer(0, 1, MB, tenant="never-registered"))
    assert end == pytest.approx(MB / PROFILE.node_bandwidth)
    assert sim.weight_of("never-registered") == 1.0
    assert sim.weight_of("slow") == 0.25


def test_unregistered_int_priority_keeps_legacy_throttle():
    """Pre-tenant callers could use any non-FOREGROUND int class id and
    get background_share; that contract survives the tenant refactor."""
    for mode in ("fifo", "quantum"):
        sim = NetSimulator(PROFILE, background_share=0.5, mode=mode)
        assert sim.weight_of(2) == 0.5  # custom legacy class id
        assert sim.weight_of(FOREGROUND) == 1.0
        end = sim.transfer(Transfer(0, 1, MB, priority=2))
        assert end == pytest.approx(MB / (0.5 * PROFILE.node_bandwidth), rel=0.02)


def test_invalid_tenant_weight_rejected():
    with pytest.raises(ValueError):
        NetSimulator(PROFILE, tenant_weights={"a": 0.0})
    with pytest.raises(ValueError):
        NetSimulator(PROFILE, tenant_weights={"a": 1.5})


def test_starvation_accounting_tracks_queueing_delay():
    """tenant_wait_max records how long a transfer queued before its
    first byte — zero for an uncontended tenant, the blocking time for
    one that waited behind another's reservation."""
    sim = NetSimulator(PROFILE, mode="quantum")
    sim.transfer(Transfer(0, 1, 12 * MB, tenant="a"))  # 1 s, holds port
    end_b = sim.transfer(Transfer(0, 1, MB, tenant="b"))
    assert sim.tenant_wait_max["a"] == pytest.approx(0.0)
    # b queued the full second behind a's contiguous reservation
    assert sim.tenant_wait_max["b"] == pytest.approx(1.0)
    assert sim.tenant_transfers == {"a": 1, "b": 1}
    assert end_b == pytest.approx(1.0 + MB / PROFILE.node_bandwidth)


def test_deadline_accounting_counts_misses_per_tenant():
    sim = NetSimulator(PROFILE, mode="quantum")
    dur = MB / PROFILE.node_bandwidth
    sim.transfer(Transfer(0, 1, MB, tenant="t", deadline=dur * 2))  # met
    sim.transfer(Transfer(0, 1, MB, tenant="t", deadline=dur / 2))  # missed
    sim.transfer(Transfer(0, 1, MB, tenant="t"))  # no deadline: uncounted
    assert sim.tenant_deadline_met == {"t": 1}
    assert sim.tenant_deadline_missed == {"t": 1}
    assert sim.deadline_miss_rate("t") == pytest.approx(0.5)
    assert sim.deadline_miss_rate("other") == 0.0


def test_port_timeline_first_fit_and_merge():
    tl = _PortTimeline()
    tl.occupy(1.0, 2.0)
    tl.occupy(3.0, 4.0)
    assert tl.next_fit(0.0, 1.0) == 0.0  # fits before the first interval
    assert tl.next_fit(0.5, 1.0) == 2.0  # hole [2, 3] found
    assert tl.next_fit(0.5, 2.0) == 4.0  # too big for the hole
    tl.occupy(2.0, 3.0)  # bridges [1,2] and [3,4]
    assert tl.starts == [1.0] and tl.ends == [4.0]
    assert tl.next_fit(0.0, 0.5) == 0.0
    assert tl.next_fit(1.5, 0.5) == 4.0
