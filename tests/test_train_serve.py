"""Integration tests: training loop + CORE checkpoint/restart under node
failure, elastic runtime units, data pipeline determinism, serving slot
manager."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.data.pipeline import SyntheticPipeline
from repro.train import optimizer as opt
from repro.train.elastic import ElasticPlan, HostMonitor, shrink_mesh_shape
from repro.train.loop import LoopConfig, Trainer


@pytest.fixture(scope="module")
def tiny_trainer():
    cfg = get_config("qwen2_72b").reduced(num_layers=2)
    lc = LoopConfig(steps=6, ckpt_every=3, log_every=100, seq_len=32,
                    global_batch=2, num_nodes=20)
    oc = opt.OptConfig(lr=1e-3, warmup_steps=2, decay_steps=10)
    return Trainer(cfg, lc, oc)


def test_train_ckpt_kill_restore_resume(tiny_trainer):
    tr = tiny_trainer
    state = tr.run()
    assert int(np.asarray(state.step)) == 6
    losses = [m["loss"] for m in tr.metrics_log]
    assert all(np.isfinite(l) for l in losses)

    # kill two storage nodes -> degraded restore must still be bit-exact
    tr.store.fail_nodes([0, 1])
    restored = tr.restore_latest()
    assert restored is not None
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert tr.last_restore_report.blocks_fetched > 0

    # background repair regenerates the dead nodes' blocks
    tr.store.heal_node(0)
    tr.store.heal_node(1)
    rep = tr.ckpt.repair(6)
    assert rep.recovered

    # resume training from the restored state
    state2 = tr.run(state=restored, until=8)
    assert int(np.asarray(state2.step)) == 8


def test_quantized_v_optimizer_converges():
    cfg = get_config("qwen2_72b").reduced(num_layers=2)
    lc = LoopConfig(steps=5, ckpt_every=100, log_every=100, seq_len=32,
                    global_batch=2)
    tr = Trainer(cfg, lc, opt.OptConfig(lr=1e-3, quantize_v=True,
                                        warmup_steps=1, decay_steps=10))
    state = tr.run()
    assert np.isfinite(tr.metrics_log[-1]["loss"])
    # quantized leaves are (int8 q, f32 scales) tuples
    leaves = jax.tree.leaves(state.opt["v"])
    assert any(l.dtype == jnp.int8 for l in leaves)


# -- elastic ------------------------------------------------------------------


def test_host_monitor_detects_stragglers_and_deaths():
    m = HostMonitor(timeout_s=10, straggler_factor=2.0)
    for step in range(5):
        for h in ("h0", "h1", "h2", "h3"):
            m.beat(h, step, 1.0 if h != "h3" else 3.5, now=float(step))
    assert m.stragglers() == ["h3"]
    m.beat("h0", 5, 1.0, now=100.0)
    assert "h1" in m.dead_hosts(now=100.0) and "h0" not in m.dead_hosts(now=100.0)


def test_elastic_plan_replace_and_shrink():
    plan = ElasticPlan(hosts=[0, 1, 2, 3], spares=[7, 8])
    pos, new = plan.replace(2)
    assert pos == 2 and new == 7 and plan.hosts == [0, 1, 7, 3]
    released = plan.shrink_to(2)
    assert plan.hosts == [0, 1] and released == [7, 3]
    assert shrink_mesh_shape(16, 3) == 8  # largest divisor of 16 <= 13
    assert shrink_mesh_shape(16, 1) == 8


# -- data pipeline --------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 5))
def test_pipeline_deterministic_and_in_range(step, seed):
    cfg = get_config("olmoe_1b_7b").reduced()
    p1 = SyntheticPipeline(cfg, seq_len=16, global_batch=2, seed=seed)
    p2 = SyntheticPipeline(cfg, seq_len=16, global_batch=2, seed=seed)
    b1, b2 = p1.batch_at(step), p2.batch_at(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < cfg.vocab_size
    np.testing.assert_array_equal(
        b1["labels"][:, :-1], b1["tokens"][:, 1:]
    )


def test_pipeline_stub_embeddings():
    for arch in ("pixtral_12b", "seamless_m4t_large_v2"):
        cfg = get_config(arch).reduced()
        p = SyntheticPipeline(cfg, seq_len=32, global_batch=2)
        b = p.batch_at(0)
        key = "patch_embed" if cfg.family == "vlm" else "src_embed"
        assert b[key].shape == (2, cfg.num_stub_tokens, cfg.d_model)


# -- serving ------------------------------------------------------------------


def test_slot_manager_continuous_batching():
    from repro.serve.kvcache import Request, SlotManager

    mgr = SlotManager(batch=2, cache_len=64)
    for rid in range(5):
        mgr.submit(Request(rid, np.arange(4, dtype=np.int32), max_new=3))
    steps = 0
    while (mgr.live or mgr.waiting) and steps < 100:
        mgr.admit()
        assert mgr.live <= 2
        toks = np.arange(mgr.batch, dtype=np.int32)
        mgr.record(toks)
        steps += 1
    assert len(mgr.finished) == 5
    assert all(len(r.generated) == 3 for r in mgr.finished)


def test_serve_cache_bytes_accounting():
    from repro.models.registry import get_model
    from repro.serve.kvcache import cache_bytes

    cfg = get_config("mistral_large_123b")
    api = get_model(cfg)
    got = cache_bytes(cfg, api, batch=128, cache_len=32768)
    want = 2 * cfg.num_layers * 128 * 32768 * cfg.num_kv_heads * cfg.head_dim * 2
    assert got == want
