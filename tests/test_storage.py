"""Storage substrate tests: placement, failure injection, BlockFixer modes
(paper §7/§8 semantics), degraded reads."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoreCode, CoreCodec
from repro.storage import BlockFixer, BlockStore, ClusterProfile


def make_group(code: CoreCode, store: BlockStore, group_id="g0", q=1024, seed=0):
    rng = np.random.default_rng(seed)
    objects = rng.integers(0, 256, size=(code.t, code.k, q), dtype=np.uint8)
    matrix = np.asarray(CoreCodec(code).encode(jnp.asarray(objects)))
    store.put_group(group_id, matrix)
    return objects, matrix


def test_placement_anti_colocation():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=40)
    make_group(code, store)
    nodes = [store.node_of(("g0", r, c)) for r in range(4) for c in range(9)]
    assert len(set(nodes)) == len(nodes)  # every block on a distinct node


def test_node_failure_marks_blocks_unavailable():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=40)
    make_group(code, store)
    victim = store.node_of(("g0", 1, 2))
    store.fail_nodes([victim])
    assert not store.available(("g0", 1, 2))
    fm = store.failure_matrix("g0", 4, 9)
    assert fm.sum() == 1 and fm[1, 2]


@pytest.mark.parametrize("mode,expected_fetch", [
    ("hdfs_raid", 8),       # all remaining blocks of the stripe
    ("hdfs_raid_opt", 6),   # Opt1: exactly k
    ("core", 3),            # vertical: t blocks
])
def test_single_failure_fetch_counts_9_6_3(mode, expected_fetch):
    """Paper Fig 12 'X' pattern, (9,6,3): the fetch counts that produce
    the 50%-less-bandwidth headline."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    _, matrix = make_group(code, store)
    store.fail_nodes([store.node_of(("g0", 1, 3))])
    fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode=mode)
    report = fixer.fix_group("g0")
    assert report.recovered
    assert report.blocks_fetched == expected_fetch
    np.testing.assert_array_equal(store.blocks[("g0", 1, 3)], matrix[1, 3])


@pytest.mark.parametrize("mode,expected_fetch", [
    ("hdfs_raid", 7 + 8),    # two sequential full-stripe fetches
    ("hdfs_raid_opt", 6),    # Opt2: one decode for both
    ("core", 6),             # two vertical repairs, t each
])
def test_double_failure_same_row_9_6_3(mode, expected_fetch):
    """Paper Fig 12 'XX' pattern (both failures on the same object)."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    _, matrix = make_group(code, store)
    store.fail_nodes([store.node_of(("g0", 1, 3)), store.node_of(("g0", 1, 5))])
    fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode=mode)
    report = fixer.fix_group("g0")
    assert report.recovered
    assert report.blocks_fetched == expected_fetch
    np.testing.assert_array_equal(store.blocks[("g0", 1, 3)], matrix[1, 3])
    np.testing.assert_array_equal(store.blocks[("g0", 1, 5)], matrix[1, 5])


def test_double_failure_14_12_5_bandwidth_gap():
    """(14,12,5) XX: CORE 2t=10 vs optimized RS k=12 — the ~16% saving."""
    code = CoreCode(14, 12, 5)
    store = BlockStore(num_nodes=120)
    make_group(code, store)
    store.fail_nodes([store.node_of(("g0", 2, 1)), store.node_of(("g0", 2, 7))])
    core = BlockFixer(store, code, ClusterProfile.network_critical(), mode="core")
    r_core = core.fix_group("g0")
    assert r_core.blocks_fetched == 10
    # rebuild a fresh store for the RS comparison
    store2 = BlockStore(num_nodes=120)
    make_group(code, store2)
    store2.fail_nodes([store2.node_of(("g0", 2, 1)), store2.node_of(("g0", 2, 7))])
    opt = BlockFixer(store2, code, ClusterProfile.network_critical(), mode="hdfs_raid_opt")
    r_opt = opt.fix_group("g0")
    assert r_opt.blocks_fetched == 12
    assert 1 - r_core.blocks_fetched / r_opt.blocks_fetched == pytest.approx(1 / 6)


def test_core_repairs_beyond_rs_tolerance():
    """A row with m+1 failures is lost to plain RS but CORE recovers it
    via vertical parities (the paper's fault-tolerance bonus)."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    _, matrix = make_group(code, store)
    cells = [(1, c) for c in range(4)]  # 4 > m = 3 failures in one row
    store.fail_nodes([store.node_of(("g0", r, c)) for r, c in cells])
    raid = BlockFixer(store, code, ClusterProfile.network_critical(), mode="hdfs_raid_opt")
    # RS alone cannot: row 1 has > m failures
    rep = raid.fix_group("g0")
    assert not rep.recovered
    fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode="core")
    report = fixer.fix_group("g0")
    assert report.recovered
    for r, c in cells:
        np.testing.assert_array_equal(store.blocks[("g0", r, c)], matrix[r, c])


def test_network_vs_compute_profiles():
    """Vertical XOR repair must beat RS decode on compute time; the
    network-critical profile must amplify network gaps."""
    code = CoreCode(14, 12, 5)
    q = 1 << 18  # 256 KiB blocks
    results = {}
    for mode in ("core", "hdfs_raid_opt"):
        store = BlockStore(num_nodes=120)
        make_group(code, store, q=q)
        store.fail_nodes([store.node_of(("g0", 2, 3))])
        fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode=mode)
        fixer.fix_group("g0")  # warm the jit caches
        store.fail_nodes([store.node_of(("g0", 2, 4))])
        results[mode] = fixer.fix_group("g0")
    assert results["core"].network_time < results["hdfs_raid_opt"].network_time
    assert results["core"].bytes_fetched < results["hdfs_raid_opt"].bytes_fetched


def test_degraded_read_with_vertical_repair():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    objects, _ = make_group(code, store)
    store.fail_nodes([store.node_of(("g0", 0, 2))])
    fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode="core")
    data, report = fixer.degraded_read("g0", 0)
    np.testing.assert_array_equal(data, objects[0])
    # 5 direct reads + 3 vertical sources
    assert report.blocks_fetched == 5 + 3
    # read is non-destructive: the block is still missing
    assert not store.available(("g0", 0, 2))


def test_degraded_read_falls_back_to_row_decode():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=60)
    objects, _ = make_group(code, store)
    # two failures in the same column -> vertical impossible for (0,2)
    store.fail_nodes([store.node_of(("g0", 0, 2)), store.node_of(("g0", 2, 2))])
    fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode="core")
    data, report = fixer.degraded_read("g0", 0)
    np.testing.assert_array_equal(data, objects[0])
    assert report.blocks_fetched == 6  # full row decode


def test_partial_recovery_across_clusters():
    """An unrecoverable cluster must not block repair of an independent
    recoverable cluster (§6.1 benefit ii)."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=80)
    _, matrix = make_group(code, store)
    # unrecoverable cluster: two rows x (m+1) identical columns
    bad = [(0, c) for c in range(4)] + [(1, c) for c in range(4)]
    # recoverable singleton elsewhere
    good = [(3, 8)]
    store.fail_nodes([store.node_of(("g0", r, c)) for r, c in bad + good])
    fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode="core")
    report = fixer.fix_group("g0")
    assert not report.recovered  # overall group not fully recovered
    np.testing.assert_array_equal(store.blocks[("g0", 3, 8)], matrix[3, 8])


# -- rack-aware placement (failure domains) -----------------------------------


def test_rack_aware_placement_row_and_col_distinct():
    """With nodes_per_rack set, no two blocks of the same row OR column
    share a rack — a whole-rack failure (ToR/PDU) costs each stripe and
    each vertical repair group at most one block."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=36, nodes_per_rack=3)  # 12 racks >= n=9
    for g in range(6):
        make_group(code, store, group_id=f"g{g}", seed=g)
    for g in range(6):
        racks = {
            (r, c): store.rack_of(store.node_of((f"g{g}", r, c)))
            for r in range(code.rows)
            for c in range(code.n)
        }
        for r in range(code.rows):
            assert len({racks[(r, c)] for c in range(code.n)}) == code.n
        for c in range(code.n):
            assert len({racks[(r, c)] for r in range(code.rows)}) == code.rows


def test_whole_rack_failure_costs_one_block_per_line():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=36, nodes_per_rack=3)
    for g in range(4):
        make_group(code, store, group_id=f"g{g}", seed=10 + g)
    for rack in range(12):
        lo = rack * 3
        store.fail_nodes([lo, lo + 1, lo + 2])
        for g in range(4):
            fm = store.failure_matrix(f"g{g}", code.rows, code.n)
            assert fm.sum(axis=1).max() <= 1  # <= 1 loss per row
            assert fm.sum(axis=0).max() <= 1  # <= 1 loss per column
        store.heal_node(lo), store.heal_node(lo + 1), store.heal_node(lo + 2)


def test_rack_aware_repair_writeback_keeps_invariant():
    """Repair write-back must re-place the healed block without putting
    it in a rack already hosting a live block of its row or column."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=36, nodes_per_rack=3)
    _, matrix = make_group(code, store, seed=3)
    key = ("g0", 1, 4)
    store.fail_nodes([store.node_of(key)])
    fixer = BlockFixer(store, code, ClusterProfile.network_critical(), mode="core")
    assert fixer.fix_group("g0").recovered
    np.testing.assert_array_equal(store.blocks[key], matrix[1, 4])
    new_rack = store.rack_of(store.node_of(key))
    peer_racks = {
        store.rack_of(store.node_of(("g0", r, c)))
        for r in range(code.rows)
        for c in range(code.n)
        if (r, c) != (1, 4) and (r == 1 or c == 4)
        and store.available(("g0", r, c))
    }
    assert new_rack not in peer_racks


def test_rack_aware_placement_needs_enough_racks():
    from repro.storage.blockstore import PlacementError

    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=12, nodes_per_rack=3)  # 4 racks < n=9
    with pytest.raises(PlacementError):
        make_group(code, store)


def test_rackless_store_placement_unchanged():
    """nodes_per_rack=None must keep the classic layout byte-identical
    (the rack plane is strictly opt-in)."""
    code = CoreCode(9, 6, 3)
    a, b = BlockStore(num_nodes=40), BlockStore(num_nodes=40, nodes_per_rack=None)
    make_group(code, a, seed=5)
    make_group(code, b, seed=5)
    assert a.placement == b.placement


def test_gateway_wires_rack_aware_placement():
    from repro.gateway import GatewayConfig, ObjectGateway, WorkloadConfig, generate_requests

    code = CoreCode(9, 6, 3)
    cfg = GatewayConfig(batch_window=0.01, nodes_per_rack=3)
    gw = ObjectGateway(code, ClusterProfile.network_critical(), 36, cfg)
    rng = np.random.default_rng(9)
    gw.load_objects(rng.integers(0, 256, (6, code.k, 1024), dtype=np.uint8))
    assert gw.store.nodes_per_rack == 3
    wl = WorkloadConfig(num_objects=6, num_requests=40, arrival_rate=500.0, seed=9)
    rep = gw.serve(generate_requests(wl), [])
    assert len(rep.completed) == 40
