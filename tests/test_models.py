"""Per-arch smoke tests: reduced config, one forward/loss + a prefill +
two decode steps on CPU; asserts shapes and finiteness (brief: smoke
tests instantiate a REDUCED config of the same family)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import get_model
from repro.models.shardings import SINGLE, ServePlan


def make_batch(cfg, rng, b=2, s=64):
    tok = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.family == "vlm":
        p = cfg.num_stub_tokens
        batch["patch_embed"] = jax.random.normal(rng, (b, p, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        t = cfg.num_stub_tokens
        batch["src_embed"] = jax.random.normal(rng, (b, t, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_loss(arch, rng):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(cfg, rng)
    batch = make_batch(cfg, rng)
    loss = jax.jit(lambda p, b: api.loss(p, b, cfg, SINGLE))(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    # one grad step must also be finite (exercises the remat/scan bwd)
    g = jax.jit(jax.grad(lambda p, b: api.loss(p, b, cfg, SINGLE)))(params, batch)
    flat = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(np.asarray(x, np.float32))) for x in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch, rng):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(cfg, rng)
    b, s, cache_len = 2, 64, 128
    batch = make_batch(cfg, rng, b=b, s=s)
    plan = ServePlan()
    logits, cache = jax.jit(
        lambda p, bt: api.prefill(p, bt, cfg, SINGLE, cache_len)
    )(params, batch)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    step = jax.jit(
        lambda p, t, c, pos: api.decode(p, t, c, pos, cfg, SINGLE, plan)
    )
    for i in range(2):
        logits, cache = step(params, tok, cache, jnp.asarray(s + i, jnp.int32))
        assert logits.shape == (b, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), (arch, i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


def test_decode_matches_prefill_dense(rng):
    """Teacher-forced decode after prefill must reproduce the prefill's
    next-token logits (cache correctness oracle, dense family)."""
    cfg = get_config("qwen2_72b").reduced(num_layers=2)
    api = get_model(cfg)
    params = api.init(cfg, rng)
    b, s = 2, 16
    tok = jax.random.randint(rng, (b, s + 4), 0, cfg.vocab_size)
    plan = ServePlan()

    lp, cache = api.prefill(params, {"tokens": tok[:, :s]}, cfg, SINGLE, 64)
    # decode the next 4 gold tokens; compare against prefill over longer prefix
    for i in range(4):
        ld, cache = api.decode(
            params, tok[:, s + i : s + i + 1], cache, jnp.asarray(s + i), cfg, SINGLE, plan
        )
    lp2, _ = api.prefill(params, {"tokens": tok}, cfg, SINGLE, 64)
    np.testing.assert_allclose(
        np.asarray(ld, np.float32), np.asarray(lp2, np.float32), rtol=0.05, atol=0.05
    )
