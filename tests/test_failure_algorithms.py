"""Tests for §6 algorithms: clustering, recoverability, scheduling.

The Table 1 costs (Step = {24, 22, 17}, Plus = {41, 39, 34} for the
(14,12,5) code) are the paper's own worked examples and are asserted
exactly.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CoreCode,
    CoreCodec,
    independent_clusters,
    irrecoverability_lower_bound,
    is_recoverable,
    num_clusters,
    plus_pattern,
    random_failure_matrix,
    recoverability_upper_bound,
    schedule_column_first,
    schedule_rgs,
    schedule_row_first,
    step_pattern,
)

CODE = CoreCode(n=14, k=12, t=5)  # the paper's Azure-inspired parameters
ROWS, COLS = CODE.t + 1, CODE.n


# ---------------------------------------------------------------------------
# §6.1 clustering
# ---------------------------------------------------------------------------


def test_clusters_disjoint_failures():
    fm = np.zeros((ROWS, COLS), dtype=bool)
    fm[0, 0] = fm[2, 5] = fm[4, 9] = True
    assert num_clusters(fm) == 3


def test_clusters_merge_on_shared_row_and_column():
    fm = np.zeros((ROWS, COLS), dtype=bool)
    fm[0, 0] = fm[0, 5] = True  # same row
    fm[3, 5] = True  # shares column 5 with (0,5)
    fm[3, 9] = True  # same row as (3,5)
    fm[1, 2] = True  # isolated
    clusters = independent_clusters(fm)
    assert len(clusters) == 2
    sizes = sorted(int(c.sum()) for c in clusters)
    assert sizes == [1, 4]
    # clusters partition the failure set
    np.testing.assert_array_equal(sum(c.astype(int) for c in clusters), fm.astype(int))


def test_cluster_count_bounds():
    rng = np.random.default_rng(0)
    for nf in range(1, 21):
        fm = random_failure_matrix(ROWS, COLS, nf, rng)
        nc = num_clusters(fm)
        assert 1 <= nc <= min(nf, ROWS)


# ---------------------------------------------------------------------------
# §6.2 recoverability
# ---------------------------------------------------------------------------


def test_bounds_match_paper():
    # (14,12,5): L = 2*(14-12+1) = 6, U = 5*2 + (24-14) = 20
    assert irrecoverability_lower_bound(CODE) == 6
    assert recoverability_upper_bound(CODE) == 20


def test_below_lower_bound_always_recoverable():
    rng = np.random.default_rng(1)
    for _ in range(300):
        nf = int(rng.integers(1, irrecoverability_lower_bound(CODE)))
        fm = random_failure_matrix(ROWS, COLS, nf, rng)
        assert is_recoverable(CODE, fm)


def test_above_upper_bound_rarely_recoverable():
    """The paper claims > U ⇒ irrecoverable. That is not strictly true
    (see the counterexample test below), but it holds for almost every
    uniformly-sampled pattern — which is why the paper's 10M-run Fig. 10
    never observed one."""
    rng = np.random.default_rng(2)
    u = recoverability_upper_bound(CODE)
    recoverable = 0
    for _ in range(300):
        nf = int(rng.integers(u + 1, ROWS * COLS + 1))
        fm = random_failure_matrix(ROWS, COLS, nf, rng)
        recoverable += is_recoverable(CODE, fm)
    assert recoverable / 300 < 0.05


def test_upper_bound_counterexample_documented():
    """Recoverable pattern with 24 > U = 20 failures: 12 singleton columns
    peel vertically, then 6 rows of 2 identical-column failures repair
    horizontally. Documents that the paper's U is not a converse bound."""
    fm = np.zeros((ROWS, COLS), dtype=bool)
    fm[:, :2] = True  # 6 rows x 2 failures, identical columns
    for r in range(ROWS):
        fm[r, 2 + 2 * r] = fm[r, 3 + 2 * r] = True  # 12 singleton columns
    assert fm.sum() == 24 > recoverability_upper_bound(CODE)
    assert is_recoverable(CODE, fm)


def test_irrecoverable_pattern_at_lower_bound():
    # two rows with n-k+1 failures at identical columns
    fm = np.zeros((ROWS, COLS), dtype=bool)
    fm[0, :3] = fm[1, :3] = True
    assert not is_recoverable(CODE, fm)


def test_recoverable_pattern_at_upper_bound():
    # t rows with n-k failures at identical columns + 2k-n singleton columns
    fm = np.zeros((ROWS, COLS), dtype=bool)
    fm[:5, :2] = True
    for j in range(10):
        fm[j % 5, 2 + j] = False  # keep rows at exactly n-k... build directly:
    fm = np.zeros((ROWS, COLS), dtype=bool)
    fm[:5, :2] = True  # 5 rows x 2 failures, identical columns
    fm[5, 2:12] = True  # 10 singleton-column failures on the parity row
    assert fm.sum() == recoverability_upper_bound(CODE)
    assert is_recoverable(CODE, fm)


def test_recoverability_vs_exhaustive_rank_check():
    """Cross-validate the recursive checker against exact linear-algebra
    decodability of the full product code on a small code."""
    from repro.coding.linear import LinearCode
    from repro.coding import rs as rs_mod

    code = CoreCode(n=5, k=3, t=2)
    # full product-code generator: (t+1)*n rows, t*k message symbols
    g_h = rs_mod.generator_matrix(code.n, code.k)  # (n, k)
    g_v = np.concatenate(
        [np.eye(code.t, dtype=np.uint8), np.ones((1, code.t), dtype=np.uint8)]
    )  # (t+1, t)
    gen = np.kron(g_v, g_h)  # ((t+1)n, tk) — G = G_c (x) G_o
    full = LinearCode(gen=gen)
    cells = [(r, c) for r in range(code.t + 1) for c in range(code.n)]
    rng = np.random.default_rng(3)
    mismatch_dir = []
    for nf in range(1, 9):
        for _ in range(60):
            idx = rng.choice(len(cells), size=nf, replace=False)
            fm = np.zeros((code.t + 1, code.n), dtype=bool)
            for i in idx:
                fm[cells[i]] = True
            avail = [r * code.n + c for r in range(code.t + 1) for c in range(code.n) if not fm[r, c]]
            exact = full.decodable(np.asarray(avail))
            recursive = is_recoverable(code, fm)
            # the recursive checker is the paper's algorithm: it must never
            # claim recoverable when exact algebra says impossible
            if recursive:
                assert exact, (fm, "checker claimed recoverable but rank-deficient")
            else:
                mismatch_dir.append(exact)
    # the recursive (peeling) checker may be conservative vs full algebra,
    # but should agree in the overwhelming majority of sampled cases
    if mismatch_dir:
        assert sum(mismatch_dir) / len(mismatch_dir) < 0.35


# ---------------------------------------------------------------------------
# §6.3 scheduling — Table 1 exact reproduction
# ---------------------------------------------------------------------------


def test_table1_step_costs():
    fm = step_pattern(ROWS, COLS)
    k, t = CODE.k, CODE.t
    assert schedule_row_first(CODE, fm).traffic == 2 * k  # 24
    assert schedule_column_first(CODE, fm).traffic == 2 * t + k  # 22
    assert schedule_rgs(CODE, fm).traffic == k + t  # 17


def test_table1_plus_costs():
    fm = plus_pattern(ROWS, COLS)
    k, t = CODE.k, CODE.t
    assert schedule_row_first(CODE, fm).traffic == 3 * k + t  # 41
    assert schedule_column_first(CODE, fm).traffic == 3 * t + 2 * k  # 39
    assert schedule_rgs(CODE, fm).traffic == 2 * t + 2 * k  # 34


def test_table1_step_schedules_shape():
    fm = step_pattern(ROWS, COLS)
    rf = schedule_row_first(CODE, fm)
    cf = schedule_column_first(CODE, fm)
    rgs = schedule_rgs(CODE, fm)
    assert [s.kind for s in rf.steps] == ["H", "H"]
    assert [s.kind for s in cf.steps] == ["V", "H", "V"]
    assert [s.kind for s in rgs.steps] == ["H", "V"]


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=12), st.integers(min_value=0, max_value=10**6))
def test_schedules_fix_everything_and_rgs_never_worse(nf, seed):
    rng = np.random.default_rng(seed)
    fm = random_failure_matrix(ROWS, COLS, nf, rng)
    if not is_recoverable(CODE, fm):
        return
    scheds = {
        "row": schedule_row_first(CODE, fm),
        "col": schedule_column_first(CODE, fm),
        "rgs": schedule_rgs(CODE, fm),
    }
    for name, s in scheds.items():
        assert s is not None, (name, fm)
        fixed = set()
        for step in s.steps:
            fixed.update(step.repairs)
        assert fixed == {tuple(c) for c in np.argwhere(fm)}, name
    assert scheds["rgs"].traffic <= scheds["row"].traffic
    # RGS vs column-first: paper Fig 11 — RGS <= column-first on average;
    # we assert it per-pattern (holds for this greedy pair by construction)
    assert scheds["rgs"].traffic <= scheds["col"].traffic + CODE.k


def test_unrecoverable_returns_none():
    fm = np.zeros((ROWS, COLS), dtype=bool)
    fm[0, :3] = fm[1, :3] = True
    assert schedule_rgs(CODE, fm) is None
    assert schedule_column_first(CODE, fm) is None
    assert schedule_row_first(CODE, fm) is None


# ---------------------------------------------------------------------------
# schedule execution against the real codec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pattern", [step_pattern, plus_pattern])
@pytest.mark.parametrize("scheduler", [schedule_row_first, schedule_column_first, schedule_rgs])
def test_schedule_executes_to_correct_blocks(pattern, scheduler):
    import jax.numpy as jnp

    code = CoreCode(n=9, k=6, t=3)
    codec = CoreCodec(code)
    rng = np.random.default_rng(11)
    objects = rng.integers(0, 256, size=(code.t, code.k, 40), dtype=np.uint8)
    matrix = np.asarray(codec.encode(jnp.asarray(objects)))
    fm = pattern(code.t + 1, code.n)
    sched = scheduler(code, fm)
    assert sched is not None
    store = {
        (r, c): matrix[r, c]
        for r in range(code.t + 1)
        for c in range(code.n)
        if not fm[r, c]
    }
    for step in sched.steps:
        assert all(src in store for src in step.sources), "read a missing block"
        if step.kind == "V":
            stack = jnp.asarray(np.stack([store[s] for s in step.sources]))
            ((r, c),) = step.repairs
            store[(r, c)] = np.asarray(codec.repair_vertical(stack))
        else:
            r = step.index
            avail = np.asarray([c for (_, c) in step.sources])
            blocks = jnp.asarray(np.stack([store[s] for s in step.sources]))
            missing = np.asarray([c for (_, c) in step.repairs])
            rep = np.asarray(codec.repair_horizontal(blocks, avail, missing))
            for i, (_, c) in enumerate(step.repairs):
                store[(r, c)] = rep[i]
    for r in range(code.t + 1):
        for c in range(code.n):
            np.testing.assert_array_equal(store[(r, c)], matrix[r, c])


def test_codec_encode_properties():
    import jax.numpy as jnp

    code = CoreCode(n=9, k=6, t=3)
    codec = CoreCodec(code)
    rng = np.random.default_rng(12)
    objects = rng.integers(0, 256, size=(code.t, code.k, 16), dtype=np.uint8)
    matrix = codec.encode(jnp.asarray(objects))
    assert matrix.shape == (code.t + 1, code.n, 16)
    assert codec.verify(matrix)
    # stretch factor: (n (t+1)) / (k t) — paper Fig 1 example = 2.0
    assert abs(CoreCode(9, 6, 3).stretch - 2.0) < 1e-9
