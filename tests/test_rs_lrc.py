"""Reed-Solomon + LRC codec tests: MDS property, erasure decode, repair."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.coding import lrc, rs
from repro.coding.linear import rank_gf256


@pytest.mark.parametrize("n,k", [(5, 3), (9, 6), (14, 12), (10, 6)])
def test_rs_systematic_and_mds(n, k):
    code = rs.make_rs(n, k)
    assert np.array_equal(code.gen[:k], np.eye(k, dtype=np.uint8))
    # MDS: every k-subset of rows has rank k (exhaustive for small n)
    for subset in itertools.combinations(range(n), k):
        assert rank_gf256(code.gen[list(subset)]) == k, subset


@pytest.mark.parametrize("n,k", [(9, 6), (14, 12)])
def test_rs_encode_decode_roundtrip(n, k):
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, size=(k, 64), dtype=np.uint8)
    code = rs.make_rs(n, k)
    cw = np.asarray(code.encode(jnp.asarray(data)))
    assert cw.shape == (n, 64)
    np.testing.assert_array_equal(cw[:k], data)  # systematic
    # erase m arbitrary blocks, decode from the rest
    for _ in range(10):
        erased = rng.choice(n, size=n - k, replace=False)
        avail = np.setdiff1d(np.arange(n), erased)
        dec = np.asarray(code.decode(avail, jnp.asarray(cw[avail])))
        np.testing.assert_array_equal(dec, data)


def test_rs_repair_specific_blocks():
    n, k = 9, 6
    rng = np.random.default_rng(8)
    data = rng.integers(0, 256, size=(k, 32), dtype=np.uint8)
    code = rs.make_rs(n, k)
    cw = np.asarray(code.encode(jnp.asarray(data)))
    missing = np.asarray([2, 7])
    avail = np.setdiff1d(np.arange(n), missing)
    rep = np.asarray(code.repair(avail, jnp.asarray(cw[avail]), missing))
    np.testing.assert_array_equal(rep, cw[missing])


@given(st.integers(min_value=2, max_value=12), st.data())
@settings(max_examples=25, deadline=None)
def test_rs_any_k_of_n_property(k, data_st):
    n = data_st.draw(st.integers(min_value=k, max_value=min(k + 6, 18)))
    rng = np.random.default_rng(k * 31 + n)
    data = rng.integers(0, 256, size=(k, 8), dtype=np.uint8)
    code = rs.make_rs(n, k)
    cw = np.asarray(code.encode(jnp.asarray(data)))
    avail = np.sort(rng.choice(n, size=k, replace=False))
    dec = np.asarray(code.decode(avail, jnp.asarray(cw[avail])))
    np.testing.assert_array_equal(dec, data)


# ---------------------------------------------------------------------------
# LRC
# ---------------------------------------------------------------------------


def test_lrc_layout_and_parities():
    code = lrc.make_lrc(10, 6)
    rng = np.random.default_rng(9)
    data = rng.integers(0, 256, size=(6, 16), dtype=np.uint8)
    cw = np.asarray(code.encode(jnp.asarray(data)))
    assert cw.shape == (10, 16)
    np.testing.assert_array_equal(cw[:6], data)
    # p_1 / p_2 are XORs of the halves (paper Fig. 2)
    np.testing.assert_array_equal(cw[6], np.bitwise_xor.reduce(data[:3], axis=0))
    np.testing.assert_array_equal(cw[7], np.bitwise_xor.reduce(data[3:], axis=0))


def test_lrc_local_repair_paper_example():
    # paper: o_{1,2} = o_{1,1} + o_{1,3} + p_{1,1} — 3 transfers for (10,6)
    code = lrc.make_lrc(10, 6)
    plan = code.repair_plan({1})
    assert plan is not None and len(plan) == 1
    kind, sources, repaired = plan[0]
    assert kind == "local" and repaired == [1]
    assert sorted(sources) == [0, 2, 6]


def test_lrc_global_parity_needs_k():
    code = lrc.make_lrc(10, 6)
    plan = code.repair_plan({8})  # a global parity
    assert plan is not None and len(plan) == 1
    kind, sources, _ = plan[0]
    assert kind == "global" and len(sources) == 6


def test_lrc_tolerates_m_minus_2_always():
    # any n-k-2 failures decodable via global code
    code = lrc.make_lrc(10, 6)
    for erased in itertools.combinations(range(10), 2):
        avail = np.setdiff1d(np.arange(10), erased)
        assert code.decodable(avail), erased


def test_lrc_avg_single_repair_cost_formula():
    # (k+2)/n * k/2 + (n-k-2)/n * k == (2kn - k^2 - 2k)/2n
    n, k = 10, 6
    direct = (k + 2) / n * (k / 2) + (n - k - 2) / n * k
    assert abs(lrc.avg_single_repair_cost(n, k) - direct) < 1e-12


def test_lrc_repair_plan_executes_correctly():
    code = lrc.make_lrc(10, 6)
    rng = np.random.default_rng(10)
    data = rng.integers(0, 256, size=(6, 16), dtype=np.uint8)
    cw = np.asarray(code.encode(jnp.asarray(data)))
    failed = {1, 4, 8}
    plan = code.repair_plan(set(failed))
    assert plan is not None
    store = {i: cw[i] for i in range(10) if i not in failed}
    for kind, sources, repaired in plan:
        assert all(s in store for s in sources)
        if kind == "local":
            (tgt,) = repaired
            store[tgt] = np.bitwise_xor.reduce(
                np.stack([store[s] for s in sources]), axis=0
            )
        else:
            dec = np.asarray(
                code.decode(
                    np.asarray(sources),
                    jnp.asarray(np.stack([store[s] for s in sources])),
                )
            )
            full = np.asarray(code.encode(jnp.asarray(dec)))
            for t in repaired:
                store[t] = full[t]
    for i in range(10):
        np.testing.assert_array_equal(store[i], cw[i])
