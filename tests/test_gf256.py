"""Field-axiom and table-consistency tests for GF(2^8)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.coding import gf256

bytes_ = st.integers(min_value=0, max_value=255)


def m(a, b):
    return int(gf256._MUL_NP[a, b])


@given(bytes_, bytes_)
def test_mul_commutative(a, b):
    assert m(a, b) == m(b, a)


@given(bytes_, bytes_, bytes_)
@settings(max_examples=200)
def test_mul_associative(a, b, c):
    assert m(m(a, b), c) == m(a, m(b, c))


@given(bytes_, bytes_, bytes_)
@settings(max_examples=200)
def test_distributive(a, b, c):
    assert m(a, b ^ c) == m(a, b) ^ m(a, c)


@given(bytes_)
def test_identity_and_zero(a):
    assert m(a, 1) == a
    assert m(a, 0) == 0


@given(st.integers(min_value=1, max_value=255))
def test_inverse(a):
    assert m(a, int(gf256._INV_NP[a])) == 1


def test_mul_matches_carryless_reference():
    # bit-by-bit carryless multiply + reduction, independent implementation
    def ref_mul(a, b):
        r = 0
        for i in range(8):
            if (b >> i) & 1:
                r ^= a << i
        for bit in range(15, 7, -1):
            if (r >> bit) & 1:
                r ^= gf256._POLY << (bit - 8)
        return r

    rng = np.random.default_rng(0)
    for _ in range(500):
        a, b = int(rng.integers(256)), int(rng.integers(256))
        assert m(a, b) == ref_mul(a, b)


def test_jnp_mul_matches_table():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 256, size=(64,), dtype=np.uint8)
    b = rng.integers(0, 256, size=(64,), dtype=np.uint8)
    got = np.asarray(gf256.mul(jnp.asarray(a), jnp.asarray(b)))
    want = gf256._MUL_NP[a, b]
    np.testing.assert_array_equal(got, want)


def test_matmul_matches_np():
    rng = np.random.default_rng(2)
    a = rng.integers(0, 256, size=(5, 7), dtype=np.uint8)
    b = rng.integers(0, 256, size=(7, 3), dtype=np.uint8)
    got = np.asarray(gf256.matmul(jnp.asarray(a), jnp.asarray(b)))
    want = gf256.np_matmul(a, b)
    np.testing.assert_array_equal(got, want)


def test_np_inv_matrix_roundtrip():
    rng = np.random.default_rng(3)
    for n in (1, 2, 5, 12):
        while True:
            mt = rng.integers(0, 256, size=(n, n), dtype=np.uint8)
            try:
                minv = gf256.np_inv_matrix(mt)
                break
            except np.linalg.LinAlgError:
                continue
        eye = gf256.np_matmul(mt, minv)
        np.testing.assert_array_equal(eye, np.eye(n, dtype=np.uint8))


def test_xor_reduce():
    rng = np.random.default_rng(4)
    x = rng.integers(0, 256, size=(6, 33), dtype=np.uint8)
    got = np.asarray(gf256.xor_reduce(jnp.asarray(x), axis=0))
    want = np.bitwise_xor.reduce(x, axis=0)
    np.testing.assert_array_equal(got, want)
