"""Batched (stacked) GF(256) decode path: the (B, M, K) x (B, K, N) entry
must match a loop of single-stripe gf256_matmul calls and the numpy/jnp
reference across shapes. No hypothesis dependency — this file must run
everywhere (it guards the gateway coalescer's kernel)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.coding import gf256
from repro.kernels import ops, ref


@pytest.mark.parametrize("b,m,k", [(1, 1, 3), (2, 2, 6), (3, 1, 12), (5, 3, 6), (8, 2, 4)])
@pytest.mark.parametrize("n", [128, 512, 1000, 4096])
def test_batched_matches_single_stripe_loop(b, m, k, n):
    rng = np.random.default_rng(b * 10000 + m * 1000 + k * 10 + n)
    coefs = rng.integers(0, 256, size=(b, m, k), dtype=np.uint8)
    data = rng.integers(0, 256, size=(b, k, n), dtype=np.uint8)
    got = np.asarray(ops.gf256_matmul_batched(coefs, jnp.asarray(data), interpret=True))
    assert got.shape == (b, m, n)
    want = np.stack(
        [
            np.asarray(ops.gf256_matmul(coefs[i], jnp.asarray(data[i]), interpret=True))
            for i in range(b)
        ]
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("b,m,k,n", [(2, 2, 6, 777), (4, 1, 3, 2048), (3, 4, 16, 512)])
def test_batched_matches_numpy_reference(b, m, k, n):
    rng = np.random.default_rng(b + m + k + n)
    coefs = rng.integers(0, 256, size=(b, m, k), dtype=np.uint8)
    data = rng.integers(0, 256, size=(b, k, n), dtype=np.uint8)
    got = np.asarray(ops.gf256_matmul_batched(coefs, jnp.asarray(data), interpret=True))
    for i in range(b):
        want = np.asarray(ref.gf256_matmul(jnp.asarray(coefs[i]), jnp.asarray(data[i])))
        np.testing.assert_array_equal(got[i], want)


@pytest.mark.parametrize("b,t,n", [(1, 2, 128), (3, 3, 512), (4, 5, 1000), (2, 13, 4096)])
def test_batched_xor_parity_matches_loop_and_reference(b, t, n):
    rng = np.random.default_rng(b * 100 + t * 10 + n)
    data = rng.integers(0, 256, size=(b, t, n), dtype=np.uint8)
    got = np.asarray(ops.xor_parity_batched(jnp.asarray(data), interpret=True))
    assert got.shape == (b, n)
    for i in range(b):
        single = np.asarray(ops.xor_parity(jnp.asarray(data[i]), interpret=True))
        want = np.asarray(ref.xor_parity(jnp.asarray(data[i])))
        np.testing.assert_array_equal(got[i], single)
        np.testing.assert_array_equal(got[i], want)


def test_batched_decode_recovers_rs_stripes():
    """End-to-end: B stripes with different erasure patterns decode in one
    batched call via per-stripe repair matrices."""
    from repro.coding import rs

    n_code, k = 9, 6
    q = 1024
    code = rs.make_rs(n_code, k)
    rng = np.random.default_rng(42)
    patterns = [(0,), (3,), (5,)]  # a different lost block per stripe
    coefs, survivors, want = [], [], []
    for i, missing in enumerate(patterns):
        data = rng.integers(0, 256, size=(k, q), dtype=np.uint8)
        cw = np.asarray(code.encode(jnp.asarray(data)))
        avail = np.asarray([c for c in range(n_code) if c not in missing])
        row_ids, cf = code.repair_matrix(avail, np.asarray(missing))
        coefs.append(cf)
        survivors.append(cw[row_ids])
        want.append(cw[list(missing)])
    got = np.asarray(
        ops.gf256_matmul_batched(
            np.stack(coefs), jnp.asarray(np.stack(survivors)), interpret=True
        )
    )
    np.testing.assert_array_equal(got, np.stack(want))


def test_batched_rejects_mismatched_shapes():
    coefs = np.zeros((2, 1, 3), dtype=np.uint8)
    data = jnp.zeros((3, 3, 128), dtype=jnp.uint8)  # B mismatch
    with pytest.raises(AssertionError):
        ops.gf256_matmul_batched(coefs, data, interpret=True)


def test_batched_gf256_used_by_vertical_equivalence():
    """XOR == GF(256) matmul with all-ones coefficients — the identity the
    coalescer's V fast path relies on."""
    rng = np.random.default_rng(0)
    b, t, n = 3, 4, 512
    data = rng.integers(0, 256, size=(b, t, n), dtype=np.uint8)
    ones = np.ones((b, 1, t), dtype=np.uint8)
    via_gf = np.asarray(ops.gf256_matmul_batched(ones, jnp.asarray(data), interpret=True))
    via_xor = np.asarray(ops.xor_parity_batched(jnp.asarray(data), interpret=True))
    np.testing.assert_array_equal(via_gf[:, 0], via_xor)
    np.testing.assert_array_equal(via_xor, np.bitwise_xor.reduce(data, axis=1))
    # sanity vs the scalar gf256 helper
    assert gf256.mul_scalar_np(1, 7) == 7
