"""Ragged decode megakernel (kernels/ragged_decode.py + the coalescer's
``mode="ragged"`` dataplane): kernel-level correctness against the jnp
oracles, the byte-identity property against the bucketed baseline over
randomized mixed-shape windows (H+V, ragged lengths, top-rung-overflow
batch sizes), the O(1)-per-kind jit-signature bound, and the LaunchUnit
accounting contract the gateway's engine dispatch relies on."""

from collections import defaultdict

import jax.numpy as jnp
import numpy as np
import pytest

from repro.gateway.coalescer import (
    PAD_LADDER,
    BUCKETED,
    RAGGED,
    DecodeCoalescer,
)
from repro.gateway.planner import DecodeOp
from repro.kernels import ops, ref
from repro.kernels.gf256_matmul import expand_coeff_bitplanes
from repro.kernels.ragged_decode import CHUNK_BIG, CHUNK_SMALL, chunk_sizes


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [False, True])
def test_ragged_gf256_matches_reference_per_tile(packed):
    """Each tile applies ITS OWN coefficient row: C tiles with C distinct
    rows must match C independent reference products."""
    rng = np.random.default_rng(7 + packed)
    c, kk, tn = 8, 6, 256
    coef_rows = rng.integers(0, 256, (c, kk), dtype=np.uint8)
    mc = np.stack(
        [expand_coeff_bitplanes(coef_rows[i][None, :])[0] for i in range(c)]
    )
    data = rng.integers(0, 256, (c, kk, tn), dtype=np.uint8)
    out = np.asarray(
        ops.gf256_ragged(mc, jnp.asarray(data), interpret=True, packed=packed)
    )
    for i in range(c):
        want = np.asarray(
            ref.gf256_matmul(jnp.asarray(coef_rows[i][None, :]), jnp.asarray(data[i]))
        )[0]
        np.testing.assert_array_equal(out[i], want)


def test_ragged_xor_matches_reduce():
    rng = np.random.default_rng(11)
    data = rng.integers(0, 256, (12, 5, 512), dtype=np.uint8)
    out = np.asarray(ops.xor_ragged(jnp.asarray(data), interpret=True))
    np.testing.assert_array_equal(out, np.bitwise_xor.reduce(data, axis=1))


def test_ragged_zero_padding_is_identity():
    """Zero K rows and zero tail bytes contribute nothing — the staging
    contract the coalescer's gather relies on instead of masking."""
    rng = np.random.default_rng(13)
    c, kk, tn = 4, 6, 128
    coef_rows = rng.integers(0, 256, (c, 3), dtype=np.uint8)  # 3 live rows
    mc = np.zeros((c, kk, 8), dtype=np.uint8)
    for i in range(c):
        mc[i, :3] = expand_coeff_bitplanes(coef_rows[i][None, :])[0]
    data = np.zeros((c, kk, tn), dtype=np.uint8)
    live = rng.integers(0, 256, (c, 3, 100), dtype=np.uint8)  # ragged tail
    data[:, :3, :100] = live
    out = np.asarray(ops.gf256_ragged(mc, jnp.asarray(data), interpret=True))
    for i in range(c):
        want = np.asarray(
            ref.gf256_matmul(jnp.asarray(coef_rows[i][None, :]), jnp.asarray(live[i]))
        )[0]
        np.testing.assert_array_equal(out[i, :100], want)
        assert not out[i, 100:].any()  # zero tail stays zero


def test_chunk_sizes_two_rungs_bound_padding():
    for t in (1, 3, CHUNK_SMALL, CHUNK_SMALL + 1, CHUNK_BIG - 1, CHUNK_BIG,
              CHUNK_BIG + 1, 3 * CHUNK_BIG + 5, 517):
        chunks = chunk_sizes(t)
        assert set(chunks) <= {CHUNK_SMALL, CHUNK_BIG}
        total = sum(chunks)
        assert 0 <= total - t < CHUNK_SMALL  # padding < one small chunk
        # big chunks first, so signatures and padding are deterministic
        assert chunks == sorted(chunks, reverse=True)


# ---------------------------------------------------------------------------
# coalescer property: ragged vs bucketed vs reference, randomized windows
# ---------------------------------------------------------------------------

def _random_window(rng, n_ops, lengths=(100, 512, 1000, 4096)):
    """Synthetic mixed-shape window: V ops (t sources), H ops with 1-3
    targets over k sources, ragged per-op byte lengths."""
    ops_, store = [], {}
    for i in range(n_ops):
        kind = ["V", "H"][int(rng.integers(0, 2))]
        length = int(rng.choice(lengths))
        if kind == "V":
            kk = int(rng.choice([3, 5]))
            sources = tuple((f"g{i}", r, 0) for r in range(kk))
            op = DecodeOp("V", f"g{i}", kk, (0,), sources, None)
        else:
            kk = 6
            m = int(rng.integers(1, 4))
            sources = tuple((f"g{i}", 0, c) for c in range(kk))
            coeffs = rng.integers(0, 256, (m, kk), dtype=np.uint8)
            op = DecodeOp("H", f"g{i}", 0, tuple(range(m)), sources, coeffs)
        for s in sources:
            store[s] = rng.integers(0, 256, length, dtype=np.uint8)
        ops_.append(op)
    return ops_, store


def _reference(op, store):
    srcs = np.stack([store[s] for s in op.sources])
    if op.kind == "V":
        return {op.targets[0]: np.bitwise_xor.reduce(srcs, axis=0)}
    out = np.asarray(
        ref.gf256_matmul(jnp.asarray(op.coeffs), jnp.asarray(srcs))
    )
    return {col: out[m] for m, col in enumerate(op.targets)}


@pytest.mark.parametrize("seed", range(5))
def test_ragged_matches_bucketed_and_reference_on_mixed_windows(seed):
    """The megakernel changes HOW a window decodes, never WHAT: over
    randomized mixed-shape windows the ragged path must be byte-identical
    to the bucketed baseline and to the jnp oracle, with zero filler
    stripes (padded_ops) by construction."""
    rng = np.random.default_rng(seed)
    window, store = _random_window(rng, n_ops=int(rng.integers(1, 16)))
    fetch = lambda key: store[key]
    rag = DecodeCoalescer(interpret=True, mode=RAGGED)
    buck = DecodeCoalescer(interpret=True, mode=BUCKETED)
    res_r, units_r = rag.execute(window, fetch)
    res_b, _units_b = buck.execute(window, fetch)
    assert len(res_r) == len(res_b) == len(window)
    for op, a, b in zip(window, res_r, res_b):
        want = _reference(op, store)
        assert set(a) == set(b) == set(want)
        for col in want:
            np.testing.assert_array_equal(a[col], b[col])
            np.testing.assert_array_equal(a[col], want[col])
    assert rag.stats.padded_ops == 0
    assert rag.stats.decode_ops == buck.stats.decode_ops == len(window)
    # unit fractions of each physical launch sum to 1 (modeled-cost
    # billing depends on it), and every op got at least one unit
    frac = defaultdict(float)
    owned = set()
    for u in units_r:
        frac[u.launch_id] += u.fraction
        owned.update(u.op_indices)
    assert all(abs(v - 1.0) < 1e-9 for v in frac.values())
    assert owned == set(range(len(window)))


def test_ragged_top_rung_overflow_window():
    """A window far beyond the bucketed top rung (PAD_LADDER[-1]) — the
    bucketed path splits into top-rung chunks, the ragged path into
    big/small tile chunks; bytes must agree either way."""
    rng = np.random.default_rng(99)
    n_ops = PAD_LADDER[-1] + 10
    ops_, store = [], {}
    for i in range(n_ops):
        sources = tuple((f"g{i}", r, 0) for r in range(3))
        for s in sources:
            store[s] = rng.integers(0, 256, 64, dtype=np.uint8)
        ops_.append(DecodeOp("V", f"g{i}", 3, (0,), sources, None))
    fetch = lambda key: store[key]
    rag = DecodeCoalescer(interpret=True, mode=RAGGED)
    buck = DecodeCoalescer(interpret=True, mode=BUCKETED)
    res_r, _ = rag.execute(ops_, fetch)
    res_b, _ = buck.execute(ops_, fetch)
    for a, b in zip(res_r, res_b):
        np.testing.assert_array_equal(a[0], b[0])
    assert buck.stats.decode_calls == 2  # 256 + 10-padded-to-16
    # ragged: 266 tiles -> 8 big + 3 small chunks, all one signature set
    assert rag.stats.decode_calls == len(chunk_sizes(n_ops))
    assert rag.stats.max_batch >= CHUNK_BIG


def test_ragged_multi_tile_rows_roundtrip():
    """Rows longer than the tile width span several tiles; the scatter
    must reassemble them exactly (including a ragged tail tile)."""
    rng = np.random.default_rng(5)
    length = 10_000  # > 2 tiles at the minimum 128-wide tile, ragged tail
    sources = tuple(("g0", r, 0) for r in range(3))
    store = {s: rng.integers(0, 256, length, dtype=np.uint8) for s in sources}
    op = DecodeOp("V", "g0", 3, (0,), sources, None)
    co = DecodeCoalescer(interpret=True, mode=RAGGED, autotune_kernels=False)
    res, _units = co.execute([op], lambda k: store[k])
    want = np.bitwise_xor.reduce(np.stack([store[s] for s in sources]), axis=0)
    np.testing.assert_array_equal(res[0][0], want)


# ---------------------------------------------------------------------------
# jit-signature bound
# ---------------------------------------------------------------------------

def test_ragged_jit_entries_bounded_at_two_per_kind():
    """Arbitrary traffic — window sizes from 1 op to far beyond the big
    chunk, every (M, K) mix, multiple windows — must settle at <= 2
    traced signatures per kind (the two chunk rungs). This is the
    megakernel's core promise: shape diversity costs zero retraces."""
    rng = np.random.default_rng(3)
    co = DecodeCoalescer(interpret=True, mode=RAGGED)
    for n_ops in (1, 3, 9, 40, 130):
        window, store = _random_window(
            rng, n_ops, lengths=(512, 1000, 4096)
        )
        co.execute(window, lambda key: store[key])
    by_kind = co.jit_entries_by_kind()
    assert by_kind, "no launches traced"
    assert all(n <= 2 for n in by_kind.values()), by_kind
    assert co.stats.jit_entries <= 2 * len(by_kind)
    assert co.stats.decode_calls > 10  # plenty of launches, few traces


def test_gateway_ragged_jit_entries_bounded_end_to_end():
    """Through the full gateway (default coalesce="ragged"): a degraded
    500-request run with organically varying window sizes stays within
    2 signatures per kind."""
    from repro.core.product_code import CoreCode
    from repro.gateway import (
        GatewayConfig,
        ObjectGateway,
        WorkloadConfig,
        generate_requests,
    )
    from repro.gateway.workload import FailureEvent
    from repro.storage.netmodel import ClusterProfile

    code = CoreCode(9, 6, 3)
    gw = ObjectGateway(
        code, ClusterProfile.network_critical(), 60,
        GatewayConfig(batch_window=0.01),
    )
    rng = np.random.default_rng(9)
    gw.load_objects(rng.integers(0, 256, (12, code.k, 512), dtype=np.uint8))
    victim = gw.store.node_of(("g0", 0, 0))
    reqs = generate_requests(
        WorkloadConfig(num_objects=12, num_requests=500, arrival_rate=4000.0,
                       seed=13)
    )
    report = gw.serve(reqs, [FailureEvent(time=0.005, node=victim)])
    assert len(report.completed) == 500
    by_kind = gw.coalescer.jit_entries_by_kind()
    assert by_kind and all(n <= 2 for n in by_kind.values()), by_kind
    assert report.decode_launches == gw.coalescer.stats.decode_calls
    assert report.launches_per_window > 0


# ---------------------------------------------------------------------------
# stats contract
# ---------------------------------------------------------------------------

def test_batch_histogram_is_bounded_and_consistent():
    """The per-launch batch-size list was unbounded (one int per launch
    forever); the histogram keys by batch size, so a long run's memory
    stays O(distinct sizes) while max_batch / coalescing_ratio hold."""
    rng = np.random.default_rng(21)
    co = DecodeCoalescer(interpret=True, mode=RAGGED)
    for _ in range(6):
        window, store = _random_window(rng, 6, lengths=(256,))
        co.execute(window, lambda key: store[key])
    st = co.stats
    assert sum(st.batch_hist.values()) == st.decode_calls
    assert max(st.batch_hist) == st.max_batch
    assert all(
        isinstance(k, int) and v > 0 for k, v in st.batch_hist.items()
    )
    assert st.coalescing_ratio == st.decode_ops / st.decode_calls
    assert 0.0 <= st.padded_byte_ratio < 1.0
    assert st.windows == 6
    assert st.launches_per_window == st.decode_calls / 6


def test_gateway_ragged_and_bucketed_serve_identical_bytes():
    """End to end through the gateway: coalesce="ragged" vs "bucketed"
    changes WHEN decodes are billed, never WHAT is served — per-request
    payload digests must match on a degraded trace."""
    from repro.core.product_code import CoreCode
    from repro.gateway import (
        GatewayConfig,
        ObjectGateway,
        WorkloadConfig,
        generate_requests,
    )
    from repro.gateway.workload import FailureEvent
    from repro.storage.netmodel import ClusterProfile

    code = CoreCode(9, 6, 3)
    reports = {}
    for coalesce in ("ragged", "bucketed"):
        gw = ObjectGateway(
            code, ClusterProfile.network_critical(), 60,
            GatewayConfig(batch_window=0.01, coalesce=coalesce,
                          record_payloads=True),
        )
        rng = np.random.default_rng(9)
        gw.load_objects(rng.integers(0, 256, (12, code.k, 2048), dtype=np.uint8))
        reqs = generate_requests(
            WorkloadConfig(num_objects=12, num_requests=150,
                           arrival_rate=3000.0, seed=4)
        )
        # fail nodes that provably hold data blocks of live objects
        # (placement is process-stable, so both runs fail the same nodes)
        victims = [gw.store.node_of(("g0", 0, 0)), gw.store.node_of(("g1", 0, 2))]
        failures = [
            FailureEvent(time=0.005 + 0.01 * i, node=n)
            for i, n in enumerate(victims)
        ]
        reports[coalesce] = gw.serve(reqs, failures)
    rag, buck = reports["ragged"].records, reports["bucketed"].records
    assert len(rag) == len(buck) == 150
    for a, b in zip(rag, buck):
        assert (a.time, a.object_id, a.kind, a.degraded) == (
            b.time, b.object_id, b.kind, b.degraded,
        )
        assert a.payload_digest == b.payload_digest
    assert any(r.degraded for r in rag)


def test_gateway_rejects_unknown_coalesce_mode():
    from repro.core.product_code import CoreCode
    from repro.gateway import GatewayConfig, ObjectGateway
    from repro.storage.netmodel import ClusterProfile

    with pytest.raises(ValueError):
        ObjectGateway(
            CoreCode(9, 6, 3), ClusterProfile.network_critical(), 60,
            GatewayConfig(coalesce="mega"),
        )
    with pytest.raises(ValueError):
        DecodeCoalescer(mode="mega")
