"""Shared pytest configuration.

Optional-dependency guard: some test extras cannot always be installed
(no network in some environments), so modules that use them are skipped
at collection time instead of erroring the whole run. The scan is
content-based, keyed on the table below, so new test modules using an
optional dependency are covered automatically. The same guard style
protects the CI benchmark smoke: benchmarks/run.py applies it for the
accelerator backend (falling back to the Pallas interpreter sweep when
no TPU/GPU is attached) rather than for Python packages.
"""

from __future__ import annotations

import importlib.util
import pathlib

# package name -> import markers that identify a module using it
OPTIONAL_DEPS = {
    "hypothesis": ("import hypothesis", "from hypothesis"),
}

collect_ignore: list[str] = []

_here = pathlib.Path(__file__).parent
for _pkg, _markers in OPTIONAL_DEPS.items():
    if importlib.util.find_spec(_pkg) is not None:
        continue
    for _path in sorted(_here.glob("test_*.py")):
        text = _path.read_text(encoding="utf-8", errors="ignore")
        if any(m in text for m in _markers):
            collect_ignore.append(_path.name)
