"""Shared pytest configuration.

``hypothesis`` is an optional test dependency (no network in some
environments, so it cannot always be installed). Modules that use it are
skipped at collection time instead of erroring the whole collection run.
The scan is content-based so new hypothesis-using test modules are
covered automatically.
"""

from __future__ import annotations

import importlib.util
import pathlib

collect_ignore: list[str] = []

if importlib.util.find_spec("hypothesis") is None:
    _here = pathlib.Path(__file__).parent
    for _path in sorted(_here.glob("test_*.py")):
        text = _path.read_text(encoding="utf-8", errors="ignore")
        if "import hypothesis" in text or "from hypothesis" in text:
            collect_ignore.append(_path.name)
