"""Shared pytest configuration.

Optional-dependency guard: some test extras cannot always be installed
(no network in some environments), so modules that use them are skipped
at collection time instead of erroring the whole run. The scan is
content-based, keyed on the table below, so new test modules using an
optional dependency are covered automatically. The same guard style
protects the CI benchmark smoke: benchmarks/run.py applies it for the
accelerator backend (falling back to the Pallas interpreter sweep when
no TPU/GPU is attached) rather than for Python packages.

Autotune-cache isolation: kernels/autotune.py persists sweep winners to
a per-user disk cache by default. A test run must neither read ambient
home-directory state (a stale winner would silently skip the sweep
paths the tests exercise) nor write to the user's real cache, so the
whole suite is pointed at a throwaway path unless the caller already
pinned one.
"""

from __future__ import annotations

import importlib.util
import os
import pathlib
import tempfile

if "REPRO_AUTOTUNE_CACHE" not in os.environ:
    # module-level reference keeps the directory alive for the whole
    # run; TemporaryDirectory's finalizer removes it at interpreter exit
    _AUTOTUNE_TMP = tempfile.TemporaryDirectory(prefix="repro-autotune-")
    os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
        _AUTOTUNE_TMP.name, "autotune.json"
    )

# package name -> import markers that identify a module using it
OPTIONAL_DEPS = {
    "hypothesis": ("import hypothesis", "from hypothesis"),
}

collect_ignore: list[str] = []

_here = pathlib.Path(__file__).parent
for _pkg, _markers in OPTIONAL_DEPS.items():
    if importlib.util.find_spec(_pkg) is not None:
        continue
    for _path in sorted(_here.glob("test_*.py")):
        text = _path.read_text(encoding="utf-8", errors="ignore")
        if any(m in text for m in _markers):
            collect_ignore.append(_path.name)
