"""Fused selective-scan Pallas kernel vs the sequential oracle
(shape/chunk sweep, interpret mode)."""

from __future__ import annotations

import importlib

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

ssk = importlib.import_module("repro.kernels.selective_scan")


def oracle(da, dbu, cm):
    b, s, d, n = da.shape
    h = np.zeros((b, d, n), np.float32)
    ys = []
    for t in range(s):
        h = np.asarray(da[:, t]) * h + np.asarray(dbu[:, t])
        ys.append(np.einsum("bdn,bn->bd", h, np.asarray(cm[:, t])))
    return np.stack(ys, axis=1)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([8, 16]),
    n=st.sampled_from([4, 16]),
    bs=st.sampled_from([4, 8, 32]),
    bd=st.sampled_from([8, 16]),
    seed=st.integers(0, 3),
)
def test_kernel_matches_oracle(s, d, n, bs, bd, seed):
    if s % bs or d % bd:
        return
    rng = np.random.default_rng(seed)
    da = jnp.asarray(rng.uniform(0.6, 0.999, (2, s, d, n)).astype(np.float32))
    dbu = jnp.asarray(rng.standard_normal((2, s, d, n)).astype(np.float32))
    cm = jnp.asarray(rng.standard_normal((2, s, n)).astype(np.float32))
    got = np.asarray(ssk.selective_scan(da, dbu, cm, bs=bs, bd=bd))
    want = oracle(da, dbu, cm)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_hbm_traffic_is_roofline_floor():
    """The kernel's HBM bytes = inputs + output exactly (the fused win
    over associative_scan's log2(S) state materializations)."""
    b, s, d, n = 1, 64, 16, 8
    in_bytes = 2 * b * s * d * n * 4 + b * s * n * 4
    out_bytes = b * s * d * 4
    # structural statement (no TPU here): block specs tile exactly these
    # arrays once; scratch h never leaves VMEM.
    assert in_bytes + out_bytes == 2 * 32768 + 2048 + 4096
