"""Gray-failure hardening tests: the corruption-as-erasure integrity
plane (per-block digests, read/scrub/write/repair detection, tombstone
+ quarantine + repair), fail-slow injection through the fabric model,
hedged degraded reads, and the within-tolerance property that silent
corruption plus fail-slow never serves a wrong byte.

The property test uses hypothesis when installed and a seeded
parametrize fallback otherwise (same idiom as tests/test_scenario.py).
"""

from __future__ import annotations

import importlib
import importlib.util

import numpy as np
import pytest

from repro.core.product_code import CoreCode, CoreCodec
from repro.gateway import (
    CorruptionEvent,
    GatewayConfig,
    ObjectGateway,
    SlowNicEvent,
    SlowNodeEvent,
    WorkloadConfig,
)
from repro.gateway.planner import DegradedReadPlanner
from repro.gateway.workload import Request
from repro.scenario import (
    ScenarioConfig,
    ScenarioTrace,
    deterministic_fingerprint,
    flapping_slow,
    generate_scenario,
    run_scenario,
    trace_from_jsonable,
)
from repro.storage.blockstore import BlockStore
from repro.storage.netmodel import ClusterProfile, NetSimulator, Transfer
from repro.storage.repair import Scrubber

_HYP = importlib.util.find_spec("hypothesis") is not None


def make_group(code, store, group_id="g0", q=1024, seed=0):
    rng = np.random.default_rng(seed)
    objects = rng.integers(0, 256, size=(code.t, code.k, q), dtype=np.uint8)
    store.put_group(group_id, np.asarray(CoreCodec(code).encode(objects)))
    return objects


def _gateway(code, num_nodes=60, q=2048, num_objects=12, seed=9, **cfg_kw):
    gw = ObjectGateway(
        code, ClusterProfile.network_critical(), num_nodes, GatewayConfig(**cfg_kw)
    )
    rng = np.random.default_rng(seed)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))
    return gw


# ---------------------------------------------------------------------------
# block store: digests, corruption modes, quarantine
# ---------------------------------------------------------------------------

def test_put_records_digest_and_verify_passes_when_clean():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=30)
    make_group(code, store)
    assert len(store.checksums) == len(store.blocks)
    for key in list(store.blocks):
        assert store.verify(key)
        assert store.checksum_ok(key, store.get(key)) is True


def test_corrupt_block_modes_break_verify_but_not_checksum():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=30)
    make_group(code, store)
    for mode, key in (("bitflip", ("g0", 0, 0)), ("torn", ("g0", 0, 1))):
        digest_before = store.checksums[key]
        assert store.corrupt_block(key, mode=mode)
        # silent damage: the stored digest stays STALE (that is the
        # fault model), so verify now fails
        assert store.checksums[key] == digest_before
        assert not store.verify(key)
        assert store.checksum_ok(key, store.get(key)) is False
    # erase is a hard loss, not silent damage
    assert store.corrupt_block(("g0", 0, 2), mode="erase")
    assert not store.available(("g0", 0, 2))
    # corrupting an absent block is a no-op
    assert not store.corrupt_block(("g0", 0, 2), mode="bitflip")


def test_corrupt_block_writes_a_new_array_not_in_place():
    """Cached copies handed out before the corruption event must stay
    clean — the event replaces the stored array, it does not mutate the
    one previous readers hold."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=30)
    make_group(code, store)
    key = ("g0", 1, 3)
    held = store.get(key)
    snapshot = held.copy()
    assert store.corrupt_block(key, mode="bitflip")
    np.testing.assert_array_equal(held, snapshot)
    assert not np.array_equal(store.get(key), snapshot)


def test_quarantine_keeps_placement_and_digest_drop_block_delegates():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=30)
    make_group(code, store)
    key = ("g0", 2, 4)
    node = store.node_of(key)
    store.quarantine(key)
    assert not store.available(key)
    # placement + trusted digest survive: repair can verify its rebuild
    assert store.node_of(key) == node
    assert key in store.checksums
    # the legacy test hook is now a thin wrapper over the erase path
    other = ("g0", 2, 5)
    store.drop_block(other)
    assert not store.available(other)


def test_scrubber_walks_the_store_and_reports_mismatches():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=30)
    make_group(code, store)
    bad_key = ("g0", 0, 4)
    store.corrupt_block(bad_key, mode="torn")
    scrubber = Scrubber(store, blocks_per_run=8)
    found = []
    for _ in range(len(store.blocks) // 8 + 2):  # full cursor lap
        found.extend(scrubber.scan(8))
    assert bad_key in found


# ---------------------------------------------------------------------------
# fabric model: fail-slow rates
# ---------------------------------------------------------------------------

def test_set_node_rate_validation_and_restore():
    sim = NetSimulator(ClusterProfile.network_critical())
    with pytest.raises(ValueError):
        sim.set_node_rate(3, 0.0)
    with pytest.raises(ValueError):
        sim.set_node_rate(3, 1.5)
    with pytest.raises(ValueError):
        sim.set_node_rate(3, 0.5, direction="up")
    sim.set_node_rate(3, 0.25, direction="send")
    assert sim.node_rate(3, "send") == 0.25
    assert sim.node_rate(3, "recv") == 1.0
    sim.set_node_rate(3, 1.0, direction="both")  # restore drops the entry
    assert sim.node_rate(3, "send") == 1.0
    assert not sim._node_rate


def test_slow_sender_stretches_transfer_by_rate_factor():
    prof = ClusterProfile.network_critical()
    sim = NetSimulator(prof)
    nbytes = 1 << 20
    healthy = sim.transfer(Transfer(0, 1, nbytes))
    sim.set_node_rate(2, 0.1)
    slow = sim.transfer(Transfer(2, 3, nbytes))
    assert slow == pytest.approx(healthy * 10, rel=1e-6)


def test_slow_inbound_stream_does_not_block_the_receivers_nic():
    """The gray-failure scheduling invariant: a trickling transfer from
    a fail-slow sender occupies the receiver's port only for the bytes'
    own wire time (tail-anchored), so a later healthy fetch into the
    same receiver lands in the head hole instead of queueing behind the
    slow stream — this is what makes hedging winnable at all."""
    prof = ClusterProfile.network_critical()
    sim = NetSimulator(prof)
    nbytes = 1 << 20
    wire = nbytes / prof.node_bandwidth
    sim.set_node_rate(5, 0.05)
    slow_end = sim.transfer(Transfer(5, 1, nbytes))
    healthy_end = sim.transfer(Transfer(6, 1, nbytes))
    assert slow_end == pytest.approx(20 * wire, rel=1e-6)
    # the healthy transfer completes in its own wire time, not after the
    # slow stream drains
    assert healthy_end < 3 * wire
    assert healthy_end < slow_end / 4


# ---------------------------------------------------------------------------
# planner: hedge alternate paths
# ---------------------------------------------------------------------------

def test_recovery_ops_orders_vertical_then_horizontal():
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=30)
    make_group(code, store)
    planner = DegradedReadPlanner(store, code)
    ops = planner.recovery_ops("g0", 0, 0)
    assert [op.kind for op in ops] == ["V", "H"]
    assert len(ops[0].sources) == code.rows - 1
    assert len(ops[1].sources) == code.k
    assert ops[0].targets == ops[1].targets == (0,)
    assert planner.recovery_op("g0", 0, 0) == ops[0]
    # break the column: only the RS row path remains
    store.drop_block(("g0", 1, 0))
    ops = planner.recovery_ops("g0", 0, 0)
    assert [op.kind for op in ops] == ["H"]
    # starve the row below k survivors: no recovery path at all
    for c in range(1, code.n - code.k + 1):
        store.drop_block(("g0", 0, c))
    assert planner.recovery_ops("g0", 0, 0) == ()
    assert planner.recovery_op("g0", 0, 0) is None


# ---------------------------------------------------------------------------
# end to end: read-path detection, tombstones, repair heal
# ---------------------------------------------------------------------------

def test_read_detects_silent_corruption_and_serves_correct_bytes():
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, batch_window=0.01, cache_bytes=4 * 1024 * 1024,
        repair_on_failure=True, repair_delay=0.02, record_payloads=True,
    )
    gid, row = gw._objects[0]
    bad = (gid, row, 2)
    events = [CorruptionEvent(time=0.005, node=gw.store.node_of(bad),
                              blocks=(bad,), mode="bitflip")]
    reqs = [Request(time=0.01 + 0.02 * i, object_id=0) for i in range(3)]
    report = gw.serve(reqs, events)
    m = report.metrics
    # the first GET trips the digest check mid-fetch, replans degraded,
    # and still completes with the right bytes (serve verifies payloads
    # against ground truth and would raise otherwise)
    assert all(r.latency is not None for r in report.records)
    first = report.records[0]
    assert first.degraded and first.reconstruction_blocks > 0
    assert m.counter_total("corruption_detected", source="read") >= 1
    assert m.counter_total("verified_gets") == 3
    # detection reclassified the corruption as an erasure and repair
    # healed it before the run drained
    assert gw.store.verify(bad)
    assert gw.audit_durability()["missing_blocks"] == 0
    assert report.corruption_latency.count >= 1
    assert all(s >= 0.0 for s in report.corruption_latency)


def test_corrupt_then_repaired_block_sheds_its_tombstone():
    """Satellite: a corrupt block is tombstoned in the negative cache at
    detection; once repair rewrites it the tombstone must be purged so
    later reads go direct again instead of riding the TTL."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, batch_window=0.01, cache_bytes=2 * 2048,  # tiny: the
        # corrupt block cannot hide as a positive cache hit
        repair_on_failure=True, repair_delay=0.02,
    )
    gid, row = gw._objects[0]
    bad = (gid, row, 1)
    events = [CorruptionEvent(time=0.005, node=gw.store.node_of(bad),
                              blocks=(bad,), mode="torn")]
    reqs = [Request(time=0.01, object_id=0)]
    reqs += [Request(time=0.5 + 0.01 * i, object_id=0) for i in range(2)]
    report = gw.serve(reqs, events)
    assert all(r.latency is not None for r in report.records)
    assert report.records[0].degraded
    assert gw.store.verify(bad)
    assert gw.cache.negative_entries == 0
    # the post-heal reads are clean direct reads
    assert not report.records[-1].degraded


def test_scrub_detects_latent_corruption_without_a_read():
    """Blocks nobody fetches still get caught: the background scrubber
    walks stored digests on the simulated clock and feeds the same
    corruption-as-erasure path, giving a bounded MTTD."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, batch_window=0.01, repair_on_failure=True, repair_delay=0.02,
        scrub_interval=0.05, scrub_blocks_per_run=256,
    )
    gid, row = gw._objects[0]
    bad = (gid, row, 3)
    events = [CorruptionEvent(time=0.01, node=gw.store.node_of(bad),
                              blocks=(bad,), mode="bitflip")]
    # the request stream never touches object 0 — only scrub can see it
    reqs = [Request(time=0.02 * (i + 1), object_id=1 + (i % 3)) for i in range(25)]
    report = gw.serve(reqs, events)
    m = report.metrics
    assert m.counter_total("corruption_detected", source="scrub") >= 1
    assert m.counter_total("scrub_blocks") > 0
    assert report.corruption_latency.count >= 1
    mttd = max(report.corruption_latency)
    assert 0.0 <= mttd < 0.5  # bounded by the scan cadence, not the run
    assert gw.store.verify(bad)


def test_slow_events_drive_the_fabric_rate_and_restore():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, batch_window=0.01)
    events = [
        SlowNodeEvent(time=0.0, node=7, rate_factor=0.2),
        SlowNicEvent(time=0.0, node=8, rate_factor=0.5, direction="recv"),
        SlowNodeEvent(time=0.05, node=7, rate_factor=1.0),
    ]
    reqs = [Request(time=0.01, object_id=0), Request(time=0.1, object_id=1)]
    report = gw.serve(reqs, events)
    assert report.metrics.counter_total("slow_events") == 3
    assert gw.sim.node_rate(7, "send") == 1.0  # restored mid-run
    assert gw.sim.node_rate(8, "recv") == 0.5
    assert gw.sim.node_rate(8, "send") == 1.0


# ---------------------------------------------------------------------------
# hedged degraded reads
# ---------------------------------------------------------------------------

def _fail_slow_run(hedge: bool, budget: float = 1.0):
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, batch_window=0.005, decode_cost=0.0005,
        hedge=hedge, hedge_budget=budget,
    )
    slow = gw.store.node_of((gw._objects[0][0], gw._objects[0][1], 0))
    events = [SlowNodeEvent(time=0.0, node=slow, rate_factor=0.05)]
    reqs = [Request(time=0.01 * i, object_id=i % 12) for i in range(120)]
    return gw, gw.serve(reqs, events)


def test_hedged_reads_beat_unhedged_p99_under_fail_slow():
    _, base = _fail_slow_run(hedge=False)
    _, hedged = _fail_slow_run(hedge=True)
    m = hedged.metrics
    assert m.counter_total("hedge_launched") > 0
    assert m.counter_total("hedge_wins") > 0
    assert all(r.latency is not None for r in hedged.records)
    assert hedged.latency_percentile(99) < base.latency_percentile(99)
    # hedge decodes must still produce verified bytes (serve checks
    # payloads against ground truth), and wins reroute the plan
    assert m.counter_total("verified_gets") == len(hedged.records)


def test_hedge_byte_budget_is_a_structural_cap():
    gw, report = _fail_slow_run(hedge=True, budget=0.05)
    m = report.metrics
    hedge_bytes = m.counter_total("hedge_bytes")
    primary_bytes = sum(gw._fetch_bytes.values())
    assert primary_bytes > 0
    # the ledger admits a hedge only while spent + cost fits under
    # budget x primary bytes, so the final ratio cannot exceed it
    assert hedge_bytes <= 0.05 * primary_bytes + 1e-9
    if m.counter_total("hedge_budget_denied"):
        assert hedge_bytes > 0 or m.counter_total("hedge_launched") == 0


def test_tiny_hedge_budget_denies_every_hedge():
    _, report = _fail_slow_run(hedge=True, budget=1e-6)
    m = report.metrics
    assert m.counter_total("hedge_launched") == 0
    assert m.counter_total("hedge_budget_denied") > 0
    assert m.counter_total("hedge_bytes") == 0
    assert all(r.latency is not None for r in report.records)


# ---------------------------------------------------------------------------
# trace schema: gray events round-trip + generator tolerance
# ---------------------------------------------------------------------------

def test_gray_events_roundtrip_through_json():
    trace = ScenarioTrace(
        num_nodes=12, nodes_per_rack=4,
        events=(
            CorruptionEvent(time=0.1, node=3, blocks=(("g0", 0, 1),),
                            mode="torn"),
            SlowNodeEvent(time=0.2, node=5, rate_factor=0.25),
            SlowNicEvent(time=0.3, node=7, rate_factor=0.5, direction="recv"),
            SlowNodeEvent(time=0.4, node=5, rate_factor=1.0),
        ),
    )
    trace = flapping_slow(trace, node=9, start=0.5, period=0.1, count=2,
                          rate_factor=0.1)
    again = trace_from_jsonable(trace.to_jsonable())
    assert again.cluster_events() == trace.cluster_events()
    # block keys survive as tuples (JSON lists must be re-tupled)
    evt = next(e for e in again.events if isinstance(e, CorruptionEvent))
    assert evt.blocks == (("g0", 0, 1),)


def test_generated_gray_traces_are_deterministic_and_bounded():
    cfg = ScenarioConfig(
        duration=1.0, num_nodes=60, nodes_per_rack=3,
        max_concurrent_failures=3, crash_rate=8.0, mean_downtime=0.05,
        corruption_rate=6.0, slow_rate=6.0, mean_slow_time=0.1, seed=4,
    )
    trace = generate_scenario(cfg)
    assert any(isinstance(e, CorruptionEvent) for e in trace.events)
    assert any(isinstance(e, SlowNodeEvent) for e in trace.events)
    assert trace.max_concurrent_down() <= 3
    assert generate_scenario(cfg).cluster_events() == trace.cluster_events()
    again = trace_from_jsonable(trace.to_jsonable())
    assert again.cluster_events() == trace.cluster_events()


# ---------------------------------------------------------------------------
# property: within-tolerance gray mixes never serve a wrong byte
# ---------------------------------------------------------------------------

def _gray_gateway(code):
    return _gateway(
        code, batch_window=0.01, cache_bytes=4 * 1024 * 1024,
        repair_on_failure=True, repair_delay=0.03, record_payloads=True,
        scrub_interval=0.1, decode_cost=0.002,
    )


def _assert_correct_under_gray_trace(seed: int) -> None:
    """Random crash + corruption + fail-slow mix bounded at n - k
    concurrently-affected nodes: every GET completes and returns the
    same payload digest as a clean run of the identical request stream
    (zero wrong bytes), and the faulty run is replay-deterministic."""
    code = CoreCode(9, 6, 3)
    cfg = ScenarioConfig(
        duration=0.5, num_nodes=60, nodes_per_rack=3,
        max_concurrent_failures=code.n - code.k, crash_rate=6.0,
        mean_downtime=0.08, transient_fraction=0.5,
        corruption_rate=8.0, corruption_blocks=2,
        slow_rate=6.0, slow_factor=0.2, mean_slow_time=0.1,
        seed=seed,
    )
    trace = generate_scenario(cfg)
    wl = WorkloadConfig(
        num_objects=12, num_requests=100, arrival_rate=300.0, seed=seed
    )
    faulty = run_scenario(_gray_gateway(code), trace, wl)
    clean = run_scenario(
        _gray_gateway(code),
        ScenarioTrace(num_nodes=60, nodes_per_rack=3),
        wl,
    )
    assert all(r.latency is not None for r in faulty.report.records)
    assert faulty.blocks_lost == 0
    assert faulty.durability["unreadable_objects"] == 0
    got = [(r.object_id, r.payload_digest) for r in faulty.report.records
           if r.kind == "get"]
    want = [(r.object_id, r.payload_digest) for r in clean.report.records
            if r.kind == "get"]
    assert got == want
    # discrete outcomes (digests included) replay bit-for-bit
    replay = run_scenario(_gray_gateway(code), trace, wl)
    assert deterministic_fingerprint(replay) == deterministic_fingerprint(faulty)


if _HYP:
    _hyp = importlib.import_module("hypothesis")
    _st = importlib.import_module("hypothesis.strategies")

    @_hyp.settings(max_examples=4, deadline=None)
    @_hyp.given(seed=_st.integers(min_value=0, max_value=2**16))
    def test_gray_property_within_tolerance(seed):
        _assert_correct_under_gray_trace(seed)
else:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_gray_property_within_tolerance(seed):
        _assert_correct_under_gray_trace(seed)
