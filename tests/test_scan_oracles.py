"""Numerics oracles for the chunked/scanned compute paths: each
optimized formulation must match its naive reference (hypothesis sweeps
shapes; these are the model-side analogues of the kernel allclose
tests)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import layers as L
from repro.models import mamba, rglru
from repro.models.shardings import SINGLE


def naive_causal_attention(q, k, v, window=None):
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bthd->bhqt", q, k).astype(jnp.float32) / math.sqrt(d)
    pos = np.arange(s)
    mask = pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    scores = jnp.where(jnp.asarray(mask)[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqt,bthd->bqhd", w, v).reshape(b, s, h * d)


@settings(max_examples=12, deadline=None)
@given(
    s=st.sampled_from([8, 24, 64]),
    chunk=st.sampled_from([4, 8, 16, 64]),
    window=st.sampled_from([None, 4, 16]),
)
def test_chunked_attention_matches_naive(s, chunk, window):
    cfg = get_config("qwen2_72b").reduced(
        num_layers=1, attn_chunk=chunk, sliding_window=window
    )
    rng = jax.random.PRNGKey(0)
    q, k, v = (
        jax.random.normal(jax.random.fold_in(rng, i), (2, s, 4, 16), jnp.float32)
        for i in range(3)
    )
    got = L.attention_core_train(q, k, v, cfg, SINGLE)
    want = naive_causal_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def _naive_selective_scan(da, dbu, cm):
    # da/dbu: (B,S,di,N) f32; cm: (B,S,N)
    b, s, di, n = da.shape
    h = np.zeros((b, di, n), np.float32)
    ys = []
    for t in range(s):
        h = np.asarray(da[:, t]) * h + np.asarray(dbu[:, t])
        ys.append(np.einsum("bdn,bn->bd", h, np.asarray(cm[:, t])))
    return np.stack(ys, axis=1)


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([6, 16, 32]), chunk=st.sampled_from([4, 8, 32]))
def test_mamba_chunked_scan_matches_sequential(s, chunk):
    b, di, n = 2, 8, 4
    rng = np.random.default_rng(0)
    da = jnp.asarray(rng.uniform(0.7, 0.99, (b, s, di, n)).astype(np.float32))
    dbu = jnp.asarray(rng.standard_normal((b, s, di, n)).astype(np.float32))
    cm = jnp.asarray(rng.standard_normal((b, s, n)).astype(np.float32))

    # chunked path (mirrors mamba_mix's inner loop)
    h0 = jnp.zeros((b, di, n), jnp.float32)
    nch = s // chunk if s % chunk == 0 else 1
    chunk_eff = s // nch
    ys = []
    h = h0
    for i in range(nch):
        sl = slice(i * chunk_eff, (i + 1) * chunk_eff)
        h_all, h = mamba._chunk_scan(da[:, sl], dbu[:, sl], h)
        ys.append(jnp.einsum("bcdn,bcn->bcd", h_all, cm[:, sl]))
    got = jnp.concatenate(ys, axis=1)
    want = _naive_selective_scan(da, dbu, cm)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([5, 12, 33]), chunk=st.sampled_from([4, 16]))
def test_rglru_scan_matches_stepwise(s, chunk):
    cfg = get_config("recurrentgemma_9b").reduced(scan_chunk=chunk)
    p = rglru.init_rglru(jax.random.PRNGKey(3), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, s, cfg.lru_width), jnp.float32)

    ys, h_last = rglru.rglru_scan(x, p, cfg)
    # stepwise reference
    h = jnp.zeros((2, cfg.lru_width), jnp.float32)
    outs = []
    for t in range(s):
        y1, h = rglru.rglru_step(x[:, t : t + 1], p, cfg, h)
        outs.append(y1)
    want = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_grouped_decode_attend_matches_expanded():
    """_grouped_attend (GQA-native) == expand_kv + dense softmax."""
    cfg = get_config("mistral_large_123b").reduced(num_heads=8, num_kv_heads=2,
                                                   sliding_window=None)
    rng = jax.random.PRNGKey(0)
    b, smax, hd = 2, 16, cfg.head_dim
    q = jax.random.normal(rng, (b, 1, 8, hd), jnp.float32)
    ck = jax.random.normal(jax.random.fold_in(rng, 1), (b, smax, 2, hd), jnp.float32)
    cv = jax.random.normal(jax.random.fold_in(rng, 2), (b, smax, 2, hd), jnp.float32)
    valid = jnp.arange(smax) <= 9
    o, m, l = L._grouped_attend(q, ck, cv, cfg, valid)
    got = (o / l[..., None]).transpose(0, 3, 1, 2, 4).reshape(b, 1, 8 * hd)

    ke, ve = L.expand_kv(ck, cfg), L.expand_kv(cv, cfg)
    scores = jnp.einsum("bqhd,bthd->bhqt", q, ke).astype(jnp.float32) / math.sqrt(hd)
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    want = jnp.einsum("bhqt,bthd->bqhd", w, ve).reshape(b, 1, 8 * hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_ring_valid_semantics():
    # unwrapped cache: positions 0..pos valid
    v = L._ring_valid(jnp.asarray(5), 16, None)
    assert np.asarray(v).tolist() == [True] * 6 + [False] * 10
    # wrapped window cache (smax=4, pos=9): slots hold abs pos {8,9,6,7}
    v = L._ring_valid(jnp.asarray(9), 4, None)
    assert np.asarray(v).all()
    v = L._ring_valid(jnp.asarray(9), 4, 2)  # window 2: only abs 8,9 valid
    assert np.asarray(v).tolist() == [True, True, False, False]
