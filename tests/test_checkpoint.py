"""CORE checkpoint tests: save/restore equality, degraded restore under
node failures, background repair, restart semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CoreCheckpointer
from repro.core import CoreCode
from repro.storage import BlockStore, ClusterProfile


def make_state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w1": rng.normal(size=(64, 128)).astype(np.float32),
            "b1": rng.normal(size=(128,)).astype(np.float32),
            "embed": jnp.asarray(rng.normal(size=(1000, 64)), dtype=jnp.bfloat16),
        },
        "opt": {
            "mu": rng.normal(size=(64, 128)).astype(np.float32),
            "nu": rng.normal(size=(64, 128)).astype(np.float32),
        },
        "step": np.asarray(123, dtype=np.int64),
    }


def trees_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def make_ckpt(num_nodes=200, code=CoreCode(9, 6, 3), block_size=1 << 12):
    store = BlockStore(num_nodes=num_nodes)
    return store, CoreCheckpointer(
        store, code, ClusterProfile.network_critical(), block_size=block_size
    )


def test_save_restore_roundtrip():
    store, ckpt = make_ckpt()
    state = make_state()
    man = ckpt.save(100, state)
    assert man.group_ids
    restored, rep = ckpt.restore(100)
    trees_equal(state, restored)
    assert rep.compute_time >= 0.0


def test_degraded_restore_single_node_failure():
    store, ckpt = make_ckpt()
    state = make_state(1)
    ckpt.save(7, state)
    victim = store.node_of((ckpt.manifests[7].group_ids[0], 0, 2))
    store.fail_nodes([victim])
    restored, rep = ckpt.restore(7)
    trees_equal(state, restored)
    assert rep.blocks_fetched > 0


def test_degraded_restore_multi_failure_same_group():
    store, ckpt = make_ckpt()
    state = make_state(2)
    ckpt.save(8, state)
    gid = ckpt.manifests[8].group_ids[0]
    # fail three blocks: two in one row (row decode) + one elsewhere (vertical)
    victims = [store.node_of((gid, 0, 1)), store.node_of((gid, 0, 4)),
               store.node_of((gid, 2, 7))]
    store.fail_nodes(victims)
    restored, _ = ckpt.restore(8)
    trees_equal(state, restored)


def test_background_repair_replenishes_blocks():
    store, ckpt = make_ckpt()
    state = make_state(3)
    ckpt.save(9, state)
    gid = ckpt.manifests[9].group_ids[0]
    victims = [store.node_of((gid, 1, 0)), store.node_of((gid, 3, 5))]
    store.fail_nodes(victims)
    rep = ckpt.repair(9)
    assert rep.recovered and rep.blocks_repaired >= 2
    # all blocks available again on alive nodes
    fm = store.failure_matrix(gid, ckpt.code.rows, ckpt.code.n)
    assert not fm.any()
    restored, rd = ckpt.restore(9)
    trees_equal(state, restored)
    # post-repair restore is clean: systematic reads only
    k, t = ckpt.code.k, ckpt.code.t
    groups = len(ckpt.manifests[9].group_ids)
    assert rd.blocks_fetched == groups * t * k


def test_restore_beyond_rs_tolerance_via_vertical():
    """Lose m+1 blocks of one object row — impossible for plain RS(n,k),
    recovered through cross-object parity."""
    store, ckpt = make_ckpt()
    state = make_state(4)
    ckpt.save(10, state)
    gid = ckpt.manifests[10].group_ids[0]
    m = ckpt.code.m
    victims = [store.node_of((gid, 0, c)) for c in range(m + 1)]
    store.fail_nodes(victims)
    restored, _ = ckpt.restore(10)
    trees_equal(state, restored)


def test_checkpoint_restart_training_semantics():
    """Simulated crash/restart: latest_step + restore gives back the exact
    train state."""
    store, ckpt = make_ckpt()
    s1, s2 = make_state(5), make_state(6)
    ckpt.save(100, s1)
    ckpt.save(200, s2)
    assert ckpt.latest_step() == 200
    restored, _ = ckpt.restore(200)
    trees_equal(s2, restored)


def test_restore_fails_loud_when_unrecoverable():
    from repro.storage import UnrecoverableError

    store, ckpt = make_ckpt()
    state = make_state(7)
    ckpt.save(11, state)
    gid = ckpt.manifests[11].group_ids[0]
    m = ckpt.code.m
    victims = set()
    for r in (0, 1):  # two rows, identical m+1 columns -> irrecoverable
        for c in range(m + 1):
            victims.add(store.node_of((gid, r, c)))
    store.fail_nodes(victims)
    with pytest.raises(UnrecoverableError):
        ckpt.restore(11)
