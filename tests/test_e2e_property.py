"""Full-stack property tests: random (n, k, t) codes x random failure
patterns x every repair mode/scheduler, verified byte-for-byte against
the original data. This is the system-level invariant of the paper:

    recoverable(pattern)  =>  repair(pattern) restores every block.
"""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.product_code import CoreCode, CoreCodec
from repro.core.recoverability import is_recoverable
from repro.storage.blockstore import BlockStore
from repro.storage.netmodel import ClusterProfile
from repro.storage.repair import BlockFixer

CODES = [(9, 6, 3), (14, 12, 5), (6, 4, 2), (8, 6, 4)]


@settings(max_examples=25, deadline=None)
@given(
    code_i=st.integers(0, len(CODES) - 1),
    p=st.sampled_from([0.05, 0.12, 0.25]),
    seed=st.integers(0, 1000),
    mode=st.sampled_from(["core", "hdfs_raid", "hdfs_raid_opt"]),
    scheduler=st.sampled_from(["rgs", "column_first", "row_first"]),
)
def test_random_pattern_repair_roundtrip(code_i, p, seed, mode, scheduler):
    n, k, t = CODES[code_i]
    code = CoreCode(n, k, t)
    rng = np.random.default_rng(seed)
    q = 512
    objects = rng.integers(0, 256, (t, k, q), dtype=np.uint8)
    matrix = np.asarray(CoreCodec(code).encode(objects))

    fm = rng.random((t + 1, n)) < p
    store = BlockStore(num_nodes=max(40, (t + 1) * n))
    store.put_group("g", matrix)
    for r, c in zip(*np.nonzero(fm)):
        store.drop_block(("g", int(r), int(c)))

    fixer = BlockFixer(store, code, ClusterProfile.computation_critical(),
                       mode=mode, scheduler=scheduler)
    rep = fixer.fix_group("g")

    if mode == "core":
        expected_full = is_recoverable(code, fm)
    else:
        # row-RS can only fix <= n-k failures per row, and never the rows
        # that exceed it
        expected_full = bool((fm.sum(axis=1) <= n - k).all())
    assert rep.recovered == expected_full, (fm.astype(int), mode)
    if expected_full:
        for r in range(t + 1):
            for c in range(n):
                assert np.array_equal(store.get(("g", r, c)), matrix[r, c]), (r, c)
    else:
        # partial recovery: whatever was repaired must still be correct
        for r in range(t + 1):
            for c in range(n):
                if store.available(("g", r, c)):
                    assert np.array_equal(store.get(("g", r, c)), matrix[r, c])


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 100),
    n_leaves=st.integers(1, 4),
    kill=st.integers(0, 2),
)
def test_checkpoint_roundtrip_random_trees(seed, n_leaves, kill):
    """Random mixed-dtype pytrees survive CORE save -> node kills ->
    degraded restore bit-exactly."""

    from repro.checkpoint.core_ckpt import CoreCheckpointer

    rng = np.random.default_rng(seed)
    dtypes = [np.float32, np.int32, np.uint8, np.float16]
    tree = {
        f"leaf{i}": rng.standard_normal(
            tuple(rng.integers(1, 40, size=rng.integers(1, 3)))
        ).astype(dtypes[rng.integers(0, len(dtypes))])
        for i in range(n_leaves)
    }
    store = BlockStore(num_nodes=20)
    ckpt = CoreCheckpointer(store, CoreCode(9, 6, 3), block_size=1 << 10)
    ckpt.save(1, tree)
    store.fail_nodes(list(range(kill)))
    restored, rep = ckpt.restore(1)
    for kname in tree:
        got = np.asarray(restored[kname])
        assert got.dtype == tree[kname].dtype
        np.testing.assert_array_equal(got, tree[kname])
