"""Fault-injection scenario engine tests: trace DSL + seeded generators,
property-based durability over random within-tolerance traces, golden-
trace determinism, SLO-aware closed-loop repair pacing, negative/TTL
cache behavior, and the weighted engine pool / pacing controller units.

The durability property uses hypothesis when it is installed and a
seeded parametrize fallback otherwise (the optional import goes through
importlib so this module still collects without the package).
"""

from __future__ import annotations

import importlib
import importlib.util

import numpy as np
import pytest

from repro.core.product_code import CoreCode, CoreCodec
from repro.gateway import (
    EnginePool,
    GatewayConfig,
    LRUBlockCache,
    ObjectGateway,
    WorkloadConfig,
)
from repro.gateway.workload import (
    CapacityLossEvent,
    FailureEvent,
    NodeRecoverEvent,
    Request,
)
from repro.scenario import (
    SURGE_END,
    SURGE_FAIL_AT,
    ScenarioConfig,
    ScenarioTrace,
    correlated_surge_setup,
    deterministic_fingerprint,
    flapping_node,
    generate_scenario,
    load_surge,
    rack_failure,
    run_scenario,
    scenario_requests,
    trace_from_jsonable,
)
from repro.storage.blockstore import BlockStore
from repro.storage.netmodel import ClusterProfile
from repro.storage.repair import PacingController

_HYP = importlib.util.find_spec("hypothesis") is not None


def make_group(code, store, group_id="g0", q=1024, seed=0):
    rng = np.random.default_rng(seed)
    objects = rng.integers(0, 256, size=(code.t, code.k, q), dtype=np.uint8)
    store.put_group(group_id, np.asarray(CoreCodec(code).encode(objects)))
    return objects


def _gateway(code, num_nodes=60, q=2048, num_objects=12, seed=9, **cfg_kw):
    gw = ObjectGateway(
        code, ClusterProfile.network_critical(), num_nodes, GatewayConfig(**cfg_kw)
    )
    rng = np.random.default_rng(seed)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))
    return gw


# ---------------------------------------------------------------------------
# trace DSL + generators
# ---------------------------------------------------------------------------

def test_generated_traces_respect_tolerance_bound():
    for seed in range(6):
        cfg = ScenarioConfig(
            duration=1.0, num_nodes=60, nodes_per_rack=3,
            max_concurrent_failures=3, crash_rate=20.0, mean_downtime=0.05,
            transient_fraction=0.5, rack_burst_times=(0.2, 0.7),
            flap_nodes=2, seed=seed,
        )
        trace = generate_scenario(cfg)
        assert trace.max_concurrent_down() <= 3
        assert trace.events  # the bound trims, it doesn't empty the trace
        times = [e.time for e in trace.cluster_events()]
        assert times == sorted(times)
        # generation is a pure function of the config
        again = generate_scenario(cfg)
        assert again.cluster_events() == trace.cluster_events()


def test_rack_failure_expands_to_rack_members_and_roundtrips():
    base = ScenarioTrace(num_nodes=12, nodes_per_rack=4)
    trace = rack_failure(base, 0.5, rack=1, downtime=0.3)
    crashed = {e.node for e in trace.events if isinstance(e, FailureEvent)}
    recovered = {e.node for e in trace.events if isinstance(e, NodeRecoverEvent)}
    assert crashed == recovered == {4, 5, 6, 7}
    trace = flapping_node(trace, node=0, start=1.0, period=0.2, count=2)
    trace = load_surge(trace, 0.5, 0.3, 2.5)
    # JSON round trip preserves the full schedule
    again = trace_from_jsonable(trace.to_jsonable())
    assert again.cluster_events() == trace.cluster_events()
    assert again.surges == trace.surges
    assert again.num_nodes == trace.num_nodes


def test_scenario_requests_follow_load_surges():
    trace = load_surge(
        ScenarioTrace(num_nodes=10), time=0.5, duration=0.5, multiplier=4.0
    )
    wl = WorkloadConfig(num_objects=20, num_requests=3000, arrival_rate=1000.0, seed=2)
    reqs = scenario_requests(wl, trace)
    assert len(reqs) == 3000
    assert reqs == scenario_requests(wl, trace)  # reproducible
    in_surge = sum(1 for r in reqs if 0.5 <= r.time < 1.0)
    before = sum(1 for r in reqs if 0.0 <= r.time < 0.5)
    # 4x the rate => roughly 4x the arrivals in an equal-length window
    assert in_surge > 2.5 * before


# ---------------------------------------------------------------------------
# property: within-tolerance traces never lose data
# ---------------------------------------------------------------------------

def _assert_durable_under_random_trace(seed: int) -> None:
    """Random seeded trace bounded at n - k concurrently-affected nodes:
    every GET must complete (verify=True checks payloads byte-for-byte
    against ground truth and raises on mismatch) and the final durability
    audit must show zero lost blocks."""
    code = CoreCode(9, 6, 3)
    cfg = ScenarioConfig(
        duration=0.5, num_nodes=60, nodes_per_rack=3,
        max_concurrent_failures=code.n - code.k, crash_rate=12.0,
        mean_downtime=0.08, transient_fraction=0.5, flap_nodes=1,
        seed=seed,
    )
    trace = generate_scenario(cfg)
    gw = _gateway(
        code, batch_window=0.01, cache_bytes=4 * 1024 * 1024,
        repair_on_failure=True, repair_delay=0.03,
    )
    wl = WorkloadConfig(
        num_objects=12, num_requests=120, arrival_rate=400.0, seed=seed
    )
    res = run_scenario(gw, trace, wl)
    assert len(res.report.records) == 120
    # within tolerance every object stays readable: no failed GETs
    assert all(r.latency is not None for r in res.report.records)
    assert res.blocks_lost == 0
    assert res.durability["unreadable_objects"] == 0
    # the trace fully drains: every loss was repaired or recovered
    assert res.durability["missing_blocks"] == 0


if _HYP:
    _hyp = importlib.import_module("hypothesis")
    _st = importlib.import_module("hypothesis.strategies")

    @_hyp.settings(max_examples=6, deadline=None)
    @_hyp.given(seed=_st.integers(min_value=0, max_value=2**16))
    def test_durability_property_within_tolerance(seed):
        _assert_durable_under_random_trace(seed)
else:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
    def test_durability_property_within_tolerance(seed):
        _assert_durable_under_random_trace(seed)


def test_beyond_tolerance_reports_data_loss_without_crashing():
    """The paper's minimal irrecoverable pattern — two rows with
    identical failure columns of size n - k + 1 (no row has <= m
    failures, no column has exactly one) — is past the code's tolerance:
    the gateway must keep serving what it can, record the unreadable GET
    as failed, and the audit must report the loss — not raise."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, num_objects=code.t,  # a single group
        batch_window=0.01, repair_on_failure=True, repair_delay=0.05,
    )
    cols = range(code.n - code.k + 1)  # m + 1 identical columns
    victims = {gw.store.node_of(("g0", r, c)) for r in (0, 1) for c in cols}
    events = [CapacityLossEvent(time=0.01, node=n) for n in sorted(victims)]
    reqs = [Request(time=0.02, object_id=0), Request(time=0.02, object_id=2)]
    report = gw.serve(reqs, events)
    rec0 = next(r for r in report.records if r.object_id == 0)
    rec2 = next(r for r in report.records if r.object_id == 2)
    assert rec0.latency is None  # unreadable, reported not raised
    assert rec2.latency is not None  # untouched rows keep serving
    audit = gw.audit_durability()
    assert audit["blocks_lost"] > 0
    assert audit["unreadable_objects"] >= 1
    assert report.repair_reports and not all(
        r.recovered for r in report.repair_reports
    )


# ---------------------------------------------------------------------------
# golden-trace determinism
# ---------------------------------------------------------------------------

def _golden_run():
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, batch_window=0.01, cache_bytes=4 * 2048,  # 4 blocks: hot
        # objects cannot become fully cache-resident, so post-crash
        # reads really exercise the degraded path
        repair_on_failure=True, repair_delay=0.05, record_payloads=True,
        repair_pacing=True, tenant_slo_p99={"foreground": 0.1},
        decode_cost=0.002,  # modeled billing: bit-for-bit replayable
    )
    base = load_surge(
        ScenarioTrace(num_nodes=60, nodes_per_rack=3), 0.1, 0.2, 2.0
    )
    wl = WorkloadConfig(num_objects=12, num_requests=200, arrival_rate=600.0, seed=31)
    # fault the hottest object's row so the trace provably exercises
    # degraded reads (scenario_requests is deterministic, so peeking at
    # the stream here changes nothing downstream)
    counts = np.bincount(
        [r.object_id for r in scenario_requests(wl, base)], minlength=12
    )
    gid, row = gw._objects[int(np.argmax(counts))]
    v1 = gw.store.node_of((gid, row, 0))
    v2 = gw.store.node_of((gid, row, 2))
    trace = ScenarioTrace(
        num_nodes=60, nodes_per_rack=3,
        events=(
            FailureEvent(time=0.05, node=v1),
            CapacityLossEvent(time=0.15, node=v2),
            NodeRecoverEvent(time=0.35, node=v1),
        ),
        surges=base.surges,
    )
    return run_scenario(gw, trace, wl)


def test_golden_trace_replay_is_deterministic():
    """Replaying the same ScenarioTrace + workload seed must reproduce
    the discrete outcome bit-for-bit — the guard on simulated-clock
    event ordering. (Latency floats are excluded by construction: they
    embed measured kernel wall time.)"""
    a, b = _golden_run(), _golden_run()
    assert deterministic_fingerprint(a) == deterministic_fingerprint(b)
    sa, sb = a.summary(), b.summary()
    for key in (
        "requests", "completed", "rejected", "degraded_gets",
        "durability_events", "repairs", "blocks_repaired", "blocks_lost",
        "unreadable_objects", "pacing_updates",
    ):
        assert sa[key] == sb[key], key
    # the trace really exercised all three event kinds
    assert sa["repairs"] > 0 and sa["degraded_gets"] > 0


# ---------------------------------------------------------------------------
# SLO-aware closed-loop repair pacing
# ---------------------------------------------------------------------------

def _surge_scenario_run(pacing: bool):
    """The canonical paced-vs-fixed scenario (see
    repro.scenario.correlated_surge_setup — shared with the benchmark
    gate and the example demo, so this regression test validates the
    same setup the BENCH numbers report). Only the pacing differs
    between the two runs."""
    code = CoreCode(9, 6, 3)
    setup = correlated_surge_setup(code)
    gw = _gateway(
        code,
        num_nodes=setup["num_nodes"],
        q=setup["block_bytes"],
        num_objects=setup["num_objects"],
        seed=setup["seed"],
        repair_pacing=pacing,
        **setup["gateway_kwargs"],
    )
    return run_scenario(gw, setup["trace"], setup["workload"])


def test_paced_repair_protects_p99_and_still_converges():
    """Both directions of the pacing claim: under a foreground surge a
    paced repair keeps tier-0 p99 (over requests arriving during the
    failure + surge window — the requests the SLO protects) below the
    fixed full-weight baseline, AND the repair still completes
    everything (same blocks repaired, nothing missing at the end, MTTR
    within 2x of repair-at-full-weight)."""
    fixed = _surge_scenario_run(pacing=False)
    paced = _surge_scenario_run(pacing=True)
    # direction 1: pacing helps foreground latency under the surge
    assert (
        paced.p99_window(SURGE_FAIL_AT, SURGE_END)
        < fixed.p99_window(SURGE_FAIL_AT, SURGE_END)
    )
    # direction 2: repair still converges, MTTR bounded
    for res in (fixed, paced):
        assert res.durability["missing_blocks"] == 0
        assert res.blocks_lost == 0
        assert res.report.mttr_samples
    assert paced.report.mttr_mean <= 2.0 * fixed.report.mttr_mean
    same = sum(r.blocks_repaired for r in fixed.report.repair_reports)
    assert same == sum(r.blocks_repaired for r in paced.report.repair_reports)
    assert same > 0
    # the pacer actually acted, within its configured band, and backed
    # off decisively while the surge was live
    assert paced.report.pacing
    assert all(0.25 <= s <= 1.0 for _, s in paced.report.pacing)
    assert min(s for _, s in paced.report.pacing) < 0.5
    assert not fixed.report.pacing


def test_pacing_controller_policy():
    pc = PacingController(min_share=0.2, max_share=1.0, mttr_target=10.0)
    # idle / nothing to protect => full speed toward the MTTR target
    assert pc.share(None, 0.1) == 1.0
    assert pc.share(0.05, None) == 1.0
    # p99 at/above the SLO => floor
    assert pc.share(0.1, 0.1) == pytest.approx(0.2)
    assert pc.share(0.5, 0.1) == pytest.approx(0.2)
    # comfortable headroom => ceiling; monotonic in between
    assert pc.share(0.01, 0.1) == 1.0
    mid = pc.share(0.08, 0.1)
    assert 0.2 < mid < 1.0
    assert pc.share(0.09, 0.1) < mid
    # urgency overrides the backoff once the repair drags past target
    assert pc.share(0.5, 0.1, outstanding_for=20.1) == pytest.approx(1.0)
    assert 0.2 < pc.share(0.5, 0.1, outstanding_for=15.0) < 1.0
    with pytest.raises(ValueError):
        PacingController(min_share=0.0)
    with pytest.raises(ValueError):
        PacingController(min_share=0.9, max_share=0.5)


# ---------------------------------------------------------------------------
# negative / TTL cache entries
# ---------------------------------------------------------------------------

def test_cache_negative_entries_ttl_and_purge():
    cache = LRUBlockCache(capacity_bytes=1024)
    key = ("g", 0, 0)
    cache.put_negative(key, now=1.0, ttl=2.0)
    assert cache.is_negative(key, 1.5)
    assert cache.negative_entries == 1
    assert not cache.is_negative(key, 3.0)  # TTL lapsed: dropped
    assert cache.negative_entries == 0
    assert cache.stats.negative_expired == 1
    # eager purge beats the TTL
    cache.put_negative(key, now=1.0, ttl=100.0)
    assert cache.purge_negative([key, ("g", 0, 9)]) == 1
    assert not cache.is_negative(key, 1.1)
    # negative entries hold no bytes and never shadow a positive copy
    cache.put_negative(key, now=0.0, ttl=10.0)
    cache.put(key, np.zeros(16, dtype=np.uint8))
    assert cache.nbytes == 16
    assert key in cache and cache.is_negative(key, 1.0)


def test_gateway_negative_caches_crashed_blocks_and_purges_on_recover():
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, batch_window=0.005, cache_bytes=4 * 1024 * 1024, negative_ttl=50.0
    )
    victim = gw.store.node_of(("g0", 0, 0))
    n_keys = len(gw.store.keys_on_node(victim))
    assert n_keys > 0
    events = [
        FailureEvent(time=0.01, node=victim),
        NodeRecoverEvent(time=0.5, node=victim),
    ]
    reqs = [Request(time=0.02 + 0.002 * i, object_id=0) for i in range(3)]
    reqs.append(Request(time=1.0, object_id=0))
    report = gw.serve(reqs, events)
    assert len(report.completed) == 4
    early = [r for r in report.records if r.time < 0.5]
    late = [r for r in report.records if r.time >= 0.5]
    assert all(r.degraded for r in early)  # planned around the tombstones
    assert all(not r.degraded for r in late)  # recover purged them
    assert gw.cache.negative_entries == 0
    assert gw.cache.stats.negative_hits > 0  # probes were short-circuited
    assert report.restored_samples  # loss -> recover time was sampled


def test_gateway_negative_ttl_expires_without_recover_event():
    """No recover event: the tombstones go stale via their TTL and the
    gateway re-probes the (still down) store — counted as expiries."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, batch_window=0.005, cache_bytes=4 * 1024 * 1024, negative_ttl=0.1
    )
    victim = gw.store.node_of(("g0", 0, 0))
    reqs = [Request(time=0.02, object_id=0), Request(time=5.0, object_id=0)]
    report = gw.serve(reqs, [FailureEvent(time=0.01, node=victim)])
    assert len(report.completed) == 2
    early, late = report.records
    assert early.degraded  # reconstructed around the fresh tombstone
    # the late GET plans off the CACHED reconstruction (not the store —
    # the node is still down); its tombstone lapsed and was re-probed
    assert not late.degraded and late.cache_hits > 0
    assert gw.cache.stats.negative_expired > 0


def test_repair_heal_purges_negative_and_repriced_via_hook():
    """The on_block_repaired hook still drives refresh_cost re-pricing,
    and the repair heal also clears the block's negative entry — the
    healed block plans as a cheap store read again."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, batch_window=0.02, cache_bytes=4 * 1024 * 1024,
        repair_on_failure=True, repair_delay=0.05, background_share=0.5,
        negative_ttl=1e9,  # only heal/recover can clear tombstones
    )
    victim = gw.store.node_of(("g0", 0, 0))
    key = ("g0", 0, 0)
    reqs = [Request(time=0.03 + 0.001 * i, object_id=0) for i in range(5)]
    report = gw.serve(reqs, [FailureEvent(time=0.01, node=victim)])
    assert report.repair_reports
    assert report.mttr_samples  # loss -> heal completion sampled
    assert key in gw.cache and gw.cache._cost[key] == code.t
    assert not gw.cache.is_negative(key, 1e8)  # heal purged the tombstone
    # a read long after the heal completes applies the deferred re-price
    report2 = gw.serve([Request(time=50.0, object_id=0)])
    assert len(report2.completed) == 1
    assert not report2.records[0].degraded
    assert gw.cache._cost[key] == 1.0


# ---------------------------------------------------------------------------
# weighted engine pool
# ---------------------------------------------------------------------------

def test_engine_pool_full_weight_matches_least_loaded_fifo():
    pool = EnginePool(2)
    assert pool.dispatch(0.0, 1.0, tenant="a") == (0.0, 1.0)
    assert pool.dispatch(0.0, 1.0, tenant="b") == (0.0, 1.0)  # second engine
    assert pool.dispatch(0.0, 1.0) == (1.0, 2.0)  # queues behind the earliest
    assert pool.earliest_start(0.0) == 1.0


def test_engine_pool_earliest_start_sees_throttle_holes():
    """The admission estimator's queueing view must not be fooled by a
    throttled tenant's cursor-delayed bookings: the engine is idle NOW
    even though its high-water mark sits far in the future."""
    pool = EnginePool(1, weights={"repair": 0.25})
    for _ in range(4):
        pool.dispatch(0.0, 0.1, tenant="repair")
    assert pool.free[0] > 1.0  # bookings pushed out by the rate cap
    assert pool.earliest_start(0.15) < 0.2  # ...but the engine is idle


def test_engine_pool_throttled_tenant_is_rate_capped():
    pool = EnginePool(1, weights={"repair": 0.25})
    # foreground unaffected by the repair tenant's cursor
    _, end_fg = pool.dispatch(0.0, 1.0, tenant="fg")
    assert end_fg == 1.0
    # repair launches space at dur / share even on an idle pool
    s1, e1 = pool.dispatch(1.0, 1.0, tenant="repair")
    s2, e2 = pool.dispatch(1.0, 1.0, tenant="repair")
    assert (s1, e1) == (1.0, 2.0)
    assert (s2, e2) == (5.0, 6.0)  # cursor: 1.0 + 1.0/0.25
    # the throttle gap [2, 5) is a real hole, not a reservation:
    # a full-weight launch backfills it instead of queueing at 6.0
    s3, e3 = pool.dispatch(0.0, 1.0, tenant="fg")
    assert (s3, e3) == (2.0, 3.0)
    pool.set_weight("repair", 1.0)
    s4, _ = pool.dispatch(3.0, 1.0, tenant="repair")
    assert s4 == 3.0  # full weight again: earliest fit, no cursor
    with pytest.raises(ValueError):
        pool.set_weight("repair", 0.0)
    with pytest.raises(ValueError):
        EnginePool(1, weights={"x": 2.0})


def test_gateway_rejects_zero_repair_budget():
    # a zero budget would requeue continuations that never make progress
    code = CoreCode(9, 6, 3)
    with pytest.raises(ValueError):
        ObjectGateway(
            code, ClusterProfile.network_critical(), 60,
            GatewayConfig(repair_on_failure=True, repair_groups_per_run=0),
        )


def test_scenario_requests_overlapping_surges_multiply():
    """The thinning envelope must track the PRODUCT of overlapping
    surges, not the largest single multiplier."""
    trace = ScenarioTrace(num_nodes=10)
    trace = load_surge(trace, 0.5, 0.5, 1.5)
    trace = load_surge(trace, 0.75, 0.5, 1.5)  # overlap [0.75, 1.0): 2.25x
    wl = WorkloadConfig(num_objects=20, num_requests=4000, arrival_rate=1000.0, seed=4)
    reqs = scenario_requests(wl, trace)
    base = sum(1 for r in reqs if 0.0 <= r.time < 0.25)
    overlap = sum(1 for r in reqs if 0.75 <= r.time < 1.0)
    assert overlap > 1.8 * base  # ~2.25x, not capped at 1.5x


def test_scenario_requests_throttle_window_expiry_peak():
    """The rate can RISE at a throttle window's end: the envelope must
    cover the post-expiry product, not just surge-start instants."""
    trace = ScenarioTrace(num_nodes=10)
    trace = load_surge(trace, 0.0, 1.0, 0.5)  # throttle [0, 1)
    trace = load_surge(trace, 0.5, 1.5, 3.0)  # surge [0.5, 2): 1.5x then 3x
    wl = WorkloadConfig(num_objects=20, num_requests=4000, arrival_rate=1000.0, seed=5)
    reqs = scenario_requests(wl, trace)
    mid = sum(1 for r in reqs if 0.5 <= r.time < 1.0)  # 1.5x window
    late = sum(1 for r in reqs if 1.0 <= r.time < 1.5)  # 3.0x window
    assert late > 1.6 * mid  # ~2x, not clamped by a stale 1.5x peak


def test_max_concurrent_down_counts_capacity_loss_forever():
    """A reboot cannot restore destroyed disks: a recover event for a
    capacity-lost node must not shrink the affected set."""
    trace = ScenarioTrace(
        num_nodes=10,
        events=(
            CapacityLossEvent(time=0.0, node=3),
            FailureEvent(time=0.1, node=4),
            NodeRecoverEvent(time=0.2, node=3),  # ineffective: data gone
            FailureEvent(time=0.3, node=5),
            NodeRecoverEvent(time=0.4, node=4),
        ),
    )
    assert trace.max_concurrent_down() == 3  # {3, 4, 5} at t=0.3


def test_recovery_retriggers_repair_of_stuck_group():
    """A group stuck on an unrecoverable cluster must be retried when a
    recovery restores its sources — the recover event itself queues the
    re-scan (there is no failure event left to do it)."""
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code, num_objects=code.t,  # one group
        batch_window=0.01, repair_on_failure=True, repair_delay=0.05,
    )
    # rows 0 and 1 both missing columns 0..m: unrecoverable while the
    # row-1 nodes are down, recoverable once they come back
    cols = list(range(code.n - code.k + 1))
    lost_nodes = sorted({gw.store.node_of(("g0", 0, c)) for c in cols})
    crash_nodes = sorted({gw.store.node_of(("g0", 1, c)) for c in cols})
    events = [CapacityLossEvent(time=0.01, node=n) for n in lost_nodes]
    events += [FailureEvent(time=0.01, node=n) for n in crash_nodes]
    events += [NodeRecoverEvent(time=1.0, node=n) for n in crash_nodes]
    report = gw.serve([Request(time=0.02, object_id=2)], events)
    # repair first ran while unrecoverable, then the recovery re-scan
    # rebuilt the capacity-lost blocks
    assert any(not r.recovered for r in report.repair_reports)
    assert any(r.recovered and r.blocks_repaired for r in report.repair_reports)
    audit = gw.audit_durability()
    assert audit["missing_blocks"] == 0 and audit["blocks_lost"] == 0
    assert report.mttr_samples  # the lost blocks' MTTR was recorded


def test_put_block_dense_fallback_keeps_row_col_anticolocation():
    """When every alive node already hosts a group block, re-placement
    must still avoid nodes holding another live block of the same row
    or column (one node failure => at most one loss per stripe)."""
    code = CoreCode(9, 6, 3)
    store = BlockStore(num_nodes=20)  # 36-cell group: denser than nodes
    make_group(code, store, q=256)
    victim = store.node_of(("g0", 0, 0))
    store.fail_nodes([victim])
    store.put_block(("g0", 0, 0), np.zeros(256, dtype=np.uint8))
    new_node = store.node_of(("g0", 0, 0))
    assert new_node != victim and new_node not in store.failed_nodes
    for k, n in store.placement.items():
        if k == ("g0", 0, 0) or not store.available(k):
            continue
        if k[1] == 0 or k[2] == 0:  # same row or same column
            assert n != new_node, (k, n)
