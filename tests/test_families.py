"""Code-family semantics (PR 8): RS / CORE / LRC behind one planner.

Covers the bake-off's correctness surface:

- family geometry, tolerance, and the Table-1 repair cost model per
  column (CORE verticals at t, RS at k, LRC local groups at k/2);
- LRC local-group repair fetches STRICTLY fewer blocks than the RS
  k-block re-decode — measured through the real BlockFixer, not the
  cost model;
- decode byte-identity through degraded paths: all three families
  serve sha256-identical payloads for the same stripe data with a
  data block missing;
- the Weibull / trace-driven failure inter-arrival laws (1309.0186):
  mean preservation (crash_rate stays 1/mean under every law),
  determinism, and the admission bound under bursty churn.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.product_code import CoreCode
from repro.gateway import (
    GatewayConfig,
    ObjectGateway,
    WorkloadConfig,
    generate_requests,
)
from repro.gateway.planner import FAMILY_NAMES, make_family
from repro.scenario.trace import ScenarioConfig, _crash_gap, generate_scenario
from repro.storage.netmodel import ClusterProfile

CODE = CoreCode(9, 6, 3)  # even k, n >= k+2: valid for all three families
NUM_OBJECTS = 6
Q = 256


def _mk_gateway(fam: str, seed: int = 3, **cfg_kw) -> ObjectGateway:
    cfg = GatewayConfig(code_family=fam, record_payloads=True, **cfg_kw)
    gw = ObjectGateway(CODE, ClusterProfile.network_critical(), 40, cfg)
    rng = np.random.default_rng(seed)
    gw.load_objects(
        rng.integers(0, 256, (NUM_OBJECTS, CODE.k, Q), dtype=np.uint8)
    )
    return gw


# -- family geometry + cost model ------------------------------------------


def test_family_geometry():
    core = make_family(CODE, "core")
    rs = make_family(CODE, "rs")
    lrc = make_family(CODE, "lrc")
    assert (core.rows, core.n, core.k) == (CODE.t + 1, CODE.n, CODE.k)
    for fam in (rs, lrc):
        assert (fam.rows, fam.n, fam.k) == (1, CODE.n, CODE.k)
        assert fam.objects_per_group == 1
    assert core.objects_per_group == CODE.t
    assert set(FAMILY_NAMES) == {"core", "rs", "lrc"}
    with pytest.raises(ValueError):
        make_family(CODE, "raptor")


def test_family_tolerance_and_overhead():
    core = make_family(CODE, "core")
    rs = make_family(CODE, "rs")
    lrc = make_family(CODE, "lrc")
    m = CODE.n - CODE.k
    assert core.tolerance == m
    assert rs.tolerance == m
    # LRC trades one guaranteed erasure for cheap local repair
    assert lrc.tolerance == m - 1
    assert rs.storage_overhead == lrc.storage_overhead == CODE.n / CODE.k
    # CORE's vertical parity row costs extra stretch
    assert core.storage_overhead == pytest.approx(CODE.stretch)
    assert core.storage_overhead > rs.storage_overhead


def test_single_repair_cost_model():
    core = make_family(CODE, "core")
    rs = make_family(CODE, "rs")
    lrc = make_family(CODE, "lrc")
    k = CODE.k
    for col in range(CODE.n):
        assert core.single_repair_cost(col) == CODE.t
        assert rs.single_repair_cost(col) == k
        expected = k // 2 if lrc.code.local_group(col) is not None else k
        assert lrc.single_repair_cost(col) == expected
    # every local repair beats the RS re-decode; globals tie it
    assert lrc.avg_repair_cost < rs.avg_repair_cost
    assert core.avg_repair_cost < rs.avg_repair_cost


def test_lrc_repair_plan_is_local_first():
    lrc = make_family(CODE, "lrc")
    # a single lost data column repairs from its k/2-member local group
    plan = lrc.repair_plan([0])
    assert plan is not None and len(plan) == 1
    kind, sources, repaired = plan[0]
    assert kind == "local"
    assert len(sources) == CODE.k // 2
    assert tuple(repaired) == (0,)
    # RS always re-decodes from k sources
    rs_plan = make_family(CODE, "rs").repair_plan([0])
    assert rs_plan is not None
    _, rs_sources, _ = rs_plan[0]
    assert len(rs_sources) == CODE.k


# -- repair through the real BlockFixer ------------------------------------


def _repair_one_block(fam: str):
    gw = _mk_gateway(fam, seed=7)
    gid, row = gw._objects[0]
    key = (gid, row, 0)  # a data column: LRC repairs it locally
    gw.store.drop_block(key)
    rep = gw.fixer.fix_group(gid)
    assert rep.recovered
    assert gw.store.available(key)
    return rep


def test_local_group_repair_fetches_fewer_than_rs():
    reports = {fam: _repair_one_block(fam) for fam in ("rs", "lrc", "core")}
    assert reports["rs"].blocks_fetched == CODE.k
    assert reports["lrc"].blocks_fetched == CODE.k // 2
    assert reports["core"].blocks_fetched == CODE.t
    # the bake-off's structural claim, as an inequality
    assert reports["lrc"].blocks_fetched < reports["rs"].blocks_fetched
    assert reports["core"].blocks_fetched < reports["rs"].blocks_fetched


def test_lrc_global_parity_repair_falls_back_to_k():
    gw = _mk_gateway("lrc", seed=7)
    gid, row = gw._objects[0]
    # the last column is a global parity: no local group, k-block decode
    assert gw.family.code.local_group(CODE.n - 1) is None
    key = (gid, row, CODE.n - 1)
    gw.store.drop_block(key)
    rep = gw.fixer.fix_group(gid)
    assert rep.recovered and gw.store.available(key)
    assert rep.blocks_fetched == CODE.k


# -- byte identity through degraded paths ----------------------------------


def _serve_degraded(fam: str) -> dict[int, str]:
    gw = _mk_gateway(fam, seed=11, batch_window=0.005)
    # lose one data block of objects 0 and 1 — every GET for them goes
    # through the family's degraded path (no repair: raw reconstruction)
    for obj, col in ((0, 0), (1, 2)):
        gw.store.drop_block((*gw._objects[obj], col))
    wl = WorkloadConfig(
        num_objects=NUM_OBJECTS, num_requests=60, arrival_rate=300.0, seed=11
    )
    rep = gw.serve(generate_requests(wl), [])
    assert len(rep.completed) == len(rep.records)
    assert len(rep.degraded_gets) > 0, fam
    digests: dict[int, str] = {}
    for r in rep.completed:
        if r.kind == "get" and r.payload_digest:
            assert digests.setdefault(r.object_id, r.payload_digest) == (
                r.payload_digest
            )
    assert {0, 1} <= set(digests)  # the degraded objects were read
    return digests


def test_degraded_byte_identity_across_families():
    digests = {fam: _serve_degraded(fam) for fam in FAMILY_NAMES}
    assert digests["core"] == digests["rs"] == digests["lrc"]


# -- failure inter-arrival laws (1309.0186) --------------------------------


def _gaps(law: str, n: int = 4000, **kw) -> np.ndarray:
    cfg = ScenarioConfig(
        duration=1.0, num_nodes=30, crash_rate=5.0, interarrival=law, **kw
    )
    rng = np.random.default_rng(0)
    return np.asarray([_crash_gap(rng, cfg) for _ in range(n)])


def test_interarrival_laws_preserve_mean():
    mean = 1.0 / 5.0
    for law, kw in (
        ("exponential", {}),
        ("weibull", {"interarrival_shape": 0.7}),
        ("trace", {"interarrival_samples": (0.3, 1.0, 2.5, 7.0)}),
    ):
        gaps = _gaps(law, **kw)
        assert np.all(gaps > 0)
        assert gaps.mean() == pytest.approx(mean, rel=0.1), law


def test_weibull_shape_below_one_is_burstier_than_exponential():
    # shape < 1: heavier tail AND more near-zero gaps than exponential
    # at the same mean — the warehouse-cluster churn signature
    exp, wei = _gaps("exponential"), _gaps("weibull", interarrival_shape=0.7)
    assert wei.std() > exp.std()
    assert np.median(wei) < np.median(exp)


def test_trace_law_resamples_rescaled_empirical_gaps():
    samples = (0.5, 1.0, 4.0)
    gaps = _gaps("trace", interarrival_samples=samples)
    scaled = set(
        np.round(np.asarray(samples) * (0.2 / np.mean(samples)), 12)
    )
    assert set(np.round(gaps, 12)) <= scaled


def test_interarrival_validation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        _crash_gap(
            rng, ScenarioConfig(1.0, 30, interarrival="pareto")
        )
    with pytest.raises(ValueError):
        _crash_gap(
            rng,
            ScenarioConfig(1.0, 30, interarrival="weibull", interarrival_shape=0.0),
        )
    with pytest.raises(ValueError):
        _crash_gap(rng, ScenarioConfig(1.0, 30, interarrival="trace"))


def test_weibull_scenario_deterministic_and_bounded():
    cfg = ScenarioConfig(
        duration=2.0,
        num_nodes=30,
        nodes_per_rack=3,
        max_concurrent_failures=2,
        crash_rate=8.0,
        mean_downtime=0.1,
        transient_fraction=0.8,
        interarrival="weibull",
        interarrival_shape=0.7,
        seed=13,
    )
    t1, t2 = generate_scenario(cfg), generate_scenario(cfg)
    assert t1.events == t2.events  # seeded: bit-for-bit reproducible
    crashes = [
        e for e in t1.events
        if type(e).__name__ in ("FailureEvent", "CapacityLossEvent")
    ]
    assert crashes, "trace produced no failures"
    # the admission bound holds under the bursty law: never more than
    # max_concurrent_failures nodes down at once
    down: set[int] = set()
    peak = 0
    for ev in t1.events:
        name = type(ev).__name__
        if name in ("FailureEvent", "CapacityLossEvent"):
            down.add(ev.node)
        elif name == "NodeRecoverEvent":
            down.discard(ev.node)
        peak = max(peak, len(down))
    assert 0 < peak <= cfg.max_concurrent_failures
