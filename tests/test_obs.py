"""Observability-plane tests (repro.obs): streaming-estimator accuracy
bounds, bounded-memory guarantees, span parenting/ordering invariants on
real gateway traces, sampling policies, the observation-only contract
(tracing on/off is byte-identical), critical-path additivity, and the
chrome-tracing exporter + validator.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core.product_code import CoreCode
from repro.gateway import (
    GatewayConfig,
    ObjectGateway,
    WorkloadConfig,
    generate_requests,
)
from repro.gateway.gateway import RECENT_CAP
from repro.gateway.workload import FailureEvent
from repro.obs import (
    NULL_TRACER,
    STAGES,
    BoundedLog,
    BoundedSamples,
    MetricsRegistry,
    P2Quantile,
    StreamHist,
    Tracer,
    critical_path,
    launch_amortization,
    stage_shares,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.scenario import (
    correlated_surge_setup,
    deterministic_fingerprint,
    run_scenario,
)
from repro.storage.netmodel import ClusterProfile


# ---------------------------------------------------------------------------
# streaming estimators: accuracy vs exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["uniform", "lognormal", "exponential"])
@pytest.mark.parametrize("q", [0.5, 0.9, 0.99])
def test_p2_quantile_tracks_exact(dist, q):
    rng = np.random.default_rng(7)
    xs = {
        "uniform": rng.uniform(0.001, 1.0, 20000),
        "lognormal": rng.lognormal(-3.0, 1.0, 20000),
        "exponential": rng.exponential(0.05, 20000),
    }[dist]
    est = P2Quantile(q)
    for x in xs:
        est.observe(float(x))
    exact = float(np.quantile(xs, q))
    # P2 is approximate; on smooth unimodal streams it lands within a
    # modest relative band of the exact quantile
    assert est.count == len(xs)
    assert abs(est.value - exact) / exact < 0.15


def test_p2_quantile_exact_below_five_samples():
    est = P2Quantile(0.5)
    for x in (3.0, 1.0, 2.0):
        est.observe(x)
    assert est.value == 2.0  # exact median of {1,2,3}
    with pytest.raises(ValueError):
        P2Quantile(1.5)


@pytest.mark.parametrize("dist", ["uniform", "lognormal"])
def test_streamhist_quantile_relative_error_bound(dist):
    """Log-spaced bins bound RELATIVE quantile error by the bin growth
    factor (plus one bin of rank slack at the ends)."""
    rng = np.random.default_rng(11)
    xs = {
        "uniform": rng.uniform(0.001, 2.0, 20000),
        "lognormal": rng.lognormal(-2.0, 1.5, 20000),
    }[dist]
    h = StreamHist()
    for x in xs:
        h.observe(float(x))
    for q in (0.1, 0.5, 0.9, 0.99):
        exact = float(np.quantile(xs, q))
        got = h.quantile(q)
        # one bin of rank slack can shift the answer a neighbouring bin:
        # allow 2x the single-bin relative width
        assert abs(got - exact) / exact < 2 * (h.growth - 1.0), (q, got, exact)
    # exact streaming scalars ride alongside
    assert h.count == len(xs)
    assert h.min == float(xs.min()) and h.max == float(xs.max())
    assert h.quantile(0.0) == h.min and h.quantile(1.0) == h.max
    assert h.cdf(h.max) == 1.0
    assert h.cdf(h.min - 1e-12) == 0.0


def test_streamhist_merge_matches_union():
    rng = np.random.default_rng(3)
    a, b = rng.exponential(0.1, 5000), rng.exponential(0.3, 5000)
    ha, hb, hu = StreamHist(), StreamHist(), StreamHist()
    for x in a:
        ha.observe(float(x))
        hu.observe(float(x))
    for x in b:
        hb.observe(float(x))
        hu.observe(float(x))
    ha.merge(hb)
    assert ha.count == hu.count and ha.bins == hu.bins
    assert ha.quantile(0.9) == hu.quantile(0.9)


# ---------------------------------------------------------------------------
# bounded containers + registry: memory stays O(1) in samples
# ---------------------------------------------------------------------------

def test_bounded_samples_memory_and_exact_scalars():
    bs = BoundedSamples(cap=64)
    xs = np.random.default_rng(5).uniform(0.0, 10.0, 100_000)
    for x in xs:
        bs.append(float(x))
    assert len(bs) == 100_000  # len() = TOTAL observed, list-compatible
    assert bs.resident() == 64  # memory bounded by the cap
    assert list(bs) == [float(x) for x in xs[:64]]
    assert bs.mean == pytest.approx(float(xs.mean()))
    assert bs.max == float(xs.max()) and bs.min == float(xs.min())
    assert bool(bs) and not bool(BoundedSamples())


def test_bounded_log_keeps_tail():
    log = BoundedLog(cap=16)
    for i in range(1000):
        log.append((i, i * 2))
    assert len(log) == 1000
    assert log.resident() == 16
    assert list(log)[0] == (984, 1968) and list(log)[-1] == (999, 1998)


def test_metrics_registry_bounded_and_queryable():
    m = MetricsRegistry()
    for i in range(50_000):
        m.counter("requests", tenant="a").inc()
        m.histogram("latency", kind="get", tenant="a").observe(0.01)
        m.histogram("latency", kind="get", tenant="b").observe(0.5)
    assert m.counter_total("requests") == 50_000
    # resident memory is per-SERIES, never per-sample
    before = m.resident_samples()
    m.histogram("latency", kind="get", tenant="a").observe(0.01)
    assert m.resident_samples() == before
    merged = m.merged_histogram("latency", kind="get")
    assert merged is not None and merged.count == 100_001
    assert merged.quantile(0.25) == pytest.approx(0.01, rel=0.2)
    snap = m.snapshot()
    assert snap["counters"]["requests{tenant=a}"] == 50_000
    assert "latency{kind=get,tenant=a}" in snap["histograms"]


# ---------------------------------------------------------------------------
# tracer: sampling policies + bounded ring
# ---------------------------------------------------------------------------

def _one_trace(tr: Tracer, latency: float) -> int:
    tid = tr.begin_trace()
    tr.span("fetch", 0.0, latency / 2, tid, tid)
    tr.root_span("request", 0.0, latency, tid)
    tr.end_trace(tid, latency=latency)
    return tid


def test_tracer_sampling_policies():
    head = Tracer(sample="head:3")
    for _ in range(10):
        _one_trace(head, 0.01)
    assert head.traces_kept == 3 and head.traces_dropped == 7

    tail = Tracer(sample="tail:0.1")
    kept = [_one_trace(tail, lat) for lat in (0.01, 0.5, 0.02, 0.2)]
    assert tail.traces_kept == 2  # slow traces are never dropped
    assert set(tail.trace_ids()) == {kept[1], kept[3]}

    combo = Tracer(sample="head:1,tail:0.1")
    for lat in (0.01, 0.02, 0.5):
        _one_trace(combo, lat)
    assert combo.traces_kept == 2  # head keeps the first, tail the slow one

    with pytest.raises(ValueError):
        Tracer(sample="p50")
    with pytest.raises(ValueError):
        Tracer(sample="")


def test_tracer_ring_buffer_bounded():
    tr = Tracer(sample="always", capacity=100)
    for _ in range(200):
        _one_trace(tr, 0.01)
    assert tr.resident() <= 100
    assert tr.stats()["spans_resident"] <= 100


def test_tracer_drops_spans_outside_open_traces():
    tr = Tracer()
    tid = tr.begin_trace()
    tr.end_trace(tid, latency=0.0)
    assert tr.span("late", 0.0, 1.0, tid, tid) == 0  # closed: dropped
    assert tr.span("bogus", 0.0, 1.0, 999999) == 0  # never opened
    assert NULL_TRACER.begin_trace() == 0 and not NULL_TRACER.enabled


def test_tracer_replay_preserves_stream():
    # replay_into (the overhead bench's measured workload) must re-emit
    # the exact committed stream: same span count, names, intervals,
    # tracks and attrs, with parenting preserved per trace
    tr = Tracer()
    for lat in (0.01, 0.2):
        _one_trace(tr, lat)
    sink = Tracer(sample=tr.sample, capacity=tr.capacity)
    n = tr.replay_into(sink)
    assert n == len(tr.spans) == len(sink.spans)
    assert sink.traces_kept == tr.traces_kept
    strip = lambda spans: sorted(
        (s.name, s.start, s.end, s.track, tuple(sorted(s.attrs.items())))
        for s in spans
    )
    assert strip(sink.spans) == strip(tr.spans)
    roots = [s for s in sink.spans if s.span_id == s.trace_id]
    assert len(roots) == sink.traces_kept
    for s in sink.spans:
        if s.parent_id is not None and s.span_id != s.trace_id:
            assert s.parent_id == s.trace_id  # reparented onto new root


# ---------------------------------------------------------------------------
# gateway traces: parenting/ordering invariants + critical path
# ---------------------------------------------------------------------------

def _traced_gateway_run(**cfg_kw):
    code = CoreCode(9, 6, 3)
    cfg = GatewayConfig(
        batch_window=0.02,
        decode_cost=0.002,
        repair_on_failure=True,
        repair_delay=0.05,
        background_share=0.5,
        tracing=True,
        **cfg_kw,
    )
    gw = ObjectGateway(code, ClusterProfile.network_critical(), 60, cfg)
    rng = np.random.default_rng(9)
    gw.load_objects(rng.integers(0, 256, (12, code.k, 2048), dtype=np.uint8))
    reqs = generate_requests(
        WorkloadConfig(num_objects=12, num_requests=200, arrival_rate=500.0, seed=5)
    )
    victim = gw.store.node_of(("g0", 0, 0))
    report = gw.serve(reqs, [FailureEvent(time=0.02, node=victim)])
    return gw, report


def test_gateway_span_parenting_and_ordering():
    gw, report = _traced_gateway_run()
    tr = gw.tracer
    assert tr.traces_kept > 0
    request_roots = 0
    for tid in tr.trace_ids():
        spans = tr.trace(tid)
        by_id = {s.span_id: s for s in spans}
        roots = [s for s in spans if s.parent_id is None]
        assert len(roots) == 1  # exactly one root per trace
        root = roots[0]
        assert root.span_id == tid  # trace id doubles as the root span id
        if root.name == "request":
            request_roots += 1
        for s in spans:
            assert s.end >= s.start
            if s.parent_id is not None:
                parent = by_id[s.parent_id]
                # children nest within their parent on the sim clock
                assert parent.start <= s.start + 1e-9
                assert s.end <= parent.end + 1e-9
        # a decode's sources land before its launch barrier opens and
        # its engine time starts: fetch -> staging -> decode ordering
        for d in (s for s in spans if s.name == "decode"):
            assert d.attrs["op_ready"] <= d.attrs["ready"] + 1e-9
            assert d.attrs["ready"] <= d.start + 1e-9
        # every fetch ends no later than the request completes
        for f in (s for s in spans if s.name == "fetch"):
            assert f.end <= root.end + 1e-9
    assert request_roots == len(report.completed)


def test_gateway_critical_path_additive():
    gw, _ = _traced_gateway_run()
    tr = gw.tracer
    degraded_seen = 0
    for tid in tr.trace_ids():
        spans = tr.trace(tid)
        root = next((s for s in spans if s.name == "request"), None)
        if root is None:
            continue  # repair.run trace
        bd = critical_path(spans)
        assert bd is not None
        assert set(bd.stages) == set(STAGES)
        assert all(v >= 0.0 for v in bd.stages.values())
        # the six stages sum EXACTLY to the request's latency
        assert sum(bd.stages.values()) == pytest.approx(bd.latency, abs=1e-12)
        if root.attrs.get("degraded"):
            degraded_seen += 1
            assert bd.gated_by in ("decode", "fetch")
    assert degraded_seen > 0
    sh = stage_shares(tr)
    assert sh["traces"] > 0
    assert sum(sh["shares"].values()) == pytest.approx(1.0, abs=1e-9)
    amort = launch_amortization(tr)
    assert amort["launches"] > 0
    assert amort["ops_per_launch"] >= 1.0


def test_gateway_repair_trace_emitted():
    gw, report = _traced_gateway_run()
    assert report.repair_reports
    tr = gw.tracer
    names = {s.name for s in tr.spans}
    assert {"repair.run", "repair.fetch", "repair.group", "repair.heal"} <= names
    runs = [s for s in tr.spans if s.name == "repair.run"]
    for run in runs:
        children = [
            s for s in tr.trace(run.trace_id) if s.span_id != run.span_id
        ]
        assert children  # fetch/decode/heal ride inside the repair trace


def test_gateway_metrics_surface_jit_and_autotune():
    gw, report = _traced_gateway_run()
    snap = report.metrics.snapshot()
    assert "jit_retraces{}" in snap["gauges"]
    assert "jit_entries{}" in snap["gauges"]
    for key in ("autotune_memory_hits{}", "autotune_disk_hits{}", "autotune_sweeps{}"):
        assert key in snap["gauges"]
    assert "traces_kept{}" in snap["gauges"]


# ---------------------------------------------------------------------------
# observation-only contract: tracing cannot change the simulation
# ---------------------------------------------------------------------------

def _fingerprint_run(**extra_kw):
    code = CoreCode(9, 6, 3)
    setup = correlated_surge_setup(code, num_requests=120)
    cfg = GatewayConfig(
        record_payloads=True,
        **setup["gateway_kwargs"],
        **extra_kw,
    )
    gw = ObjectGateway(
        code, ClusterProfile.network_critical(), setup["num_nodes"], cfg
    )
    rng = np.random.default_rng(setup["seed"])
    gw.load_objects(
        rng.integers(
            0, 256, (setup["num_objects"], code.k, setup["block_bytes"]),
            dtype=np.uint8,
        )
    )
    return run_scenario(gw, setup["trace"], setup["workload"])


def test_tracing_disabled_is_byte_identical():
    """Tracing must be observation-only: the golden fingerprint (which
    covers per-request payload digests) is identical with tracing off,
    on, and on-with-sampling."""
    base = deterministic_fingerprint(_fingerprint_run())
    traced = deterministic_fingerprint(_fingerprint_run(tracing=True))
    sampled = deterministic_fingerprint(
        _fingerprint_run(tracing=True, trace_sample="head:5,tail:0.1")
    )
    assert base == traced == sampled


def test_streaming_mode_bounded_and_aggregates_agree():
    """record_requests=False keeps NO per-request records; aggregates
    fall back to the registry and stay close to the exact answers."""
    full = _fingerprint_run().report
    stream = _fingerprint_run(record_requests=False).report
    assert len(stream.records) == 0
    assert stream.resident_samples() <= full.resident_samples()
    assert stream.resident_samples() < 10_000  # bounded, not per-request
    exact_p99 = full.latency_percentile(99)
    sketch_p99 = stream.latency_percentile(99)
    assert sketch_p99 == pytest.approx(exact_p99, rel=0.25)
    assert stream.throughput == pytest.approx(full.throughput, rel=1e-6)
    # pacer inputs ride the bounded deque, capped
    assert len(stream.recent) <= RECENT_CAP


# ---------------------------------------------------------------------------
# chrome-tracing export + validation
# ---------------------------------------------------------------------------

def test_chrome_export_round_trip(tmp_path):
    gw, _ = _traced_gateway_run()
    path = tmp_path / "trace.json"
    doc = write_chrome_trace(str(path), gw.tracer.spans)
    assert validate_chrome_trace(doc) == len(doc["traceEvents"])
    reloaded = json.loads(path.read_text())
    assert validate_chrome_trace(reloaded) == len(doc["traceEvents"])
    # track layout: every track group renders as one named process
    groups = {
        ev["args"]["name"]
        for ev in reloaded["traceEvents"]
        if ev["ph"] == "M" and ev["name"] == "process_name"
    }
    assert {"tenant", "engine", "fabric", "repair"} <= groups
    # intervals are complete events with durations; instants are marked
    for ev in reloaded["traceEvents"]:
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
        elif ev["ph"] == "i":
            assert ev["s"] == "t"


def test_chrome_validator_rejects_malformed():
    ok = to_chrome_trace(
        [  # minimal valid doc built from a hand-rolled span
        ]
    )
    assert validate_chrome_trace(ok) == 0
    with pytest.raises(ValueError):
        validate_chrome_trace([])  # not an object
    with pytest.raises(ValueError):
        validate_chrome_trace({})  # no traceEvents
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})  # missing fields
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "Z", "pid": 1, "tid": 1}]}
        )
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": -1}]}
        )
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": 0}]}
        )  # X without dur
