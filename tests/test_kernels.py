"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracle
(interpret=True executes the Pallas kernel body on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.coding import gf256, rs
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k", [(1, 2), (2, 12), (3, 6), (4, 16), (6, 6)])
@pytest.mark.parametrize("n", [128, 512, 1000, 2048, 4096, 5000])
def test_gf256_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    coef = rng.integers(0, 256, size=(m, k), dtype=np.uint8)
    data = rng.integers(0, 256, size=(k, n), dtype=np.uint8)
    got = np.asarray(ops.gf256_matmul(coef, jnp.asarray(data), interpret=True))
    want = np.asarray(ref.gf256_matmul(jnp.asarray(coef), jnp.asarray(data)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("t", [2, 3, 5, 6, 13])
@pytest.mark.parametrize("n", [128, 777, 2048, 4096])
def test_xor_parity_matches_ref(t, n):
    rng = np.random.default_rng(t * 97 + n)
    data = rng.integers(0, 256, size=(t, n), dtype=np.uint8)
    got = np.asarray(ops.xor_parity(jnp.asarray(data), interpret=True))
    want = np.asarray(ref.xor_parity(jnp.asarray(data)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,k", [(9, 6), (14, 12)])
def test_rs_encode_kernel_end_to_end(n, k):
    """Kernel-encoded parities must agree with the LinearCode path."""
    rng = np.random.default_rng(5)
    data = rng.integers(0, 256, size=(k, 4096), dtype=np.uint8)
    pm = rs.parity_matrix(n, k)
    got = np.asarray(ops.rs_encode(pm, jnp.asarray(data), interpret=True))
    code = rs.make_rs(n, k)
    cw = np.asarray(code.encode(jnp.asarray(data)))
    np.testing.assert_array_equal(got, cw[k:])


def test_rs_decode_kernel_end_to_end():
    n, k = 9, 6
    rng = np.random.default_rng(6)
    data = rng.integers(0, 256, size=(k, 2048), dtype=np.uint8)
    code = rs.make_rs(n, k)
    cw = np.asarray(code.encode(jnp.asarray(data)))
    avail = np.asarray([0, 2, 4, 6, 7, 8])
    row_ids, inverse = code.decode_matrix(avail)
    survivors = cw[row_ids]
    got = np.asarray(ops.rs_decode(inverse, jnp.asarray(survivors), interpret=True))
    np.testing.assert_array_equal(got, data)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=5),
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=1, max_value=3000),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gf256_matmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    coef = rng.integers(0, 256, size=(m, k), dtype=np.uint8)
    data = rng.integers(0, 256, size=(k, n), dtype=np.uint8)
    got = np.asarray(ops.gf256_matmul(coef, jnp.asarray(data), interpret=True))
    want = gf256.np_matmul(coef, data)
    np.testing.assert_array_equal(got, want)


def test_block_n_variants():
    rng = np.random.default_rng(7)
    coef = rng.integers(0, 256, size=(2, 6), dtype=np.uint8)
    data = rng.integers(0, 256, size=(6, 4096), dtype=np.uint8)
    want = gf256.np_matmul(coef, data)
    for bn in (128, 256, 1024, 4096):
        got = np.asarray(
            ops.gf256_matmul(coef, jnp.asarray(data), block_n=bn, interpret=True)
        )
        np.testing.assert_array_equal(got, want, err_msg=f"block_n={bn}")
