"""K2 packed GF(2^8) kernel vs the jnp oracle and vs the u8 kernel —
shape/dtype sweep incl. non-multiple-of-4-unfriendly sizes (ops.py pads
to the tile)."""

from __future__ import annotations

import importlib

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.coding import rs
from repro.kernels import ops, ref

gfk = importlib.import_module("repro.kernels.gf256_matmul")


@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([9, 14, 6]),
    k=st.sampled_from([6, 12, 4]),
    q=st.sampled_from([128, 1000, 4096, 70000]),
    seed=st.integers(0, 3),
)
def test_packed_kernel_matches_oracle(n, k, q, seed):
    if k >= n:
        return
    parity = rs.parity_matrix(n, k)
    rng = np.random.default_rng(seed)
    data = jnp.asarray(rng.integers(0, 256, (k, q), dtype=np.uint8))
    got = np.asarray(ops.gf256_matmul(parity, data))
    want = np.asarray(ref.gf256_matmul(jnp.asarray(parity), data))
    assert np.array_equal(got, want)


def test_packed_equals_unpacked_kernel():
    parity = rs.parity_matrix(14, 12)
    mc = jnp.asarray(gfk.expand_coeff_bitplanes(parity))
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.integers(0, 256, (12, 8192), dtype=np.uint8))
    a = np.asarray(gfk.gf256_matmul_planes(mc, data, block_n=2048, packed=True))
    b = np.asarray(gfk.gf256_matmul_planes(mc, data, block_n=2048, packed=False))
    assert np.array_equal(a, b)
