"""Write dataplane tests: the ragged ENCODE megakernel (kernel-vs-
oracle), honest PUT-path physics (billed encode launches, transfer
causality, write admission), stripe sealing for small objects, deletes,
and the end-to-end churn consistency audits under fault traces."""

import numpy as np
import pytest

from repro.coding import rs
from repro.coding.gf256 import np_matmul
from repro.core.product_code import CoreCode
from repro.gateway import (
    GatewayConfig,
    ObjectGateway,
    StripeSealer,
    WorkloadConfig,
)
from repro.gateway.workload import Request
from repro.kernels import ops
from repro.scenario.engine import (
    ScenarioResult,
    deterministic_fingerprint,
)
from repro.scenario.trace import (
    CorruptionEvent,
    ScenarioTrace,
    rack_failure,
    scenario_requests,
)
from repro.storage.netmodel import ClusterProfile

from repro.kernels.ragged_decode import CHUNK_SMALL
from repro.kernels.gf256_matmul import expand_coeff_bitplanes


def _gateway(code, num_nodes=60, q=2048, num_objects=12, **cfg_kw):
    cfg_kw.setdefault("interpret", True)
    gw = ObjectGateway(
        code, ClusterProfile.network_critical(), num_nodes, GatewayConfig(**cfg_kw)
    )
    rng = np.random.default_rng(9)
    gw.load_objects(rng.integers(0, 256, (num_objects, code.k, q), dtype=np.uint8))
    return gw


# ---------------------------------------------------------------------------
# kernel level: the ragged ENCODE entries match host oracles
# ---------------------------------------------------------------------------

def test_ragged_gf256_encode_matches_parity_oracle():
    n, k, tn = 9, 6, 256
    rng = np.random.default_rng(3)
    pmat = rs.parity_matrix(n, k)  # (n - k, k)
    c = CHUNK_SMALL
    data = rng.integers(0, 256, (c, k, tn), dtype=np.uint8)
    # one tile per op; a single coefficient row per tile (the coalescer
    # splits multi-target EH ops into one tile per parity column)
    mc = np.stack(
        [expand_coeff_bitplanes(pmat[i % (n - k)][None, :]) [0] for i in range(c)]
    )
    out = np.asarray(ops.gf256_ragged_encode(mc, data, interpret=True))
    for i in range(c):
        want = np_matmul(pmat[i % (n - k)][None, :], data[i])[0]
        assert np.array_equal(out[i], want)


def test_ragged_xor_encode_matches_fold_oracle():
    tn = 128
    rng = np.random.default_rng(4)
    c = CHUNK_SMALL
    kk = 5  # stored parity + two (old, new) delta pairs
    data = rng.integers(0, 256, (c, kk, tn), dtype=np.uint8)
    out = np.asarray(ops.xor_ragged_encode(data, interpret=True))
    for i in range(c):
        want = data[i][0].copy()
        for r in range(1, kk):
            want ^= data[i][r]
        assert np.array_equal(out[i], want)


# ---------------------------------------------------------------------------
# sealer unit behavior
# ---------------------------------------------------------------------------

def test_sealer_extents_never_span_rows_and_flush_pads():
    s = StripeSealer(k=2, q=64)  # 128-byte rows
    assert s.append(("a",), np.arange(100, dtype=np.uint8), "t") == []
    # 100 + 60 > 128: the open row seals EARLY (zero-padded tail) and
    # the new extent starts at offset 0 of the next row
    sealed = s.append(("b",), np.full(60, 7, np.uint8), "t")
    assert len(sealed) == 1
    seq, row, exts = sealed[0]
    assert seq == 0 and row.shape == (2, 64)
    assert [e.small_id for e in exts] == [("a",)]
    assert np.all(row.reshape(-1)[100:] == 0)  # zero-padded tail
    assert s.pending_extents == 1 and s.pending_bytes == 60
    (seq2, row2, exts2) = s.flush()[0]
    assert seq2 == 1 and exts2[0].offset == 0 and exts2[0].length == 60
    with pytest.raises(ValueError):
        s.append(("c",), np.zeros(129, np.uint8), "t")  # > one row


# ---------------------------------------------------------------------------
# PUT-path physics: billed encode, transfer causality, admission
# ---------------------------------------------------------------------------

def test_put_latency_includes_billed_encode_launches():
    code = CoreCode(9, 6, 3)
    enc = 0.004
    gw = _gateway(code, encode_cost=enc, decode_cost=0.002)
    reqs = [Request(time=0.001 * (i + 1), object_id=i % 6, kind="put")
            for i in range(6)]
    rep = gw.serve(reqs)
    puts = [r for r in rep.records if r.kind == "put"]
    assert len(puts) == 6
    # transfers may not start before the EH launch lands, so every PUT
    # pays at least one modeled encode launch of sim time
    assert all(r.latency is not None and r.latency > enc for r in puts)
    assert gw.coalescer.stats.encode_calls > 0
    assert rep.metrics.gauge("encode_launches").value > 0


def test_put_encode_rides_the_shared_engine_pool():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, encode_cost=0.05, decode_cost=0.002, num_engines=1)
    free0 = list(gw._pool.free)
    rep = gw.serve([Request(time=0.001, object_id=0, kind="put")])
    assert rep.records[0].latency > 0.05
    # the pool's timeline advanced: encode occupied a real engine slot
    assert max(gw._pool.free) > max(free0)


def test_put_admission_rejects_and_counts_per_tenant():
    code = CoreCode(9, 6, 3)
    gw = _gateway(
        code,
        decode_cost=0.002,
        admission="reject",
        tenant_slo_p99={"foreground": 1e-6},  # everything busts it
    )
    reqs = [Request(time=0.001 * (i + 1), object_id=i % 6, kind="put")
            for i in range(4)]
    rep = gw.serve(reqs)
    assert rep.put_rejections.get("foreground") == 4
    assert all(r.rejected and r.latency is None for r in rep.records)
    assert rep.metrics.counter("rejected_requests", tenant="foreground").value == 4


def test_write_pressure_feeds_get_admission_estimate():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, decode_cost=0.002)
    gid, row = gw._objects[0]
    plan = gw.planner.plan(gid, row, at=0.0)
    base = gw._estimate_service_time(plan, 0.0, "foreground")
    gw._put_inflight["foreground"] = [(5.0, 1e7)]  # committed write bytes
    loaded = gw._estimate_service_time(plan, 0.0, "foreground")
    assert loaded > base


# ---------------------------------------------------------------------------
# deletes
# ---------------------------------------------------------------------------

def test_delete_tombstones_and_put_resurrects():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, decode_cost=0.002)
    rep = gw.serve(
        [
            Request(time=0.001, object_id=0, kind="delete"),
            Request(time=0.002, object_id=0, kind="get"),
            Request(time=0.003, object_id=0, kind="put"),
            Request(time=0.010, object_id=0, kind="get"),
            Request(time=0.011, object_id=0, kind="delete"),
            Request(time=0.012, object_id=0, kind="delete"),  # double delete
        ]
    )
    by = {}
    for r in rep.records:
        by.setdefault(r.kind, []).append(r)
    assert by["delete"][0].latency == 0.0
    assert by["delete"][1].latency == 0.0
    assert by["delete"][2].latency is None  # already tombstoned
    assert by["get"][0].latency is None  # deleted => not found
    assert by["get"][1].latency is not None  # resurrected by the PUT
    assert gw.audit_parity()["stale_blocks"] == 0


# ---------------------------------------------------------------------------
# sync-vs-ragged write paths: identical stored state
# ---------------------------------------------------------------------------

def test_sync_and_ragged_write_paths_store_identical_bytes():
    code = CoreCode(9, 6, 3)
    reqs = []
    t = 0.001
    for i in range(8):
        reqs.append(Request(time=t, object_id=i % 5, kind="put"))
        t += 0.0005
    for i in range(6):
        reqs.append(Request(time=t, object_id=200 + i, kind="put", nbytes=4000))
        t += 0.0005
    stores = {}
    for mode in ("ragged", "sync"):
        gw = _gateway(code, decode_cost=0.002, write_coalesce=mode,
                      batch_window=0.01)
        gw.serve(list(reqs))
        gw.seal_flush(t)
        assert gw.audit_parity()["stale_blocks"] == 0
        assert gw.audit_sealed_stripes()["extents_wrong"] == 0
        stores[mode] = gw.store
    a, b = stores["ragged"], stores["sync"]
    assert set(a.blocks) == set(b.blocks)
    for key in a.blocks:
        assert np.array_equal(a.blocks[key], b.blocks[key]), key


# ---------------------------------------------------------------------------
# sealed stripes decode through degraded paths
# ---------------------------------------------------------------------------

def test_sealed_small_puts_survive_node_failure_degraded():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, decode_cost=0.002, batch_window=0.01)
    t = 0.001
    reqs = []
    for i in range(40):  # enough small puts to seal several full rows
        reqs.append(Request(time=t, object_id=1000 + i, kind="put", nbytes=3000))
        t += 0.0004
    gw.serve(reqs)
    gw.seal_flush(t)
    assert gw._seal_group_seq >= 1
    clean = gw.audit_sealed_stripes()
    assert clean["extents_checked"] == 40 and clean["extents_wrong"] == 0
    # knock out a node holding a sealed data block: the audit must now
    # route those rows through a DEGRADED decode and still match digests
    victim = gw.store.node_of(("w0", 0, 0))
    gw.store.fail_nodes([victim])
    after = gw.audit_sealed_stripes()
    assert after["rows_degraded"] >= 1
    assert after["extents_wrong"] == 0 and after["rows_unreadable"] == 0


# ---------------------------------------------------------------------------
# churn consistency: faulted trace vs clean oracle + replay identity
# ---------------------------------------------------------------------------

def _churn_setup(code):
    num_nodes = 20
    trace = ScenarioTrace(num_nodes=num_nodes, nodes_per_rack=code.n - code.k)
    trace = rack_failure(trace, 0.05, rack=1, downtime=0.6)
    trace = ScenarioTrace(
        num_nodes=num_nodes,
        nodes_per_rack=code.n - code.k,
        events=tuple(
            sorted(
                list(trace.events)
                + [CorruptionEvent(time=0.12, node=14, count=2)],
                key=lambda e: e.time,
            )
        ),
        surges=trace.surges,
    )
    wl = WorkloadConfig(
        num_objects=24,
        num_requests=160,
        arrival_rate=300.0,
        zipf_s=0.6,
        put_fraction=0.35,
        delete_fraction=0.05,
        small_put_fraction=0.3,
        small_put_bytes=3000,
        seed=11,
    )
    kwargs = dict(
        batch_window=0.01,
        decode_cost=0.002,
        repair_on_failure=True,
        repair_delay=0.05,
        record_payloads=True,
        interpret=True,
    )
    return trace, wl, kwargs


def _run_churn(code, trace, wl, kwargs, faulted=True):
    gw = ObjectGateway(
        code,
        ClusterProfile.network_critical(),
        trace.num_nodes,
        GatewayConfig(**kwargs),
    )
    rng = np.random.default_rng(9)
    gw.load_objects(
        rng.integers(0, 256, (wl.num_objects, code.k, 2048), dtype=np.uint8)
    )
    reqs = scenario_requests(wl, trace)
    events = trace.cluster_events() if faulted else []
    report = gw.serve(reqs, events)
    gw.seal_flush(reqs[-1].time + 1.0)
    return gw, ScenarioResult(
        report=report, durability=gw.audit_durability(), trace=trace
    )


def test_churn_consistency_audit_under_within_tolerance_faults():
    code = CoreCode(9, 6, 3)
    trace, wl, kwargs = _churn_setup(code)
    gw, faulted = _run_churn(code, trace, wl, kwargs, faulted=True)
    _, clean = _run_churn(code, trace, wl, kwargs, faulted=False)

    # the trace stays within tolerance: nothing provably lost
    assert faulted.durability["blocks_lost"] == 0

    # every GET that completed in BOTH runs returned byte-identical
    # payloads (faulted reads go through degraded decode paths)
    def digests(res):
        return {
            (round(r.time, 9), r.object_id): r.payload_digest
            for r in res.report.records
            if r.kind == "get" and r.latency is not None
        }
    dx, dc = digests(faulted), digests(clean)
    shared = set(dx) & set(dc)
    assert shared, "no comparable GETs between faulted and clean runs"
    assert all(dx[key] == dc[key] for key in shared)

    # vertical parity never went stale through the whole churn trace,
    # and every sealed extent decodes byte-identically
    parity = gw.audit_parity()
    assert parity["stale_blocks"] == 0
    sealed = gw.audit_sealed_stripes()
    assert sealed["extents_wrong"] == 0 and sealed["extents_pending"] == 0

    # replay identity: modeled costs make the faulted run bit-for-bit
    # reproducible
    _, faulted2 = _run_churn(code, trace, wl, kwargs, faulted=True)
    assert deterministic_fingerprint(faulted) == deterministic_fingerprint(
        faulted2
    )


def test_encode_jit_signatures_stay_bounded_per_kind():
    code = CoreCode(9, 6, 3)
    gw = _gateway(code, decode_cost=0.002, batch_window=0.01)
    t = 0.001
    reqs = []
    for i in range(30):  # mixed window sizes: 1-PUT and many-PUT batches
        reqs.append(Request(time=t, object_id=i % 12, kind="put"))
        t += 0.0003 if i % 5 else 0.05
    gw.serve(reqs)
    by_kind = gw.coalescer.jit_entries_by_kind()
    assert by_kind.get("EH", 0) >= 1
    assert all(v <= 2 for k, v in by_kind.items() if k in ("EH", "EV")), by_kind
