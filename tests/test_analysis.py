"""Tests for §5 analysis: closed forms vs Monte-Carlo, paper-claim checks."""

import numpy as np
import pytest

from repro.core import CoreCode, analysis
from repro.core.analysis import (
    degraded_read_core,
    degraded_read_lrc,
    degraded_read_mds,
    mc_repair_core,
    mc_repair_lrc,
    mc_repair_mds,
    nines,
    resilience_core_lower,
    resilience_lrc,
    resilience_mds,
)


def test_resilience_mds_edge_cases():
    assert resilience_mds(9, 6, 0.0) == pytest.approx(1.0)
    assert resilience_mds(9, 6, 1.0) == pytest.approx(0.0)
    # replication sanity: (2,1) tolerates one loss
    assert resilience_mds(2, 1, 0.5) == pytest.approx(0.75)


def test_resilience_mds_matches_simulation():
    rng = np.random.default_rng(0)
    n, k, p = 9, 6, 0.1
    hits = sum(int((rng.random(n) < p).sum() <= n - k) for _ in range(20000))
    assert resilience_mds(n, k, p) == pytest.approx(hits / 20000, abs=0.01)


def test_resilience_core_lower_is_lower_bound_vs_checker():
    from repro.core.recoverability import is_recoverable

    code = CoreCode(9, 6, 3)
    rng = np.random.default_rng(1)
    p = 0.08
    n_samples = 4000
    rec = 0
    for _ in range(n_samples):
        fm = rng.random((code.t + 1, code.n)) < p
        rec += is_recoverable(code, fm)
    empirical = rec / n_samples
    bound = resilience_core_lower(code.n, code.k, code.t, p)
    assert bound <= empirical + 0.01  # lower bound (allow MC noise)


def test_fig4_ordering_core_beats_lrc_at_same_stretch():
    """Paper Fig 4: at ~1.4x stretch, CORE's (lower-bound) resilience
    dominates LRC for realistic p. CORE (14,12,5): 14/12 * 6/5 = 1.4;
    LRC (14,10): 1.4. (At p >~ 0.1 the CORE *lower bound* becomes loose
    and dips below LRC's exact value — the bound crosses, not the code.)"""
    for p in (0.002, 0.005, 0.01, 0.02, 0.05):
        pi_l = resilience_lrc(14, 10, p)
        pi_c = resilience_core_lower(14, 12, 5, p)
        assert pi_c >= pi_l - 1e-12, (p, pi_c, pi_l)


def test_nines():
    assert nines(0.999) == pytest.approx(3.0, abs=1e-9)
    assert nines(0.0) == pytest.approx(0.0)


def test_single_failure_traffic_claims():
    """Paper: single failure — CORE transfers t blocks vs k for MDS; with
    t = k/2 this is the headline 50% saving."""
    n, k, t = 14, 12, 6
    res_core = mc_repair_core(n, k, t, p=0.004, samples=4000, seed=2)
    res_mds = mc_repair_mds(n, k, p=0.004, samples=4000, seed=2)
    # at tiny p nearly all repairs are single-failure
    assert res_core.mean_traffic == pytest.approx(t / k, abs=0.05)
    assert res_mds.mean_traffic == pytest.approx(1.0, abs=0.01)
    assert res_core.mean_traffic < 0.62 * res_mds.mean_traffic


def test_repair_time_core_much_faster():
    """Paper Fig 6: CORE repair time ~an order of magnitude below EC
    (vertical repairs run concurrently and independently)."""
    n, k, t = 14, 12, 5
    res_core = mc_repair_core(n, k, t, p=0.01, samples=2000, seed=3)
    res_mds = mc_repair_mds(n, k, p=0.01, samples=2000, seed=3)
    assert res_core.mean_time < 0.7 * res_mds.mean_time


def test_lrc_single_repair_cost_average():
    n, k = 10, 6
    res = mc_repair_lrc(n, k, p=0.003, samples=6000, seed=4)
    from repro.coding.lrc import avg_single_repair_cost

    want = avg_single_repair_cost(n, k) / k
    assert res.mean_traffic == pytest.approx(want, abs=0.06)


def test_degraded_reads_low_p_all_equal_one():
    """Paper Fig 7: at p=0.01 all three codes read ~1.0x the object."""
    for fn, args in (
        (degraded_read_mds, (9, 6)),
        (degraded_read_lrc, (10, 6)),
        (degraded_read_core, (9, 6, 3)),
    ):
        v = fn(*args, p=0.01, samples=3000, seed=5)
        assert v == pytest.approx(1.0, abs=0.1), fn.__name__


def test_degraded_reads_distributed_ec_worst():
    """Paper Fig 8: at p=0.1, EC needs more distributed-read traffic than
    LRC/CORE."""
    ec = degraded_read_mds(9, 6, p=0.1, samples=4000, seed=6, distributed=True)
    lr = degraded_read_lrc(10, 6, p=0.1, samples=4000, seed=6, distributed=True)
    co = degraded_read_core(9, 6, 3, p=0.1, samples=4000, seed=6, distributed=True)
    assert co < ec
    assert lr < ec


def test_param_sweeps_nonempty():
    assert analysis.core_params_for_stretch(1.5)
    assert analysis.ec_params_for_stretch(1.5)
    assert analysis.lrc_params_for_stretch(1.67)
