"""Version-compat shims for the jax 0.4.x <-> 0.5+ API split.

The repo targets current jax APIs; on older installs (e.g. the 0.4.37
baked into this container) the same entry points live elsewhere or take
different kwargs. Centralizing the fallbacks keeps call sites on the
modern spelling. Siblings: models/shardings.get_abstract_mesh,
launch/mesh.mesh_context and _auto_axis_kwargs,
analysis/hlo_cost.builtin_cost_dict.
"""

from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map.shard_map``
    (0.4.x, where ``check_vma`` was named ``check_rep``)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
