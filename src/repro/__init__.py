"""Reproduction of "The CORE Storage Primitive" (cs.DC 2013), grown into
a jax_pallas storage + serving system.

Package map:

  coding/    GF(2^8) arithmetic, generic linear codes, RS / LRC / SPC.
  core/      the (n, k, t) CORE product code: codec, failure matrices,
             recoverability, repair scheduling (row/column/RGS).
  kernels/   Pallas TPU kernels for the compute hot spots — bit-sliced
             GF(256) coefficient x data matmul (single and stacked
             (B, M, K) x (B, K, N) batched entry) and vertical XOR
             parity, with a pure-jnp oracle (ref.py) and backend
             auto-detect (backend.py).
  storage/   the simulated cluster: anti-colocated BlockStore, the
             priority-class NetSimulator fabric, and BlockFixer (repair
             engine: hdfs_raid / hdfs_raid_opt / core modes).
  gateway/   the client-facing serving layer: Zipf/Poisson workloads,
             per-request degraded-read planning (paper Table 1 costs),
             shape-bucketed batched decode coalescing, LRU block cache,
             and an event-driven PUT/GET gateway where background repair
             contends with foreground reads on the shared fabric
             (examples/gateway_serving.py is the quickstart).
  checkpoint/ CORE-coded training checkpoints over the block store.
  models/, train/, serve/, launch/, configs/, data/, analysis/
             the jax model stack the storage layer feeds (training and
             serving loops, meshes, HLO cost/roofline analysis).

Benchmarks mirror the paper's figures (benchmarks/run.py; --fast runs
the gateway_load + kernels smoke set), and tests/ cross-validate every
layer against analytic counts or pure-numpy oracles.
"""
