"""KV-cache management for serving: layout planning + a slot-based
continuous-batching manager.

The layout planner (models/shardings.make_serve_plan) decides, per
(arch, batch, cache_len), whether the cache shards KV heads on tp,
sequence on tp, or sequence over the whole mesh (long_500k). This module
adds the request-level bookkeeping used by serve loops: fixed-slot
continuous batching (a finished request frees its slot; a waiting
request claims it and is prefix-prefilled into the shared cache).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.registry import ModelApi
from repro.models.shardings import MeshAxes, ServePlan, make_serve_plan


def plan_for(cfg: ArchConfig, ax: MeshAxes, batch: int, cache_len: int) -> ServePlan:
    return make_serve_plan(cfg, ax, batch, cache_len)


def cache_bytes(cfg: ArchConfig, api: ModelApi, batch: int, cache_len: int) -> int:
    tree = api.cache_shape(cfg, batch, cache_len)
    return sum(
        int(np.prod(s.shape)) * s.dtype.itemsize for s in jax.tree.leaves(tree)
    )


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    generated: list[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


@dataclass
class SlotManager:
    """Fixed-B continuous batching: slot i of the batched cache belongs to
    at most one live request; pos counters are per-slot."""

    batch: int
    cache_len: int
    slots: list = field(default_factory=list)
    pos: np.ndarray = None
    waiting: list = field(default_factory=list)
    finished: list = field(default_factory=list)

    def __post_init__(self):
        self.slots = [None] * self.batch
        self.pos = np.zeros((self.batch,), np.int32)

    def submit(self, req: Request):
        self.waiting.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots from the waiting queue; returns (slot, request)
        pairs that need prefill."""
        admitted = []
        for i in range(self.batch):
            if self.slots[i] is None and self.waiting:
                req = self.waiting.pop(0)
                self.slots[i] = req
                self.pos[i] = len(req.prompt)
                admitted.append((i, req))
        return admitted

    def step_tokens(self) -> np.ndarray:
        """Last token per slot (pad = 0 for empty slots)."""
        out = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            out[i, 0] = req.generated[-1] if req.generated else req.prompt[-1]
        return out

    def record(self, next_tokens: np.ndarray):
        """Append sampled tokens; retire finished requests."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(next_tokens[i]))
            self.pos[i] += 1
            if req.done or self.pos[i] >= self.cache_len:
                self.finished.append(req)
                self.slots[i] = None

    @property
    def live(self) -> int:
        return sum(r is not None for r in self.slots)
