"""jit-able serving steps (prefill + single-token decode) with sharding
plumbing, used by launch/serve.py, launch/dryrun.py (decode cells) and
the serving example.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import ModelApi
from repro.models.shardings import MeshAxes, ServePlan


def make_prefill_step(cfg: ArchConfig, api: ModelApi, ax: MeshAxes, cache_len: int) -> Callable:
    def prefill_step(params, batch):
        return api.prefill(params, batch, cfg, ax, cache_len)

    return prefill_step


def make_decode_step(cfg: ArchConfig, api: ModelApi, ax: MeshAxes, plan: ServePlan) -> Callable:
    def decode_step(params, cache, token, pos):
        logits, new_cache = api.decode(params, token, cache, pos, cfg, ax, plan)
        return logits, new_cache

    return decode_step


def decode_input_shapes(cfg: ArchConfig, batch: int, cache_len: int, api: ModelApi):
    """ShapeDtypeStructs for the decode step: (cache, token, pos)."""
    return (
        api.cache_shape(cfg, batch, cache_len),
        jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
