"""BlockFixer: the repair engine over the simulated block store.

Three modes reproduce the paper's §8 comparison:

  * ``hdfs_raid``      — classic HDFS-RAID: discovers failures one at a
    time (no Opt2) and, per failure, fetches *all* remaining blocks of
    the stripe (generator-polynomial style, no Opt1), decodes, and
    regenerates just that block.
  * ``hdfs_raid_opt``  — with the paper's two optimizations: Opt1 fetch
    exactly k blocks; Opt2 detect all failures of a stripe up front and
    repair them with a single decode.
  * ``core``           — full §6 pipeline: failure-matrix population →
    independent clusters → recoverability check → repair scheduling
    (row-first / column-first / RGS) → execution with XOR verticals and
    RS horizontals.

Bytes moved are exact (they must match the analytical numbers — the
paper applies the same cross-check in §8); network time is simulated by
``NetSimulator``; compute time is *measured* on the real jitted codec
math and scaled by the cluster profile.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.coding import gf256
from repro.core.failure_matrix import independent_clusters
from repro.core.product_code import CoreCode, CoreCodec
from repro.core.recoverability import is_recoverable
from repro.core.scheduling import SCHEDULERS, RepairStep
from repro.storage.blockstore import BlockStore
from repro.storage.netmodel import ClusterProfile, NetSimulator, Transfer


@dataclass
class RepairReport:
    mode: str
    blocks_fetched: int = 0
    bytes_fetched: int = 0
    blocks_repaired: int = 0
    network_time: float = 0.0
    compute_time: float = 0.0
    schedule: str = ""
    recovered: bool = True

    @property
    def total_time(self) -> float:
        return self.network_time + self.compute_time


class UnrecoverableError(RuntimeError):
    pass


@dataclass
class PacingController:
    """SLO-aware closed-loop repair pacing.

    Maps observed foreground latency headroom to the repair tenant's
    fabric weight and decode-engine share: when the protected tier's p99
    approaches its SLO the repair share backs off toward ``min_share``
    (foreground keeps its headroom); when the tier is comfortably inside
    its target — or there is no foreground traffic at all — repair
    accelerates toward ``max_share`` so MTTR stays bounded. An MTTR
    urgency term overrides the backoff as a repair drags past
    ``mttr_target``: durability pressure eventually outranks latency
    pressure, which is what keeps paced MTTR within a constant factor of
    repair-at-full-weight no matter how long a foreground surge lasts.

    The controller is pure policy — callers feed it observations
    (``share(...)``) and apply the result to the fabric
    (``NetSimulator.set_tenant_weight``) and the engine pool.
    ``min_share`` also acts as the mechanical MTTR guard: repair fabric
    time at weight w is ~1/w of full-weight time, so min_share=0.5 bounds
    the paced fabric slowdown at 2x even before urgency kicks in.
    """

    min_share: float = 0.5  # floor while foreground SLOs are at risk
    max_share: float = 1.0  # ceiling when idle / healthy
    # headroom = (slo - p99) / slo. At or below the floor the repair runs
    # at min_share; at or above the ceiling it runs at max_share; linear
    # in between (a proportional controller — no integral term, so a
    # stale observation cannot wind up).
    headroom_floor: float = 0.0
    headroom_ceiling: float = 0.5
    # When a repair has been outstanding longer than mttr_target seconds,
    # urgency ramps the share back up regardless of foreground pressure
    # (reaching max_share at 2x the target).
    mttr_target: float | None = None

    def __post_init__(self):
        if not 0.0 < self.min_share <= self.max_share <= 1.0:
            raise ValueError(
                f"need 0 < min_share <= max_share <= 1, got "
                f"{self.min_share}/{self.max_share}"
            )
        if not self.headroom_floor < self.headroom_ceiling:
            raise ValueError("headroom_floor must be < headroom_ceiling")

    def share(
        self,
        observed_p99: float | None,
        slo: float | None,
        outstanding_for: float = 0.0,
    ) -> float:
        """Repair share for the next repair step.

        ``observed_p99``: the protected tier's recent p99 (None => no
        recent foreground traffic, i.e. idle). ``slo``: its latency
        target (None => nothing to protect). ``outstanding_for``: how
        long the oldest unrepaired loss has been waiting (seconds)."""
        if slo is None or observed_p99 is None:
            base = self.max_share
        else:
            headroom = (slo - observed_p99) / slo
            frac = (headroom - self.headroom_floor) / (
                self.headroom_ceiling - self.headroom_floor
            )
            frac = min(1.0, max(0.0, frac))
            base = self.min_share + frac * (self.max_share - self.min_share)
        if self.mttr_target is not None and outstanding_for > self.mttr_target:
            urgency = min(1.0, outstanding_for / self.mttr_target - 1.0)
            base = max(base, self.min_share + urgency * (self.max_share - self.min_share))
        return base


@dataclass
class Scrubber:
    """Background integrity scrubber: walks the store's blocks at a paced
    rate recomputing checksums, so LATENT corruption (a bit flip nobody
    has read yet) is found and queued for repair before a foreground GET
    trips over it — the proactive half of the corruption-as-erasure
    plane (the reactive half is the gateway's fetch-time verify).

    Pure detection: ``scan`` verifies up to ``budget`` blocks from a
    persistent cursor (round-robin over the key space, wrapping) and
    returns the keys that failed — the owner decides quarantine/repair.
    The per-tick budget is the pacing surface: the gateway multiplies
    ``blocks_per_run`` by the ``PacingController`` share, so scrubbing
    backs off exactly like repair when foreground SLOs are at risk."""

    store: BlockStore
    blocks_per_run: int = 64
    scanned: int = 0
    found: int = 0
    _cursor: int = 0

    def scan(self, budget: int | None = None) -> list:
        budget = self.blocks_per_run if budget is None else int(budget)
        keys = sorted(self.store.blocks.keys())
        if not keys or budget <= 0:
            return []
        budget = min(budget, len(keys))
        bad = []
        start = self._cursor % len(keys)
        for i in range(budget):
            key = keys[(start + i) % len(keys)]
            self.scanned += 1
            if not self.store.verify(key):
                bad.append(key)
        self._cursor = (start + budget) % len(keys)
        self.found += len(bad)
        return bad


@dataclass
class BlockFixer:
    store: BlockStore
    code: CoreCode
    profile: ClusterProfile
    mode: str = "core"  # hdfs_raid | hdfs_raid_opt | core
    scheduler: str = "rgs"  # row_first | column_first | rgs
    # Optional shared fabric: when ``sim`` is set, repair transfers are
    # scheduled on that simulator (at ``priority`` — any tenant id the
    # simulator's tenant_weights knows) instead of a private one, so they
    # contend with whatever else rides the fabric — the gateway runs
    # repair as the "repair" tenant here while client reads ride their
    # own tenants on the same NetSimulator.
    sim: NetSimulator | None = None
    priority: object = 0
    not_before: float = 0.0  # earliest start (failure-detection time)
    # Invoked with each BlockKey this fixer writes back, right after the
    # store write. The gateway uses it to re-price / refresh cache
    # entries whose underlying block just became a cheap store read
    # again (cost-aware eviction, gateway/cache.py).
    on_block_repaired: "Callable[[tuple], None] | None" = None
    # Observability (repro.obs): when the owner sets ``tracer`` and
    # ``trace_ctx`` ((trace_id, parent_span_id)), repairs emit
    # repair-track spans and their fabric transfers emit port spans
    # into that trace. Observation-only.
    tracer: object = None
    trace_ctx: tuple | None = None
    # Code family (repro.gateway.planner.CodeFamily). None or a "core"
    # family keeps the product-code modes above; a row family ("rs" /
    # "lrc") repairs through the family's repair_plan — LRC local steps
    # fetch ONLY the local group (k/2 survivors), not k blocks.
    family: object = None

    def __post_init__(self):
        self.codec = CoreCodec(self.code)
        self._timed = 0.0

    def _obs_ctx(self) -> tuple | None:
        """(trace_id, parent_id) when span emission is live, else None."""
        if (
            self.tracer is not None
            and getattr(self.tracer, "enabled", False)
            and self.trace_ctx is not None
        ):
            return self.trace_ctx
        return None

    def _sim(self) -> NetSimulator:
        sim = self.sim if self.sim is not None else NetSimulator(self.profile)
        # Baseline for duration accounting: on a shared fabric the class
        # makespan is cumulative across calls, so each call reports only
        # its own extension of it.
        self._net_baseline = sim.class_makespan.get(self.priority, 0.0)
        return sim

    def _net_time(self, sim: NetSimulator) -> float:
        end = sim.class_makespan.get(self.priority, 0.0)
        if self.sim is None:
            return end
        # shared fabric: duration of THIS repair, not the absolute clock
        start = max(self._net_baseline, self.not_before)
        return max(0.0, end - start)

    # -- timed codec ops ------------------------------------------------------
    def _measure(self, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        self._timed += (time.perf_counter() - t0) * self.profile.compute_scale
        return out

    def _vertical_repair(self, sources: np.ndarray) -> np.ndarray:
        return np.asarray(self._measure(_xor_jit, jnp.asarray(sources)))

    def _horizontal_repair(
        self, avail_cols: np.ndarray, blocks: np.ndarray, missing_cols: np.ndarray
    ) -> np.ndarray:
        row_ids, coeffs = self.code.horizontal.repair_matrix(avail_cols, missing_cols)
        pos = {int(a): i for i, a in enumerate(avail_cols)}
        sel = np.asarray([pos[int(r)] for r in row_ids])
        return np.asarray(
            self._measure(_gf_matmul_jit, jnp.asarray(coeffs), jnp.asarray(blocks[sel]))
        )

    # -- main entry ------------------------------------------------------------
    def fix_group(self, group_id: str, rows: int | None = None) -> RepairReport:
        """Detect and repair all missing blocks of a group."""
        self._timed = 0.0
        if (
            self.family is not None
            and getattr(self.family, "name", "core") != "core"
        ):
            return self._fix_family(group_id)
        rows = rows if rows is not None else self.code.rows
        cols = self.code.n
        if self.mode == "core":
            return self._fix_core(group_id, rows, cols)
        return self._fix_raid(group_id, rows, cols, optimized=self.mode == "hdfs_raid_opt")

    # -- row-family mode (rs / lrc via CodeFamily.repair_plan) -----------------
    def _fix_family(self, group_id: str) -> RepairReport:
        """Repair the group's single codeword row through the family's
        repair plan. LRC 'local' steps fetch ONLY the k/2 surviving
        members of the broken local group and XOR them — the locality
        win the bake-off bench measures against the RS baseline, whose
        every repair is a 'global' k-source GF(256) decode."""
        fam = self.family
        report = RepairReport(mode=fam.name)
        cols = self.code.n
        failed = [
            c for c in range(cols) if not self.store.available((group_id, 0, c))
        ]
        if not failed:
            return report
        sim = self._sim()
        plan = fam.repair_plan(set(failed))
        if plan is None:
            report.recovered = False
            report.network_time = self._net_time(sim)
            return report
        ctx = self._obs_ctx()
        descs = []
        # a block repaired by an earlier step may serve as a later step's
        # source; its bytes exist only once its own fetches landed
        repaired_ready: dict[int, float] = {}
        for kind, sources, repaired in plan:
            blocks = np.stack(
                [self.store.get((group_id, 0, c)) for c in sources]
            )
            dst = self._dst_node(group_id, 0, repaired[0])
            ready = 0.0
            for c in sources:
                src_node = self.store.node_of((group_id, 0, c))
                ready = max(
                    ready,
                    sim.transfer(
                        Transfer(
                            src_node,
                            dst,
                            blocks[0].nbytes,
                            max(repaired_ready.get(c, 0.0), self.not_before),
                            priority=self.priority,
                            ctx=ctx,
                        )
                    ),
                )
            if kind == "local":
                rep = self._vertical_repair(blocks)[None]
            else:
                rep = self._family_global_repair(
                    np.asarray(sources), blocks, np.asarray(repaired)
                )
            for i, c in enumerate(repaired):
                self.store.put_block((group_id, 0, c), rep[i])
                repaired_ready[c] = ready
                if self.on_block_repaired is not None:
                    self.on_block_repaired((group_id, 0, c))
                # redistribution of extra regenerated blocks to their homes
                if i > 0:
                    home = self.store.node_of((group_id, 0, c))
                    sim.transfer(
                        Transfer(
                            dst, home, rep[i].nbytes, ready,
                            priority=self.priority, ctx=ctx,
                        )
                    )
            report.blocks_fetched += len(sources)
            report.bytes_fetched += int(blocks.nbytes)
            report.blocks_repaired += len(repaired)
            descs.append(f"{'L' if kind == 'local' else 'G'}x{len(repaired)}")
        report.network_time = self._net_time(sim)
        report.compute_time = self._timed
        report.schedule = ",".join(descs)
        self._emit_group_span(group_id, sim, report)
        return report

    def _family_global_repair(
        self, sources: np.ndarray, blocks: np.ndarray, missing: np.ndarray
    ) -> np.ndarray:
        """GF(256) repair through the family's own generator (LRC's
        global parities are not the plain RS rows, so this cannot reuse
        ``code.horizontal``)."""
        row_ids, coeffs = self.family.code.repair_matrix(sources, missing)
        pos = {int(a): i for i, a in enumerate(sources)}
        sel = np.asarray([pos[int(r)] for r in row_ids])
        return np.asarray(
            self._measure(
                _gf_matmul_jit, jnp.asarray(coeffs), jnp.asarray(blocks[sel])
            )
        )

    # -- HDFS-RAID modes --------------------------------------------------------
    def _fix_raid(self, group_id: str, rows: int, cols: int, optimized: bool) -> RepairReport:
        """Row-by-row (per-stripe) RS repair, no cross-object parity use."""
        report = RepairReport(mode="hdfs_raid_opt" if optimized else "hdfs_raid")
        sim = self._sim()
        sched_desc = []
        for r in range(rows):
            failed = [c for c in range(cols) if not self.store.available((group_id, r, c))]
            if not failed:
                continue
            if len(failed) > self.code.m:
                report.recovered = False
                continue
            if optimized:
                batches = [failed]  # Opt2: all failures of the stripe at once
            else:
                batches = [[c] for c in failed]  # classic: discovered one by one
            repaired_cells: set[int] = set()
            for batch in batches:
                avail = [
                    c
                    for c in range(cols)
                    if c not in failed or c in repaired_cells
                ]
                if optimized:
                    fetch_cols = avail[: self.code.k]  # Opt1: exactly k
                else:
                    fetch_cols = avail  # classic: ALL remaining blocks
                blocks = np.stack([self._get(group_id, r, c, repaired_cells) for c in fetch_cols])
                dst = self._dst_node(group_id, r, batch[0])
                ready = 0.0
                for c in fetch_cols:
                    src = self.store.node_of((group_id, r, c))
                    ready = max(
                        ready,
                        sim.transfer(
                            Transfer(
                                src,
                                dst,
                                blocks[0].nbytes,
                                self.not_before,
                                priority=self.priority,
                            )
                        ),
                    )
                rep = self._horizontal_repair(
                    np.asarray(fetch_cols[: self.code.k]),
                    blocks[: self.code.k],
                    np.asarray(batch),
                )
                for i, c in enumerate(batch):
                    self.store.put_block((group_id, r, c), rep[i])
                    repaired_cells.add(c)
                    if self.on_block_repaired is not None:
                        self.on_block_repaired((group_id, r, c))
                report.blocks_fetched += len(fetch_cols)
                report.bytes_fetched += sum(b.nbytes for b in blocks)
                report.blocks_repaired += len(batch)
                sched_desc.append(f"H{r}x{len(batch)}")
        report.network_time = self._net_time(sim)
        report.compute_time = self._timed
        report.schedule = ",".join(sched_desc)
        return report

    # -- CORE mode ---------------------------------------------------------------
    def _fix_core(self, group_id: str, rows: int, cols: int) -> RepairReport:
        report = RepairReport(mode="core")
        fm = self.store.failure_matrix(group_id, rows, cols)
        if not fm.any():
            return report
        sim = self._sim()
        descs = []
        block_ready: dict[tuple[int, int], float] = {}
        for cluster in independent_clusters(fm):
            if not is_recoverable(self.code, cluster):
                report.recovered = False  # partial recovery: other clusters proceed
                continue
            sched = SCHEDULERS[self.scheduler](self.code, cluster)
            assert sched is not None
            descs.append(sched.describe())
            for step in sched.steps:
                self._execute_step(group_id, step, sim, block_ready, report)
        report.network_time = self._net_time(sim)
        report.compute_time = self._timed
        report.schedule = ";".join(descs)
        self._emit_group_span(group_id, sim, report)
        return report

    def _emit_group_span(
        self, group_id: str, sim: NetSimulator, report: RepairReport
    ) -> None:
        ctx = self._obs_ctx()
        if ctx is None or report.blocks_repaired == 0:
            return
        tid, pid = ctx
        end = max(
            sim.class_makespan.get(self.priority, self.not_before),
            self.not_before,
        )
        self.tracer.span(
            "repair.group",
            self.not_before,
            end,
            tid,
            pid,
            track=("repair", "repair"),
            group=group_id,
            mode=report.mode,
            blocks_repaired=report.blocks_repaired,
            bytes_fetched=report.bytes_fetched,
            recovered=report.recovered,
        )

    def _execute_step(
        self,
        group_id: str,
        step: RepairStep,
        sim: NetSimulator,
        block_ready: dict,
        report: RepairReport,
    ) -> None:
        srcs = [(r, c) for (r, c) in step.sources]
        blocks = np.stack([self.store.get((group_id, r, c)) for r, c in srcs])
        dst_cell = step.repairs[0]
        dst = self._dst_node(group_id, *dst_cell)
        ctx = self._obs_ctx()
        ready = 0.0
        for r, c in srcs:
            src_node = self.store.node_of((group_id, r, c))
            ready = max(
                ready,
                sim.transfer(
                    Transfer(
                        src_node,
                        dst,
                        blocks[0].nbytes,
                        max(block_ready.get((r, c), 0.0), self.not_before),
                        priority=self.priority,
                        ctx=ctx,
                    )
                ),
            )
        if ctx is not None:
            self.tracer.span(
                "repair.fetch",
                self.not_before,
                ready,
                ctx[0],
                ctx[1],
                track=("repair", "repair"),
                kind=step.kind,
                blocks=len(srcs),
            )
        if step.kind == "V":
            rep = self._vertical_repair(blocks)[None]
        else:
            avail_cols = np.asarray([c for (_, c) in srcs])
            missing_cols = np.asarray([c for (_, c) in step.repairs])
            rep = self._horizontal_repair(avail_cols, blocks, missing_cols)
        for i, cell in enumerate(step.repairs):
            self.store.put_block((group_id, cell[0], cell[1]), rep[i])
            block_ready[cell] = ready
            if self.on_block_repaired is not None:
                self.on_block_repaired((group_id, cell[0], cell[1]))
            # redistribution of extra regenerated blocks to their new homes
            if i > 0:
                home = self.store.node_of((group_id, cell[0], cell[1]))
                sim.transfer(
                    Transfer(
                        dst, home, rep[i].nbytes, ready,
                        priority=self.priority, ctx=ctx,
                    )
                )
        report.blocks_fetched += len(srcs)
        report.bytes_fetched += int(blocks.nbytes)
        report.blocks_repaired += len(step.repairs)

    # -- degraded read -------------------------------------------------------------
    def degraded_read(self, group_id: str, row: int) -> tuple[np.ndarray, RepairReport]:
        """Read object ``row`` (k data blocks) tolerating missing blocks,
        without writing repairs back (a pure degraded read)."""
        report = RepairReport(mode=f"{self.mode}-read")
        k, cols = self.code.k, self.code.n
        sim = NetSimulator(self.profile)
        out = []
        missing = [c for c in range(k) if not self.store.available((group_id, row, c))]
        avail_row = [c for c in range(cols) if self.store.available((group_id, row, c))]
        use_row_decode = False
        if self.mode != "core":
            use_row_decode = bool(missing)
        else:
            for c in missing:
                col_ok = all(
                    self.store.available((group_id, r, c))
                    for r in range(self.code.rows)
                    if r != row
                )
                if not col_ok:
                    use_row_decode = True
                    break
        if not missing:
            for c in range(k):
                b = self.store.get((group_id, row, c))
                sim.transfer(Transfer(self.store.node_of((group_id, row, c)), -1, b.nbytes))
                out.append(b)
                report.blocks_fetched += 1
                report.bytes_fetched += b.nbytes
            data = np.stack(out)
        elif use_row_decode:
            if len(avail_row) < k:
                raise UnrecoverableError(f"row {row} of {group_id} lost")
            fetch = avail_row[:k]
            blocks = np.stack([self.store.get((group_id, row, c)) for c in fetch])
            for c in fetch:
                sim.transfer(
                    Transfer(self.store.node_of((group_id, row, c)), -1, blocks[0].nbytes)
                )
            report.blocks_fetched += len(fetch)
            report.bytes_fetched += int(blocks.nbytes)
            data = np.asarray(
                self._measure(
                    _decode_jit_factory(self.code, tuple(fetch)), jnp.asarray(blocks)
                )
            )
        else:
            got: dict[int, np.ndarray] = {}
            for c in range(k):
                if c not in missing:
                    b = self.store.get((group_id, row, c))
                    sim.transfer(Transfer(self.store.node_of((group_id, row, c)), -1, b.nbytes))
                    got[c] = b
                    report.blocks_fetched += 1
                    report.bytes_fetched += b.nbytes
            for c in missing:
                srcs = [r for r in range(self.code.rows) if r != row]
                blocks = np.stack([self.store.get((group_id, r, c)) for r in srcs])
                for r in srcs:
                    sim.transfer(
                        Transfer(self.store.node_of((group_id, r, c)), -1, blocks[0].nbytes)
                    )
                report.blocks_fetched += len(srcs)
                report.bytes_fetched += int(blocks.nbytes)
                got[c] = self._vertical_repair(blocks)
            data = np.stack([got[c] for c in range(k)])
        report.network_time = sim.makespan
        report.compute_time = self._timed
        return data, report

    # -- helpers ----------------------------------------------------------------
    def _get(self, group_id: str, r: int, c: int, repaired: set[int]) -> np.ndarray:
        return self.store.get((group_id, r, c))

    def _dst_node(self, group_id: str, r: int, c: int) -> int:
        used = {
            self.store.placement[key]
            for key in self.store.placement
            if key[0] == group_id and self.store.available(key)
        }
        for node in self.store.alive_nodes():
            if node not in used:
                return node
        return self.store.alive_nodes()[0]


# -- jitted codec math (shared, cached) ------------------------------------------


@jax.jit
def _xor_jit(blocks: jnp.ndarray) -> jnp.ndarray:
    return gf256.xor_reduce(blocks, axis=0)


@jax.jit
def _gf_matmul_jit(coeffs: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    return gf256.matmul(coeffs, blocks)


_DECODE_CACHE: dict = {}


def _decode_jit_factory(code: CoreCode, fetch_cols: tuple[int, ...]):
    key = (code.n, code.k, fetch_cols)
    if key not in _DECODE_CACHE:
        row_ids, inverse = code.horizontal.decode_matrix(np.asarray(fetch_cols))
        pos = {int(a): i for i, a in enumerate(fetch_cols)}
        sel = np.asarray([pos[int(r)] for r in row_ids])
        inv = jnp.asarray(inverse)
        sel_j = jnp.asarray(sel)

        @jax.jit
        def _decode(blocks):
            return gf256.matmul(inv, blocks[sel_j])

        _DECODE_CACHE[key] = _decode
    return _DECODE_CACHE[key]
