"""Network cost model for the simulated distributed block store.

Mirrors the paper's §5.2 model: congestion-free fabric, per-node
bandwidth caps; delays arise when a single node sends/receives multiple
blocks. Two cluster profiles from §8 are provided:

  * network-critical     — 12 MB/s links (the university thin-client rig)
  * computation-critical — 250 MB/s links (EC2 m1.small)

Compute costs are *measured* (the codec math runs for real on this host);
network time is *simulated* from byte counts and the profile, since this
container has no real cluster fabric.

Fabric sharing comes in two modes:

  * ``fifo``    — a transfer occupies both ports contiguously from the
    moment they free up; background transfers simply run at
    ``background_share`` of the link rate. A long repair transfer
    head-of-line-blocks any later foreground read on the same ports.
  * ``quantum`` — (default) transfers are scheduled in fixed-size
    *quanta*: each quantum transmits at full link rate, and background
    quanta are spaced so the class consumes only ``background_share`` of
    the link in steady state (weighted-fair sharing; ``background_share``
    is the quantum *ratio*, not a rate cap). The idle gaps between a
    background transfer's quanta are real holes in the port timeline, so
    a foreground read arriving mid-way through a multi-second repair
    transfer slots into the next hole instead of waiting for the whole
    thing — preemption at quantum granularity, the way production
    traffic shapers (DRR/WFQ schedulers) bound repair interference.

Both modes conserve bytes exactly and an uncontended transfer finishes at
(essentially) the same time either way; they differ only in how classes
interleave under contention.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field

FIFO = "fifo"
QUANTUM = "quantum"


@dataclass(frozen=True)
class ClusterProfile:
    name: str
    node_bandwidth: float  # bytes/sec per node (send and receive)
    compute_scale: float  # multiplier on measured compute time

    @classmethod
    def network_critical(cls) -> "ClusterProfile":
        return cls(name="network-critical", node_bandwidth=12e6, compute_scale=1.0)

    @classmethod
    def computation_critical(cls) -> "ClusterProfile":
        # EC2 m1.small: fat links, weak CPU (paper: ~1.2GHz 2007 Xeon).
        return cls(name="computation-critical", node_bandwidth=250e6, compute_scale=8.0)


# Priority classes for fabric sharing. Foreground (client reads) always
# runs at full link speed; background (repair/rebalance) may be throttled
# to a fraction of the link so client traffic keeps headroom — the knob
# every production repair pipeline exposes (HDFS-RAID's RaidNode caps,
# Ceph's osd_recovery_max_active etc.).
FOREGROUND = 0
BACKGROUND = 1


@dataclass
class Transfer:
    src_node: int
    dst_node: int
    nbytes: int
    not_before: float = 0.0  # dependency: source block exists at this time
    priority: int = FOREGROUND


class _PortTimeline:
    """Busy intervals of one unidirectional port, sorted and disjoint.

    Supports first-fit gap search (``next_fit``) and interval insertion
    with adjacent-merge, so quantum-mode scheduling can place a transfer
    *inside* holes left by earlier-scheduled lower-priority quanta.
    """

    __slots__ = ("starts", "ends")

    def __init__(self):
        self.starts: list[float] = []
        self.ends: list[float] = []

    def next_fit(self, t: float, dur: float) -> float:
        """Earliest s >= t such that [s, s + dur) overlaps no interval.

        A nanosecond of tolerance keeps exact-fit holes usable — the
        weighted-fair spacing leaves holes of exactly one quantum, which
        strict float comparison would reject by one ulp."""
        i = bisect.bisect_right(self.ends, t)
        for j in range(i, len(self.starts)):
            if self.starts[j] - t >= dur - 1e-9:
                return t
            t = max(t, self.ends[j])
        return t

    def occupy(self, start: float, end: float) -> None:
        i = bisect.bisect_left(self.starts, start)
        # merge with the previous interval when contiguous
        if i > 0 and self.ends[i - 1] == start:
            if i < len(self.starts) and end == self.starts[i]:
                # bridges two intervals: fuse all three
                self.ends[i - 1] = self.ends[i]
                del self.starts[i], self.ends[i]
            else:
                self.ends[i - 1] = end
            return
        if i < len(self.starts) and end == self.starts[i]:
            self.starts[i] = start
            return
        self.starts.insert(i, start)
        self.ends.insert(i, end)


@dataclass
class NetSimulator:
    """Event-ordered per-node bandwidth simulator with priority classes.

    Each node has unit-bandwidth send and receive ports; a transfer
    occupies both, starting no earlier than its dependency time.
    Foreground and background transfers share the SAME port timelines —
    repair traffic and client reads contend on one fabric instead of
    running in separate universes. How they interleave is governed by
    ``mode`` (see the module docstring): ``quantum`` (default) schedules
    fixed-size full-rate quanta with weighted-fair spacing so foreground
    traffic preempts long background transfers at quantum boundaries;
    ``fifo`` reproduces the PR-1 hold-the-port-until-done model with
    background throttled to ``background_share`` of the rate.

    Per-class byte/busy accounting feeds the gateway's interference
    metrics (how much repair slows reads and vice versa).
    """

    profile: ClusterProfile
    background_share: float = 1.0  # quantum ratio (fifo: rate fraction)
    mode: str = QUANTUM
    quantum_bytes: int = 65536  # quantum-mode scheduling granule
    send_free: dict[int, float] = field(default_factory=dict)
    recv_free: dict[int, float] = field(default_factory=dict)
    total_bytes: int = 0
    makespan: float = 0.0
    class_bytes: dict[int, int] = field(default_factory=dict)
    class_busy: dict[int, float] = field(default_factory=dict)
    class_makespan: dict[int, float] = field(default_factory=dict)

    def __post_init__(self):
        # share 0 would mean "repair paused" — this event model cannot
        # express it (every scheduled transfer must complete)
        if not 0.0 < self.background_share <= 1.0:
            raise ValueError(
                f"background_share must be in (0, 1], got {self.background_share}"
            )
        if self.mode not in (FIFO, QUANTUM):
            raise ValueError(f"mode must be 'fifo' or 'quantum', got {self.mode!r}")
        if self.quantum_bytes <= 0:
            raise ValueError(f"quantum_bytes must be positive, got {self.quantum_bytes}")
        self._send: dict[int, _PortTimeline] = {}
        self._recv: dict[int, _PortTimeline] = {}
        # per-(direction, node, class) eligibility cursor: a share-s class
        # may claim its next quantum on a port no earlier than
        # (previous quantum start + dur/s), so the ratio holds across a
        # STREAM of small transfers too, not just within one big one
        self._class_cursor: dict[tuple[str, int, int], float] = {}
        # set once any share<1 transfer is scheduled; until then the
        # timelines are hole-free and share-1.0 transfers can take the
        # O(1) contiguous fast path (schedule-identical to chunking)
        self._seen_throttled = False

    def transfer(self, t: Transfer) -> float:
        """Schedule a transfer; returns its completion time (seconds)."""
        if self.mode == QUANTUM:
            end, busy = self._transfer_quantum(t)
        else:
            end, busy = self._transfer_fifo(t)
        self.total_bytes += t.nbytes
        self.makespan = max(self.makespan, end)
        self.class_bytes[t.priority] = self.class_bytes.get(t.priority, 0) + t.nbytes
        self.class_busy[t.priority] = self.class_busy.get(t.priority, 0.0) + busy
        self.class_makespan[t.priority] = max(
            self.class_makespan.get(t.priority, 0.0), end
        )
        return end

    # -- fifo: the PR-1 hold-until-done model ---------------------------------
    def _transfer_fifo(self, t: Transfer) -> tuple[float, float]:
        bw = self.profile.node_bandwidth
        if t.priority != FOREGROUND:
            bw *= self.background_share
        start = max(
            t.not_before,
            self.send_free.get(t.src_node, 0.0),
            self.recv_free.get(t.dst_node, 0.0),
        )
        dur = t.nbytes / bw
        end = start + dur
        self.send_free[t.src_node] = end
        self.recv_free[t.dst_node] = end
        return end, dur

    # -- quantum: weighted-fair preemptive sharing ----------------------------
    def _transfer_quantum(self, t: Transfer) -> tuple[float, float]:
        bw = self.profile.node_bandwidth
        share = 1.0 if t.priority == FOREGROUND else self.background_share
        src = self._send.setdefault(t.src_node, _PortTimeline())
        dst = self._recv.setdefault(t.dst_node, _PortTimeline())
        ck_s = ("s", t.src_node, t.priority)
        ck_r = ("r", t.dst_node, t.priority)
        cursors = self._class_cursor
        if share < 1.0:
            self._seen_throttled = True
        remaining = t.nbytes
        end = t.not_before
        busy = 0.0
        # Full-share fast path while no throttled class has ever run:
        # the timelines are hole-free, so chunking into quanta would
        # produce one contiguous reservation anyway — schedule the whole
        # transfer in one step instead of nbytes/quantum_bytes of them.
        # (Once holes can exist, per-quantum placement is what lets this
        # transfer preempt into them, so the loop is mandatory.)
        chunk_cap = (
            t.nbytes
            if share == 1.0 and not self._seen_throttled
            else self.quantum_bytes
        )
        while remaining > 0:
            chunk = min(remaining, chunk_cap)
            remaining -= chunk
            dur = chunk / bw
            # each quantum transmits at FULL rate; weighted-fair spacing
            # makes the class's next quantum on these ports eligible only
            # dur/share later, so a share-s class consumes at most s of
            # the link in steady state while the (1-s) holes it leaves
            # are real gaps other classes preempt into.
            earliest = max(
                t.not_before, cursors.get(ck_s, 0.0), cursors.get(ck_r, 0.0)
            )
            start = self._find_slot(src, dst, earliest, dur)
            src.occupy(start, start + dur)
            dst.occupy(start, start + dur)
            end = start + dur
            busy += dur
            eligible = start + dur / share
            cursors[ck_s] = eligible
            cursors[ck_r] = eligible
        # keep the scalar summaries coherent for introspection/debugging
        self.send_free[t.src_node] = max(self.send_free.get(t.src_node, 0.0), end)
        self.recv_free[t.dst_node] = max(self.recv_free.get(t.dst_node, 0.0), end)
        return end, busy

    @staticmethod
    def _find_slot(
        src: _PortTimeline, dst: _PortTimeline, t: float, dur: float
    ) -> float:
        """Earliest start >= t with a dur-sized hole on BOTH ports."""
        while True:
            t1 = src.next_fit(t, dur)
            t2 = dst.next_fit(t1, dur)
            if t2 == t1:
                return t1
            t = t2
