"""Network cost model for the simulated distributed block store.

Mirrors the paper's §5.2 model: congestion-free fabric, per-node
bandwidth caps; delays arise when a single node sends/receives multiple
blocks. Two cluster profiles from §8 are provided:

  * network-critical     — 12 MB/s links (the university thin-client rig)
  * computation-critical — 250 MB/s links (EC2 m1.small)

Compute costs are *measured* (the codec math runs for real on this host);
network time is *simulated* from byte counts and the profile, since this
container has no real cluster fabric.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ClusterProfile:
    name: str
    node_bandwidth: float  # bytes/sec per node (send and receive)
    compute_scale: float  # multiplier on measured compute time

    @classmethod
    def network_critical(cls) -> "ClusterProfile":
        return cls(name="network-critical", node_bandwidth=12e6, compute_scale=1.0)

    @classmethod
    def computation_critical(cls) -> "ClusterProfile":
        # EC2 m1.small: fat links, weak CPU (paper: ~1.2GHz 2007 Xeon).
        return cls(name="computation-critical", node_bandwidth=250e6, compute_scale=8.0)


# Priority classes for fabric sharing. Foreground (client reads) always
# runs at full link speed; background (repair/rebalance) may be throttled
# to a fraction of the link so client traffic keeps headroom — the knob
# every production repair pipeline exposes (HDFS-RAID's RaidNode caps,
# Ceph's osd_recovery_max_active etc.).
FOREGROUND = 0
BACKGROUND = 1


@dataclass
class Transfer:
    src_node: int
    dst_node: int
    nbytes: int
    not_before: float = 0.0  # dependency: source block exists at this time
    priority: int = FOREGROUND


@dataclass
class NetSimulator:
    """Event-ordered per-node bandwidth simulator with priority classes.

    Each node has unit-bandwidth send and receive ports; a transfer
    occupies both for nbytes / bandwidth seconds, starting no earlier
    than its dependency time and when both ports are free. Foreground
    and background transfers share the SAME port timelines — repair
    traffic and client reads contend on one fabric instead of running in
    separate universes — and background transfers additionally run at
    ``background_share`` of the link rate.

    Per-class byte/busy accounting feeds the gateway's interference
    metrics (how much repair slows reads and vice versa).
    """

    profile: ClusterProfile
    background_share: float = 1.0  # fraction of link rate for priority > 0
    send_free: dict[int, float] = field(default_factory=dict)
    recv_free: dict[int, float] = field(default_factory=dict)
    total_bytes: int = 0
    makespan: float = 0.0
    class_bytes: dict[int, int] = field(default_factory=dict)
    class_busy: dict[int, float] = field(default_factory=dict)
    class_makespan: dict[int, float] = field(default_factory=dict)

    def __post_init__(self):
        # share 0 would mean "repair paused" — this event model cannot
        # express it (every scheduled transfer must complete)
        if not 0.0 < self.background_share <= 1.0:
            raise ValueError(
                f"background_share must be in (0, 1], got {self.background_share}"
            )

    def transfer(self, t: Transfer) -> float:
        """Schedule a transfer; returns its completion time (seconds)."""
        bw = self.profile.node_bandwidth
        if t.priority != FOREGROUND:
            bw *= self.background_share
        start = max(
            t.not_before,
            self.send_free.get(t.src_node, 0.0),
            self.recv_free.get(t.dst_node, 0.0),
        )
        dur = t.nbytes / bw
        end = start + dur
        self.send_free[t.src_node] = end
        self.recv_free[t.dst_node] = end
        self.total_bytes += t.nbytes
        self.makespan = max(self.makespan, end)
        self.class_bytes[t.priority] = self.class_bytes.get(t.priority, 0) + t.nbytes
        self.class_busy[t.priority] = self.class_busy.get(t.priority, 0.0) + dur
        self.class_makespan[t.priority] = max(
            self.class_makespan.get(t.priority, 0.0), end
        )
        return end
