"""Network cost model for the simulated distributed block store.

Mirrors the paper's §5.2 model: congestion-free fabric, per-node
bandwidth caps; delays arise when a single node sends/receives multiple
blocks. Two cluster profiles from §8 are provided:

  * network-critical     — 12 MB/s links (the university thin-client rig)
  * computation-critical — 250 MB/s links (EC2 m1.small)

Compute costs are *measured* (the codec math runs for real on this host);
network time is *simulated* from byte counts and the profile, since this
container has no real cluster fabric.

Fabric sharing comes in two modes:

  * ``fifo``    — a transfer occupies both ports contiguously from the
    moment they free up; throttled tenants simply run at their weight
    fraction of the link rate. A long repair transfer
    head-of-line-blocks any later foreground read on the same ports.
  * ``quantum`` — (default) transfers are scheduled in fixed-size
    *quanta*: each quantum transmits at full link rate, and a weight-w
    tenant's quanta are spaced so the tenant consumes only w of the
    link in steady state (weighted-fair sharing; the weight is the
    quantum *ratio*, not a rate cap). The idle gaps between a throttled
    tenant's quanta are real holes in the port timeline, so a
    full-weight read arriving mid-way through a multi-second repair
    transfer slots into the next hole instead of waiting for the whole
    thing — preemption at quantum granularity, the way production
    traffic shapers (DRR/WFQ schedulers) bound repair interference.

Multi-tenancy: sharing is governed by ``tenant_weights``, a map from an
arbitrary hashable tenant id to a weight in (0, 1]. Each (port, tenant)
pair keeps its own eligibility cursor, so any number of tenants share a
link in proportion to their weights. The original two-class interface is
a compatibility shim over this: ``background_share`` seeds the weight of
the ``"repair"`` tenant (and the legacy ``BACKGROUND`` int id), while
``FOREGROUND``/``"foreground"`` stay at weight 1.0. A ``Transfer`` names
its tenant either via ``tenant`` or via the legacy ``priority`` field.

Accounting: per-tenant bytes/busy/makespan (``class_bytes`` et al., keyed
by tenant id), per-tenant starvation (worst and total queueing delay
before a transfer's first quantum — ``tenant_wait_max``), and optional
per-transfer deadlines (``Transfer.deadline``; misses counted per tenant
in ``tenant_deadline_missed``).

Both modes conserve bytes exactly and an uncontended transfer finishes at
(essentially) the same time either way; they differ only in how tenants
interleave under contention.

Fail-slow (gray) degradation: ``set_node_rate(node, factor, direction)``
multiplies a node's effective send/recv bandwidth — both transfer modes
honour it, and ``send_backlog`` deliberately does NOT (it keeps quoting
the healthy rate, so the gateway's hedging deadline detects a slow
source as "taking far longer than the estimate" rather than silently
re-baselining around it).
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field

FIFO = "fifo"
QUANTUM = "quantum"


@dataclass(frozen=True)
class ClusterProfile:
    name: str
    node_bandwidth: float  # bytes/sec per node (send and receive)
    compute_scale: float  # multiplier on measured compute time

    @classmethod
    def network_critical(cls) -> "ClusterProfile":
        return cls(name="network-critical", node_bandwidth=12e6, compute_scale=1.0)

    @classmethod
    def computation_critical(cls) -> "ClusterProfile":
        # EC2 m1.small: fat links, weak CPU (paper: ~1.2GHz 2007 Xeon).
        return cls(name="computation-critical", node_bandwidth=250e6, compute_scale=8.0)


# Legacy priority classes for fabric sharing. Foreground (client reads)
# always runs at full link speed; background (repair/rebalance) may be
# throttled to a fraction of the link so client traffic keeps headroom —
# the knob every production repair pipeline exposes (HDFS-RAID's RaidNode
# caps, Ceph's osd_recovery_max_active etc.). These remain valid tenant
# ids; named tenants generalize them.
FOREGROUND = 0
BACKGROUND = 1

# Canonical tenant names used by the gateway dataplane. Any hashable id
# works; these two inherit default weights from ``background_share``.
FOREGROUND_TENANT = "foreground"
REPAIR_TENANT = "repair"


def shard_tenant(tenant, shard_id: int | None):
    """Shard-qualified fabric tenant id: ``"gold" -> "gold@s2"``. The
    sharded gateway tags every fabric submission with its shard so
    per-tenant accounting (class_bytes / class_makespan / deadline
    misses) and mid-run re-weighting (the repair pacer) get a private
    lane per shard. Identity for ``shard_id=None`` or non-str tenants
    (legacy int class ids keep their two-class semantics)."""
    if shard_id is None or not isinstance(tenant, str):
        return tenant
    return f"{tenant}@s{shard_id}"


def base_tenant(tenant):
    """Strip a shard qualifier: ``"gold@s2" -> "gold"``. Identity for
    unqualified ids."""
    if isinstance(tenant, str):
        head, sep, tail = tenant.rpartition("@s")
        if sep and tail.isdigit():
            return head
    return tenant


@dataclass
class Transfer:
    src_node: int
    dst_node: int
    nbytes: int
    not_before: float = 0.0  # dependency: source block exists at this time
    priority: int = FOREGROUND
    # Tenant id for weighted-fair sharing; None falls back to the legacy
    # ``priority`` field so two-class callers keep working unchanged.
    tenant: object = None
    # Optional completion deadline (absolute simulated seconds); the
    # simulator never drops a late transfer, it counts the miss per
    # tenant so SLO layers above can act on it.
    deadline: float | None = None
    # Observability context: (trace_id, parent_span_id) of the request
    # or repair that caused this transfer. When set (and the simulator
    # carries a tracer), the transfer emits a fabric-track span into
    # that trace. Appended last so positional construction is unchanged.
    ctx: tuple | None = None

    @property
    def effective_tenant(self) -> object:
        return self.priority if self.tenant is None else self.tenant


class _PortTimeline:
    """Busy intervals of one unidirectional port, sorted and disjoint.

    Supports first-fit gap search (``next_fit``) and interval insertion
    with adjacent-merge, so quantum-mode scheduling can place a transfer
    *inside* holes left by earlier-scheduled lower-priority quanta.
    """

    __slots__ = ("starts", "ends")

    def __init__(self):
        self.starts: list[float] = []
        self.ends: list[float] = []

    def next_fit(self, t: float, dur: float) -> float:
        """Earliest s >= t such that [s, s + dur) overlaps no interval.

        A nanosecond of tolerance keeps exact-fit holes usable — the
        weighted-fair spacing leaves holes of exactly one quantum, which
        strict float comparison would reject by one ulp."""
        return self.next_gap(t, dur)[0]

    def next_gap(self, t: float, min_dur: float) -> tuple[float, float]:
        """Earliest (s, length) with s >= t, [s, s + min_dur) free, and
        ``length`` the full free run from s (inf on the open tail) —
        lets the scheduler shrink a quantum into a sub-quantum hole
        instead of skipping it."""
        i = bisect.bisect_right(self.ends, t)
        for j in range(i, len(self.starts)):
            if self.starts[j] - t >= min_dur - 1e-9:
                return t, self.starts[j] - t
            t = max(t, self.ends[j])
        return t, float("inf")

    def occupy(self, start: float, end: float) -> None:
        i = bisect.bisect_left(self.starts, start)
        # merge with the previous interval when contiguous
        if i > 0 and self.ends[i - 1] == start:
            if i < len(self.starts) and end == self.starts[i]:
                # bridges two intervals: fuse all three
                self.ends[i - 1] = self.ends[i]
                del self.starts[i], self.ends[i]
            else:
                self.ends[i - 1] = end
            return
        if i < len(self.starts) and end == self.starts[i]:
            self.starts[i] = start
            return
        self.starts.insert(i, start)
        self.ends.insert(i, end)


# Public name: the interval timeline is shared infrastructure — the
# gateway's EnginePool schedules decode engines on the same structure
# the fabric schedules ports on (earliest-fit into holes).
PortTimeline = _PortTimeline


@dataclass
class NetSimulator:
    """Event-ordered per-node bandwidth simulator with weighted-fair tenants.

    Each node has unit-bandwidth send and receive ports; a transfer
    occupies both, starting no earlier than its dependency time. All
    tenants share the SAME port timelines — repair traffic and client
    reads contend on one fabric instead of running in separate
    universes. How they interleave is governed by ``mode`` (see the
    module docstring): ``quantum`` (default) schedules fixed-size
    full-rate quanta with per-(port, tenant) weighted-fair cursors so
    full-weight traffic preempts long throttled transfers at quantum
    boundaries; ``fifo`` reproduces the PR-1 hold-the-port-until-done
    model with throttled tenants rate-capped at their weight.

    ``tenant_weights`` maps tenant id -> weight in (0, 1]; tenants not in
    the map run at weight 1.0. ``background_share`` is the two-class
    compatibility shim: it seeds the weight of the ``"repair"`` tenant
    and the legacy ``BACKGROUND`` int id (explicit ``tenant_weights``
    entries win).

    Per-tenant byte/busy/makespan accounting feeds the gateway's
    interference metrics; per-tenant starvation (queueing delay before a
    transfer's first quantum) and deadline-miss counters feed its SLO
    admission controller.
    """

    profile: ClusterProfile
    background_share: float = 1.0  # quantum ratio (fifo: rate fraction)
    mode: str = QUANTUM
    quantum_bytes: int = 65536  # quantum-mode scheduling granule
    tenant_weights: dict | None = None  # tenant id -> weight in (0, 1]
    send_free: dict[int, float] = field(default_factory=dict)
    recv_free: dict[int, float] = field(default_factory=dict)
    total_bytes: int = 0
    makespan: float = 0.0
    class_bytes: dict = field(default_factory=dict)  # tenant -> bytes
    class_busy: dict = field(default_factory=dict)  # tenant -> busy secs
    class_makespan: dict = field(default_factory=dict)  # tenant -> max end
    tenant_wait_max: dict = field(default_factory=dict)  # worst queue delay
    tenant_wait_sum: dict = field(default_factory=dict)
    tenant_transfers: dict = field(default_factory=dict)
    tenant_deadline_missed: dict = field(default_factory=dict)
    tenant_deadline_met: dict = field(default_factory=dict)
    # Optional span sink (repro.obs.Tracer): transfers whose ``ctx`` is
    # set emit fabric-track spans into it. Observation-only — the
    # schedule is byte-identical with or without a tracer attached.
    tracer: object = None
    # interned ("fabric", "portN") track tuples — xfer spans are the
    # hottest emission site, one per transfer
    _port_tracks: dict = field(default_factory=dict)
    # fail-slow (gray) degradation: ("s"|"r", node) -> rate factor in
    # (0, 1]. A transfer runs at node_bandwidth x min(send-side factor,
    # recv-side factor) — the slow NIC is the bottleneck of the path.
    _node_rate: dict = field(default_factory=dict)

    def __post_init__(self):
        # weight 0 would mean "tenant paused" — this event model cannot
        # express it (every scheduled transfer must complete)
        if not 0.0 < self.background_share <= 1.0:
            raise ValueError(
                f"background_share must be in (0, 1], got {self.background_share}"
            )
        if self.mode not in (FIFO, QUANTUM):
            raise ValueError(f"mode must be 'fifo' or 'quantum', got {self.mode!r}")
        if self.quantum_bytes <= 0:
            raise ValueError(f"quantum_bytes must be positive, got {self.quantum_bytes}")
        # compat shim: the two legacy classes are just two pre-seeded
        # tenants — background_share becomes the "repair" weight
        weights = {
            FOREGROUND: 1.0,
            FOREGROUND_TENANT: 1.0,
            BACKGROUND: self.background_share,
            REPAIR_TENANT: self.background_share,
        }
        if self.tenant_weights:
            for tenant, w in self.tenant_weights.items():
                if not 0.0 < w <= 1.0:
                    raise ValueError(
                        f"tenant weight must be in (0, 1], got {tenant!r}: {w}"
                    )
                weights[tenant] = w
        self._weights = weights
        self._send: dict[int, _PortTimeline] = {}
        self._recv: dict[int, _PortTimeline] = {}
        # per-(direction, node, tenant) eligibility cursor: a weight-w
        # tenant may claim its next quantum on a port no earlier than
        # (previous quantum start + dur/w), so the ratio holds across a
        # STREAM of small transfers too, not just within one big one
        self._class_cursor: dict[tuple, float] = {}
        # latest end of any FULL-weight quantum per send port: weight-1.0
        # reservations are not preemptible by anyone, so they bound every
        # tenant's admission-time backlog estimate (send_backlog)
        self._fw_send_end: dict[int, float] = {}
        # smallest usable hole: an eighth of a quantum bounds the chunk
        # count per transfer while letting fragmented timelines (tenants
        # with incommensurate periods) stay work-conserving
        self._granule = max(1, self.quantum_bytes // 8)
        # set once any weight<1 transfer is scheduled; until then the
        # timelines are hole-free and weight-1.0 transfers can take the
        # O(1) contiguous fast path (schedule-identical to chunking)
        self._seen_throttled = False

    def set_node_rate(
        self, node: int, factor: float, direction: str = "both"
    ) -> None:
        """Fail-slow injection actuator: degrade (or restore) a node's
        effective link rate. ``factor`` multiplies the healthy bandwidth
        for transfers the node participates in; 1.0 restores full speed.
        ``direction`` is ``"send"``, ``"recv"`` or ``"both"`` (a
        SlowNicEvent degrades one side, a SlowNodeEvent both). Applies to
        transfers scheduled AFTER the call — reservations already placed
        keep their timings, mirroring ``set_tenant_weight``."""
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"rate factor must be in (0, 1], got {factor}")
        if direction not in ("send", "recv", "both"):
            raise ValueError(f"direction must be send|recv|both, got {direction!r}")
        sides = ("s", "r") if direction == "both" else (direction[0],)
        for side in sides:
            if factor >= 1.0:
                self._node_rate.pop((side, int(node)), None)
            else:
                self._node_rate[(side, int(node))] = float(factor)

    def node_rate(self, node: int, direction: str = "send") -> float:
        """Current rate factor of one side of a node (1.0 = healthy)."""
        return self._node_rate.get((direction[0], int(node)), 1.0)

    def _link_rate(self, src_node: int, dst_node: int) -> float:
        if not self._node_rate:  # healthy fast path
            return 1.0
        return min(
            self._node_rate.get(("s", src_node), 1.0),
            self._node_rate.get(("r", dst_node), 1.0),
        )

    def set_tenant_weight(self, tenant, weight: float) -> None:
        """Re-weight a tenant mid-run (the SLO-aware repair pacer's
        actuator). Applies to quanta scheduled AFTER the call; quanta
        already placed on the timelines keep their reservations, so the
        change is a policy update, not a retroactive rewrite of history."""
        if not 0.0 < weight <= 1.0:
            raise ValueError(
                f"tenant weight must be in (0, 1], got {tenant!r}: {weight}"
            )
        self._weights[tenant] = weight

    def weight_of(self, tenant) -> float:
        """Fair-share weight of a tenant. Unregistered NAMED tenants run
        at full weight; unregistered int ids keep the legacy two-class
        contract (any priority other than FOREGROUND was throttled to
        ``background_share``), so pre-tenant callers using custom class
        ids keep their throttle."""
        w = self._weights.get(tenant)
        if w is not None:
            return w
        # shard-qualified tenants ("gold@s2") inherit the base tenant's
        # weight unless the shard lane was re-weighted explicitly — a
        # shard tag changes accounting, not policy
        base = base_tenant(tenant)
        if base is not tenant:
            w = self._weights.get(base)
            if w is not None:
                return w
            tenant = base
        if isinstance(tenant, int):
            return self.background_share
        return 1.0

    def transfer(self, t: Transfer) -> float:
        """Schedule a transfer; returns its completion time (seconds)."""
        tenant = t.effective_tenant
        if self.mode == QUANTUM:
            end, busy, first_start = self._transfer_quantum(t, tenant)
        else:
            end, busy, first_start = self._transfer_fifo(t, tenant)
        self.total_bytes += t.nbytes
        self.makespan = max(self.makespan, end)
        self.class_bytes[tenant] = self.class_bytes.get(tenant, 0) + t.nbytes
        self.class_busy[tenant] = self.class_busy.get(tenant, 0.0) + busy
        self.class_makespan[tenant] = max(
            self.class_makespan.get(tenant, 0.0), end
        )
        # starvation accounting: how long the transfer queued before its
        # first byte moved (beyond its own dependency time)
        wait = max(0.0, first_start - t.not_before)
        self.tenant_wait_max[tenant] = max(
            self.tenant_wait_max.get(tenant, 0.0), wait
        )
        self.tenant_wait_sum[tenant] = self.tenant_wait_sum.get(tenant, 0.0) + wait
        self.tenant_transfers[tenant] = self.tenant_transfers.get(tenant, 0) + 1
        if t.deadline is not None:
            key = (
                "tenant_deadline_missed" if end > t.deadline else "tenant_deadline_met"
            )
            counter = getattr(self, key)
            counter[tenant] = counter.get(tenant, 0) + 1
        if (
            t.ctx is not None
            and self.tracer is not None
            and getattr(self.tracer, "enabled", False)
        ):
            tid, pid = t.ctx
            track = self._port_tracks.get(t.src_node)
            if track is None:
                track = self._port_tracks[t.src_node] = (
                    "fabric",
                    f"port{t.src_node}",
                )
            self.tracer.span(
                "xfer",
                first_start,
                end,
                tid,
                pid,
                track=track,
                src=t.src_node,
                dst=t.dst_node,
                bytes=t.nbytes,
                tenant=tenant,
                wait=wait,
            )
        return end

    def send_backlog(self, node: int, tenant, now: float) -> float:
        """How far beyond ``now`` this tenant's next quantum on the
        node's send port is already committed — the admission-estimator
        view of fabric queueing. Quantum mode takes the max of the
        tenant's own fair-share cursor and the port's full-weight
        horizon (weight-1.0 reservations preempt nobody and are
        preemptible by nobody, so they delay every tenant; throttled
        tenants' reservations leave preemptible holes and only count
        against their own cursor). Fifo mode reads the port's
        hold-until-done horizon."""
        if self.mode == QUANTUM:
            cursor = self._class_cursor.get(("s", node, tenant), 0.0)
            fw = self._fw_send_end.get(node, 0.0)
            return max(0.0, max(cursor, fw) - now)
        return max(0.0, self.send_free.get(node, 0.0) - now)

    def deadline_miss_rate(self, tenant) -> float:
        missed = self.tenant_deadline_missed.get(tenant, 0)
        met = self.tenant_deadline_met.get(tenant, 0)
        return missed / (missed + met) if (missed + met) else 0.0

    # -- fifo: the PR-1 hold-until-done model ---------------------------------
    def _transfer_fifo(self, t: Transfer, tenant) -> tuple[float, float, float]:
        bw = (
            self.profile.node_bandwidth
            * self.weight_of(tenant)
            * self._link_rate(t.src_node, t.dst_node)
        )
        start = max(
            t.not_before,
            self.send_free.get(t.src_node, 0.0),
            self.recv_free.get(t.dst_node, 0.0),
        )
        dur = t.nbytes / bw
        end = start + dur
        self.send_free[t.src_node] = end
        self.recv_free[t.dst_node] = end
        return end, dur, start

    # -- quantum: weighted-fair preemptive sharing ----------------------------
    def _transfer_quantum(self, t: Transfer, tenant) -> tuple[float, float, float]:
        if self._node_rate:
            s_f = self._node_rate.get(("s", t.src_node), 1.0)
            r_f = self._node_rate.get(("r", t.dst_node), 1.0)
            if min(s_f, r_f) < 1.0:
                return self._transfer_degraded(t, tenant, s_f, r_f)
        bw = self.profile.node_bandwidth
        share = self.weight_of(tenant)
        src = self._send.setdefault(t.src_node, _PortTimeline())
        dst = self._recv.setdefault(t.dst_node, _PortTimeline())
        ck_s = ("s", t.src_node, tenant)
        ck_r = ("r", t.dst_node, tenant)
        cursors = self._class_cursor
        if share < 1.0:
            self._seen_throttled = True
        remaining = float(t.nbytes)
        end = t.not_before
        first_start = t.not_before
        busy = 0.0
        first = True
        # Full-share fast path while no throttled tenant has ever run:
        # the timelines are hole-free, so chunking into quanta would
        # produce one contiguous reservation anyway — schedule the whole
        # transfer in one step instead of nbytes/quantum_bytes of them.
        # (Once holes can exist, per-quantum placement is what lets this
        # transfer preempt into them, so the loop is mandatory.)
        chunk_cap = (
            t.nbytes
            if share == 1.0 and not self._seen_throttled
            else self.quantum_bytes
        )
        # Exit threshold in the same units as next_gap's acceptance
        # tolerance (1e-9 s, converted to bytes): a residual below it
        # would make min_dur sub-tolerance, where next_gap can accept
        # zero-length gaps and the loop would stop making progress.
        while remaining > bw * 1e-9:
            want_dur = min(remaining, chunk_cap) / bw
            # Sub-quantum holes are usable down to the granule: two
            # tenants with incommensurate periods fragment the timeline
            # into holes smaller than a full quantum, and a scheduler
            # that can only place whole quanta would starve a light
            # tenant out of exactly the fragments its weight entitles it
            # to (non-work-conserving). Shrinking the chunk to the hole
            # keeps delivered bytes proportional to the weights.
            min_dur = min(remaining, self._granule) / bw
            # each chunk transmits at FULL rate; weighted-fair spacing
            # makes the tenant's next chunk on these ports eligible only
            # dur/share later, so a weight-w tenant consumes at most w of
            # the link in steady state while the (1-w) holes it leaves
            # are real gaps other tenants preempt into.
            earliest = max(
                t.not_before, cursors.get(ck_s, 0.0), cursors.get(ck_r, 0.0)
            )
            start, avail = self._find_gap(src, dst, earliest, min_dur)
            dur = min(want_dur, avail)
            remaining -= dur * bw
            src.occupy(start, start + dur)
            dst.occupy(start, start + dur)
            if first:
                first_start = start
                first = False
            end = start + dur
            busy += dur
            # Virtual-clock eligibility: advance each cursor from its
            # PREVIOUS value, not from the actual (possibly collision-
            # delayed) start — a tenant knocked off its token schedule by
            # another's quantum may claim its next one on time instead of
            # compounding the delay (rate-drift-free weighted fairness).
            # Re-anchoring at the chunk's end bounds the catch-up
            # credit: a long-idle or long-blocked tenant cannot burst
            # past back-to-back quanta.
            for ck in (ck_s, ck_r):
                cursors[ck] = max(cursors.get(ck, 0.0) + dur / share, end)
        # keep the scalar summaries coherent for introspection/debugging
        self.send_free[t.src_node] = max(self.send_free.get(t.src_node, 0.0), end)
        self.recv_free[t.dst_node] = max(self.recv_free.get(t.dst_node, 0.0), end)
        if share == 1.0:
            self._fw_send_end[t.src_node] = max(
                self._fw_send_end.get(t.src_node, 0.0), end
            )
        return end, busy, first_start

    # -- degraded (fail-slow) paths -------------------------------------------
    def _transfer_degraded(
        self, t: Transfer, tenant, s_f: float, r_f: float
    ) -> tuple[float, float, float]:
        """Gray-path scheduling: one contiguous reservation at the
        bottleneck rate ``min(s_f, r_f)``. The bottleneck side's port is
        saturated for the whole stretched duration; the HEALTHY side is
        only busy for its own wire time, anchored at the transfer's END
        (in-order delivery: the receiver hands the object off at
        last-byte time). A stream trickling in from a fail-slow sender
        must not head-of-line block the receiver's NIC — otherwise every
        hedged alternate fetch would queue behind the very transfer it
        is racing, and fail-slow would be indistinguishable from
        receiver congestion.

        Weighted-fair quantum interleaving is bypassed on the stretched
        reservation: the trickle runs far below the port's healthy
        capacity, so spacing it against healthy tenants' quanta would
        model contention it does not cause. Later transfers preempt into
        the healthy-side head hole through the normal gap search."""
        bw = self.profile.node_bandwidth
        share = self.weight_of(tenant)
        rate = min(s_f, r_f)
        src = self._send.setdefault(t.src_node, _PortTimeline())
        dst = self._recv.setdefault(t.dst_node, _PortTimeline())
        cursors = self._class_cursor
        ck_s = ("s", t.src_node, tenant)
        ck_r = ("r", t.dst_node, tenant)
        earliest = max(
            t.not_before, cursors.get(ck_s, 0.0), cursors.get(ck_r, 0.0)
        )
        dur = t.nbytes / (bw * rate * share)
        if s_f <= r_f:
            bneck, other = src, dst
            o_busy = t.nbytes / (bw * r_f)
        else:
            bneck, other = dst, src
            o_busy = t.nbytes / (bw * s_f)
        # joint placement: full stretched hole on the bottleneck port,
        # tail slice on the healthy port; each miss pushes the search
        # strictly later, so the loop terminates like _find_gap's
        probe = earliest
        while True:
            b_start, _ = bneck.next_gap(probe, dur)
            end = b_start + dur
            o_start, _ = other.next_gap(max(0.0, end - o_busy), o_busy)
            if o_start <= end - o_busy + 1e-9:
                break
            probe = max(o_start + o_busy - dur, b_start + 1e-9)
        bneck.occupy(b_start, end)
        other.occupy(end - o_busy, end)
        # the tail-anchored occupation leaves a real hole on the healthy
        # port: flip chunked scheduling on so full-weight transfers can
        # preempt into it instead of skipping it
        self._seen_throttled = True
        # eligibility cursors: the bottleneck side is saturated until the
        # stretched end, so its cursor re-anchors there like any full
        # reservation; the healthy side only consumed its wire time, and
        # flooring ITS cursor at the stretched end would let the trickle
        # head-of-line block the tenant's other traffic through the back
        # door the occupation hole just opened
        if bneck is src:
            cursors[ck_s] = max(cursors.get(ck_s, 0.0) + dur / share, end)
            cursors[ck_r] = cursors.get(ck_r, 0.0) + o_busy / share
        else:
            cursors[ck_r] = max(cursors.get(ck_r, 0.0) + dur / share, end)
            cursors[ck_s] = cursors.get(ck_s, 0.0) + o_busy / share
        self.send_free[t.src_node] = max(self.send_free.get(t.src_node, 0.0), end)
        self.recv_free[t.dst_node] = max(self.recv_free.get(t.dst_node, 0.0), end)
        if share == 1.0:
            self._fw_send_end[t.src_node] = max(
                self._fw_send_end.get(t.src_node, 0.0), end
            )
        return end, dur, b_start

    @staticmethod
    def _find_gap(
        src: _PortTimeline, dst: _PortTimeline, t: float, min_dur: float
    ) -> tuple[float, float]:
        """Earliest (start, length) of a >= min_dur hole on BOTH ports."""
        while True:
            t1, g1 = src.next_gap(t, min_dur)
            t2, g2 = dst.next_gap(t1, min_dur)
            if t2 == t1:
                return t1, min(g1, g2)
            t = t2
