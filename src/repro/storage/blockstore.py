"""Simulated distributed block store (the HDFS analogue).

Blocks are addressed by (group_id, row, col) — a cell of a CORE matrix
(for plain RS groups, row is always 0). Placement is anti-colocating like
HDFS-RAID's RaidNode policy: all blocks of a group land on distinct
nodes, so a node failure costs each group at most one block — the failure
model under which the paper's per-column/-row analysis holds.

Rack awareness (XORing Elephants, 1301.3791): when ``nodes_per_rack``
is set, nodes are partitioned into failure domains of that size and
placement lifts the anti-colocation invariant from nodes to racks — no
two blocks of the same row OR column share a rack, so a whole-rack
failure (ToR switch, PDU) still costs each stripe and each vertical
group at most one block. With ``nodes_per_rack=None`` every node is its
own rack and the classic layout is byte-identical to before.

Data lives in host numpy (this is the "disk"); codec math runs in JAX.

Integrity plane: every stored block carries a crc32 digest computed at
PUT time (``checksums``). ``verify`` recomputes a block's digest against
the stored one — a mismatch means SILENT corruption (a bit flip or torn
write injected by ``corrupt_block`` leaves the stored digest stale on
purpose, exactly like a disk returning bad bytes under a good extent
map). The gateway reclassifies a verify failure as an erasure:
``quarantine`` removes the bytes from the readable set while keeping the
placement and the reference digest, so repair re-places the block in
situ and the repaired bytes can be checked against the original digest.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

BlockKey = tuple[str, int, int]  # (group_id, row, col)


class PlacementError(RuntimeError):
    pass


@dataclass
class BlockStore:
    num_nodes: int
    nodes_per_rack: int | None = None
    blocks: dict[BlockKey, np.ndarray] = field(default_factory=dict)
    placement: dict[BlockKey, int] = field(default_factory=dict)
    failed_nodes: set[int] = field(default_factory=set)
    checksums: dict[BlockKey, int] = field(default_factory=dict)
    _group_counter: int = 0

    # -- failure domains -------------------------------------------------------
    def rack_of(self, node: int) -> int:
        """Failure-domain id of ``node``. With no rack map configured,
        every node is its own rack (node-level anti-colocation only)."""
        if self.nodes_per_rack is None:
            return int(node)
        return int(node) // self.nodes_per_rack

    # -- integrity -------------------------------------------------------------
    @staticmethod
    def digest(data: np.ndarray) -> int:
        """crc32c-style content digest of a block's bytes."""
        return zlib.crc32(np.asarray(data).tobytes())

    # -- placement -----------------------------------------------------------
    def _place_group(self, group_id: str, rows: int, cols: int) -> None:
        """Anti-colocated placement of a (rows x cols) group.

        All-distinct when the cluster is big enough; otherwise a
        latin-square-style layout — node(r,c) = (off + c + K*r) mod N —
        guaranteeing no two blocks of the same row OR column share a
        node (one node failure => at most one failure per stripe and
        per vertical group), which is the paper's placement requirement
        for its 20-node clusters."""
        need = rows * cols
        alive = [n for n in range(self.num_nodes) if n not in self.failed_nodes]
        # crc32, not hash(): placement must be stable across processes
        # (PYTHONHASHSEED randomizes str hashes per run)
        salt = zlib.crc32(group_id.encode()) ^ self._group_counter
        offset = salt % len(alive)
        self._group_counter += 1
        if self.nodes_per_rack is not None:
            self._place_group_rack_aware(group_id, rows, cols, alive, salt)
            return
        if need <= len(alive):
            chosen = [alive[(offset + i) % len(alive)] for i in range(need)]
            i = 0
            for r in range(rows):
                for c in range(cols):
                    self.placement[(group_id, r, c)] = chosen[i]
                    i += 1
            return
        n = len(alive)
        if max(rows, cols) > n:
            raise PlacementError(
                f"group {group_id} needs >= {max(rows, cols)} nodes for "
                f"row/column anti-colocation, {n} alive"
            )
        k_step = next(
            (k for k in range(1, n) if all((k * d) % n for d in range(1, rows))),
            None,
        )
        if k_step is None:
            raise PlacementError(f"no anti-colocating stride for {rows}x{cols} on {n}")
        for r in range(rows):
            for c in range(cols):
                self.placement[(group_id, r, c)] = alive[(offset + c + k_step * r) % n]

    def _place_group_rack_aware(
        self, group_id: str, rows: int, cols: int, alive: list[int], salt: int
    ) -> None:
        """Latin-square layout over RACKS instead of nodes: rack(r, c) =
        racks[(off + c + step*r) mod R]. With R >= cols the racks within
        a row are all distinct, and an anti-colocating stride keeps the
        racks within a column distinct — one whole-rack failure costs
        each stripe and each vertical group at most one block. Within a
        rack, a per-group rotation spreads blocks over the rack's alive
        nodes (distinct nodes whenever capacity allows)."""
        racks: dict[int, list[int]] = {}
        for n in alive:
            racks.setdefault(self.rack_of(n), []).append(n)
        rack_ids = sorted(racks)
        n_racks = len(rack_ids)
        if n_racks < cols:
            raise PlacementError(
                f"group {group_id}: rack-aware placement needs >= {cols} racks "
                f"with alive nodes (one rack per stripe block), {n_racks} available"
            )
        step = next(
            (s for s in range(1, n_racks) if all((s * d) % n_racks for d in range(1, rows))),
            None,
        )
        if step is None:
            raise PlacementError(
                f"no anti-colocating rack stride for {rows}x{cols} over {n_racks} racks"
            )
        off = salt % n_racks
        used: set[int] = set()
        spin: dict[int, int] = {}
        for r in range(rows):
            for c in range(cols):
                rid = rack_ids[(off + c + step * r) % n_racks]
                members = racks[rid]
                start = (salt + spin.get(rid, 0)) % len(members)
                spin[rid] = spin.get(rid, 0) + 1
                node = next(
                    (
                        members[(start + i) % len(members)]
                        for i in range(len(members))
                        if members[(start + i) % len(members)] not in used
                    ),
                    members[start],
                )
                used.add(node)
                self.placement[(group_id, r, c)] = node

    # -- block API ------------------------------------------------------------
    def put_group(self, group_id: str, matrix: np.ndarray) -> None:
        """Store a full (rows, cols, q) group."""
        rows, cols = matrix.shape[:2]
        self._place_group(group_id, rows, cols)
        for r in range(rows):
            for c in range(cols):
                blk = np.asarray(matrix[r, c])
                self.blocks[(group_id, r, c)] = blk
                self.checksums[(group_id, r, c)] = self.digest(blk)

    def put_block(self, key: BlockKey, data: np.ndarray, node: int | None = None) -> None:
        cur = self.placement.get(key)
        if node is not None:
            self.placement[key] = node
        elif cur is None or cur in self.failed_nodes:
            # (re-)place on a fresh alive node not already used by the group
            alive = [n for n in range(self.num_nodes) if n not in self.failed_nodes]
            used = {
                self.placement[k]
                for k in self.placement
                if k[0] == key[0] and self.available(k)
            }
            free = [n for n in alive if n not in used]
            if free:
                if self.nodes_per_rack is not None:
                    # keep the rack invariant on repair write-back: avoid
                    # racks already hosting a live block of this row/col
                    gid, row, col = key
                    bad_racks = {
                        self.rack_of(self.placement[k])
                        for k in self.placement
                        if k[0] == gid
                        and k != key
                        and (k[1] == row or k[2] == col)
                        and self.available(k)
                    }
                    rack_ok = [n for n in free if self.rack_of(n) not in bad_racks]
                    if rack_ok:
                        free = rack_ok
                self.placement[key] = free[0]
            else:
                # dense cluster: every alive node already hosts a group
                # block. Fall back to the weaker-but-essential invariant
                # (the paper's placement requirement): never co-locate
                # with another live block of the same ROW or COLUMN, so
                # one node failure still costs each stripe and each
                # vertical group at most one block.
                gid, row, col = key
                conflict = {
                    self.placement[k]
                    for k in self.placement
                    if k[0] == gid
                    and k != key
                    and (k[1] == row or k[2] == col)
                    and self.available(k)
                }
                if self.nodes_per_rack is not None:
                    # rack-level anti-colocation first, node-level fallback
                    bad_racks = {self.rack_of(n) for n in conflict}
                    cands = [n for n in alive if self.rack_of(n) not in bad_racks]
                    if not cands:
                        cands = [n for n in alive if n not in conflict]
                else:
                    cands = [n for n in alive if n not in conflict]
                if not cands:
                    cands = alive
                # crc32-keyed pick (process-stable, like _place_group):
                # always taking the first candidate would funnel every
                # dense re-placement onto the lowest alive ids and turn
                # them into post-repair hotspots
                self.placement[key] = cands[
                    zlib.crc32(repr(key).encode()) % len(cands)
                ]
        blk = np.asarray(data)
        self.blocks[key] = blk
        self.checksums[key] = self.digest(blk)

    def node_of(self, key: BlockKey) -> int:
        return self.placement[key]

    def available(self, key: BlockKey) -> bool:
        return (
            key in self.blocks
            and self.placement.get(key) is not None
            and self.placement[key] not in self.failed_nodes
        )

    def get(self, key: BlockKey) -> np.ndarray:
        if not self.available(key):
            raise KeyError(f"block {key} unavailable (node failed or missing)")
        return self.blocks[key]

    def verify(self, key: BlockKey) -> bool:
        """Recompute ``key``'s digest against the one stored at PUT.
        False means silent corruption. Blocks with no stored digest
        (pre-integrity writers) pass vacuously."""
        want = self.checksums.get(key)
        if want is None or key not in self.blocks:
            return True
        return self.digest(self.blocks[key]) == want

    def checksum_ok(self, key: BlockKey, data: np.ndarray) -> bool | None:
        """Check reconstructed ``data`` against ``key``'s reference digest
        (decode-output verification). None when no digest is on file."""
        want = self.checksums.get(key)
        if want is None:
            return None
        return self.digest(data) == want

    def keys_on_node(self, node: int) -> list[BlockKey]:
        """All block keys currently placed on ``node`` (whether or not the
        node is alive) — the unit a node-level fault event acts on."""
        return [k for k, n in self.placement.items() if n == node]

    # -- failures --------------------------------------------------------------
    def fail_nodes(self, nodes: set[int] | list[int]) -> None:
        self.failed_nodes.update(int(n) for n in nodes)

    def heal_node(self, node: int) -> None:
        """Transient failure over: the node rejoins with its blocks
        intact (a reboot / network partition, not a disk loss)."""
        self.failed_nodes.discard(int(node))

    def lose_node_blocks(self, node: int) -> list[BlockKey]:
        """Permanent capacity loss: the node's blocks are destroyed (disk
        failure). The node itself rejoins the alive set empty — only a
        repair write-back can bring the data back. Returns the lost keys."""
        lost = self.keys_on_node(node)
        for key in lost:
            self.blocks.pop(key, None)
            self.placement.pop(key, None)
            self.checksums.pop(key, None)
        self.failed_nodes.discard(int(node))
        return lost

    # -- corruption ------------------------------------------------------------
    def corrupt_block(self, key: BlockKey, mode: str = "bitflip") -> bool:
        """Damage one stored block in place — the single implementation
        behind both enforced-failure-pattern tests and the scenario
        engine's ``CorruptionEvent``.

        ``bitflip`` flips one bit at a key-derived offset; ``torn``
        zeroes the trailing half (a torn write); both leave the stored
        digest STALE, so the damage is silent until a fetch or scrub
        verifies. ``erase`` destroys the bytes outright (the old
        ``drop_block`` semantics). Returns False (no-op) when the block
        holds no bytes to damage. Always writes a fresh array — callers
        (the cache, test expectations) may hold references to the old
        one."""
        blk = self.blocks.get(key)
        if blk is None:
            return False
        if mode == "erase":
            self.blocks.pop(key, None)
            return True
        flat = np.asarray(blk).copy().reshape(-1).view(np.uint8)
        if flat.size == 0:
            return False
        if mode == "bitflip":
            pos = zlib.crc32(repr(key).encode()) % flat.size
            flat[pos] ^= 1 << (zlib.crc32(repr(key).encode(), 7) % 8)
        elif mode == "torn":
            flat[flat.size // 2 :] = 0
        else:
            raise ValueError(f"unknown corruption mode {mode!r}")
        self.blocks[key] = flat.view(np.asarray(blk).dtype).reshape(
            np.asarray(blk).shape
        )
        return True

    def quarantine(self, key: BlockKey) -> None:
        """Detection outcome: pull corrupt bytes out of the readable set.
        Placement and the reference digest survive, so repair re-puts the
        block on its original node and the repaired bytes can be verified
        against the original content digest."""
        self.blocks.pop(key, None)

    def drop_block(self, key: BlockKey) -> None:
        """Targeted single-block erasure (for enforced failure patterns).
        Thin wrapper over the unified corruption path."""
        self.corrupt_block(key, mode="erase")

    def failure_matrix(self, group_id: str, rows: int, cols: int) -> np.ndarray:
        fm = np.zeros((rows, cols), dtype=bool)
        for r in range(rows):
            for c in range(cols):
                fm[r, c] = not self.available((group_id, r, c))
        return fm

    def alive_nodes(self) -> list[int]:
        return [n for n in range(self.num_nodes) if n not in self.failed_nodes]
