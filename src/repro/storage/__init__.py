from repro.storage.blockstore import BlockKey, BlockStore, PlacementError
from repro.storage.netmodel import (
    BACKGROUND,
    FOREGROUND,
    FOREGROUND_TENANT,
    REPAIR_TENANT,
    ClusterProfile,
    NetSimulator,
    Transfer,
    base_tenant,
    shard_tenant,
)
from repro.storage.repair import BlockFixer, RepairReport, UnrecoverableError

__all__ = [
    "BlockKey",
    "BlockStore",
    "PlacementError",
    "BACKGROUND",
    "FOREGROUND",
    "FOREGROUND_TENANT",
    "REPAIR_TENANT",
    "ClusterProfile",
    "NetSimulator",
    "Transfer",
    "base_tenant",
    "shard_tenant",
    "BlockFixer",
    "RepairReport",
    "UnrecoverableError",
]
