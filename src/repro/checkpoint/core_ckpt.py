"""CORE-encoded distributed checkpointing — the paper's primitive as the
resilience layer of the training framework (DESIGN.md §2).

Save: pytree -> byte stream -> k-block objects -> t-object CORE groups ->
RS(n,k) horizontal + XOR vertical encode -> anti-colocated placement in
the block store.

Restore: per group, degraded reads of the systematic blocks (vertical
XOR repair for singleton column failures, RS row decode otherwise).
Restore succeeds under any failure pattern inside the code's
recoverability envelope, host failures included — this is
checkpoint/restart for free at the storage layer.

Repair: background BlockFixer pass (RGS schedule) replenishing lost
blocks onto fresh nodes — the paper's fast repair path keeping the
"unsafe window" short.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import partition
from repro.core.product_code import CoreCode, CoreCodec
from repro.storage.blockstore import BlockStore
from repro.storage.netmodel import ClusterProfile
from repro.storage.repair import BlockFixer, RepairReport


@dataclass
class CheckpointManifest:
    step: int
    group_ids: list[str]
    treedef: object
    leaf_specs: list
    total_bytes: int
    block_size: int
    code: CoreCode
    save_seconds: float = 0.0


@dataclass
class CoreCheckpointer:
    store: BlockStore
    code: CoreCode
    profile: ClusterProfile = field(default_factory=ClusterProfile.network_critical)
    block_size: int = 1 << 16
    scheduler: str = "rgs"
    manifests: dict[int, CheckpointManifest] = field(default_factory=dict)

    def __post_init__(self):
        self.codec = CoreCodec(self.code)
        self._encode_jit = jax.jit(self.codec.encode)

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree) -> CheckpointManifest:
        t0 = time.perf_counter()
        stream, treedef, specs = partition.tree_to_stream(tree)
        objects, pad, num_groups = partition.stream_to_objects(
            stream, self.block_size, self.code.k, self.code.t
        )
        group_ids = []
        for g in range(num_groups):
            matrix = np.asarray(self._encode_jit(jnp.asarray(objects[g])))
            gid = f"ckpt-{step}-{g}"
            self.store.put_group(gid, matrix)
            group_ids.append(gid)
        manifest = CheckpointManifest(
            step=step,
            group_ids=group_ids,
            treedef=treedef,
            leaf_specs=specs,
            total_bytes=len(stream),
            block_size=self.block_size,
            code=self.code,
            save_seconds=time.perf_counter() - t0,
        )
        self.manifests[step] = manifest
        return manifest

    # -- restore ------------------------------------------------------------------
    def restore(self, step: int) -> tuple[object, RepairReport]:
        """Degraded-read restore: succeeds while every group stays inside
        the code's recoverability envelope, even with failed nodes."""
        man = self.manifests[step]
        fixer = BlockFixer(self.store, self.code, self.profile, mode="core",
                           scheduler=self.scheduler)
        agg = RepairReport(mode="restore")
        parts = []
        for gid in man.group_ids:
            rows = []
            for r in range(self.code.t):
                data, rep = fixer.degraded_read(gid, r)
                agg.blocks_fetched += rep.blocks_fetched
                agg.bytes_fetched += rep.bytes_fetched
                agg.network_time += rep.network_time
                agg.compute_time += rep.compute_time
                rows.append(data)
            parts.append(np.stack(rows))
        objects = np.stack(parts)  # (groups, t, k, block)
        stream = partition.objects_to_stream(objects, man.total_bytes)
        tree = partition.stream_to_tree(stream, man.treedef, man.leaf_specs)
        return tree, agg

    # -- background repair -----------------------------------------------------------
    def repair(self, step: int) -> RepairReport:
        man = self.manifests[step]
        fixer = BlockFixer(self.store, self.code, self.profile, mode="core",
                           scheduler=self.scheduler)
        agg = RepairReport(mode="repair")
        for gid in man.group_ids:
            rep = fixer.fix_group(gid)
            agg.blocks_fetched += rep.blocks_fetched
            agg.bytes_fetched += rep.bytes_fetched
            agg.blocks_repaired += rep.blocks_repaired
            agg.network_time = max(agg.network_time, rep.network_time)
            agg.compute_time += rep.compute_time
            agg.recovered = agg.recovered and rep.recovered
        return agg

    def latest_step(self) -> int | None:
        return max(self.manifests) if self.manifests else None
