from repro.checkpoint.core_ckpt import CheckpointManifest, CoreCheckpointer
from repro.checkpoint import partition

__all__ = ["CheckpointManifest", "CoreCheckpointer", "partition"]
