"""Pytree <-> fixed-size byte-block partitioning for CORE checkpoints.

A checkpoint is serialized leaf-by-leaf into one byte stream per *shard
stream* (in a multi-host deployment each host serializes its local
shards; here one stream per save). The stream is chunked into
``block_size`` blocks; k consecutive blocks form one *object* (an RS
stripe); t objects form one CORE group (the cross-object dimension).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import ml_dtypes  # registers bfloat16/fp8 dtype strings with numpy
import numpy as np


@dataclass
class LeafSpec:
    shape: tuple[int, ...]
    dtype: str
    nbytes: int


@dataclass
class StreamSpec:
    treedef: object
    leaves: list[LeafSpec]
    total_bytes: int
    block_size: int
    k: int
    t: int
    num_groups: int
    pad_bytes: int


def tree_to_stream(tree) -> tuple[bytes, object, list[LeafSpec]]:
    leaves, treedef = jax.tree.flatten(tree)
    specs, chunks = [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        specs.append(LeafSpec(shape=arr.shape, dtype=str(arr.dtype), nbytes=arr.nbytes))
        chunks.append(arr.tobytes())
    return b"".join(chunks), treedef, specs


def stream_to_tree(stream: bytes, treedef, specs: list[LeafSpec]):
    leaves = []
    off = 0
    for spec in specs:
        raw = stream[off : off + spec.nbytes]
        off += spec.nbytes
        dtype = np.dtype(getattr(ml_dtypes, spec.dtype, spec.dtype))
        leaves.append(np.frombuffer(raw, dtype=dtype).reshape(spec.shape))
    return jax.tree.unflatten(treedef, leaves)


def stream_to_objects(
    stream: bytes, block_size: int, k: int, t: int
) -> tuple[np.ndarray, StreamSpec, object, list[LeafSpec]]:
    """bytes -> (num_groups, t, k, block_size) uint8 object array (padded)."""
    data = np.frombuffer(stream, dtype=np.uint8)
    group_bytes = block_size * k * t
    pad = (-data.size) % group_bytes
    if pad:
        data = np.concatenate([data, np.zeros(pad, dtype=np.uint8)])
    num_groups = data.size // group_bytes
    objects = data.reshape(num_groups, t, k, block_size)
    return objects, pad, num_groups


def objects_to_stream(objects: np.ndarray, total_bytes: int) -> bytes:
    return objects.reshape(-1).tobytes()[:total_bytes]
