"""Spans over the simulated clock.

A ``Span`` is a named interval ``[start, end]`` in simulated seconds,
tied to a request (or repair) by ``trace_id``, nested under a parent by
``parent_id``, and placed on a display *track* — a ``(group, name)``
pair like ``("tenant", "gold")`` or ``("engine", "engine3")`` that the
Perfetto exporter turns into process/thread rows.

The ``Tracer`` is deliberately dumb and bounded:

  * spans for an in-flight trace stage in a per-trace dict;
  * ``end_trace(trace_id, latency)`` applies the sampling policy and
    either commits the trace's spans into a ring buffer
    (``deque(maxlen=capacity)``) or drops them;
  * sampling policies compose from a spec string —
    ``"always"``, ``"head:N"`` (first N traces), ``"tail:SECONDS"``
    (keep any trace at least that slow — slow requests are never
    dropped), comma-joined meaning keep-if-ANY-matches, e.g.
    ``"head:50,tail:0.1"``.

Emission sites throughout the stack guard on ``tracer.enabled`` and are
observation-only: a tracer never changes event ordering, payload bytes,
or any simulated timestamp. ``NULL_TRACER`` is the shared disabled
instance the gateway threads through when tracing is off, so call sites
never branch on ``None``.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field


@dataclass(slots=True)
class Span:
    """One named interval on the simulated clock."""

    name: str
    start: float
    end: float
    trace_id: int
    span_id: int
    parent_id: int | None = None
    track: tuple[str, str] = ("gateway", "main")
    attrs: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


class Tracer:
    """Bounded ring-buffer span collector with trace-level sampling.

    Emission is the hot path (one call per transfer on a traced run), so
    spans are staged and committed as plain TUPLES; ``Span`` objects are
    materialized lazily — and cached per commit epoch — the first time
    ``.spans`` is read at analysis/export time. The serve loop never
    pays for object construction it isn't going to look at.
    """

    def __init__(self, sample: str = "always", capacity: int = 65536):
        self.enabled = True
        self.capacity = capacity
        self._spans: deque[tuple] = deque(maxlen=capacity)
        self._staged: dict[int, list[tuple]] = {}
        self._ids = itertools.count(1)
        self._epoch = 0  # bumped on every commit; keys the .spans cache
        self._cache: tuple[int, list[Span]] | None = None
        self.traces_started = 0
        self.traces_kept = 0
        self.traces_dropped = 0
        self._head_n, self._tail_s, self._always = self._parse(sample)
        self.sample = sample

    @property
    def spans(self) -> list[Span]:
        """Committed spans as ``Span`` objects, in commit order."""
        if self._cache is None or self._cache[0] != self._epoch:
            self._cache = (self._epoch, [Span(*t) for t in self._spans])
        return self._cache[1]

    @staticmethod
    def _parse(spec: str) -> tuple[int, float, bool]:
        head_n, tail_s, always = 0, float("inf"), False
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if part == "always":
                always = True
            elif part.startswith("head:"):
                head_n = max(head_n, int(part[5:]))
            elif part.startswith("tail:"):
                tail_s = min(tail_s, float(part[5:]))
            else:
                raise ValueError(f"unknown trace sampling policy: {part!r}")
        if head_n == 0 and tail_s == float("inf") and not always:
            raise ValueError(f"empty trace sampling spec: {spec!r}")
        return head_n, tail_s, always

    # -- trace lifecycle -------------------------------------------------
    def begin_trace(self) -> int:
        """Open a trace; the returned id doubles as the root span's id so
        children emitted before the root is finalized can parent on it."""
        tid = next(self._ids)
        self.traces_started += 1
        self._staged[tid] = []
        return tid

    def span(
        self,
        name: str,
        start: float,
        end: float,
        trace_id: int,
        parent_id: int | None = None,
        track: tuple[str, str] = ("gateway", "main"),
        **attrs,
    ) -> int:
        """Record a finished interval inside an open trace. Returns the
        new span's id (usable as a parent for further children)."""
        staged = self._staged.get(trace_id)
        if staged is None:
            return 0  # trace already closed or never opened: drop quietly
        sid = next(self._ids)
        staged.append((name, start, end, trace_id, sid, parent_id, track, attrs))
        return sid

    def root_span(
        self,
        name: str,
        start: float,
        end: float,
        trace_id: int,
        track: tuple[str, str] = ("gateway", "main"),
        **attrs,
    ) -> int:
        """Finalize the trace's ROOT span: its span id IS the trace id,
        which is why children emitted earlier could already parent on
        it."""
        staged = self._staged.get(trace_id)
        if staged is None:
            return 0
        staged.append((name, start, end, trace_id, trace_id, None, track, attrs))
        return trace_id

    def instant(
        self,
        name: str,
        at: float,
        trace_id: int,
        parent_id: int | None = None,
        track: tuple[str, str] = ("gateway", "main"),
        **attrs,
    ) -> int:
        return self.span(name, at, at, trace_id, parent_id, track, **attrs)

    def end_trace(self, trace_id: int, latency: float | None = None) -> bool:
        """Close a trace: commit its staged spans to the ring buffer if
        the sampling policy keeps it, drop them otherwise. ``latency``
        feeds the tail policy (None = not a latency-bearing trace; kept
        only by always/head)."""
        staged = self._staged.pop(trace_id, None)
        if staged is None:
            return False
        keep = (
            self._always
            or self.traces_kept < self._head_n
            or (latency is not None and latency >= self._tail_s)
        )
        if keep:
            self.traces_kept += 1
            self._spans.extend(staged)
            self._epoch += 1
        else:
            self.traces_dropped += 1
        return keep

    def abort_trace(self, trace_id: int) -> None:
        self._staged.pop(trace_id, None)

    def replay_into(self, other: "Tracer") -> int:
        """Re-emit this tracer's committed span stream into ``other`` with
        the same call sequence a live run makes (begin_trace, one
        span/root_span call per span, end_trace with the root's
        latency), trace by trace in commit order. Benchmark harnesses
        time this to price the tracer plane against a run's REAL span
        payload: a tight deterministic loop, where an end-to-end A/B
        wall comparison on a virtualized host drowns the few-percent
        tracer cost in scheduler noise. Returns spans replayed."""
        streams: dict[int, list[tuple]] = {}
        for t in self._spans:
            streams.setdefault(t[3], []).append(t)
        n = 0
        for stream in streams.values():
            nid = other.begin_trace()
            latency = None
            for name, start, end, tid, sid, parent, track, attrs in stream:
                if sid == tid:  # the trace's root span
                    other.root_span(name, start, end, nid, track=track, **attrs)
                    latency = end - start
                else:
                    other.span(
                        name,
                        start,
                        end,
                        nid,
                        nid if parent is not None else None,
                        track=track,
                        **attrs,
                    )
                n += 1
            other.end_trace(nid, latency=latency)
        return n

    # -- queries ---------------------------------------------------------
    def trace(self, trace_id: int) -> list[Span]:
        """All committed spans of one trace, ordered by (start, span_id)."""
        out = [s for s in self.spans if s.trace_id == trace_id]
        out.sort(key=lambda s: (s.start, s.span_id))
        return out

    def trace_ids(self) -> list[int]:
        seen: dict[int, None] = {}
        for s in self.spans:
            seen.setdefault(s.trace_id, None)
        return list(seen)

    def resident(self) -> int:
        return len(self._spans) + sum(len(v) for v in self._staged.values())

    def stats(self) -> dict:
        return {
            "started": self.traces_started,
            "kept": self.traces_kept,
            "dropped": self.traces_dropped,
            "spans_resident": self.resident(),
            "capacity": self.capacity,
            "sample": self.sample,
        }


class _NullTracer(Tracer):
    """Shared no-op tracer: every emission site costs one attribute
    check (``tracer.enabled``) and nothing else."""

    def __init__(self):
        super().__init__("always", capacity=1)
        self.enabled = False

    def begin_trace(self) -> int:
        return 0

    def span(self, *a, **k) -> int:
        return 0

    def root_span(self, *a, **k) -> int:
        return 0

    def instant(self, *a, **k) -> int:
        return 0

    def end_trace(self, trace_id: int, latency: float | None = None) -> bool:
        return False

    def abort_trace(self, trace_id: int) -> None:
        pass


NULL_TRACER = _NullTracer()
