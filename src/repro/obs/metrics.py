"""Streaming metrics: bounded estimators for open-loop million-request
traces.

Every container here has O(1) resident memory in the number of samples
observed — the invariant that lets the simulator run arbitrarily long
traces without the per-request lists ``GatewayReport`` used to accrete
(``mttr_samples``, latency lists, pacing logs). Three primitives:

  * ``P2Quantile``  — the Jain & Chlamtac P² estimator: one target
    quantile tracked with five markers, no stored samples. Used where a
    single quantile (a pacer's p99) is all that's needed.
  * ``StreamHist``  — a fixed-bin log-spaced histogram (the PR-5
    ``batch_hist`` pattern generalized to continuous values): relative
    quantile error is bounded by the bin growth factor, any quantile can
    be asked after the fact, and two histograms merge by bin addition.
  * ``BoundedSamples`` / ``BoundedLog`` — list-compatible shims for
    report fields that used to be raw lists: they keep exact streaming
    scalars (count/sum/min/max) plus a bounded prefix (samples) or
    suffix (log entries) of raw entries for inspection. ``len()``
    reports the TOTAL observed count; iteration yields only the
    retained subset.

``MetricsRegistry`` organizes labeled counters / gauges / histograms
under stable names (``registry.counter("requests", tenant="gold")``) and
snapshots to a plain dict; ``resident_samples()`` reports the total
retained entries across every instrument — the number the long-trace
benchmark gates on staying bounded.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field


class P2Quantile:
    """Jain & Chlamtac's P² streaming quantile estimator (CACM 1985).

    Tracks one quantile ``q`` with five markers and piecewise-parabolic
    interpolation — O(1) memory, no stored samples. Exact until five
    observations have arrived."""

    __slots__ = ("q", "_n", "_heights", "_pos", "_desired", "_inc")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self._n = 0
        self._heights: list[float] = []
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self._desired = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self._inc = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    @property
    def count(self) -> int:
        return self._n

    def observe(self, x: float) -> None:
        self._n += 1
        h = self._heights
        if len(h) < 5:
            h.append(float(x))
            h.sort()
            return
        # locate the cell and bump the extreme markers
        if x < h[0]:
            h[0] = float(x)
            k = 0
        elif x >= h[4]:
            h[4] = float(x)
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        pos = self._pos
        for i in range(k + 1, 5):
            pos[i] += 1.0
        for i in range(5):
            self._desired[i] += self._inc[i]
        # adjust interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._desired[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or (
                d <= -1.0 and pos[i - 1] - pos[i] < -1.0
            ):
                d = 1.0 if d >= 1.0 else -1.0
                cand = self._parabolic(i, d)
                if not h[i - 1] < cand < h[i + 1]:
                    cand = self._linear(i, d)
                h[i] = cand
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, pos = self._heights, self._pos
        return h[i] + d / (pos[i + 1] - pos[i - 1]) * (
            (pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i]) / (pos[i + 1] - pos[i])
            + (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1]) / (pos[i] - pos[i - 1])
        )

    def _linear(self, i: int, d: float) -> float:
        h, pos = self._heights, self._pos
        j = i + int(d)
        return h[i] + d * (h[j] - h[i]) / (pos[j] - pos[i])

    @property
    def value(self) -> float:
        h = self._heights
        if not h:
            return 0.0
        if self._n < 5:
            # exact small-sample quantile (numpy's 'linear' definition)
            idx = self.q * (len(h) - 1)
            lo = int(idx)
            hi = min(lo + 1, len(h) - 1)
            return h[lo] + (idx - lo) * (h[hi] - h[lo])
        return h[2]


class StreamHist:
    """Fixed-bin log-spaced histogram for positive-valued streams.

    Bin edges grow geometrically by ``growth`` from ``lo`` to ``hi``
    (values outside clamp into the end bins), so the RELATIVE error of
    any reported quantile is bounded by ``growth - 1`` as long as the
    mass stays inside [lo, hi]. Resident memory is the fixed bin array —
    independent of how many samples were observed. Exact count / sum /
    min / max ride alongside."""

    __slots__ = ("lo", "growth", "_log_g", "bins", "count", "sum", "min", "max")

    def __init__(self, lo: float = 1e-6, hi: float = 1e4, growth: float = 1.07):
        if not (lo > 0.0 and hi > lo and growth > 1.0):
            raise ValueError(f"bad StreamHist bounds: {lo}, {hi}, {growth}")
        self.lo = lo
        self.growth = growth
        self._log_g = math.log(growth)
        nbins = int(math.ceil(math.log(hi / lo) / self._log_g)) + 1
        self.bins = [0] * nbins
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _index(self, x: float) -> int:
        if x <= self.lo:
            return 0
        i = int(math.log(x / self.lo) / self._log_g)
        return min(i, len(self.bins) - 1)

    def observe(self, x: float) -> None:
        self.count += 1
        self.sum += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        self.bins[self._index(x)] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def _edge(self, i: int) -> float:
        return self.lo * self.growth**i

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1]: geometric midpoint of the
        bin holding the target rank (clamped to the exact min/max, so a
        single-sample histogram answers exactly)."""
        if self.count == 0:
            return 0.0
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        target = q * self.count
        acc = 0
        for i, n in enumerate(self.bins):
            acc += n
            if acc >= target:
                mid = self._edge(i) * math.sqrt(self.growth)
                return min(max(mid, self.min), self.max)
        return self.max

    def cdf(self, x: float) -> float:
        """Approximate fraction of samples <= x (bin-resolution, exact at
        the stream min/max)."""
        if self.count == 0:
            return 0.0
        if x >= self.max:
            return 1.0
        if x < self.min:
            return 0.0
        idx = self._index(x)
        return sum(self.bins[: idx + 1]) / self.count

    def merge(self, other: "StreamHist") -> None:
        assert len(self.bins) == len(other.bins) and self.lo == other.lo
        for i, n in enumerate(other.bins):
            self.bins[i] += n
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def resident(self) -> int:
        return len(self.bins)

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
        }


class BoundedSamples:
    """List-compatible bounded sample container.

    Streams exact count / sum / min / max (so means and maxima never
    degrade) while retaining only the first ``cap`` raw samples for
    inspection. ``len()`` is the TOTAL number of samples ever appended —
    the semantics every ``len(report.mttr_samples)`` caller already
    assumes — and iteration yields the retained prefix."""

    __slots__ = ("cap", "_kept", "count", "sum", "_min", "_max")

    def __init__(self, cap: int = 512):
        self.cap = cap
        self._kept: list[float] = []
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def append(self, x: float) -> None:
        self.count += 1
        self.sum += x
        self._min = min(self._min, x)
        self._max = max(self._max, x)
        if len(self._kept) < self.cap:
            self._kept.append(x)

    def __len__(self) -> int:
        return self.count

    def __bool__(self) -> bool:
        return self.count > 0

    def __iter__(self):
        return iter(self._kept)

    def __getitem__(self, i):
        return self._kept[i]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def max(self) -> float:
        return self._max if self.count else 0.0

    @property
    def min(self) -> float:
        return self._min if self.count else 0.0

    def resident(self) -> int:
        return len(self._kept)


class BoundedLog:
    """Bounded event log: retains the LAST ``cap`` entries (a deque),
    counts everything. Replaces unbounded append-only logs (pacing
    decisions) where recent history is what matters."""

    __slots__ = ("_kept", "count")

    def __init__(self, cap: int = 1024):
        self._kept: deque = deque(maxlen=cap)
        self.count = 0

    def append(self, item) -> None:
        self.count += 1
        self._kept.append(item)

    def __len__(self) -> int:
        return self.count

    def __bool__(self) -> bool:
        return self.count > 0

    def __iter__(self):
        return iter(self._kept)

    def __getitem__(self, i):
        return list(self._kept)[i]

    def resident(self) -> int:
        return len(self._kept)


@dataclass
class Counter:
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


@dataclass
class Gauge:
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = v


@dataclass
class MetricsRegistry:
    """Labeled counters / gauges / histograms under stable names.

    Instruments are created on first touch and keyed by
    (name, sorted label items) — the Prometheus shape, sized for a
    simulator: ``registry.counter("requests", tenant="gold").inc()``.
    ``snapshot()`` renders everything to one plain dict (the form
    ``GatewayReport`` exposes); ``resident_samples()`` totals the
    retained entries of every instrument, which is bounded by the number
    of DISTINCT (name, labels) series — never by the sample count."""

    _counters: dict = field(default_factory=dict)
    _gauges: dict = field(default_factory=dict)
    _hists: dict = field(default_factory=dict)

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items())))

    def counter(self, name: str, **labels) -> Counter:
        key = self._key(name, labels)
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = self._key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, **labels) -> StreamHist:
        key = self._key(name, labels)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = StreamHist()
        return h

    def counter_total(self, name: str, **match) -> float:
        """Sum of every counter series named ``name`` whose labels
        include ``match`` (empty match sums all series)."""
        total = 0.0
        for (n, items), c in self._counters.items():
            if n == name and all((k, v) in items for k, v in match.items()):
                total += c.value
        return total

    def merged_histogram(self, name: str, **match) -> StreamHist | None:
        """Bin-wise merge of every histogram series named ``name`` whose
        labels include ``match`` — how a whole-trace quantile is read
        back out of per-tenant/per-kind series."""
        out = None
        for (n, items), h in self._hists.items():
            if n == name and all((k, v) in items for k, v in match.items()):
                if out is None:
                    # hi chosen so the reconstructed bin count matches
                    # exactly (ceil(log(g^(n-1))/log g) + 1 == n)
                    out = StreamHist(
                        lo=h.lo, hi=h._edge(len(h.bins) - 1), growth=h.growth
                    )
                out.merge(h)
        return out

    @staticmethod
    def _label_str(items: tuple) -> str:
        return ",".join(f"{k}={v}" for k, v in items)

    def snapshot(self) -> dict:
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for (name, items), c in sorted(self._counters.items()):
            out["counters"][f"{name}{{{self._label_str(items)}}}"] = c.value
        for (name, items), g in sorted(self._gauges.items()):
            out["gauges"][f"{name}{{{self._label_str(items)}}}"] = g.value
        for (name, items), h in sorted(self._hists.items()):
            out["histograms"][f"{name}{{{self._label_str(items)}}}"] = h.summary()
        return out

    def resident_samples(self) -> int:
        return (
            len(self._counters)
            + len(self._gauges)
            + sum(h.resident() for h in self._hists.values())
        )
