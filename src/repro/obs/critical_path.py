"""Critical-path accounting: decompose a request's latency into additive
stage contributions.

A completed GET's latency is ``done - arrival`` where ``done`` is the
max over its direct-fetch completions and the decode launches it
depends on. Whichever dependency finishes LAST is the critical one, and
the spans the gateway emits carry exactly the intermediate timestamps
needed to cut that terminal chain into consecutive stages:

  arrival -> fetch_start -> sources_ready -> launch_barrier
          -> engine_start -> decode_end -> done

  * ``admission``   — arrival to fetch start (batching-window wait plus
    the serial-mode window barrier);
  * ``fetch``       — fetch start until the critical op's own sources
    landed (fabric serialization + queueing);
  * ``batch_wait``  — waiting for SIBLING ops staged into the same
    physical launch (the coalescing price: a launch's buffer holds every
    one of its ops' tiles);
  * ``engine_wait`` — launch barrier to engine start (decode-engine
    queueing, including tenant-share throttling);
  * ``decode``      — the launch occupying the engine;
  * ``deliver``     — anything after the terminal dependency (0 by
    construction for decode-gated requests; for fetch-gated requests the
    decode stages are all 0 and ``fetch`` runs to the last byte).

The checkpoint sequence is clamped monotonically between arrival and
``done``, so the six stages are non-negative and sum EXACTLY to the
request's latency — which is what makes fleet-level ``stage_shares``
(stage sums normalized by total latency) sum to 1.0.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

from repro.obs.tracer import Span, Tracer

STAGES = ("admission", "fetch", "batch_wait", "engine_wait", "decode", "deliver")


@dataclass
class PathBreakdown:
    trace_id: int
    latency: float
    stages: dict  # stage name -> seconds, sums to latency
    gated_by: str  # "decode" | "fetch" | "cache"

    def share(self, stage: str) -> float:
        return self.stages[stage] / self.latency if self.latency > 0 else 0.0


def _clamped_diffs(checkpoints: list[float], t0: float, done: float) -> list[float]:
    """Consecutive differences of ``checkpoints`` clamped monotonically
    into [t0, done] — non-negative, summing exactly to done - t0."""
    out = []
    prev = t0
    for c in checkpoints:
        c = min(max(c, prev), done)
        out.append(c - prev)
        prev = c
    out.append(done - prev)
    return out


def critical_path(spans: Iterable[Span], trace_id: int | None = None) -> PathBreakdown | None:
    """Stage breakdown for one request trace.

    ``spans`` is any span iterable (e.g. ``tracer.trace(tid)`` or
    ``tracer.spans``); when ``trace_id`` is given, spans are filtered to
    it first. Returns None when the trace has no request root."""
    if trace_id is not None:
        spans = [s for s in spans if s.trace_id == trace_id]
    else:
        spans = list(spans)
    root = next((s for s in spans if s.name == "request"), None)
    if root is None:
        return None
    t0, done = root.start, root.end
    latency = done - t0
    stages = dict.fromkeys(STAGES, 0.0)
    decodes = [s for s in spans if s.name == "decode"]
    fetches = [s for s in spans if s.name == "fetch"]
    term_decode = max(decodes, key=lambda s: s.end, default=None)
    term_fetch = max(fetches, key=lambda s: s.end, default=None)
    fetch_at = float(root.attrs.get("fetch_at", t0))
    if term_decode is not None and (
        term_fetch is None or term_decode.end >= term_fetch.end
    ):
        gated = "decode"
        d = term_decode
        diffs = _clamped_diffs(
            [
                fetch_at,
                float(d.attrs.get("op_ready", d.start)),
                float(d.attrs.get("ready", d.start)),
                d.start,
                d.end,
            ],
            t0,
            done,
        )
        for name, dt in zip(
            ("admission", "fetch", "batch_wait", "engine_wait", "decode", "deliver"),
            diffs,
        ):
            stages[name] = dt
    elif term_fetch is not None:
        gated = "fetch"
        adm, fetch, deliver = _clamped_diffs(
            [term_fetch.start, term_fetch.end], t0, done
        )
        stages["admission"] = adm
        stages["fetch"] = fetch
        stages["deliver"] = deliver
    else:
        # cache-only request: no fabric or engine dependency — whatever
        # residual latency exists (cache-readiness gating) is admission
        gated = "cache"
        stages["admission"] = latency
    return PathBreakdown(root.trace_id, latency, stages, gated)


def stage_shares(tracer: Tracer) -> dict:
    """Fleet-level stage attribution over every committed request trace:
    per-stage time sums normalized by total latency. The per-trace
    breakdowns are exactly additive, so the returned shares sum to 1.0
    whenever any latency was observed."""
    sums = dict.fromkeys(STAGES, 0.0)
    total = 0.0
    n = 0
    by_trace: dict[int, list[Span]] = {}
    for s in tracer.spans:
        by_trace.setdefault(s.trace_id, []).append(s)
    for spans in by_trace.values():
        bd = critical_path(spans)
        if bd is None:
            continue
        n += 1
        total += bd.latency
        for k, v in bd.stages.items():
            sums[k] += v
    shares = {
        k: (v / total if total > 0 else 0.0) for k, v in sums.items()
    }
    return {
        "traces": n,
        "total_latency": total,
        "stage_seconds": sums,
        "shares": shares,
    }


def launch_amortization(tracer: Tracer) -> dict:
    """Per-window launch-amortization breakdown from decode spans: how
    many ops shared each physical launch and how its tiles split across
    them (megakernel fractions sum to ~1.0 per launch)."""
    per_launch: dict[int, dict] = {}
    seen: set[tuple] = set()  # a shared op spans once per OWNER trace
    for s in tracer.spans:
        if s.name != "decode":
            continue
        lid = s.attrs.get("launch_id")
        if lid is None or lid < 0:
            continue
        key = (lid, s.attrs.get("op"))
        if key in seen:
            continue
        seen.add(key)
        agg = per_launch.setdefault(lid, {"ops": 0, "fraction": 0.0, "tiles": 0})
        agg["ops"] += 1
        agg["fraction"] += float(s.attrs.get("fraction", 1.0))
        agg["tiles"] += int(s.attrs.get("tiles", 0))
    if not per_launch:
        return {"launches": 0, "ops_per_launch": 0.0, "tiles_per_launch": 0.0}
    n = len(per_launch)
    return {
        "launches": n,
        "ops_per_launch": sum(a["ops"] for a in per_launch.values()) / n,
        "tiles_per_launch": sum(a["tiles"] for a in per_launch.values()) / n,
    }
