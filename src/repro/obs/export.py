"""Perfetto / chrome-tracing JSON export of a run's spans.

The exporter maps the tracer's ``(group, name)`` tracks onto the chrome
trace model: each track GROUP becomes a process (``pid``) and each track
member a thread (``tid``), named via ``"M"`` metadata events — so a
gateway run opens in https://ui.perfetto.dev (or chrome://tracing) with
one process row per subsystem:

  * ``tenant``  — one thread per tenant: request roots, per-source
    fetches, decode attribution spans;
  * ``engine``  — one thread per simulated decode engine: the launches
    actually occupying it;
  * ``fabric``  — one thread per send port: individual transfers with
    their queueing delay in ``args``;
  * ``repair``  — background repair groups, their fetch phases and
    pacing decisions.

Timestamps are the SIMULATED clock converted to microseconds (the chrome
format's unit) — a span of 3 ms simulated latency renders as 3 ms.
Intervals emit ``ph: "X"`` complete events; zero-duration spans emit
``ph: "i"`` instants. Span attributes ride in ``args`` alongside the
trace/span/parent ids, so Perfetto's flow/selection UI can correlate a
request root with its engine and fabric spans.

``validate_chrome_trace`` is the schema check the CI smoke step runs on
the exported file: structural rules only (required fields, known
phases, non-negative times, metadata naming), not a rendering test.
"""

from __future__ import annotations

import json
from typing import Iterable

from repro.obs.tracer import Span

PHASES = {"X", "i", "M"}


def to_chrome_trace(spans: Iterable[Span]) -> dict:
    """Render spans to a chrome-tracing document (dict, JSON-ready)."""
    pids: dict[str, int] = {}
    tids: dict[tuple[str, str], int] = {}
    events: list[dict] = []
    for s in spans:
        group, member = s.track
        pid = pids.get(group)
        if pid is None:
            pid = pids[group] = len(pids) + 1
            events.append(
                {
                    "name": "process_name",
                    "ph": "M",
                    "pid": pid,
                    "tid": 0,
                    "args": {"name": group},
                }
            )
        tkey = (group, member)
        tid = tids.get(tkey)
        if tid is None:
            tid = tids[tkey] = len(tids) + 1
            events.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": pid,
                    "tid": tid,
                    "args": {"name": member},
                }
            )
        args = {
            "trace_id": s.trace_id,
            "span_id": s.span_id,
            "parent_id": s.parent_id,
        }
        args.update(s.attrs)
        ev = {
            "name": s.name,
            "cat": group,
            "pid": pid,
            "tid": tid,
            "ts": s.start * 1e6,
            "args": args,
        }
        if s.end > s.start:
            ev["ph"] = "X"
            ev["dur"] = (s.end - s.start) * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"  # thread-scoped instant
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Iterable[Span]) -> dict:
    """Export spans to ``path``; returns the document written."""
    doc = to_chrome_trace(spans)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_chrome_trace(doc: dict) -> int:
    """Structural chrome-tracing schema check; raises ValueError on the
    first violation, returns the event count when clean."""
    if not isinstance(doc, dict):
        raise ValueError(f"trace document must be an object, got {type(doc).__name__}")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace document must carry a 'traceEvents' list")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where}: event must be an object")
        for fieldname in ("name", "ph", "pid", "tid"):
            if fieldname not in ev:
                raise ValueError(f"{where}: missing required field {fieldname!r}")
        if not isinstance(ev["name"], str) or not ev["name"]:
            raise ValueError(f"{where}: 'name' must be a non-empty string")
        ph = ev["ph"]
        if ph not in PHASES:
            raise ValueError(f"{where}: unknown phase {ph!r} (want one of {sorted(PHASES)})")
        for fieldname in ("pid", "tid"):
            if not isinstance(ev[fieldname], int):
                raise ValueError(f"{where}: {fieldname!r} must be an int")
        if ph == "M":
            args = ev.get("args")
            if not isinstance(args, dict) or "name" not in args:
                raise ValueError(f"{where}: metadata event needs args.name")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"{where}: 'ts' must be a non-negative number, got {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"{where}: complete event needs non-negative 'dur', got {dur!r}"
                )
    return len(events)


def validate_file(path: str) -> int:
    """Load ``path`` and validate it; returns the event count."""
    with open(path) as f:
        doc = json.load(f)
    return validate_chrome_trace(doc)
