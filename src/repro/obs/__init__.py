"""Sim-time observability plane: spans, streaming metrics, Perfetto
export and critical-path accounting for the gateway stack.

Everything here runs over the SIMULATED clock — spans measure simulated
seconds, not wall time — and is observation-only by contract: enabling
tracing never changes event ordering, simulated timestamps, or payload
bytes (tests/test_obs.py pins traced ≡ untraced fingerprints).

Span taxonomy
=============

Request traces (one per completed GET/PUT; ``trace_id`` doubles as the
root span id so children parent on it before the root is finalized):

  ``request``        root span [arrival, completion]; attrs: object_id,
                     kind, tenant, degraded, bytes, cache_hits, fetch_at
  ``plan``           instant at plan time; attrs: degraded, sources,
                     decodes (instants for admission estimate too)
  ``fetch``          one per fabric-fetched source block
                     [fetch start, block landed]; attrs: key, src, bytes
  ``cache.hit``      instant per cache-served source block
  ``decode``         one per (request, decode op): the launch interval
                     that completed the op [engine start, engine end];
                     attrs: kind, launch_id, fraction, tiles, op (window
                     op index), shared (co-owning requests),
                     op_ready (own sources landed),
                     ready (launch-wide source barrier)
  ``verify``         instant at delivery (ground-truth check, 0 sim cost)
  ``hedge``          one per speculative alternate-path fetch racing a
                     slow direct fetch [hedge launch, last hedge source
                     landed]; attrs: key, kind (V|H), won, attempt
  ``corrupt``        instant at digest-mismatch detection (corruption
                     reclassified as an erasure); attrs: key, source
                     (read | scrub | write | repair)

Infrastructure tracks (emitted into whichever request/repair trace
caused the work):

  ``xfer``           fabric transfer [first byte, last byte] on the
                     SOURCE port's track; attrs: src, dst, bytes,
                     tenant, wait (queueing before the first quantum)
  ``engine.launch``  engine occupancy [start, end] on the engine's track

Repair traces (one per background-repair run):

  ``repair.run``     root span over the run; attrs: groups, healed
  ``pacing``         instant per closed-loop share decision; attrs:
                     share, observed_p99, pressure
  ``repair.group``   one group's fix [detection, fabric makespan];
                     attrs: group, mode, blocks_repaired, recovered
  ``repair.fetch``   one repair step's source gathering; attrs: kind,
                     blocks
  ``repair.decode``  the repair's decode billing on the engine pool
  ``repair.heal``    instant when a block becomes readable again

Scrub traces (one per background scrub tick, on the repair track):

  ``scrub.run``      root span over the tick; attrs: scanned, found
                     (``corrupt`` instants for blocks it catches parent
                     on it)

Track layout (Perfetto: one process per group, one thread per member):

  ``("tenant", <tenant>)``   request roots + per-request stages
  ``("engine", engine<i>)``  decode-engine occupancy
  ``("fabric", port<n>)``    per-send-port transfers
  ``("repair", repair)``     background repair activity

Sampling: ``Tracer(sample=...)`` takes ``"always"``, ``"head:N"``,
``"tail:SECONDS"`` or comma-combinations (keep if ANY matches), so
tail-latency traces are never dropped while steady-state traffic can be
heavily sampled. Spans land in a bounded ring buffer (``capacity``).

Metrics: ``MetricsRegistry`` (labeled counters / gauges / log-binned
histograms), ``P2Quantile``, ``StreamHist``, and the list-compatible
``BoundedSamples`` / ``BoundedLog`` that replaced ``GatewayReport``'s
unbounded per-request lists — resident memory stays O(1) in trace
length.

Analysis: ``critical_path`` cuts one request's latency into additive
stages (admission / fetch / batch_wait / engine_wait / decode /
deliver); ``stage_shares`` aggregates a run into shares summing to 1.0;
``launch_amortization`` reports how ops shared physical launches.
Export: ``write_chrome_trace`` / ``validate_chrome_trace`` produce and
check Perfetto-loadable JSON (see examples/gateway_serving.py --trace).
"""

from repro.obs.critical_path import (
    PathBreakdown,
    STAGES,
    critical_path,
    launch_amortization,
    stage_shares,
)
from repro.obs.export import (
    to_chrome_trace,
    validate_chrome_trace,
    validate_file,
    write_chrome_trace,
)
from repro.obs.metrics import (
    BoundedLog,
    BoundedSamples,
    Counter,
    Gauge,
    MetricsRegistry,
    P2Quantile,
    StreamHist,
)
from repro.obs.tracer import NULL_TRACER, Span, Tracer

__all__ = [
    "NULL_TRACER",
    "STAGES",
    "BoundedLog",
    "BoundedSamples",
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "P2Quantile",
    "PathBreakdown",
    "Span",
    "StreamHist",
    "Tracer",
    "critical_path",
    "launch_amortization",
    "stage_shares",
    "to_chrome_trace",
    "validate_chrome_trace",
    "validate_file",
    "write_chrome_trace",
]
