"""Config registry: --arch <id> resolves here."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, CoreCodeCfg, ShapeCell

ARCH_IDS = [
    "mistral_large_123b",
    "command_r_35b",
    "starcoder2_15b",
    "qwen2_72b",
    "recurrentgemma_9b",
    "granite_moe_3b_a800m",
    "olmoe_1b_7b",
    "falcon_mamba_7b",
    "seamless_m4t_large_v2",
    "pixtral_12b",
]


def get_config(arch: str) -> ArchConfig:
    arch = arch.replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


__all__ = ["ARCH_IDS", "ArchConfig", "CoreCodeCfg", "SHAPES", "ShapeCell", "get_config"]
