"""IBM Granite-3.0 3B-A800M MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
Assigned config line says "MoE 40e top-8" with a trailing "32 experts"
note; we follow the config field (40 experts, top-8) and record the
discrepancy here. 40 % 16 != 0 -> experts replicated, TP inside the
(d_ff=512) expert MLPs (DESIGN.md §5)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,  # per-expert hidden
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    act="silu",
    tie_embeddings=True,
)
