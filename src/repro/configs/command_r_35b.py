"""Cohere Command-R 35B (dense, GQA, no-bias).
[hf:CohereForAI/c4ai-command-r-v01; unverified]
Note: the HF model uses parallel attn+MLP blocks and tied embeddings; we
keep the standard sequential residual wiring (backbone-equivalent FLOPs)
and tie embeddings."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    rope_theta=8e6,
    norm="layernorm",
    tie_embeddings=True,
    act="silu",
)
