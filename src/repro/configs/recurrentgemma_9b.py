"""RecurrentGemma-9B (hybrid: RG-LRU + local attention, 2:1 pattern).
[arXiv:2402.19427; unverified]
38 layers = 12 x (rec, rec, attn) + (rec, rec). MQA (kv=1), window 2048."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,  # MQA
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    sliding_window=2048,
    act="gelu_gated",
)
