"""Architecture configuration schema + registry.

One config file per assigned architecture lives in this package; each
exposes ``CONFIG``. ``--arch <id>`` in the launchers resolves through
``repro.configs.get_config``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class CoreCodeCfg:
    """CORE protection level for this arch's checkpoints (paper §4)."""

    n: int = 14
    k: int = 12
    t: int = 5


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    use_rope: bool = True
    rope_theta: float = 1e4
    qkv_bias: bool = False
    sliding_window: int | None = None
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (gated) | gelu (non-gated, classic 2-matrix MLP)
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba-1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0

    # hybrid (recurrentgemma): repeating block pattern, e.g. ("rec","rec","attn")
    block_pattern: tuple[str, ...] = ()
    lru_width: int = 0

    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0

    # modality stub (audio frames / vision patches), prepended embeddings
    num_stub_tokens: int = 0

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    core_code: CoreCodeCfg = field(default_factory=CoreCodeCfg)

    # training-time knobs (overridable per run)
    microbatches: int = 1
    attn_chunk: int = 512
    scan_chunk: int = 128  # ssm/rglru chunked-scan length
    remat_block: int = 0  # two-level remat group size (0 = per-layer only)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test-sized sibling: same family/wiring, tiny dims."""
        small = dict(
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=8 if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.num_experts else 0,
            ssm_state=8 if self.ssm_state else 0,
            dt_rank=8 if self.ssm_state else 0,
            lru_width=128 if self.lru_width else 0,
            sliding_window=64 if self.sliding_window else None,
            enc_layers=2 if self.enc_layers else 0,
            dec_layers=2 if self.dec_layers else 0,
            num_stub_tokens=8 if self.num_stub_tokens else 0,
            block_pattern=self.block_pattern,
            attn_chunk=32,
            scan_chunk=16,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# shape cells assigned to the LM pool --------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
