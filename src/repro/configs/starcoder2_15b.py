"""StarCoder2-15B (dense, GQA kv=4, RoPE, gelu MLP, biases).
[arXiv:2402.19173; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=1e5,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",  # classic 2-matrix MLP
    sliding_window=4096,
)
