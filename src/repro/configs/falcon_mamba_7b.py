"""Falcon-Mamba-7B (attention-free Mamba-1 SSM).
[arXiv:2410.05355; unverified]
d_inner = 2 * d_model = 8192, ssm_state = 16, conv4, dt_rank = 256."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    d_conv=4,
    expand=2,
    dt_rank=256,
    tie_embeddings=True,
)
