"""Mistral-Large-Instruct-2407 (123B dense).
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,  # GQA
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1e6,
    act="silu",
    remat_block=8,  # 88 layers of d=12288: two-level remat to fit HBM (Perf iter B)
)
