"""SeamlessM4T-large-v2 transformer backbone (enc-dec).
[arXiv:2308.11596; hf]
Modality frontend is a STUB: input_specs() provides precomputed speech
frame embeddings (B, T_frames, d_model). 24 encoder + 24 decoder layers."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=48,  # 24 enc + 24 dec
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    num_stub_tokens=1024,  # precomputed audio frame embeddings
    norm="layernorm",
    act="gelu",
    use_rope=False,  # sinusoidal absolute positions (NLLB lineage)
)
