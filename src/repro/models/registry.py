"""Family registry: one uniform ModelApi per architecture family.

    api = get_model(cfg)
    loss              = api.loss(params, batch, cfg, ax)
    logits, cache     = api.prefill(params, batch, cfg, ax, cache_len)
    logits, cache     = api.decode(params, token, cache, pos, cfg, ax, plan)

``batch`` is a dict: tokens/labels (+ patch_embed for vlm, src_embed for
encdec, loss_mask optional). All ten assigned archs resolve here.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, mamba, moe, rglru, transformer as T


@dataclass(frozen=True)
class ModelApi:
    family: str
    init: Callable  # (cfg, rng) -> params
    specs: Callable  # (cfg, ax) -> pytree of PartitionSpec
    loss: Callable  # (params, batch, cfg, ax) -> scalar
    prefill: Callable  # (params, batch, cfg, ax, cache_len) -> (logits, cache)
    decode: Callable  # (params, token, cache, pos, cfg, ax, plan) -> (logits, cache)
    init_cache: Callable  # (cfg, batch, cache_len) -> cache
    cache_shape: Callable  # (cfg, batch, cache_len) -> ShapeDtypeStruct tree
    cache_specs: Callable  # (cfg, ax, batch, plan) -> pytree of PartitionSpec


# -- dense / vlm --------------------------------------------------------------


def _dense_prefill(params, batch, cfg, ax, cache_len):
    return T.prefill(
        params, batch["tokens"], cfg, ax, cache_len,
        prefix_embed=batch.get("patch_embed"),
    )


DENSE = ModelApi(
    family="dense",
    init=T.init_lm,
    specs=T.lm_specs,
    loss=T.lm_loss,
    prefill=_dense_prefill,
    decode=T.decode_step,
    init_cache=T.init_cache,
    cache_shape=T.cache_shape,
    cache_specs=T.cache_specs,
)

VLM = DENSE  # patch-embedding stub prefix is handled inside loss/prefill


# -- moe ----------------------------------------------------------------------


def _moe_init(cfg, rng):
    import jax

    ke, kl, kh = jax.random.split(rng, 3)
    from repro.models import layers as L
    from repro.models import stack

    params = {
        "embed": L.init_embed(ke, cfg),
        "layers": stack.stacked_init(
            functools.partial(
                T.init_decoder_layer, cfg=cfg, ffn_init=moe.init_moe
            ),
            kl,
            cfg.num_layers,
        ),
        "ln_f": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.init_dense(kh, cfg.d_model, cfg.vocab_size, False)["w"]
    return params


def _moe_specs(cfg, ax):
    from jax.sharding import PartitionSpec as P
    from repro.models import stack

    specs = {
        "embed": T.embed_specs(cfg, ax),
        "layers": stack.stacked_specs(
            T.decoder_layer_specs(cfg, ax, ffn_specs=moe.moe_specs)
        ),
        "ln_f": T.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["head"] = P(ax.fsdp_if(cfg.d_model), ax.tp_if(cfg.vocab_size))
    return specs


def _moe_loss(params, batch, cfg, ax):
    """Dense-LM wiring + per-layer load-balance aux threaded through the
    scan carry (weight 0.01, Switch-style)."""
    import jax
    from repro.models import layers as L
    from repro.models import stack
    from repro.models.shardings import constrain
    from jax.sharding import PartitionSpec as P

    x = L.embed_tokens(params["embed"], batch["tokens"], ax)
    s = x.shape[1]
    x = constrain(x, T.res_spec(ax, s))
    positions = jnp.arange(s)

    def body(carry, lp):
        h, aux = carry
        h = h + L.attention_train(L.norm(h, lp["ln1"], cfg), lp["attn"], cfg, ax, positions)
        h = constrain(h, T.res_spec(ax, s))
        y, a = moe.moe_ffn(L.norm(h, lp["ln2"], cfg), lp["ffn"], cfg, ax)
        h = constrain(h + y, T.res_spec(ax, s))
        return (h, aux + a), None

    ck = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(lambda c, lp: ck(c, lp), (x, jnp.zeros(())), params["layers"])
    x = L.norm(x, params["ln_f"], cfg)
    xent = T.chunked_xent(
        x, T.unembed_weight(params, cfg), batch["labels"], cfg, ax, batch.get("loss_mask")
    )
    return xent + 0.01 * aux / cfg.num_layers


def _moe_prefill(params, batch, cfg, ax, cache_len):
    return T.prefill(
        params, batch["tokens"], cfg, ax, cache_len, ffn_apply=moe.moe_ffn_noaux
    )


def _moe_decode(params, token, cache, pos, cfg, ax, plan):
    return T.decode_step(
        params, token, cache, pos, cfg, ax, plan, ffn_apply=moe.moe_ffn_noaux
    )


MOE = ModelApi(
    family="moe",
    init=_moe_init,
    specs=_moe_specs,
    loss=_moe_loss,
    prefill=_moe_prefill,
    decode=_moe_decode,
    init_cache=T.init_cache,
    cache_shape=T.cache_shape,
    cache_specs=T.cache_specs,
)


# -- ssm / hybrid / encdec ----------------------------------------------------


def _ssm_prefill(params, batch, cfg, ax, cache_len):
    return mamba.prefill(params, batch["tokens"], cfg, ax, cache_len)


SSM = ModelApi(
    family="ssm",
    init=mamba.init_lm,
    specs=mamba.lm_specs,
    loss=mamba.lm_loss,
    prefill=_ssm_prefill,
    decode=mamba.decode_step,
    init_cache=mamba.init_cache,
    cache_shape=mamba.cache_shape,
    cache_specs=mamba.cache_specs,
)


def _hybrid_prefill(params, batch, cfg, ax, cache_len):
    return rglru.prefill(params, batch["tokens"], cfg, ax, cache_len)


HYBRID = ModelApi(
    family="hybrid",
    init=rglru.init_lm,
    specs=rglru.lm_specs,
    loss=rglru.lm_loss,
    prefill=_hybrid_prefill,
    decode=rglru.decode_step,
    init_cache=rglru.init_cache,
    cache_shape=rglru.cache_shape,
    cache_specs=rglru.cache_specs,
)


def _encdec_prefill(params, batch, cfg, ax, cache_len):
    return encdec.prefill(
        params, batch["tokens"], cfg, ax, cache_len, src_embed=batch["src_embed"]
    )


ENCDEC = ModelApi(
    family="encdec",
    init=encdec.init_lm,
    specs=encdec.lm_specs,
    loss=encdec.lm_loss,
    prefill=_encdec_prefill,
    decode=encdec.decode_step,
    init_cache=encdec.init_cache,
    cache_shape=encdec.cache_shape,
    cache_specs=encdec.cache_specs,
)


_FAMILIES = {
    "dense": DENSE,
    "vlm": VLM,
    "moe": MOE,
    "ssm": SSM,
    "hybrid": HYBRID,
    "encdec": ENCDEC,
}


def get_model(cfg: ArchConfig) -> ModelApi:
    return _FAMILIES[cfg.family]
