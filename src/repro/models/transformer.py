"""Decoder-only transformer LM — the `dense` family (mistral-large,
command-r, starcoder2, qwen2) and, with a patch-embedding stub prefix,
the `vlm` family (pixtral).

Pure-function / params-dict style (see layers.py). Layer stacks are
scanned (stack.py). Three entry points per model:
  * ``loss``    — train forward + chunked cross-entropy (logits are never
                  materialized beyond (B, chunk, V), sharded on tp).
  * ``prefill`` — fills a KV cache, returns last-position logits.
  * ``decode``  — one-token step against the cache (plain / seq-sharded).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import stack
from repro.models.shardings import MeshAxes, constrain


# ---------------------------------------------------------------------------
# param init & sharding specs
# ---------------------------------------------------------------------------


def init_decoder_layer(rng, cfg: ArchConfig, ffn_init=None):
    k1, k2 = jax.random.split(rng)
    ffn_init = ffn_init or L.init_mlp
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attn(k1, cfg),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "ffn": ffn_init(k2, cfg),
    }


def norm_specs(cfg: ArchConfig):
    s = {"scale": P(None)}
    if cfg.norm == "layernorm":
        s["bias"] = P(None)
    return s


def dense_specs(d_in_spec, d_out_spec, bias: bool):
    s = {"w": P(d_in_spec, d_out_spec)}
    if bias:
        s["b"] = P(d_out_spec)
    return s


def attn_specs(cfg: ArchConfig, ax: MeshAxes):
    """Column-parallel qkv (out dim on tp), row-parallel out-proj, fsdp on
    the other dim. KV projections replicate over tp when kv_dim % tp != 0
    (GQA with few KV heads) — see DESIGN.md §5."""
    tp_q = ax.tp_if(cfg.q_dim)
    tp_kv = ax.tp_if(cfg.kv_dim)
    fs = ax.fsdp_if(cfg.d_model)
    return {
        "wq": dense_specs(fs, tp_q, cfg.qkv_bias),
        "wk": dense_specs(fs, tp_kv, cfg.qkv_bias),
        "wv": dense_specs(fs, tp_kv, cfg.qkv_bias),
        "wo": dense_specs(tp_q, fs, False),
    }


def mlp_specs(cfg: ArchConfig, ax: MeshAxes, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    tp_f = ax.tp_if(d_ff)
    fs = ax.fsdp_if(cfg.d_model)
    if cfg.act == "gelu":
        return {
            "wi": dense_specs(fs, tp_f, True),
            "wd": dense_specs(tp_f, fs, True),
        }
    return {
        "wg": dense_specs(fs, tp_f, False),
        "wu": dense_specs(fs, tp_f, False),
        "wd": dense_specs(tp_f, fs, False),
    }


def decoder_layer_specs(cfg: ArchConfig, ax: MeshAxes, ffn_specs=None):
    return {
        "ln1": norm_specs(cfg),
        "attn": attn_specs(cfg, ax),
        "ln2": norm_specs(cfg),
        "ffn": (ffn_specs or mlp_specs)(cfg, ax),
    }


def embed_specs(cfg: ArchConfig, ax: MeshAxes):
    return P(ax.tp_if(cfg.vocab_size), ax.fsdp_if(cfg.d_model))


def init_lm(cfg: ArchConfig, rng) -> dict:
    ke, kl, kh = jax.random.split(rng, 3)
    params = {
        "embed": L.init_embed(ke, cfg),
        "layers": stack.stacked_init(
            functools.partial(init_decoder_layer, cfg=cfg), kl, cfg.num_layers
        ),
        "ln_f": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.init_dense(kh, cfg.d_model, cfg.vocab_size, False)["w"]
    return params


def lm_specs(cfg: ArchConfig, ax: MeshAxes) -> dict:
    specs = {
        "embed": embed_specs(cfg, ax),
        "layers": stack.stacked_specs(decoder_layer_specs(cfg, ax)),
        "ln_f": norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["head"] = P(ax.fsdp_if(cfg.d_model), ax.tp_if(cfg.vocab_size))
    return specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def res_spec(ax: MeshAxes, s: int) -> P:
    """Residual-stream spec: batch on dp, sequence on tp (Megatron-SP)
    whenever the sequence divides the tp axis."""
    seq = ax.tp if (ax.tp and s % ax.tp_size == 0 and s > 1) else None
    return P(ax.dp, seq, None)


def apply_decoder_layer(x, p, cfg: ArchConfig, ax: MeshAxes, positions=None, ffn_apply=None):
    s = x.shape[1]
    x = x + L.attention_train(L.norm(x, p["ln1"], cfg), p["attn"], cfg, ax, positions)
    x = constrain(x, res_spec(ax, s))
    x = x + (ffn_apply or L.mlp)(L.norm(x, p["ln2"], cfg), p["ffn"], cfg, ax)
    return constrain(x, res_spec(ax, s))


def lm_hidden(params, cfg: ArchConfig, ax: MeshAxes, tokens, prefix_embed=None, ffn_apply=None):
    """Token (+ optional stub prefix) embeddings -> final hidden states."""
    x = L.embed_tokens(params["embed"], tokens, ax)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    x = constrain(x, res_spec(ax, s))
    positions = jnp.arange(s)

    def body(h, lp):
        return apply_decoder_layer(h, lp, cfg, ax, positions, ffn_apply)

    x = stack.scan_layers(body, x, params["layers"], block=cfg.remat_block)
    return L.norm(x, params["ln_f"], cfg)


def unembed_weight(params, cfg: ArchConfig):
    return params["embed"] if cfg.tie_embeddings else params["head"]


def chunked_xent(x, w, labels, cfg: ArchConfig, ax: MeshAxes, loss_mask=None, chunk=256):
    """Cross-entropy without materializing (B, S, V): scan over S chunks;
    each chunk's logits are (B, chunk, V) with V sharded on tp."""
    b, s, d = x.shape
    from repro.models.layers import fit_chunk
    chunk = fit_chunk(s, chunk)
    nch = s // chunk
    xs = x.reshape(b, nch, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nch, chunk).transpose(1, 0, 2)
    if loss_mask is None:
        ms = jnp.ones((nch, b, chunk), jnp.float32)
    else:
        ms = loss_mask.reshape(b, nch, chunk).transpose(1, 0, 2).astype(jnp.float32)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def body(acc, inp):
        xc, lc, mc = inp
        logits = L.unembed(xc, w, ax, cfg.vocab_size).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot, cnt = acc
        return (tot + jnp.sum((lse - ll) * mc), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, batch, cfg: ArchConfig, ax: MeshAxes, ffn_apply=None):
    prefix = batch.get("patch_embed")
    x = lm_hidden(params, cfg, ax, batch["tokens"], prefix_embed=prefix, ffn_apply=ffn_apply)
    if prefix is not None:
        x = x[:, prefix.shape[1]:]
    return chunked_xent(
        x, unembed_weight(params, cfg), batch["labels"], cfg, ax, batch.get("loss_mask")
    )


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    shape = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_shape(cfg: ArchConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    shape = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    sds = jax.ShapeDtypeStruct(shape, dtype)
    return {"k": sds, "v": sds}


def cache_specs(cfg: ArchConfig, ax: MeshAxes, batch: int, plan) -> dict:
    spec = P(plan.batch_axes, plan.seq_axes if plan.seq_axes else None,
             plan.kv_axes if plan.kv_axes else None, None)
    spec = P(None, *spec)  # layer dim
    return {"k": spec, "v": spec}


def prefill(params, tokens, cfg: ArchConfig, ax: MeshAxes, cache_len: int,
            prefix_embed=None, ffn_apply=None):
    """Full-sequence forward that also fills the KV cache. Returns
    (last-position logits (B, V), cache)."""
    x = L.embed_tokens(params["embed"], tokens, ax)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    x = constrain(x, res_spec(ax, s))
    positions = jnp.arange(s)

    def body(h, lp):
        xn = L.norm(h, lp["ln1"], cfg)
        q, k, v = L.qkv_proj(xn, lp["attn"], cfg, ax, positions)
        ke, ve = L.expand_kv(k, cfg), L.expand_kv(v, cfg)
        o = L.attention_core_train(q, ke, ve, cfg, ax)
        h = h + L.dense(o, lp["attn"]["wo"]["w"], lp["attn"]["wo"].get("b"))
        h = constrain(h, res_spec(ax, s))
        h = h + (ffn_apply or L.mlp)(L.norm(h, lp["ln2"], cfg), lp["ffn"], cfg, ax)
        return constrain(h, res_spec(ax, s)), (k, v)

    def step(carry, lp):
        h, kv = body(carry, lp)
        return h, kv

    x, (ks, vs) = jax.lax.scan(step, x, params["layers"])
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(x[:, -1:], unembed_weight(params, cfg), ax, cfg.vocab_size)
    pad = cache_len - s
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits[:, 0], {"k": ks.astype(jnp.bfloat16), "v": vs.astype(jnp.bfloat16)}


def decode_step(params, token, cache, pos, cfg: ArchConfig, ax: MeshAxes, plan,
                ffn_apply=None):
    """One-token decode. token: (B, 1) int32; pos: scalar int32 (position
    being written). Returns (logits (B, V), new cache)."""
    x = L.embed_tokens(params["embed"], token, ax)

    def body(h, lp, lc):
        xn = L.norm(h, lp["ln1"], cfg)
        o, nk, nv = L.attention_decode_general(
            xn, lc["k"], lc["v"], lp["attn"], cfg, ax, pos, plan
        )
        h = h + o
        h = h + (ffn_apply or L.mlp)(L.norm(h, lp["ln2"], cfg), lp["ffn"], cfg, ax)
        return h, {"k": nk, "v": nv}

    x, new_cache = stack.scan_layers_with_cache(body, x, params["layers"], cache)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, unembed_weight(params, cfg), ax, cfg.vocab_size)
    return logits[:, 0], new_cache
