"""Mesh-axis abstraction + sharding-constraint helpers.

The production mesh is (data, model) or (pod, data, model); smoke tests
run on a single device with no mesh. ``constrain`` no-ops when there is
no mesh in context so model code is mesh-agnostic.

Logical sharding rules (DESIGN.md §5):
  batch    -> (pod, data)          activations' leading dim
  seq      -> model                sequence-sharded residual saves (Megatron-SP)
  heads    -> model                q-head / TP dim
  d_ff     -> model                TP dim of MLP hidden
  vocab    -> model                logits TP
  fsdp     -> data                 parameter/optimizer FSDP dim
  experts  -> model (if divisible) EP dim
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class MeshAxes:
    dp: tuple[str, ...] = ("data",)  # batch axes (includes 'pod' when present)
    fsdp: str | tuple | None = "data"  # parameter-shard axis (or axes)
    tp: str | None = "model"  # tensor-parallel axis
    dp_size: int = 1  # product of dp axis sizes
    fsdp_size: int = 1
    tp_size: int = 1

    @property
    def all_seq(self) -> tuple[str, ...]:
        """Axes jointly sharding a long KV-cache sequence dim."""
        return tuple(a for a in (*self.dp, self.tp) if a)

    @property
    def all_seq_size(self) -> int:
        return self.dp_size * self.tp_size

    def tp_divides(self, dim: int) -> bool:
        return self.tp is not None and dim % self.tp_size == 0

    def fsdp_divides(self, dim: int) -> bool:
        return self.fsdp is not None and dim % self.fsdp_size == 0

    def fsdp_if(self, dim: int):
        return self.fsdp if self.fsdp_divides(dim) else None

    def tp_if(self, dim: int):
        return self.tp if self.tp_divides(dim) else None


SINGLE = MeshAxes(dp=(), fsdp=None, tp=None)


@dataclass(frozen=True)
class ServePlan:
    """How a decode-shape cell shards its KV cache / recurrent state.

    batch_axes — mesh axes sharding the request batch dim (() when B=1).
    seq_axes   — mesh axes sharding the cache sequence dim; non-empty
                 selects the shard_map flash-combine decode path.
    kv_axes    — tp axis on the KV-head dim (plain GSPMD path), or None.
    """

    batch_axes: tuple[str, ...] = ()
    seq_axes: tuple[str, ...] = ()
    kv_axes: str | None = None


def make_serve_plan(cfg, ax: MeshAxes, batch: int, cache_len: int) -> ServePlan:
    """Pick the decode cache layout for (arch, batch, cache_len).

    Priority: shard KV heads on tp when divisible (cheapest — pure local
    attention); otherwise shard the cache sequence dim on tp; for B == 1
    (long_500k) spread the sequence over every mesh axis.
    """
    if ax.tp is None and not ax.dp:
        return ServePlan()
    batch_axes = ax.dp if (ax.dp and batch % ax.dp_size == 0 and batch >= ax.dp_size) else ()
    kv = getattr(cfg, "num_kv_heads", 0) or 0
    if not batch_axes:
        seq_axes = tuple(a for a in (*ax.dp, ax.tp) if a)
        sz = 1
        for a in seq_axes:
            sz *= ax.dp_size if a in ax.dp else ax.tp_size
        if cache_len and cache_len % max(sz, 1) == 0:
            return ServePlan(batch_axes=(), seq_axes=seq_axes, kv_axes=None)
        return ServePlan()
    if ax.tp and kv and kv % ax.tp_size == 0:
        return ServePlan(batch_axes=batch_axes, seq_axes=(), kv_axes=ax.tp)
    if ax.tp and cache_len and cache_len % ax.tp_size == 0:
        return ServePlan(batch_axes=batch_axes, seq_axes=(ax.tp,), kv_axes=None)
    return ServePlan(batch_axes=batch_axes)


def axes_for_mesh(mesh, strategy: str = "2d") -> MeshAxes:
    """strategy:
      "2d"   — batch on (pod, data); params FSDP on data, TP on model
               (Megatron x ZeRO; the default and the decode/prefill mode).
      "fsdp" — no tensor parallelism: batch on (pod, data, model) when it
               divides, params FSDP over (data, model). Eliminates all
               per-layer activation collectives in exchange for per-layer
               parameter all-gathers (§Perf iteration A).
      "tp_only" — serving mode: params replicated over data, TP over
               model. Decode steps stop paying per-layer FSDP weight
               gathers (28 MB/layer) for tiny activation ARs
               (§Perf iteration E); requires params_bf16/tp to fit HBM."""
    names = mesh.axis_names
    shape = dict(zip(mesh.axis_names, mesh.devices.shape)) if hasattr(mesh, "devices") else dict(mesh.shape)
    if strategy == "tp_only":
        dp = tuple(a for a in ("pod", "data") if a in names)
        dp_size = 1
        for a in dp:
            dp_size *= shape[a]
        return MeshAxes(dp=dp, fsdp=None, tp="model" if "model" in names else None,
                        dp_size=dp_size, fsdp_size=1, tp_size=shape.get("model", 1))
    if strategy == "fsdp":
        fsdp_axes = tuple(a for a in ("data", "model") if a in names)
        fsdp_size = 1
        for a in fsdp_axes:
            fsdp_size *= shape[a]
        dp = tuple(a for a in ("pod", *fsdp_axes) if a in names)
        dp_size = 1
        for a in dp:
            dp_size *= shape[a]
        return MeshAxes(dp=dp, fsdp=fsdp_axes, tp=None, dp_size=dp_size,
                        fsdp_size=fsdp_size, tp_size=1)
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_size = 1
    for a in dp:
        dp_size *= shape[a]
    return MeshAxes(
        dp=dp,
        fsdp="data" if "data" in names else None,
        tp="model" if "model" in names else None,
        dp_size=dp_size,
        fsdp_size=shape.get("data", 1),
        tp_size=shape.get("model", 1),
    )


def get_abstract_mesh():
    """Version-compat shim: ``jax.sharding.get_abstract_mesh`` only exists
    in newer jax. On older releases (e.g. 0.4.37) fall back to the
    internal abstract-mesh context, then to the physical mesh entered via
    ``with mesh:`` (thread_resources). Returns None when no mesh is in
    context."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    try:
        from jax._src import mesh as _mesh_internal
    except ImportError:  # pragma: no cover - future jax without _src.mesh
        return None
    getter = getattr(_mesh_internal, "get_abstract_mesh", None)
    if getter is not None:
        m = getter()
        # 0.4.37 returns the raw context value: () when unset.
        if m is not None and not isinstance(m, tuple):
            return m
    env = getattr(_mesh_internal, "thread_resources", None)
    physical = getattr(getattr(env, "env", None), "physical_mesh", None)
    if physical is not None and not physical.empty:
        return physical
    return None


def has_mesh() -> bool:
    m = get_abstract_mesh()
    return m is not None and not m.empty


def constrain(x, spec: P):
    if not has_mesh():
        return x
    return jax.lax.with_sharding_constraint(x, spec)
