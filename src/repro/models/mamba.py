"""Mamba-1 selective SSM (falcon-mamba family). Attention-free.

Train path: chunked selective scan — within a chunk the diagonal
recurrence h_t = a_t * h_{t-1} + b_t runs as an associative scan; chunk
carries propagate through an outer lax.scan. The (B, chunk, d_inner, N)
state tensor only ever exists per-chunk, sharded on tp over d_inner.

Decode path: O(1) state update per token (conv ring + ssm state); this
is why long_500k is *native* for this family (DESIGN.md §6).

TPU adaptation: d_inner (= expand * d_model) is the tensor-parallel dim;
the recurrence is independent per channel so the scan needs no
collectives — x_proj (row-parallel) and dt/B/C broadcast are the only
tp-crossing ops per layer.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import stack
from repro.models.shardings import MeshAxes, constrain


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------


def init_mamba_layer(rng, cfg: ArchConfig, dtype=jnp.bfloat16):
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(rng, 6)

    def w(key, shape, scale):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)

    # S4D-real init for A; dt bias init for softplus ~ [1e-3, 1e-1]
    a_log = jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)))
    dt_init = jnp.exp(
        jax.random.uniform(ks[0], (di,), jnp.float32)
        * (math.log(1e-1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "norm": L.init_norm(cfg, d),
        "in_proj": {"w": w(ks[1], (d, 2 * di), 1.0 / math.sqrt(d))},
        "conv_w": w(ks[2], (cfg.d_conv, di), 1.0 / math.sqrt(cfg.d_conv)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": {"w": w(ks[3], (di, r + 2 * n), 1.0 / math.sqrt(di))},
        "dt_proj": {"w": w(ks[4], (r, di), 1.0 / math.sqrt(r)), "b": dt_bias},
        "a_log": a_log,
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": {"w": w(ks[5], (di, d), 1.0 / math.sqrt(di))},
    }


def mamba_layer_specs(cfg: ArchConfig, ax: MeshAxes):
    tp = ax.tp_if(cfg.d_inner)
    fs = ax.fsdp_if(cfg.d_model)
    return {
        "norm": {"scale": P(None)},
        "in_proj": {"w": P(fs, tp)},
        "conv_w": P(None, tp),
        "conv_b": P(tp),
        "x_proj": {"w": P(tp, None)},
        "dt_proj": {"w": P(None, tp), "b": P(tp)},
        "a_log": P(tp, None),
        "d_skip": P(tp),
        "out_proj": {"w": P(tp, fs)},
    }


def init_lm(cfg: ArchConfig, rng) -> dict:
    ke, kl = jax.random.split(rng)
    return {
        "embed": L.init_embed(ke, cfg),
        "layers": stack.stacked_init(
            functools.partial(init_mamba_layer, cfg=cfg), kl, cfg.num_layers
        ),
        "ln_f": L.init_norm(cfg, cfg.d_model),
    }


def lm_specs(cfg: ArchConfig, ax: MeshAxes) -> dict:
    return {
        "embed": P(ax.tp_if(cfg.vocab_size), ax.fsdp_if(cfg.d_model)),
        "layers": stack.stacked_specs(mamba_layer_specs(cfg, ax)),
        "ln_f": {"scale": P(None)},
    }


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------


def _causal_conv(x, conv_w, conv_b, init_state=None):
    """Depthwise causal conv. x: (B, S, di); conv_w: (K, di).
    init_state: (B, K-1, di) carried from the previous chunk (zeros at
    t=0). Returns (y (B,S,di), new_state (B, K-1, di))."""
    k = conv_w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * conv_w[i].astype(x.dtype) for i in range(k)
    )
    return y + conv_b.astype(x.dtype), xp[:, -(k - 1):]


def _ssm_params(u, p, cfg: ArchConfig):
    """u: (B, S, di) post-conv. Returns dA (B,S,di,N) f32, dBu (B,S,di,N) f32,
    C (B,S,N) f32."""
    n, r = cfg.ssm_state, cfg.dt_rank
    xdbc = L.dense(u, p["x_proj"]["w"])  # (B,S,r+2N)
    dt_r, bm, cm = jnp.split(xdbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        (L.dense(dt_r, p["dt_proj"]["w"]) + p["dt_proj"]["b"]).astype(jnp.float32)
    )  # (B,S,di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N)
    da = jnp.exp(dt[..., None] * a[None, None])  # (B,S,di,N)
    dbu = (dt * u.astype(jnp.float32))[..., None] * bm.astype(jnp.float32)[:, :, None, :]
    return da, dbu, cm.astype(jnp.float32)


def _chunk_scan(da, dbu, h0):
    """Associative scan of h_t = da_t h_{t-1} + dbu_t within one chunk.
    da/dbu: (B, c, di, N) f32; h0: (B, di, N) f32. Returns (h_all, h_last)."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbu), axis=1)
    h_all = b_cum + a_cum * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_mix(x, p, cfg: ArchConfig, ax: MeshAxes, init_state=None):
    """The Mamba mixer. x: (B, S, d_model) -> (B, S, d_model).
    init_state: None (train) or dict(conv, ssm) for stateful chunks."""
    b, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    tp = ax.tp_if(di)
    xz = L.dense(x, p["in_proj"]["w"])  # (B,S,2di)
    xz = constrain(xz, P(ax.dp, None, tp))
    u, z = jnp.split(xz, 2, axis=-1)
    conv0 = init_state["conv"] if init_state else None
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], conv0)
    u = jax.nn.silu(u)
    u = constrain(u, P(ax.dp, None, tp))

    chunk = L.fit_chunk(s, cfg.scan_chunk)
    nch = s // chunk
    h0 = (
        init_state["ssm"]
        if init_state
        else jnp.zeros((b, di, n), jnp.float32)
    )

    us = u.reshape(b, nch, chunk, di).transpose(1, 0, 2, 3)

    def body(h, uc):
        da, dbu, cm = _ssm_params(uc, p, cfg)
        h_all, h_last = _chunk_scan(da, dbu, h)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, cm)
        return h_last, y.astype(x.dtype)

    h_last, ys = jax.lax.scan(body, h0, us)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = y + u * p["d_skip"].astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = constrain(y, P(ax.dp, None, tp))
    out = L.dense(y, p["out_proj"]["w"])
    new_state = {"conv": conv_state, "ssm": h_last}
    return out, new_state


def apply_mamba_layer(x, p, cfg: ArchConfig, ax: MeshAxes):
    y, _ = mamba_mix(L.norm(x, p["norm"], cfg), p, cfg, ax)
    return x + y


# ---------------------------------------------------------------------------
# LM entry points
# ---------------------------------------------------------------------------


def lm_loss(params, batch, cfg: ArchConfig, ax: MeshAxes):
    from repro.models.transformer import chunked_xent, res_spec

    x = L.embed_tokens(params["embed"], batch["tokens"], ax)
    s = x.shape[1]
    x = constrain(x, res_spec(ax, s))

    def body(h, lp):
        return apply_mamba_layer(h, lp, cfg, ax)

    x = stack.scan_layers(body, x, params["layers"])
    x = L.norm(x, params["ln_f"], cfg)
    return chunked_xent(x, params["embed"], batch["labels"], cfg, ax,
                        batch.get("loss_mask"))


def init_cache(cfg: ArchConfig, batch: int, cache_len: int = 0):
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.d_conv
    return {
        "conv": jnp.zeros((cfg.num_layers, batch, k - 1, di), jnp.bfloat16),
        "ssm": jnp.zeros((cfg.num_layers, batch, di, n), jnp.float32),
    }


def cache_shape(cfg: ArchConfig, batch: int, cache_len: int = 0):
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.d_conv
    return {
        "conv": jax.ShapeDtypeStruct((cfg.num_layers, batch, k - 1, di), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct((cfg.num_layers, batch, di, n), jnp.float32),
    }


def cache_specs(cfg: ArchConfig, ax: MeshAxes, batch: int, plan) -> dict:
    b = plan.batch_axes or None
    tp = ax.tp_if(cfg.d_inner)
    return {
        "conv": P(None, b, None, tp),
        "ssm": P(None, b, tp, None),
    }


def prefill(params, tokens, cfg: ArchConfig, ax: MeshAxes, cache_len: int):
    """Run the full prompt, returning last-token logits + decode state."""
    from repro.models.transformer import res_spec

    x = L.embed_tokens(params["embed"], tokens, ax)
    s = x.shape[1]
    x = constrain(x, res_spec(ax, s))

    def body(h, lp):
        xn = L.norm(h, lp["norm"], cfg)
        y, st = mamba_mix(xn, lp, cfg, ax)
        return h + y, st

    x, states = jax.lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(x[:, -1:], params["embed"], ax, cfg.vocab_size)
    return logits[:, 0], states


def decode_step(params, token, cache, pos, cfg: ArchConfig, ax: MeshAxes, plan):
    """Single-token decode: conv ring shift + one recurrence step."""
    x = L.embed_tokens(params["embed"], token, ax)  # (B,1,D)

    def body(h, lp, lc):
        xn = L.norm(h, lp["norm"], cfg)
        y, st = mamba_mix(xn, lp, cfg, ax, init_state=lc)
        return h + y, st

    x, new_cache = stack.scan_layers_with_cache(body, x, params["layers"], cache)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], ax, cfg.vocab_size)
    return logits[:, 0], new_cache
