"""Griffin-style hybrid blocks (recurrentgemma family): RG-LRU recurrent
blocks interleaved 2:1 with local sliding-window MQA blocks
[arXiv:2402.19427].

Layer pattern handling: the 38-layer stack = 12 scanned copies of the
(rec, rec, attn) *supergroup* + an unscanned (rec, rec) tail, so
lax.scan still bounds compile time despite the heterogeneous stack.

RG-LRU recurrence (diagonal, per-channel):
    r_t = sigmoid(W_r x_t)         (block-diagonal gate, H blocks)
    i_t = sigmoid(W_i x_t)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)
Diagonal -> chunked associative scan, state (B, W); decode is O(1).
The sliding-window KV cache is O(window), which together with the O(1)
LRU state is what makes long_500k native for this family.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import stack
from repro.models import transformer as T
from repro.models.shardings import MeshAxes, constrain

_C = 8.0  # RG-LRU temperature


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------


def init_rglru(rng, cfg: ArchConfig, dtype=jnp.bfloat16):
    w = cfg.lru_width
    h = cfg.num_heads
    wh = w // h
    ks = jax.random.split(rng, 3)
    scale = 1.0 / math.sqrt(wh)
    # Lambda init so a ~ uniform(0.9, 0.999)^... (Griffin appendix)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "w_r": (jax.random.normal(ks[1], (h, wh, wh), jnp.float32) * scale).astype(dtype),
        "w_i": (jax.random.normal(ks[2], (h, wh, wh), jnp.float32) * scale).astype(dtype),
        "b_r": jnp.zeros((w,), jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam,
    }


def rglru_specs(cfg: ArchConfig, ax: MeshAxes):
    tp_h = ax.tp_if(cfg.num_heads)
    return {
        "w_r": P(tp_h, None, None),
        "w_i": P(tp_h, None, None),
        "b_r": P(None),
        "b_i": P(None),
        "lam": P(None),
    }


def _gates(x, p, cfg: ArchConfig):
    """x: (B, S, W) -> (log_a (B,S,W) f32, gated input (B,S,W) f32)."""
    b, s, w = x.shape
    h = cfg.num_heads
    xh = x.reshape(b, s, h, w // h)
    r = L.einsum_f32("bshi,hij->bshj", xh, p["w_r"])
    i = L.einsum_f32("bshi,hij->bshj", xh, p["w_i"])
    r = jax.nn.sigmoid(r.reshape(b, s, w) + p["b_r"])
    i = jax.nn.sigmoid(i.reshape(b, s, w) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    gated = i * x.astype(jnp.float32)
    return log_a, gated


def rglru_scan(x, p, cfg: ArchConfig, h0=None):
    """x: (B, S, W); h0: (B, W) f32 carry. Returns (y (B,S,W), h_last)."""
    b, s, w = x.shape
    log_a, gated = _gates(x, p, cfg)
    a = jnp.exp(log_a)
    bt = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    chunk = L.fit_chunk(s, cfg.scan_chunk)
    nch = s // chunk
    a_c = a.reshape(b, nch, chunk, w).transpose(1, 0, 2, 3)
    b_c = bt.reshape(b, nch, chunk, w).transpose(1, 0, 2, 3)

    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a1 * a2, a2 * b1 + b2

    def body(h, ab):
        ac, bc = ab
        a_cum, b_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = b_cum + a_cum * h[:, None]
        return hs[:, -1], hs.astype(x.dtype)

    h_last, ys = jax.lax.scan(body, h0, (a_c, b_c))
    return ys.transpose(1, 0, 2, 3).reshape(b, s, w), h_last


def rglru_step(x1, p, cfg: ArchConfig, h):
    """One-token recurrence. x1: (B, 1, W); h: (B, W) f32."""
    log_a, gated = _gates(x1, p, cfg)
    a = jnp.exp(log_a[:, 0])
    bt = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated[:, 0]
    h = a * h + bt
    return h.astype(x1.dtype)[:, None], h


# ---------------------------------------------------------------------------
# recurrent block (conv + RG-LRU + gate)
# ---------------------------------------------------------------------------


def init_rec_block(rng, cfg: ArchConfig, dtype=jnp.bfloat16):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(rng, 5)
    return {
        "lin_x": L.init_dense(ks[0], d, w, False, dtype),
        "lin_y": L.init_dense(ks[1], d, w, False, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.d_conv, w), jnp.float32) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "lru": init_rglru(ks[3], cfg, dtype),
        "lin_out": L.init_dense(ks[4], w, d, False, dtype),
    }


def rec_block_specs(cfg: ArchConfig, ax: MeshAxes):
    tp = ax.tp_if(cfg.lru_width)
    fs = ax.fsdp_if(cfg.d_model)
    return {
        "lin_x": {"w": P(fs, tp)},
        "lin_y": {"w": P(fs, tp)},
        "conv_w": P(None, tp),
        "conv_b": P(tp),
        "lru": rglru_specs(cfg, ax),
        "lin_out": {"w": P(tp, fs)},
    }


def rec_mix(x, p, cfg: ArchConfig, ax: MeshAxes, state=None):
    """Griffin recurrent temporal-mix. state: None or dict(conv, lru)."""
    from repro.models.mamba import _causal_conv

    tp = ax.tp_if(cfg.lru_width)
    xb = L.dense(x, p["lin_x"]["w"])
    yb = jax.nn.gelu(L.dense(x, p["lin_y"]["w"]))
    xb = constrain(xb, P(ax.dp, None, tp))
    conv0 = state["conv"] if state else None
    xb, conv_state = _causal_conv(xb, p["conv_w"], p["conv_b"], conv0)
    if x.shape[1] == 1 and state is not None:
        lru_out, h_last = rglru_step(xb, p["lru"], cfg, state["lru"])
    else:
        h0 = state["lru"] if state else None
        lru_out, h_last = rglru_scan(xb, p["lru"], cfg, h0)
    out = L.dense(lru_out * yb, p["lin_out"]["w"])
    return out, {"conv": conv_state, "lru": h_last}


# ---------------------------------------------------------------------------
# supergroup wiring
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ArchConfig, kind: str):
    k1, k2 = jax.random.split(rng)
    mix = init_rec_block(k1, cfg) if kind == "rec" else L.init_attn(k1, cfg)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "mix": mix,
        "ln2": L.init_norm(cfg, cfg.d_model),
        "ffn": L.init_mlp(k2, cfg),
    }


def block_specs(cfg: ArchConfig, ax: MeshAxes, kind: str):
    mix = rec_block_specs(cfg, ax) if kind == "rec" else T.attn_specs(cfg, ax)
    return {
        "ln1": T.norm_specs(cfg),
        "mix": mix,
        "ln2": T.norm_specs(cfg),
        "ffn": T.mlp_specs(cfg, ax),
    }


def _group_layout(cfg: ArchConfig) -> tuple[int, tuple[str, ...]]:
    pat = cfg.block_pattern
    groups = cfg.num_layers // len(pat)
    tail = cfg.num_layers % len(pat)
    return groups, pat[:tail]


def init_group(rng, cfg: ArchConfig):
    pat = cfg.block_pattern
    ks = jax.random.split(rng, len(pat))
    return {f"b{i}": init_block(ks[i], cfg, kind) for i, kind in enumerate(pat)}


def group_specs(cfg: ArchConfig, ax: MeshAxes):
    return {
        f"b{i}": block_specs(cfg, ax, kind) for i, kind in enumerate(cfg.block_pattern)
    }


def init_lm(cfg: ArchConfig, rng) -> dict:
    ke, kg, kt = jax.random.split(rng, 3)
    groups, tail = _group_layout(cfg)
    params = {
        "embed": L.init_embed(ke, cfg),
        "groups": stack.stacked_init(
            functools.partial(init_group, cfg=cfg), kg, groups
        ),
        "tail": [
            init_block(k, cfg, kind)
            for k, kind in zip(jax.random.split(kt, max(len(tail), 1)), tail)
        ],
        "ln_f": L.init_norm(cfg, cfg.d_model),
    }
    return params


def lm_specs(cfg: ArchConfig, ax: MeshAxes) -> dict:
    _, tail = _group_layout(cfg)
    return {
        "embed": P(ax.tp_if(cfg.vocab_size), ax.fsdp_if(cfg.d_model)),
        "groups": stack.stacked_specs(group_specs(cfg, ax)),
        "tail": [block_specs(cfg, ax, kind) for kind in tail],
        "ln_f": T.norm_specs(cfg),
    }


def apply_block(x, p, kind: str, cfg: ArchConfig, ax: MeshAxes, positions):
    s = x.shape[1]
    xn = L.norm(x, p["ln1"], cfg)
    if kind == "rec":
        mix, _ = rec_mix(xn, p["mix"], cfg, ax)
    else:
        mix = L.attention_train(xn, p["mix"], cfg, ax, positions)
    x = x + mix
    x = constrain(x, T.res_spec(ax, s))
    x = x + L.mlp(L.norm(x, p["ln2"], cfg), p["ffn"], cfg, ax)
    return constrain(x, T.res_spec(ax, s))


def lm_loss(params, batch, cfg: ArchConfig, ax: MeshAxes):
    x = L.embed_tokens(params["embed"], batch["tokens"], ax)
    x = x * math.sqrt(cfg.d_model)  # gemma-style embedding scale
    s = x.shape[1]
    x = constrain(x, T.res_spec(ax, s))
    positions = jnp.arange(s)
    pat = cfg.block_pattern

    def group_body(h, gp):
        for i, kind in enumerate(pat):
            h = apply_block(h, gp[f"b{i}"], kind, cfg, ax, positions)
        return h

    x = stack.scan_layers(group_body, x, params["groups"])
    _, tail = _group_layout(cfg)
    for p, kind in zip(params["tail"], tail):
        x = apply_block(x, p, kind, cfg, ax, positions)
    x = L.norm(x, params["ln_f"], cfg)
    return T.chunked_xent(x, params["embed"], batch["labels"], cfg, ax,
                          batch.get("loss_mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def _block_cache(cfg: ArchConfig, kind: str, batch: int, window: int, shape_only=False):
    mk = jax.ShapeDtypeStruct if shape_only else jnp.zeros
    if kind == "rec":
        return {
            "conv": mk((batch, cfg.d_conv - 1, cfg.lru_width), jnp.bfloat16),
            "lru": mk((batch, cfg.lru_width), jnp.float32),
        }
    return {
        "k": mk((batch, window, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16),
        "v": mk((batch, window, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16),
    }


def _cache_window(cfg: ArchConfig, cache_len: int) -> int:
    # local attention only ever needs the window, regardless of context len
    return min(cfg.sliding_window or cache_len, cache_len)


def _stack_tree(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, shape_only=False):
    groups, tail = _group_layout(cfg)
    w = _cache_window(cfg, cache_len)
    gcache = {
        f"b{i}": _block_cache(cfg, kind, batch, w, shape_only)
        for i, kind in enumerate(cfg.block_pattern)
    }
    if shape_only:
        gstack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((groups, *s.shape), s.dtype), gcache
        )
    else:
        gstack = jax.tree.map(
            lambda s: jnp.zeros((groups, *s.shape), s.dtype), gcache
        )
    return {
        "groups": gstack,
        "tail": [_block_cache(cfg, kind, batch, w, shape_only) for kind in tail],
    }


def cache_shape(cfg: ArchConfig, batch: int, cache_len: int):
    return init_cache(cfg, batch, cache_len, shape_only=True)


def _block_cache_specs(cfg: ArchConfig, ax: MeshAxes, kind: str, plan):
    b = plan.batch_axes or None
    if kind == "rec":
        tp = ax.tp_if(cfg.lru_width)
        return {"conv": P(b, None, tp), "lru": P(b, tp)}
    # window cache is small; shard batch only (window rarely divides tp)
    return {"k": P(b, None, None, None), "v": P(b, None, None, None)}


def cache_specs(cfg: ArchConfig, ax: MeshAxes, batch: int, plan) -> dict:
    _, tail = _group_layout(cfg)
    g = {
        f"b{i}": _block_cache_specs(cfg, ax, kind, plan)
        for i, kind in enumerate(cfg.block_pattern)
    }
    g = jax.tree.map(
        lambda s: P(None, *s), g, is_leaf=lambda x: isinstance(x, P)
    )
    return {
        "groups": g,
        "tail": [_block_cache_specs(cfg, ax, kind, plan) for kind in tail],
    }


def _decode_block(x1, p, kind: str, cfg: ArchConfig, ax: MeshAxes, pos, lc, plan):
    xn = L.norm(x1, p["ln1"], cfg)
    if kind == "rec":
        mix, st = rec_mix(xn, p["mix"], cfg, ax, state=lc)
    else:
        from repro.models.shardings import ServePlan

        wplan = ServePlan(batch_axes=plan.batch_axes)  # window cache: no seq shard
        mix, nk, nv = L.attention_decode_general(
            xn, lc["k"], lc["v"], p["mix"], cfg, ax, pos, wplan
        )
        st = {"k": nk, "v": nv}
    x1 = x1 + mix
    x1 = x1 + L.mlp(L.norm(x1, p["ln2"], cfg), p["ffn"], cfg, ax)
    return x1, st


def decode_step(params, token, cache, pos, cfg: ArchConfig, ax: MeshAxes, plan):
    x = L.embed_tokens(params["embed"], token, ax) * math.sqrt(cfg.d_model)
    pat = cfg.block_pattern

    def group_body(h, gp, gc):
        ncache = {}
        for i, kind in enumerate(pat):
            h, ncache[f"b{i}"] = _decode_block(h, gp[f"b{i}"], kind, cfg, ax, pos,
                                               gc[f"b{i}"], plan)
        return h, ncache

    x, gcache = stack.scan_layers_with_cache(group_body, x, params["groups"],
                                             cache["groups"])
    _, tail = _group_layout(cfg)
    tcache = []
    for p, kind, tc in zip(params["tail"], tail, cache["tail"]):
        x, st = _decode_block(x, p, kind, cfg, ax, pos, tc, plan)
        tcache.append(st)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(x, params["embed"], ax, cfg.vocab_size)
    return logits[:, 0], {"groups": gcache, "tail": tcache}


def prefill(params, tokens, cfg: ArchConfig, ax: MeshAxes, cache_len: int):
    """Prompt pass. Fills LRU/conv states + window KV caches; returns
    (last logits, cache). Window cache holds the trailing ``window``
    positions of the prompt (ring layout: slot = pos % window)."""
    x = L.embed_tokens(params["embed"], tokens, ax) * math.sqrt(cfg.d_model)
    b, s, _ = x.shape
    x = constrain(x, T.res_spec(ax, s))
    positions = jnp.arange(s)
    w = _cache_window(cfg, cache_len)
    pat = cfg.block_pattern

    def prefill_block(h, p, kind):
        xn = L.norm(h, p["ln1"], cfg)
        if kind == "rec":
            mix, st = rec_mix(xn, p["mix"], cfg, ax)
        else:
            q, k, v = L.qkv_proj(xn, p["mix"], cfg, ax, positions)
            ke, ve = L.expand_kv(k, cfg), L.expand_kv(v, cfg)
            o = L.attention_core_train(q, ke, ve, cfg, ax)
            mix = L.dense(o, p["mix"]["wo"]["w"], p["mix"]["wo"].get("b"))
            # ring-layout trailing window: roll so slot = pos % w
            kw, vw = k[:, -w:], v[:, -w:]
            shift = jnp.asarray(s % w, jnp.int32)
            kw = jnp.roll(kw, shift, axis=1)
            vw = jnp.roll(vw, shift, axis=1)
            st = {"k": kw.astype(jnp.bfloat16), "v": vw.astype(jnp.bfloat16)}
        h = h + mix
        h = h + L.mlp(L.norm(h, p["ln2"], cfg), p["ffn"], cfg, ax)
        return h, st

    def group_body(h, gp):
        sts = {}
        for i, kind in enumerate(pat):
            h, sts[f"b{i}"] = prefill_block(h, gp[f"b{i}"], kind)
        return h, sts

    x, gcache = jax.lax.scan(lambda c, gp: group_body(c, gp), x, params["groups"])
    _, tail = _group_layout(cfg)
    tcache = []
    for p, kind in zip(params["tail"], tail):
        x, st = prefill_block(x, p, kind)
        tcache.append(st)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(x[:, -1:], params["embed"], ax, cfg.vocab_size)
    return logits[:, 0], {"groups": gcache, "tail": tcache}
