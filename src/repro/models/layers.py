"""Shared model primitives: norms, RoPE, GQA attention (train / prefill /
decode / seq-sharded long-context decode), MLPs, embeddings.

All functions are pure; parameters are dict pytrees. Weights use bf16,
norm scales fp32, logits/softmax math fp32.

Attention strategy (see DESIGN.md §5 and the spike notes in
EXPERIMENTS.md §Perf):
  * flat-H layout: q-heads sharded on the tp axis; KV heads are expanded
    (repeated) to H locally — legal because KV projections are
    model-replicated whenever kv_heads % tp != 0, and a local gather
    when they are sharded.
  * training uses q-chunked attention via lax.scan (memory-bounded,
    compile-friendly; scores never materialize beyond
    (B, H, chunk, S) fp32).
  * single-token decode attends directly over the cache.
  * long_500k decode uses a shard_map two-pass flash combine over the
    sequence-sharded cache.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.models.shardings import MeshAxes, constrain, get_abstract_mesh

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(v + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    v = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(v + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x, p, cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg: ArchConfig, d: int):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D) with D even; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
        ang = ang[None, :, None, :]  # (1, S, 1, half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense projections
# ---------------------------------------------------------------------------


def einsum_f32(subscripts, *ops):
    """bf16 inputs, fp32 accumulate/output. On TPU this is a native MXU
    mode (preferred_element_type); the CPU fallback computes the dot in
    bf16 and upcasts the (small) result — upcasting the *operands*
    instead makes XLA-CPU materialize f32 copies of whole KV caches /
    weight stacks inside scan loops, which would poison the dry-run
    byte counts (EXPERIMENTS.md §Dry-run notes)."""
    if jax.default_backend() == "tpu":
        return jnp.einsum(subscripts, *ops, preferred_element_type=jnp.float32)
    return jnp.einsum(subscripts, *ops).astype(jnp.float32)


def dense(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def init_dense(rng, d_in, d_out, bias: bool, dtype=jnp.bfloat16):
    scale = 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def qkv_proj(x, p, cfg: ArchConfig, ax: MeshAxes, positions):
    b, s, _ = x.shape
    q = dense(x, p["wq"]["w"], p["wq"].get("b")).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = dense(x, p["wk"]["w"], p["wk"].get("b")).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = dense(x, p["wv"]["w"], p["wv"].get("b")).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def expand_kv(k: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """(.., KV, D) -> (.., H, D) repeating each kv head over its q group."""
    g = cfg.num_heads // cfg.num_kv_heads
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=-2)


def fit_chunk(s: int, want: int) -> int:
    """Largest chunk <= want that divides s (trace-time)."""
    c = max(1, min(want, s))
    while s % c:
        c -= 1
    return c


def _causal_window_mask(pos_q, pos_k, window):
    m = pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        m &= pos_q[:, None] - pos_k[None, :] < window
    return m


def attention_core_train(q, k, v, cfg: ArchConfig, ax: MeshAxes, base_pos=0):
    """Chunked causal attention. q, k, v: (B, S, H, D) (kv already
    expanded). Scans over q chunks; scores (B, H, chunk, S) fp32.

    §Perf notes: (1) a flash-style double-chunked online-softmax variant
    was measured and REFUTED at the HLO level — without kernel fusion
    the total score bytes are invariant and the carry adds ~60% traffic
    (EXPERIMENTS.md §Perf, iteration C). (2) the explicit constraint on
    the q-chunk stack below is load-bearing: without it GSPMD shards the
    *chunk* dim over tp and then all-gathers the whole stack every
    iteration (4.3 GB/iter on command-r prefill — iteration D)."""
    b, s, h, d = q.shape
    chunk = fit_chunk(s, cfg.attn_chunk)
    nchunk = s // chunk
    inv = 1.0 / math.sqrt(d)
    pos_k = base_pos + jnp.arange(s)

    qs = q.reshape(b, nchunk, chunk, h, d).transpose(1, 0, 2, 3, 4)
    qs = constrain(qs, P(None, ax.dp, None, ax.tp_if(h), None))

    def body(_, qc_i):
        qc, i = qc_i
        scores = jnp.einsum("bqhd,bthd->bhqt", qc, k).astype(jnp.float32) * inv
        pos_q = base_pos + i * chunk + jnp.arange(chunk)
        mask = _causal_window_mask(pos_q, pos_k, cfg.sliding_window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqt,bthd->bqhd", w, v)
        return (), constrain(o, P(ax.dp, None, ax.tp_if(h), None))

    _, outs = jax.lax.scan(body, (), (qs, jnp.arange(nchunk)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h * d)


def attention_train(x, p, cfg: ArchConfig, ax: MeshAxes, positions=None, bidirectional=False):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = qkv_proj(
        x, p, cfg, ax,
        positions if (cfg.use_rope and cfg.head_dim % 2 == 0) else None,
    )
    k, v = expand_kv(k, cfg), expand_kv(v, cfg)
    q = constrain(q, P(ax.dp, None, ax.tp_if(cfg.num_heads), None))
    k = constrain(k, P(ax.dp, None, ax.tp_if(cfg.num_heads), None))
    v = constrain(v, P(ax.dp, None, ax.tp_if(cfg.num_heads), None))
    if bidirectional:
        cfg2 = dataclasses.replace(cfg, sliding_window=None)
        o = _attention_full_bidir(q, k, v, cfg2)
    else:
        o = attention_core_train(q, k, v, cfg, ax)
    return dense(o, p["wo"]["w"], p["wo"].get("b"))


def _attention_full_bidir(q, k, v, cfg: ArchConfig):
    b, s, h, d = q.shape
    chunk = fit_chunk(s, cfg.attn_chunk)
    nchunk = s // chunk
    inv = 1.0 / math.sqrt(d)
    qs = q.reshape(b, nchunk, chunk, h, d).transpose(1, 0, 2, 3, 4)

    def body(_, qc):
        scores = jnp.einsum("bqhd,bthd->bhqt", qc, k).astype(jnp.float32) * inv
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return (), jnp.einsum("bhqt,bthd->bqhd", w, v)

    _, outs = jax.lax.scan(body, (), qs)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h * d)


def cross_attention(x, mem_k, mem_v, p, cfg: ArchConfig, ax: MeshAxes):
    """x: (B, S, D) queries; mem_k/mem_v: (B, T, H, hd) precomputed."""
    b, s, _ = x.shape
    q = dense(x, p["wq"]["w"], p["wq"].get("b")).reshape(b, s, cfg.num_heads, cfg.head_dim)
    inv = 1.0 / math.sqrt(cfg.head_dim)
    scores = jnp.einsum("bqhd,bthd->bhqt", q, mem_k).astype(jnp.float32) * inv
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqt,bthd->bqhd", w, mem_v).reshape(b, s, cfg.q_dim)
    return dense(o, p["wo"]["w"], p["wo"].get("b"))


# -- decode (KV cache) --------------------------------------------------------


def _ring_valid(pos, smax: int, window: int | None):
    """Validity mask + absolute positions for a ring-buffer cache slot.

    Slot i holds absolute position ``pos - ((pos - i) mod smax)`` (the
    most recent write to that slot). Negative -> never written."""
    tpos = jnp.arange(smax)
    abs_pos = pos - jnp.mod(pos - tpos, smax)
    valid = abs_pos >= 0
    if window is not None:
        valid &= (pos - abs_pos) < window
    return valid


def _grouped_attend(q, ck, cv, cfg: ArchConfig, valid, offset_pos=None):
    """Grouped-query attention of one token over a cache shard — the KV
    heads are never expanded/materialized to H (GQA-native einsum).

    q: (B, 1, H, hd); ck/cv: (B, Sloc, KV, hd); valid: (Sloc,) bool.
    Returns fp32 partials (o (B,KV,G,1,hd), m (B,KV,G,1), l (B,KV,G,1))
    so callers can flash-combine across shards."""
    b, _, h, d = q.shape
    kv = ck.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, d)
    inv = 1.0 / math.sqrt(d)
    scores = einsum_f32("bqkgd,btkd->bkgqt", qg, ck) * inv
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    m = jnp.max(scores, axis=-1)  # (B, KV, G, 1)
    e = jnp.exp(scores - m[..., None])
    l = jnp.sum(e, axis=-1)
    o = einsum_f32("bkgqt,btkd->bkgqd", e.astype(cv.dtype), cv)
    return o, m, l


def attention_decode_general(x1, cache_k, cache_v, p, cfg: ArchConfig, ax: MeshAxes,
                             pos, plan):
    """One-token decode against a (possibly sharded) KV ring-buffer cache.

    plan (ServePlan) picks the layout: kv-head-sharded / plain (GSPMD
    path) or sequence-sharded (shard_map two-pass flash combine over
    plan.seq_axes, batch sharded over plan.batch_axes)."""
    b = x1.shape[0]
    smax = cache_k.shape[1]
    q, k1, v1 = qkv_proj(x1, p, cfg, ax, None)
    if cfg.use_rope and cfg.head_dim % 2 == 0:
        q = rope(q, jnp.full((1,), pos), cfg.rope_theta)
        k1 = rope(k1, jnp.full((1,), pos), cfg.rope_theta)

    if not plan.seq_axes:
        slot = jnp.asarray(pos % smax, jnp.int32)
        cache_k = jax.lax.dynamic_update_slice(cache_k, k1.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v1.astype(cache_v.dtype), (0, slot, 0, 0))
        bspec = plan.batch_axes or None
        cache_k = constrain(cache_k, P(bspec, None, plan.kv_axes, None))
        cache_v = constrain(cache_v, P(bspec, None, plan.kv_axes, None))
        valid = _ring_valid(pos, smax, cfg.sliding_window)
        o, m, l = _grouped_attend(q, cache_k, cache_v, cfg, valid)
        o = (o / l[..., None]).astype(x1.dtype)
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, 1, cfg.q_dim)
        return dense(o, p["wo"]["w"], p["wo"].get("b")), cache_k, cache_v

    mesh = get_abstract_mesh()
    seq_axes = plan.seq_axes
    nshard = 1
    for a in seq_axes:
        nshard *= mesh.shape[a]
    sloc = smax // nshard
    bspec = plan.batch_axes or None

    def local(q, k1, v1, ck, cv):
        idx = jnp.zeros((), jnp.int32)
        mul = 1
        for a in reversed(seq_axes):
            idx = idx + jax.lax.axis_index(a) * mul
            mul *= mesh.shape[a]
        offset = idx * sloc
        slot = jnp.asarray(pos % smax, jnp.int32)
        local_slot = jnp.clip(slot - offset, 0, sloc - 1)
        mine = (slot >= offset) & (slot < offset + sloc)
        k1w = jnp.where(mine, 1.0, 0.0).astype(ck.dtype)
        ck = jax.lax.dynamic_update_slice(
            ck,
            k1.astype(ck.dtype) * k1w + jax.lax.dynamic_slice(
                ck, (0, local_slot, 0, 0), k1.shape) * (1 - k1w),
            (0, local_slot, 0, 0),
        )
        cv = jax.lax.dynamic_update_slice(
            cv,
            v1.astype(cv.dtype) * k1w + jax.lax.dynamic_slice(
                cv, (0, local_slot, 0, 0), v1.shape) * (1 - k1w),
            (0, local_slot, 0, 0),
        )
        tpos_abs = pos - jnp.mod(pos - (offset + jnp.arange(sloc)), smax)
        valid = tpos_abs >= 0
        if cfg.sliding_window is not None:
            valid &= (pos - tpos_abs) < cfg.sliding_window
        o_loc, m_loc, l_loc = _grouped_attend(q, ck, cv, cfg, valid)
        m = m_loc
        for a in seq_axes:
            m = jax.lax.pmax(m, a)
        corr = jnp.exp(m_loc - m)
        l = l_loc * corr
        o = o_loc * corr[..., None]
        for a in seq_axes:
            l = jax.lax.psum(l, a)
            o = jax.lax.psum(o, a)
        o = (o / l[..., None]).astype(x1.dtype)  # (B, KV, G, 1, hd)
        o = o.transpose(0, 3, 1, 2, 4).reshape(q.shape[0], 1, cfg.q_dim)
        return o, ck, cv

    qspec = P(bspec, None, None, None)
    seq_spec = P(bspec, seq_axes, None, None)
    o, cache_k, cache_v = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(qspec, qspec, qspec, seq_spec, seq_spec),
        out_specs=(P(bspec, None, None), seq_spec, seq_spec),
        check_vma=False,
    )(q, k1, v1, cache_k, cache_v)
    return dense(o, p["wo"]["w"], p["wo"].get("b")), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp(x, p, cfg: ArchConfig, ax: MeshAxes):
    if cfg.act == "gelu":  # classic 2-matrix MLP (starcoder2, seamless)
        h = jax.nn.gelu(dense(x, p["wi"]["w"], p["wi"].get("b")))
        h = constrain(h, P(ax.dp, None, ax.tp_if(cfg.d_ff)))
        return dense(h, p["wd"]["w"], p["wd"].get("b"))
    gate_act = jax.nn.gelu if cfg.act == "gelu_gated" else jax.nn.silu
    h = gate_act(dense(x, p["wg"]["w"])) * dense(x, p["wu"]["w"])
    h = constrain(h, P(ax.dp, None, ax.tp_if(cfg.d_ff)))
    return dense(h, p["wd"]["w"], p["wd"].get("b"))


def init_attn(rng, cfg: ArchConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 4)
    return {
        "wq": init_dense(ks[0], cfg.d_model, cfg.q_dim, cfg.qkv_bias, dtype),
        "wk": init_dense(ks[1], cfg.d_model, cfg.kv_dim, cfg.qkv_bias, dtype),
        "wv": init_dense(ks[2], cfg.d_model, cfg.kv_dim, cfg.qkv_bias, dtype),
        "wo": init_dense(ks[3], cfg.q_dim, cfg.d_model, False, dtype),
    }


def init_mlp(rng, cfg: ArchConfig, d_ff: int | None = None, dtype=jnp.bfloat16):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.act == "gelu":
        return {
            "wi": init_dense(ks[0], cfg.d_model, d_ff, True, dtype),
            "wd": init_dense(ks[1], d_ff, cfg.d_model, True, dtype),
        }
    return {
        "wg": init_dense(ks[0], cfg.d_model, d_ff, False, dtype),
        "wu": init_dense(ks[1], cfg.d_model, d_ff, False, dtype),
        "wd": init_dense(ks[2], d_ff, cfg.d_model, False, dtype),
    }


# ---------------------------------------------------------------------------
# embeddings & loss
# ---------------------------------------------------------------------------


def init_embed(rng, cfg: ArchConfig, dtype=jnp.bfloat16):
    e = jax.random.normal(rng, (cfg.vocab_size, cfg.d_model), jnp.float32)
    return (e * 0.02).astype(dtype)


def embed_tokens(embed, tokens, ax: MeshAxes):
    x = jnp.take(embed, tokens, axis=0)
    return constrain(x, P(ax.dp, None, None))


def unembed(x, embed_or_head, ax: MeshAxes, vocab: int):
    w = embed_or_head
    if w.shape[0] == vocab:  # tied embedding: (V, D) -> project with transpose
        logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype))
    else:
        logits = x @ w.astype(x.dtype)
    return constrain(logits, P(ax.dp, None, ax.tp_if(vocab)))


def xent_loss(logits, labels, ax: MeshAxes):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(lse - ll)
