"""Mixture-of-Experts FFN (granite-moe, olmoe families).

Top-k routing with per-sequence capacity groups and gather/scatter
dispatch — no (S, E, C) one-hot dispatch tensor is ever materialized
(GShard-style einsum dispatch would be O(S·E·C); here dispatch is two
gathers + one scatter, O(S·k + E·C)).

Expert placement (DESIGN.md §5): the expert dim shards on the tp axis
when num_experts % tp == 0 (olmoe 64/16) — expert-parallelism, GSPMD
inserts the token all-to-alls around the gathers. Otherwise experts
replicate over tp and the per-expert FFN shards its hidden dim
(granite: 40 experts, d_ff=512).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.shardings import MeshAxes, constrain


def ep_axis(cfg: ArchConfig, ax: MeshAxes):
    return ax.tp if (ax.tp and cfg.num_experts % ax.tp_size == 0) else None


def expert_ff_axis(cfg: ArchConfig, ax: MeshAxes):
    """TP inside each expert's FFN, only when experts are not EP-sharded."""
    if ep_axis(cfg, ax) is not None:
        return None
    return ax.tp_if(cfg.d_ff)


def init_moe(rng, cfg: ArchConfig, dtype=jnp.bfloat16):
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)

    def w(key, shape, scale):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)

    return {
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.float32) * scale_in},
        "wg": w(ks[1], (e, d, f), scale_in),
        "wu": w(ks[2], (e, d, f), scale_in),
        "wd": w(ks[3], (e, f, d), scale_out),
    }


def moe_specs(cfg: ArchConfig, ax: MeshAxes):
    ep = ep_axis(cfg, ax)
    ff = expert_ff_axis(cfg, ax)
    fs = ax.fsdp_if(cfg.d_model)
    return {
        "router": {"w": P(fs, None)},
        "wg": P(ep, fs, ff),
        "wu": P(ep, fs, ff),
        "wd": P(ep, ff, fs),
    }


def capacity(cfg: ArchConfig, s: int) -> int:
    """Per-sequence expert capacity (tokens/expert), padded to 8."""
    c = int(math.ceil(cfg.capacity_factor * cfg.experts_per_token * s / cfg.num_experts))
    return max(8, -(-c // 8) * 8)


def route(x, router_w, cfg: ArchConfig):
    """x: (B, S, D) -> (gates (B,S,kk) f32, expert idx (B,S,kk) i32, aux loss)."""
    logits = L.einsum_f32("bsd,de->bse", x, router_w.astype(x.dtype))
    kk = cfg.experts_per_token
    top_vals, top_idx = jax.lax.top_k(logits, kk)
    gates = jax.nn.softmax(top_vals, axis=-1)
    # Switch-style load-balance aux: E * sum_e( frac_tokens_e * mean_prob_e )
    probs = jax.nn.softmax(logits, axis=-1)
    e = cfg.num_experts
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, e, dtype=jnp.float32), axis=2), axis=(0, 1)
    ) / kk
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))
    return gates, top_idx, aux


def moe_ffn(x, p, cfg: ArchConfig, ax: MeshAxes):
    """Capacity-dropped top-k MoE. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    e, kk = cfg.num_experts, cfg.experts_per_token
    cap = capacity(cfg, s)
    ep = ep_axis(cfg, ax)
    ff = expert_ff_axis(cfg, ax)

    gates, idx, aux = route(x, p["router"]["w"], cfg)  # (B,S,kk)

    # ---- slot assignment: rank of each (token, choice) within its expert --
    # flatten choices token-major so earlier tokens win capacity slots
    fidx = idx.reshape(b, s * kk)  # (B, S*kk)
    onehot = jax.nn.one_hot(fidx, e, dtype=jnp.int32)  # (B, S*kk, E)
    ranks = jnp.cumsum(onehot, axis=1) - 1  # rank within expert
    pos = jnp.take_along_axis(ranks, fidx[..., None], axis=-1)[..., 0]  # (B, S*kk)
    keep = pos < cap
    # scatter token index s into dispatch table (B, E, cap)
    tok_of_choice = jnp.repeat(jnp.arange(s)[None, :], b, axis=0)
    tok_of_choice = jnp.repeat(tok_of_choice[..., None], kk, axis=-1).reshape(b, s * kk)
    flat_slot = fidx * cap + jnp.where(keep, pos, cap * e)  # dropped -> OOB
    dispatch = jnp.full((b, e * cap + 1), s, jnp.int32)  # sentinel = s (pad row)
    dispatch = dispatch.at[
        jnp.arange(b)[:, None], flat_slot
    ].set(tok_of_choice, mode="drop")
    dispatch = dispatch[:, : e * cap].reshape(b, e, cap)

    # ---- gather tokens -> (B, E, cap, D), pad row for sentinel ------------
    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad[:, None], dispatch[..., None], axis=2
    )  # (B, E, cap, D)
    xe = constrain(xe, P(ax.dp, ep, None, None))

    # ---- expert FFN (batched einsum over E) -------------------------------
    act = jax.nn.gelu if cfg.act.startswith("gelu") else jax.nn.silu
    h = act(jnp.einsum("becd,edf->becf", xe, p["wg"])) * jnp.einsum(
        "becd,edf->becf", xe, p["wu"]
    )
    h = constrain(h, P(ax.dp, ep, None, ff))
    ye = jnp.einsum("becf,efd->becd", h, p["wd"])
    ye = constrain(ye, P(ax.dp, ep, None, None))

    # ---- combine: gather back each token's kk expert outputs --------------
    gather_idx = jnp.where(keep, flat_slot, e * cap).reshape(b, s, kk)
    yflat = jnp.concatenate(
        [ye.reshape(b, e * cap, d), jnp.zeros((b, 1, d), ye.dtype)], axis=1
    )
    yk = jnp.take_along_axis(
        yflat[:, :, None], gather_idx.reshape(b, s * kk)[..., None, None], axis=1
    )  # -> (B, S*kk, 1, D)
    yk = yk.reshape(b, s, kk, d)
    gk = (gates * keep.reshape(b, s, kk)).astype(yk.dtype)
    y = jnp.einsum("bskd,bsk->bsd", yk, gk)
    return constrain(y, P(ax.dp, None, None)), aux


def moe_ffn_noaux(x, p, cfg: ArchConfig, ax: MeshAxes):
    y, _ = moe_ffn(x, p, cfg, ax)
    return y
