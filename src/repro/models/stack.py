"""Scan-over-layers utilities.

Layer stacks are represented as *stacked* param pytrees: every leaf gains
a leading ``L`` dim and the stack is traversed with ``jax.lax.scan`` —
one layer's HLO is compiled once and reused, which keeps CPU compile
times of 88-layer dry-runs bounded and gives XLA a natural
remat/overlap boundary.

``scan_layers`` applies ``jax.checkpoint`` (policy: nothing saveable)
to the body so backward recomputes each layer from its (sharded)
input — the activation footprint is O(L x residual-shard), see
DESIGN.md §5.
"""

from __future__ import annotations

from typing import Callable

import jax


def stacked_init(init_fn: Callable, rng: jax.Array, num: int):
    """vmap an init over ``num`` rng splits -> stacked params (leading L)."""
    return jax.vmap(init_fn)(jax.random.split(rng, num))


def stacked_specs(specs, prefix_dim=None):
    """Prepend a (replicated) layer dim to every PartitionSpec leaf."""
    from jax.sharding import PartitionSpec as P

    def add(s: P) -> P:
        return P(prefix_dim, *s)

    return jax.tree.map(add, specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def scan_layers(body: Callable, x, stacked_params, *, remat: bool = True,
                unroll: int = 1, block: int = 0):
    """x -> fold ``body(x, layer_params) -> x`` over the leading L dim.

    block > 0 enables two-level (nested) remat: the outer scan runs over
    L/block groups and checkpoints only each *block input*, the inner
    scan re-checkpoints per layer during the block's backward. Saved
    activations shrink from O(L x residual) to O(L/block x residual) at
    the cost of ~one extra forward pass (8N·D -> 10N·D flops) — how the
    123B train cell fits v5e HBM (§Perf iteration B)."""
    leaves = jax.tree.leaves(stacked_params)
    num = leaves[0].shape[0] if leaves else 0
    if block and num > block and num % block == 0:
        grouped = jax.tree.map(
            lambda a: a.reshape(num // block, block, *a.shape[1:]), stacked_params
        )

        def block_body(c, bp):
            return scan_layers(body, c, bp, remat=remat, unroll=unroll)

        blk = jax.checkpoint(block_body, policy=jax.checkpoint_policies.nothing_saveable)

        def step(carry, bp):
            return blk(carry, bp), None

        x, _ = jax.lax.scan(step, x, grouped)
        return x

    fn = body
    if remat:
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    def step(carry, p):
        return fn(carry, p), None

    x, _ = jax.lax.scan(step, x, stacked_params, unroll=unroll)
    return x


def scan_layers_with_cache(body: Callable, x, stacked_params, cache):
    """Decode traversal: body(x, layer_params, layer_cache) -> (x, new_cache).

    cache is a pytree whose leaves have leading L; returns updated stack.
    """

    def step(carry, pc):
        p, c = pc
        y, c2 = body(carry, p, c)
        return y, c2

    x, new_cache = jax.lax.scan(step, x, (stacked_params, cache))
    return x, new_cache
