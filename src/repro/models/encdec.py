"""Encoder-decoder transformer backbone (seamless-m4t family).

The modality frontend is a STUB per the brief: ``input_specs`` provides
precomputed speech-frame embeddings (B, T_frames, d_model). The encoder
(24 bidirectional layers), decoder (24 layers: causal self-attn +
cross-attn + classic gelu MLP) and vocab head are real.

Positions: sinusoidal absolute (added to embeddings), as in the
NLLB/transformer lineage — no RoPE.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import stack
from repro.models import transformer as T
from repro.models.shardings import MeshAxes, constrain


def sinusoid(positions, d: int):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (S, d)


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------


def init_enc_layer(rng, cfg: ArchConfig):
    return T.init_decoder_layer(rng, cfg)  # same shape: attn + mlp


def init_dec_layer(rng, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "self_attn": L.init_attn(k1, cfg),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "cross_attn": L.init_attn(k2, cfg),
        "ln3": L.init_norm(cfg, cfg.d_model),
        "ffn": L.init_mlp(k3, cfg),
    }


def dec_layer_specs(cfg: ArchConfig, ax: MeshAxes):
    return {
        "ln1": T.norm_specs(cfg),
        "self_attn": T.attn_specs(cfg, ax),
        "ln2": T.norm_specs(cfg),
        "cross_attn": T.attn_specs(cfg, ax),
        "ln3": T.norm_specs(cfg),
        "ffn": T.mlp_specs(cfg, ax),
    }


def init_lm(cfg: ArchConfig, rng) -> dict:
    ke, k1, k2, kh = jax.random.split(rng, 4)
    return {
        "embed": L.init_embed(ke, cfg),
        "enc": stack.stacked_init(
            functools.partial(init_enc_layer, cfg=cfg), k1, cfg.enc_layers
        ),
        "dec": stack.stacked_init(
            functools.partial(init_dec_layer, cfg=cfg), k2, cfg.dec_layers
        ),
        "ln_enc": L.init_norm(cfg, cfg.d_model),
        "ln_dec": L.init_norm(cfg, cfg.d_model),
        "head": L.init_dense(kh, cfg.d_model, cfg.vocab_size, False)["w"],
    }


def lm_specs(cfg: ArchConfig, ax: MeshAxes) -> dict:
    return {
        "embed": P(ax.tp_if(cfg.vocab_size), ax.fsdp_if(cfg.d_model)),
        "enc": stack.stacked_specs(T.decoder_layer_specs(cfg, ax)),
        "dec": stack.stacked_specs(dec_layer_specs(cfg, ax)),
        "ln_enc": T.norm_specs(cfg),
        "ln_dec": T.norm_specs(cfg),
        "head": P(ax.fsdp_if(cfg.d_model), ax.tp_if(cfg.vocab_size)),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def encode(params, src_embed, cfg: ArchConfig, ax: MeshAxes):
    """src_embed: (B, T, D) precomputed frames -> encoder states (B, T, D)."""
    b, t, d = src_embed.shape
    x = src_embed.astype(jnp.bfloat16) + sinusoid(jnp.arange(t), d)[None].astype(jnp.bfloat16)
    x = constrain(x, T.res_spec(ax, t))

    def body(h, lp):
        h = h + L.attention_train(
            L.norm(h, lp["ln1"], cfg), lp["attn"], cfg, ax, None, bidirectional=True
        )
        h = constrain(h, T.res_spec(ax, t))
        h = h + L.mlp(L.norm(h, lp["ln2"], cfg), lp["ffn"], cfg, ax)
        return constrain(h, T.res_spec(ax, t))

    x = stack.scan_layers(body, x, params["enc"])
    return L.norm(x, params["ln_enc"], cfg)


def _cross_kv(mem, lp, cfg: ArchConfig):
    b, t, _ = mem.shape
    k = L.dense(mem, lp["cross_attn"]["wk"]["w"], lp["cross_attn"]["wk"].get("b"))
    v = L.dense(mem, lp["cross_attn"]["wv"]["w"], lp["cross_attn"]["wv"].get("b"))
    return (
        k.reshape(b, t, cfg.num_kv_heads, cfg.head_dim),
        v.reshape(b, t, cfg.num_kv_heads, cfg.head_dim),
    )


def apply_dec_layer(x, lp, mem, cfg: ArchConfig, ax: MeshAxes):
    s = x.shape[1]
    x = x + L.attention_train(L.norm(x, lp["ln1"], cfg), lp["self_attn"], cfg, ax, None)
    x = constrain(x, T.res_spec(ax, s))
    mk, mv = _cross_kv(mem, lp, cfg)
    x = x + L.cross_attention(L.norm(x, lp["ln2"], cfg), mk, mv, lp["cross_attn"], cfg, ax)
    x = constrain(x, T.res_spec(ax, s))
    x = x + L.mlp(L.norm(x, lp["ln3"], cfg), lp["ffn"], cfg, ax)
    return constrain(x, T.res_spec(ax, s))


def lm_loss(params, batch, cfg: ArchConfig, ax: MeshAxes):
    mem = encode(params, batch["src_embed"], cfg, ax)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, ax)
    x = x + sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    x = constrain(x, T.res_spec(ax, s))

    def body(h, lp):
        return apply_dec_layer(h, lp, mem, cfg, ax)

    x = stack.scan_layers(body, x, params["dec"])
    x = L.norm(x, params["ln_dec"], cfg)
    return T.chunked_xent(x, params["head"], batch["labels"], cfg, ax,
                          batch.get("loss_mask"))


# ---------------------------------------------------------------------------
# serving (decoder-side KV cache + precomputed cross-attn memory)
# ---------------------------------------------------------------------------


def cache_shape(cfg: ArchConfig, batch: int, cache_len: int, mem_len: int | None = None):
    mem_len = mem_len or cfg.num_stub_tokens
    kv = (cfg.dec_layers, batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    xk = (cfg.dec_layers, batch, mem_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(kv, jnp.bfloat16),
        "v": jax.ShapeDtypeStruct(kv, jnp.bfloat16),
        "mem_k": jax.ShapeDtypeStruct(xk, jnp.bfloat16),
        "mem_v": jax.ShapeDtypeStruct(xk, jnp.bfloat16),
    }


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, mem_len: int | None = None):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shape(cfg, batch, cache_len, mem_len)
    )


def cache_specs(cfg: ArchConfig, ax: MeshAxes, batch: int, plan) -> dict:
    b = plan.batch_axes or None
    kv_spec = P(None, b, plan.seq_axes if plan.seq_axes else None,
                plan.kv_axes if plan.kv_axes else None, None)
    mem_spec = P(None, b, None, plan.kv_axes if plan.kv_axes else None, None)
    return {"k": kv_spec, "v": kv_spec, "mem_k": mem_spec, "mem_v": mem_spec}


def prefill(params, tokens, cfg: ArchConfig, ax: MeshAxes, cache_len: int, src_embed=None):
    """Encoder pass + decoder prompt pass; returns (last logits, cache)."""
    mem = encode(params, src_embed, cfg, ax)
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, ax)
    x = x + sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    x = constrain(x, T.res_spec(ax, s))

    def body(h, lp):
        xn = L.norm(h, lp["ln1"], cfg)
        q, k, v = L.qkv_proj(xn, lp["self_attn"], cfg, ax, None)
        ke, ve = L.expand_kv(k, cfg), L.expand_kv(v, cfg)
        o = L.attention_core_train(q, ke, ve, cfg, ax)
        h = h + L.dense(o, lp["self_attn"]["wo"]["w"], lp["self_attn"]["wo"].get("b"))
        mk, mv = _cross_kv(mem, lp, cfg)
        h = h + L.cross_attention(L.norm(h, lp["ln2"], cfg), mk, mv, lp["cross_attn"], cfg, ax)
        h = h + L.mlp(L.norm(h, lp["ln3"], cfg), lp["ffn"], cfg, ax)
        return constrain(h, T.res_spec(ax, s)), (k, v, mk, mv)

    x, (ks, vs, mks, mvs) = jax.lax.scan(lambda c, lp: body(c, lp), x, params["dec"])
    x = L.norm(x, params["ln_dec"], cfg)
    logits = L.unembed(x[:, -1:], params["head"], ax, cfg.vocab_size)
    pad = cache_len - s
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {
        "k": ks.astype(jnp.bfloat16),
        "v": vs.astype(jnp.bfloat16),
        "mem_k": mks.astype(jnp.bfloat16),
        "mem_v": mvs.astype(jnp.bfloat16),
    }
    return logits[:, 0], cache


def decode_step(params, token, cache, pos, cfg: ArchConfig, ax: MeshAxes, plan):
    x = L.embed_tokens(params["embed"], token, ax)
    x = x + sinusoid(jnp.full((1,), pos), cfg.d_model)[None].astype(x.dtype)

    def body(h, lp, lc):
        xn = L.norm(h, lp["ln1"], cfg)
        o, nk, nv = L.attention_decode_general(
            xn, lc["k"], lc["v"], lp["self_attn"], cfg, ax, pos, plan
        )
        h = h + o
        h = h + L.cross_attention(
            L.norm(h, lp["ln2"], cfg), lc["mem_k"], lc["mem_v"], lp["cross_attn"], cfg, ax
        )
        h = h + L.mlp(L.norm(h, lp["ln3"], cfg), lp["ffn"], cfg, ax)
        return h, {"k": nk, "v": nv, "mem_k": lc["mem_k"], "mem_v": lc["mem_v"]}

    x, new_cache = stack.scan_layers_with_cache(body, x, params["dec"], cache)
    x = L.norm(x, params["ln_dec"], cfg)
    logits = L.unembed(x, params["head"], ax, cfg.vocab_size)
    return logits[:, 0], new_cache
