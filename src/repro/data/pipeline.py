"""Deterministic synthetic data pipeline.

Stateless-resumable: ``batch_at(step)`` is a pure function of
(seed, step), so a restarted job replays the exact token stream from its
checkpointed cursor — no pipeline state needs to be saved beyond the
step counter (the cursor *is* part of the CORE-encoded checkpoint via
TrainState.step).

Shard-awareness: batches are produced as global arrays and placed via
``jax.device_put`` with the step's batch sharding; per-host slicing at
1000+-node scale would use the same ``batch_at`` with a host-rank
offset (each host materializes only its slice — the generator is
index-addressable by construction).

The stream is not uniform noise: tokens follow a per-sequence 2-state
Markov chain over vocab halves, so the LM loss has learnable structure
(quickstart/train examples show loss decreasing).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell


def batch_specs(cfg: ArchConfig, ax, *, with_stub: bool = True) -> dict:
    """PartitionSpecs for a train batch (batch dim over dp axes)."""
    specs = {"tokens": P(ax.dp, None), "labels": P(ax.dp, None)}
    if with_stub and cfg.family == "vlm":
        specs["patch_embed"] = P(ax.dp, None, None)
    if with_stub and cfg.family == "encdec":
        specs["src_embed"] = P(ax.dp, None, None)
    return specs


@dataclass
class SyntheticPipeline:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def _text_len(self) -> int:
        if self.cfg.family == "vlm":
            return self.seq_len - self.cfg.num_stub_tokens
        return self.seq_len

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step) -> batch dict of host arrays."""
        s = self._text_len()
        b = self.global_batch
        v = self.cfg.vocab_size
        rng = np.random.default_rng(np.uint64(self.seed * 1_000_003 + step))
        # 2-state Markov over vocab halves: learnable bigram structure
        state = rng.integers(0, 2, size=(b, 1))
        flips = rng.random((b, s)) < 0.15
        states = np.bitwise_xor.accumulate(
            np.concatenate([state, flips[:, 1:]], axis=1), axis=1
        )
        half = v // 2
        tok = (rng.integers(0, half, size=(b, s)) + states * half).astype(np.int32)
        batch = {
            "tokens": tok,
            "labels": np.roll(tok, -1, axis=1).astype(np.int32),
        }
        if self.cfg.family == "vlm":
            batch["patch_embed"] = rng.standard_normal(
                (b, self.cfg.num_stub_tokens, self.cfg.d_model), np.float32
            ).astype(jnp.bfloat16)
        if self.cfg.family == "encdec":
            batch["src_embed"] = rng.standard_normal(
                (b, self.cfg.num_stub_tokens, self.cfg.d_model), np.float32
            ).astype(jnp.bfloat16)
        return batch

    def device_batch(self, step: int, mesh=None, ax=None) -> dict:
        batch = self.batch_at(step)
        if mesh is None:
            return {k: jnp.asarray(x) for k, x in batch.items()}
        specs = batch_specs(self.cfg, ax)
        return {
            k: jax.device_put(x, jax.sharding.NamedSharding(mesh, specs[k]))
            for k, x in batch.items()
        }


def shapes_for_cell(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs of a *train/prefill* batch for dry-run lowering."""
    s = cell.seq_len - (cfg.num_stub_tokens if cfg.family == "vlm" else 0)
    b = cell.global_batch
    out = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        out["patch_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.num_stub_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "encdec":
        out["src_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.num_stub_tokens, cfg.d_model), jnp.bfloat16
        )
    if cell.kind != "train":
        out.pop("labels")
    return out
