from repro.data.pipeline import SyntheticPipeline, batch_specs  # noqa: F401
