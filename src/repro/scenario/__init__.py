# Fault-injection scenario engine: drives the serving gateway over
# simulated time with the failure regimes real clusters actually see.
#
# The trace DSL (trace.py): a ScenarioTrace is a replayable, time-sorted
# schedule of node-level cluster events — FailureEvent (transient crash:
# disks survive), NodeRecoverEvent (the node rejoins with its blocks;
# the gateway purges its negative cache entries), CapacityLossEvent
# (disk death: blocks destroyed, only repair restores them) — plus
# LoadSurge windows that multiply the workload's arrival rate. Rack
# failures (one switch, many disks — the correlated mode the
# XORing-Elephants study emphasizes) and flapping nodes are builders
# that expand into the same three node-level events, so the gateway's
# event loop stays small. Gray-failure events join them: CorruptionEvent
# (silent bit-flip / torn write / erase on one node's blocks),
# SlowNodeEvent / SlowNicEvent (fail-slow: a rate factor degrades the
# node's effective link speed until a factor-1.0 event restores it;
# flapping_slow expands a duty cycle into such pairs), and
# ShardFailEvent kills a whole serving shard mid-run (storage survives;
# the ShardedGateway front door fails the namespace range over to the
# survivors). generate_scenario draws seeded random traces
# from a ScenarioConfig with a hard admission bound: with anti-colocated
# placement, f concurrently-affected nodes cost any stripe at most f
# blocks, so traces bounded at f <= n - k never exceed the code's
# tolerance — every GET stays servable and every repair recoverable
# (corruption counts against the same bound; fail-slow events don't —
# slow is not down).
# Traces serialize to JSON so a failing seed commits as a fixture.
#
# The closed loop (engine.py + gateway/gateway.py + storage/repair.py):
# the gateway consumes trace events MID-RUN — the planner replans
# against the shifting failure set, blocks on down nodes are
# negative-cached with a TTL (purged on recover/heal), and the admission
# controller's estimates track the changing plans. Repair is paced by a
# PacingController: observed foreground p99 headroom against
# tenant_slo_p99 modulates the "repair" tenant's fabric weight AND its
# decode-engine share (slowing repair when the tier nears its SLO,
# accelerating toward the MTTR target when idle), and run_scenario
# returns MTTR / durability / p99-under-failure metrics so paced and
# fixed-weight repair compare head to head (BENCH_gateway.json
# gateway_scenario rows). deterministic_fingerprint hashes the
# wall-clock-free outcome so golden-trace replays guard event ordering.
from repro.scenario.engine import (
    SURGE_FAIL_AT,
    SURGE_END,
    ScenarioResult,
    correlated_surge_setup,
    deterministic_fingerprint,
    run_scenario,
)
from repro.scenario.trace import (
    ClusterEvent,
    LoadSurge,
    ScenarioConfig,
    ScenarioTrace,
    flapping_node,
    flapping_slow,
    generate_scenario,
    load_surge,
    rack_failure,
    scenario_requests,
    trace_from_jsonable,
)

__all__ = [
    "ClusterEvent",
    "LoadSurge",
    "SURGE_END",
    "SURGE_FAIL_AT",
    "ScenarioConfig",
    "ScenarioResult",
    "ScenarioTrace",
    "correlated_surge_setup",
    "deterministic_fingerprint",
    "flapping_node",
    "flapping_slow",
    "generate_scenario",
    "load_surge",
    "rack_failure",
    "run_scenario",
    "scenario_requests",
    "trace_from_jsonable",
]
