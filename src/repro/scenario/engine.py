"""Scenario runner: drive a gateway over a fault trace and summarize.

``run_scenario`` is the one-call harness the benchmarks, tests and the
example share: it synthesizes the surge-aware request stream, replays
the trace's cluster events through ``ObjectGateway.serve`` (the gateway
consumes them mid-run — the planner, negative cache and admission
controller all see availability change between requests), audits
durability at the end, and returns a ``ScenarioResult`` with the
SLO/MTTR metrics the closed-loop repair pacer is judged on.

``deterministic_fingerprint`` hashes the simulation's *discrete*
outcomes (request stream, degradation/rejection flags, fabric bytes,
repair and durability counters) while excluding latency floats and
pacing shares — replaying the same trace + workload seed reproduces it
bit-for-bit, which is the golden-trace guard on the simulated-clock
event ordering. The guarantee requires the discrete outcomes themselves
to be wall-clock-free: bill decode with the modeled
``GatewayConfig.decode_cost`` (as the canonical scenario does), since
under measured billing an admission controller or pacing-dependent
heal gate can flip a borderline degraded/rejected flag between cold
and warm jit caches.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass

from repro.gateway.gateway import GatewayReport, ObjectGateway
from repro.gateway.workload import WorkloadConfig
from repro.scenario.trace import (
    ScenarioTrace,
    load_surge,
    rack_failure,
    scenario_requests,
)


@dataclass
class ScenarioResult:
    report: GatewayReport
    durability: dict  # ObjectGateway.audit_durability()
    trace: ScenarioTrace

    @property
    def mttr_mean(self) -> float:
        return self.report.mttr_mean

    @property
    def mttr_max(self) -> float:
        return self.report.mttr_max

    @property
    def blocks_lost(self) -> int:
        return int(self.durability["blocks_lost"])

    def p99_since(self, since: float, tenant: str | None = None) -> float:
        if tenant is None:
            return self.report.latency_percentile(99, since=since)
        return self.report.tenant_latency_percentile(tenant, 99, since=since)

    def p99_window(self, lo: float, hi: float, tenant: str | None = None) -> float:
        """p99 over completed requests ARRIVING in [lo, hi) — the
        under-pressure statistic the pacing gates use: an SLO protects
        the requests that arrive while the fault and surge are live, not
        the calm tail after them. Delegates to the report's single
        quantile definition."""
        if tenant is None:
            return self.report.latency_percentile(99, since=lo, until=hi)
        return self.report.tenant_latency_percentile(tenant, 99, since=lo, until=hi)

    def summary(self) -> dict:
        rep = self.report
        return {
            "requests": len(rep.records),
            "completed": len(rep.completed),
            "rejected": len(rep.rejected),
            "degraded_gets": len(rep.degraded_gets),
            "durability_events": len(self.trace.fault_events()),
            "repairs": len(rep.repair_reports),
            "blocks_repaired": sum(r.blocks_repaired for r in rep.repair_reports),
            "mttr_mean_s": round(self.mttr_mean, 4),
            "mttr_max_s": round(self.mttr_max, 4),
            "blocks_lost": self.blocks_lost,
            "unreadable_objects": int(self.durability["unreadable_objects"]),
            "pacing_updates": len(rep.pacing),
        }


SURGE_FAIL_AT = 0.05
SURGE_END = 0.65


def correlated_surge_setup(code, num_requests: int = 200) -> dict:
    """The canonical paced-vs-fixed repair scenario, defined ONCE and
    shared by the benchmark gate (benchmarks/gateway_load.py), the
    regression test (tests/test_scenario.py) and the example demo — so
    all three always validate the same setup.

    Shape: a dense 20-node cluster (racks of n - k, so the correlated
    burst sits exactly at the code's tolerance) loses rack 2 at t=0.05
    while arrivals rise 1.5x until t=0.65. With 40 groups the repair
    backlog is far too large to finish inside the surge even at full
    weight — the regime where pacing is a real decision: the only
    choice is how hard repair leans on the fabric while the surge
    lasts. Decode billing is modeled (``decode_cost``) so replays and
    paced-vs-fixed comparisons are bit-for-bit deterministic.

    Returns a dict with the trace, workload, cluster shape, and the
    GatewayConfig kwargs (everything except ``repair_pacing``, which is
    the variable under test)."""
    num_nodes = 20
    q = 1 << 16
    trace = ScenarioTrace(num_nodes=num_nodes, nodes_per_rack=code.n - code.k)
    trace = rack_failure(trace, SURGE_FAIL_AT, rack=2)
    trace = load_surge(trace, SURGE_FAIL_AT, SURGE_END - SURGE_FAIL_AT, 1.5)
    workload = WorkloadConfig(
        num_objects=120,
        num_requests=num_requests,
        arrival_rate=80.0,
        zipf_s=0.2,  # spread load: no single hot source port
        seed=17,
    )
    slo = 0.12
    gateway_kwargs = dict(
        batch_window=0.01,
        cache_bytes=48 * q,
        repair_on_failure=True,
        repair_delay=0.1,
        background_share=1.0,  # fixed baseline: repair at full weight
        repair_min_share=0.25,
        repair_mttr_target=0.8,
        repair_groups_per_run=2,  # incremental drain: the pacer
        repair_respacing=0.03,  # re-observes between batches
        tenant_slo_p99={"foreground": slo},
        decode_cost=0.002,  # modeled billing: replayable
    )
    return {
        "num_nodes": num_nodes,
        "block_bytes": q,
        "num_objects": workload.num_objects,
        "seed": 17,
        "slo": slo,
        "fail_at": SURGE_FAIL_AT,
        "surge_end": SURGE_END,
        "trace": trace,
        "workload": workload,
        "gateway_kwargs": gateway_kwargs,
    }


def run_scenario(
    gw: ObjectGateway,
    trace: ScenarioTrace,
    wl: WorkloadConfig,
    tenant: str = "foreground",
) -> ScenarioResult:
    reqs = scenario_requests(wl, trace, tenant=tenant)
    report = gw.serve(reqs, trace.cluster_events())
    return ScenarioResult(
        report=report, durability=gw.audit_durability(), trace=trace
    )


def deterministic_fingerprint(result: ScenarioResult) -> str:
    """sha256 over the discrete (wall-clock-free) outcome of a scenario
    run. Two replays of the same trace + workload seed must match."""
    rep = result.report
    payload = {
        "records": [
            [
                round(r.time, 9),
                r.object_id,
                r.kind,
                r.latency is None,
                r.degraded,
                r.rejected,
                r.bytes_read,
                r.reconstruction_blocks,
                r.cache_hits,
                r.tenant,
                r.payload_digest,
            ]
            for r in rep.records
        ],
        "repairs": [
            [r.mode, r.blocks_fetched, r.bytes_fetched, r.blocks_repaired, r.recovered]
            for r in rep.repair_reports
        ],
        "rejections": dict(sorted(rep.rejections.items())),
        "mttr_samples": len(rep.mttr_samples),
        "restored_samples": len(rep.restored_samples),
        "pacing_updates": len(rep.pacing),
        "durability": {
            k: int(v) for k, v in sorted(result.durability.items())
        },
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()
