"""Fault-injection scenario traces: the event DSL and seeded generators.

A ``ScenarioTrace`` is a time-sorted sequence of cluster fault events —
node crash/recover pairs (transient failures: reboots, partitions),
capacity losses (disk death: blocks destroyed, only repair brings them
back), load surges (arrival-rate multipliers the workload generator
honours), and GRAY failures — ``CorruptionEvent`` (silent bit flips /
torn writes: nothing fails until a checksum verify catches the bytes)
and ``SlowNodeEvent`` / ``SlowNicEvent`` (fail-slow rate-factor
degradation honoured by the fabric ports) — over a cluster whose nodes
are grouped into racks (failure domains). Rack-level events, flapping
nodes and flapping-slow nodes are *builders* that expand into the same
node-level vocabulary (``repro.gateway.workload`` event types), and
every trace is replayable verbatim: same trace + same workload seed =>
same simulated run.

``generate_scenario`` draws a random trace from a seeded
``ScenarioConfig``: Poisson background crashes with exponential
downtimes, correlated rack bursts, flapping nodes, and a configurable
transient/permanent split — with a hard admission bound
(``max_concurrent_failures``) so generated traces never exceed the
code's tolerance: with anti-colocated placement, f concurrently-affected
nodes cost any stripe at most f blocks, so f <= n - k keeps every object
readable and every repair recoverable. Events that would breach the
bound are dropped in a deterministic admission pass (rack bursts are
trimmed, keeping the correlation as large as the bound allows).

Traces serialize to plain JSON (``to_jsonable`` / ``trace_from_jsonable``)
so a failing seed can be committed as a regression fixture.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

import numpy as np

from repro.gateway.workload import (
    CapacityLossEvent,
    CorruptionEvent,
    DEFAULT_TENANT,
    FailureEvent,
    NodeRecoverEvent,
    Request,
    ShardFailEvent,
    SlowNicEvent,
    SlowNodeEvent,
    WorkloadConfig,
    zipf_probs,
)

ClusterEvent = (
    FailureEvent
    | NodeRecoverEvent
    | CapacityLossEvent
    | CorruptionEvent
    | SlowNodeEvent
    | SlowNicEvent
    | ShardFailEvent
)

_EVENT_TYPES = {
    "crash": FailureEvent,
    "recover": NodeRecoverEvent,
    "capacity_loss": CapacityLossEvent,
    "corrupt": CorruptionEvent,
    "slow_node": SlowNodeEvent,
    "slow_nic": SlowNicEvent,
    "shard_fail": ShardFailEvent,
}
_EVENT_NAMES = {v: k for k, v in _EVENT_TYPES.items()}


def _event_to_jsonable(e: ClusterEvent) -> dict:
    d: dict = {"kind": _EVENT_NAMES[type(e)], "time": e.time, "node": e.node}
    if isinstance(e, CorruptionEvent):
        d["blocks"] = [list(k) for k in e.blocks]
        d["mode"] = e.mode
        d["count"] = e.count
    elif isinstance(e, (SlowNodeEvent, SlowNicEvent)):
        d["rate_factor"] = e.rate_factor
        if isinstance(e, SlowNicEvent):
            d["direction"] = e.direction
    elif isinstance(e, ShardFailEvent):
        d["shard"] = e.shard
    return d


def _event_from_jsonable(d: dict) -> ClusterEvent:
    kind, t, node = d["kind"], float(d["time"]), int(d["node"])
    if kind == "corrupt":
        return CorruptionEvent(
            time=t,
            node=node,
            blocks=tuple(
                (str(k[0]), int(k[1]), int(k[2])) for k in d.get("blocks", [])
            ),
            mode=str(d.get("mode", "bitflip")),
            count=int(d.get("count", 1)),
        )
    if kind == "slow_node":
        return SlowNodeEvent(
            time=t, node=node, rate_factor=float(d.get("rate_factor", 0.1))
        )
    if kind == "slow_nic":
        return SlowNicEvent(
            time=t,
            node=node,
            rate_factor=float(d.get("rate_factor", 0.1)),
            direction=str(d.get("direction", "send")),
        )
    if kind == "shard_fail":
        return ShardFailEvent(time=t, shard=int(d["shard"]))
    return _EVENT_TYPES[kind](time=t, node=node)


@dataclass(frozen=True)
class LoadSurge:
    """Multiply the base arrival rate by ``multiplier`` for
    [time, time + duration) — the foreground pressure that makes
    SLO-aware repair pacing bite."""

    time: float
    duration: float
    multiplier: float

    def active_at(self, t: float) -> bool:
        return self.time <= t < self.time + self.duration


@dataclass(frozen=True)
class ScenarioTrace:
    """A replayable fault schedule: node-level cluster events plus load
    surges, both time-sorted. ``rack_of(node)`` exposes the failure-
    domain map the trace was built against (contiguous racks of
    ``nodes_per_rack`` nodes)."""

    num_nodes: int
    events: tuple = ()  # ClusterEvent, time-sorted
    surges: tuple = ()  # LoadSurge, time-sorted
    nodes_per_rack: int = 8
    seed: int | None = None  # generator provenance (None: hand-built)

    def rack_of(self, node: int) -> int:
        return node // self.nodes_per_rack

    def rack_nodes(self, rack: int) -> list[int]:
        lo = rack * self.nodes_per_rack
        return [n for n in range(lo, lo + self.nodes_per_rack) if n < self.num_nodes]

    def cluster_events(self) -> list[ClusterEvent]:
        """The node-level events the gateway consumes, time-sorted."""
        return sorted(self.events, key=lambda e: e.time)

    def fault_events(self) -> list[ClusterEvent]:
        """Down/degrade events only — recoveries undo faults, they aren't
        faults, and a slow event restoring full speed (rate_factor 1.0)
        is likewise a recovery. The count durability claims should be
        quoted against."""
        return [
            e for e in self.cluster_events()
            if not isinstance(e, NodeRecoverEvent)
            and not (
                isinstance(e, (SlowNodeEvent, SlowNicEvent))
                and e.rate_factor >= 1.0
            )
        ]

    def rate_multiplier(self, t: float) -> float:
        m = 1.0
        for s in self.surges:
            if s.active_at(t):
                m *= s.multiplier
        return m

    @property
    def span(self) -> float:
        ends = [e.time for e in self.events]
        ends += [s.time + s.duration for s in self.surges]
        return max(ends, default=0.0)

    def max_concurrent_down(self) -> int:
        """Worst-case concurrently-affected node count over the trace.
        Capacity-lost nodes count as affected forever (the trace itself
        cannot know when repair heals them) — the conservative bound the
        generator's admission pass enforces."""
        affected: set[int] = set()
        lost: set[int] = set()  # capacity-lost: a reboot can't restore data
        worst = 0
        # conservative same-instant ordering: a crash and a recovery at
        # the same timestamp count as overlapping (crashes first)
        ordered = sorted(
            self.events, key=lambda e: (e.time, isinstance(e, NodeRecoverEvent))
        )
        for evt in ordered:
            if isinstance(evt, (SlowNodeEvent, SlowNicEvent, ShardFailEvent)):
                # slowness / serving-shard death: data intact on the
                # storage fabric, erasure tolerance untouched
                continue
            if isinstance(evt, NodeRecoverEvent):
                if evt.node not in lost:
                    affected.discard(evt.node)
            elif isinstance(evt, CorruptionEvent):
                # corrupt bytes are erasures once detected; like capacity
                # loss, the trace can't know when repair heals them
                lost.add(evt.node)
                affected.add(evt.node)
            else:
                if isinstance(evt, CapacityLossEvent):
                    lost.add(evt.node)
                affected.add(evt.node)
            worst = max(worst, len(affected))
        return worst

    # -- serialization (replayable fixtures) --------------------------------
    def to_jsonable(self) -> dict:
        return {
            "num_nodes": self.num_nodes,
            "nodes_per_rack": self.nodes_per_rack,
            "seed": self.seed,
            "events": [_event_to_jsonable(e) for e in self.cluster_events()],
            "surges": [
                {"time": s.time, "duration": s.duration, "multiplier": s.multiplier}
                for s in self.surges
            ],
        }


def trace_from_jsonable(obj: dict) -> ScenarioTrace:
    return ScenarioTrace(
        num_nodes=int(obj["num_nodes"]),
        nodes_per_rack=int(obj.get("nodes_per_rack", 8)),
        seed=obj.get("seed"),
        events=tuple(_event_from_jsonable(e) for e in obj.get("events", [])),
        surges=tuple(
            LoadSurge(float(s["time"]), float(s["duration"]), float(s["multiplier"]))
            for s in obj.get("surges", [])
        ),
    )


# -- trace builders (the DSL's correlated / transient idioms) ----------------


def rack_failure(
    trace: ScenarioTrace, time: float, rack: int, downtime: float | None = None
) -> ScenarioTrace:
    """Correlated failure: crash every node of ``rack`` at ``time`` (one
    switch/PDU, many disks — the XORing-Elephants failure mode), with a
    rack-wide recovery ``downtime`` seconds later when given."""
    events = list(trace.events)
    for n in trace.rack_nodes(rack):
        events.append(FailureEvent(time=time, node=n))
        if downtime is not None:
            events.append(NodeRecoverEvent(time=time + downtime, node=n))
    return replace(trace, events=tuple(sorted(events, key=lambda e: e.time)))


def flapping_node(
    trace: ScenarioTrace,
    node: int,
    start: float,
    period: float,
    count: int,
    duty: float = 0.5,
) -> ScenarioTrace:
    """Transient flapping: ``count`` crash/recover cycles of ``period``
    seconds each, down for ``duty`` of every cycle."""
    events = list(trace.events)
    for i in range(count):
        t0 = start + i * period
        events.append(FailureEvent(time=t0, node=node))
        events.append(NodeRecoverEvent(time=t0 + period * duty, node=node))
    return replace(trace, events=tuple(sorted(events, key=lambda e: e.time)))


def flapping_slow(
    trace: ScenarioTrace,
    node: int,
    start: float,
    period: float,
    count: int,
    rate_factor: float = 0.1,
    duty: float = 0.5,
) -> ScenarioTrace:
    """Flapping fail-slow (the nastiest gray mode: intermittently slow,
    never down): ``count`` slow/restore cycles of ``period`` seconds,
    degraded to ``rate_factor`` for ``duty`` of every cycle."""
    events = list(trace.events)
    for i in range(count):
        t0 = start + i * period
        events.append(SlowNodeEvent(time=t0, node=node, rate_factor=rate_factor))
        events.append(
            SlowNodeEvent(time=t0 + period * duty, node=node, rate_factor=1.0)
        )
    return replace(trace, events=tuple(sorted(events, key=lambda e: e.time)))


def load_surge(
    trace: ScenarioTrace, time: float, duration: float, multiplier: float
) -> ScenarioTrace:
    surges = sorted(
        list(trace.surges) + [LoadSurge(time, duration, multiplier)],
        key=lambda s: s.time,
    )
    return replace(trace, surges=tuple(surges))


# -- seeded random generation -------------------------------------------------


@dataclass(frozen=True)
class ScenarioConfig:
    """Knobs for ``generate_scenario``. Rates are per second of simulated
    time; all randomness derives from ``seed``."""

    duration: float
    num_nodes: int
    nodes_per_rack: int = 8
    # the hard tolerance bound: concurrently-affected nodes never exceed
    # this (pass the code's n - k for always-recoverable traces)
    max_concurrent_failures: int = 2
    crash_rate: float = 1.0  # background node crashes (1/mean interarrival)
    mean_downtime: float = 0.5  # exponential transient downtime
    transient_fraction: float = 0.75  # rest are capacity losses
    # Crash inter-arrival law. "exponential" (default) is the Poisson
    # assumption; "weibull" draws Weibull(interarrival_shape) gaps —
    # shape < 1 gives the bursty, heavy-tailed churn the warehouse-
    # cluster failure study measures (Rashmi et al., 1309.0186: most
    # failures arrive in correlated bursts, not as a memoryless
    # process) — and "trace" resamples the empirical gap samples in
    # ``interarrival_samples`` (seconds). All three laws preserve
    # ``crash_rate`` as 1/mean, so tolerance-bound admission pressure is
    # comparable across laws; only the clustering changes.
    interarrival: str = "exponential"  # "exponential" | "weibull" | "trace"
    interarrival_shape: float = 0.7  # Weibull shape (k < 1 = bursty)
    interarrival_samples: tuple = ()  # empirical gaps for "trace"
    rack_burst_times: tuple = ()  # correlated bursts at these times
    rack_downtime: float = 0.5
    flap_nodes: int = 0
    flap_period: float = 0.2
    flap_count: int = 3
    # gray failures: silent corruption + fail-slow (Poisson, per second)
    corruption_rate: float = 0.0
    corruption_blocks: int = 2  # blocks damaged per corruption event
    slow_rate: float = 0.0
    slow_factor: float = 0.1  # degraded bandwidth multiplier
    mean_slow_time: float = 0.5  # exponential slow-episode length
    surges: tuple = ()  # LoadSurge passthrough
    seed: int = 0


def _crash_gap(rng: np.random.Generator, cfg: ScenarioConfig) -> float:
    """One crash inter-arrival draw under the configured law, with mean
    1/crash_rate in every mode (the Weibull scale is mean/Γ(1 + 1/k), so
    changing the law changes burstiness, not total churn)."""
    mean = 1.0 / cfg.crash_rate
    if cfg.interarrival == "exponential":
        return float(rng.exponential(mean))
    if cfg.interarrival == "weibull":
        shape = cfg.interarrival_shape
        if shape <= 0:
            raise ValueError(f"interarrival_shape must be > 0, got {shape}")
        scale = mean / math.gamma(1.0 + 1.0 / shape)
        return float(scale * rng.weibull(shape))
    if cfg.interarrival == "trace":
        samples = np.asarray(cfg.interarrival_samples, dtype=np.float64)
        if samples.size == 0 or np.any(samples <= 0):
            raise ValueError(
                "interarrival='trace' needs positive interarrival_samples"
            )
        # resample the empirical distribution, rescaled to the configured
        # mean so crash_rate stays the single churn knob
        return float(rng.choice(samples) * (mean / samples.mean()))
    raise ValueError(
        f"unknown interarrival law {cfg.interarrival!r} "
        "(want 'exponential', 'weibull' or 'trace')"
    )


def generate_scenario(cfg: ScenarioConfig) -> ScenarioTrace:
    """Draw a random trace and run the bounded admission pass.

    Candidate events come from three independent processes — background
    Poisson crashes (transient or permanent), rack bursts at the
    configured times, and flapping nodes — then a single deterministic
    sweep admits them in time order, dropping any down-event that would
    push the concurrently-affected set past ``max_concurrent_failures``
    (a dropped crash also drops its paired recovery; rack bursts are
    trimmed to the largest correlated subset that fits)."""
    rng = np.random.default_rng(cfg.seed)
    # candidate pairs: (down_time, node, kind, recover_time | None)
    candidates: list[tuple[float, int, str, float | None]] = []

    t = 0.0
    while cfg.crash_rate > 0:
        t += _crash_gap(rng, cfg)
        if t >= cfg.duration:
            break
        node = int(rng.integers(cfg.num_nodes))
        if rng.random() < cfg.transient_fraction:
            down = float(rng.exponential(cfg.mean_downtime))
            candidates.append((t, node, "crash", t + down))
        else:
            candidates.append((t, node, "capacity_loss", None))

    t = 0.0
    while cfg.corruption_rate > 0:
        t += float(rng.exponential(1.0 / cfg.corruption_rate))
        if t >= cfg.duration:
            break
        candidates.append((t, int(rng.integers(cfg.num_nodes)), "corrupt", None))

    t = 0.0
    while cfg.slow_rate > 0:
        t += float(rng.exponential(1.0 / cfg.slow_rate))
        if t >= cfg.duration:
            break
        slow_for = float(rng.exponential(cfg.mean_slow_time))
        candidates.append((t, int(rng.integers(cfg.num_nodes)), "slow", t + slow_for))

    base = ScenarioTrace(
        num_nodes=cfg.num_nodes, nodes_per_rack=cfg.nodes_per_rack, seed=cfg.seed
    )
    num_racks = max(1, (cfg.num_nodes + cfg.nodes_per_rack - 1) // cfg.nodes_per_rack)
    for bt in cfg.rack_burst_times:
        rack = int(rng.integers(num_racks))
        for n in base.rack_nodes(rack):
            candidates.append((float(bt), n, "crash", float(bt) + cfg.rack_downtime))

    flappers = rng.choice(
        cfg.num_nodes, size=min(cfg.flap_nodes, cfg.num_nodes), replace=False
    )
    for node in flappers:
        start = float(rng.uniform(0.0, max(cfg.duration - cfg.flap_count * cfg.flap_period, 0.0)))
        for i in range(cfg.flap_count):
            t0 = start + i * cfg.flap_period
            candidates.append((t0, int(node), "crash", t0 + cfg.flap_period * 0.5))

    # admission pass: stable time order (ties broken by node then kind so
    # the pass is deterministic across runs)
    candidates.sort(key=lambda c: (c[0], c[1], c[2]))
    affected: dict[int, float] = {}  # node -> release time (inf: permanent)
    events: list[ClusterEvent] = []
    for down_t, node, kind, recover_t in candidates:
        if kind == "slow":
            # fail-slow never consumes the erasure budget: the bytes are
            # intact and every transfer still completes — admit freely
            events.append(
                SlowNodeEvent(time=down_t, node=node, rate_factor=cfg.slow_factor)
            )
            events.append(SlowNodeEvent(time=recover_t, node=node, rate_factor=1.0))
            continue
        # STRICT release: a node recovering at exactly down_t still
        # counts as overlapping, so the bound holds under any
        # same-instant event ordering downstream
        for n, rel in list(affected.items()):
            if rel < down_t:
                del affected[n]
        if node in affected:
            continue  # already down/lost — flap cycle overlapping a crash
        if len(affected) >= cfg.max_concurrent_failures:
            continue  # would exceed tolerance: drop (rack bursts trim here)
        if kind == "capacity_loss":
            events.append(CapacityLossEvent(time=down_t, node=node))
            affected[node] = float("inf")
        elif kind == "corrupt":
            # corrupt blocks are erasures once detected; like capacity
            # loss, conservatively hold the node's budget slot forever
            events.append(
                CorruptionEvent(
                    time=down_t, node=node, count=cfg.corruption_blocks
                )
            )
            affected[node] = float("inf")
        else:
            events.append(FailureEvent(time=down_t, node=node))
            events.append(NodeRecoverEvent(time=recover_t, node=node))
            affected[node] = recover_t
    events.sort(key=lambda e: (e.time, e.node))
    return replace(
        base, events=tuple(events), surges=tuple(sorted(cfg.surges, key=lambda s: s.time))
    )


# -- surge-aware workload synthesis ------------------------------------------


def scenario_requests(
    wl: WorkloadConfig,
    trace: ScenarioTrace,
    tenant: str = DEFAULT_TENANT,
) -> list[Request]:
    """Poisson/Zipf GET/PUT trace whose arrival rate follows the trace's
    load surges: rate(t) = arrival_rate x trace.rate_multiplier(t).
    Implemented by thinning a homogeneous process at the peak rate, so
    the stream is reproducible from the workload seed and adding or
    removing a surge only re-times arrivals inside its own window."""
    # The thinning envelope must dominate rate(t) everywhere. Overlapping
    # surges MULTIPLY, and the product is piecewise-constant, changing
    # only at surge boundaries — it can rise at a START (a >1 surge
    # begins) or at an END (a <1 throttle window expires), so the true
    # peak is the max over every boundary instant. active_at is
    # half-open, so evaluating AT an end instant sees the surge gone.
    boundaries = [s.time for s in trace.surges] + [
        s.time + s.duration for s in trace.surges
    ]
    peak = wl.arrival_rate * max(
        [1.0] + [trace.rate_multiplier(t) for t in boundaries]
    )
    rng = np.random.default_rng(wl.seed)
    # churn kinds (delete / small-put) ride a SEPARATE derived stream:
    # drawing them from ``rng`` would shift every draw after the first
    # candidate and re-time the whole preexisting trace
    churn_rng = np.random.default_rng((wl.seed ^ 0x5EA1C0DE) % (2**31))
    perm = rng.permutation(wl.num_objects)
    probs = zipf_probs(wl.num_objects, wl.zipf_s)
    out: list[Request] = []
    t = 0.0
    while len(out) < wl.num_requests:
        t += float(rng.exponential(1.0 / peak))
        accept = float(rng.random())  # drawn unconditionally: stream stability
        rank = int(rng.choice(wl.num_objects, p=probs))
        is_put = float(rng.random()) < wl.put_fraction
        # unconditional for the same stream-stability reason as accept
        is_delete = float(churn_rng.random()) < wl.delete_fraction
        is_small = float(churn_rng.random()) < wl.small_put_fraction
        if accept >= wl.arrival_rate * trace.rate_multiplier(t) / peak:
            continue
        kind = "delete" if is_delete else ("put" if is_put else "get")
        out.append(
            Request(
                time=t,
                object_id=int(perm[rank]),
                kind=kind,
                tenant=tenant,
                nbytes=(
                    int(wl.small_put_bytes)
                    if (kind == "put" and is_small)
                    else None
                ),
            )
        )
    return out
