"""Generic systematic linear erasure codes over GF(2^8).

A code is described by its (n, k) generator matrix ``gen`` (numpy uint8,
shape (n, k)): stored block i is ``c_i = XOR_j gen[i, j] * o_j`` where
``o`` is the k-symbol (k-block) message. Systematic codes have
``gen[:k] == I_k``.

Erasure decoding = picking k available rows whose submatrix is invertible
and solving. This module provides the host-side solver machinery shared by
RS / LRC / product-code decoders, plus rank-based decodability checks used
by the Monte-Carlo analysis.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.coding import gf256


def rank_gf256(m: np.ndarray) -> int:
    """Rank of a matrix over GF(2^8) via Gaussian elimination (host-side)."""
    a = m.astype(np.uint8).copy()
    rows, cols = a.shape
    rank = 0
    for col in range(cols):
        pivot = None
        for row in range(rank, rows):
            if a[row, col] != 0:
                pivot = row
                break
        if pivot is None:
            continue
        if pivot != rank:
            a[[rank, pivot]] = a[[pivot, rank]]
        pinv = gf256._INV_NP[a[rank, col]]
        a[rank] = gf256._MUL_NP[pinv, a[rank]]
        for row in range(rows):
            if row != rank and a[row, col] != 0:
                a[row] ^= gf256._MUL_NP[a[row, col], a[rank]]
        rank += 1
        if rank == rows:
            break
    return rank


@dataclass(frozen=True)
class LinearCode:
    """An (n, k) linear code over GF(2^8) given by its generator matrix."""

    gen: np.ndarray  # (n, k) uint8

    @property
    def n(self) -> int:
        return self.gen.shape[0]

    @property
    def k(self) -> int:
        return self.gen.shape[1]

    def encode(self, data: jnp.ndarray) -> jnp.ndarray:
        """data: (..., k, q) uint8 -> (..., n, q) codeword blocks."""
        gen = jnp.asarray(self.gen)  # (n, k)
        return gf256.matmul(gen, data)  # (..., n, q) via broadcasting

    def decodable(self, available: np.ndarray) -> bool:
        """Can the k message blocks be recovered from ``available`` rows?"""
        avail_rows = self.gen[np.asarray(available, dtype=np.int64)]
        if avail_rows.shape[0] < self.k:
            return False
        return rank_gf256(avail_rows) == self.k

    def decode_matrix(self, available: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Pick k independent available rows; return (row_ids, inverse).

        ``inverse`` (k, k) satisfies: message = inverse @ c[row_ids].
        Raises ValueError if not decodable.
        """
        available = np.asarray(available, dtype=np.int64)
        chosen: list[int] = []
        basis = np.zeros((0, self.k), dtype=np.uint8)
        for idx in available:
            cand = np.concatenate([basis, self.gen[idx : idx + 1]], axis=0)
            if rank_gf256(cand) > basis.shape[0]:
                basis = cand
                chosen.append(int(idx))
                if len(chosen) == self.k:
                    break
        if len(chosen) < self.k:
            raise ValueError(
                f"undecodable: only rank {len(chosen)} from {len(available)} rows"
            )
        sub = self.gen[np.asarray(chosen)]
        return np.asarray(chosen), gf256.np_inv_matrix(sub)

    def decode(self, available: np.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
        """Recover message blocks (k, q) from available codeword blocks.

        ``blocks``: (len(available), q) rows aligned with ``available``.
        """
        available = np.asarray(available, dtype=np.int64)
        row_ids, inverse = self.decode_matrix(available)
        pos = {int(a): i for i, a in enumerate(available)}
        sel = jnp.asarray([pos[int(r)] for r in row_ids])
        return gf256.matmul(jnp.asarray(inverse), blocks[sel])

    def repair_matrix(
        self, available: np.ndarray, missing: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Return (row_ids, coeffs) s.t. c[missing] = coeffs @ c[row_ids]."""
        row_ids, inverse = self.decode_matrix(available)
        miss_gen = self.gen[np.asarray(missing, dtype=np.int64)]  # (r, k)
        coeffs = gf256.np_matmul(miss_gen, inverse)  # (r, k)
        return row_ids, coeffs

    def repair(
        self, available: np.ndarray, blocks: jnp.ndarray, missing: np.ndarray
    ) -> jnp.ndarray:
        """Reconstruct the ``missing`` codeword blocks: (r, q)."""
        available = np.asarray(available, dtype=np.int64)
        row_ids, coeffs = self.repair_matrix(available, missing)
        pos = {int(a): i for i, a in enumerate(available)}
        sel = jnp.asarray([pos[int(r)] for r in row_ids])
        return gf256.matmul(jnp.asarray(coeffs), blocks[sel])
