from repro.coding import gf256, linear, lrc, rs, spc
from repro.coding.linear import LinearCode
from repro.coding.lrc import LRC, make_lrc
from repro.coding.rs import make_rs
from repro.coding.spc import make_spc

__all__ = [
    "gf256",
    "linear",
    "lrc",
    "rs",
    "spc",
    "LinearCode",
    "LRC",
    "make_lrc",
    "make_rs",
    "make_spc",
]
