"""Systematic (n, k) Reed-Solomon code over GF(2^8).

Construction: start from the n x k Vandermonde matrix V[i, j] = alpha_i^j
with distinct evaluation points alpha_i (0..n-1). Every k x k submatrix of
V is invertible, so V generates an MDS code. Systematize by right-
multiplying with (V[:k])^{-1}: gen = V @ inv(V[:k]) = [I_k; P]. Row
operations preserve the any-k-rows-invertible property, so the systematic
code is MDS: any k of the n blocks recover the object.

The paper's §4 uses a [I_k, H] Vandermonde-parity form; for H to be MDS
one needs the systematized construction (raw Vandermonde parity is not MDS
for all (n, k)). This is noted in DESIGN.md and matches what production RS
implementations (ISA-L, jerasure) do.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.coding import gf256
from repro.coding.linear import LinearCode


@functools.lru_cache(maxsize=None)
def generator_matrix(n: int, k: int) -> np.ndarray:
    """Systematic MDS generator matrix (n, k), gen[:k] == I."""
    if not (0 < k <= n <= 256):
        raise ValueError(f"invalid RS parameters (n={n}, k={k})")
    vand = np.zeros((n, k), dtype=np.uint8)
    for i in range(n):
        for j in range(k):
            vand[i, j] = gf256.pow_(i + 1, j)  # alpha_i = i+1 (nonzero, distinct)
    top_inv = gf256.np_inv_matrix(vand[:k])
    gen = gf256.np_matmul(vand, top_inv)
    assert np.array_equal(gen[:k], np.eye(k, dtype=np.uint8))
    return gen


@functools.lru_cache(maxsize=None)
def make_rs(n: int, k: int) -> LinearCode:
    return LinearCode(gen=generator_matrix(n, k))


def parity_matrix(n: int, k: int) -> np.ndarray:
    """The (m, k) parity part P: parities = P @ data."""
    return generator_matrix(n, k)[k:]
