"""GF(2^8) arithmetic, vectorized for JAX.

The field is F_{2^8} with the standard AES/Rijndael reduction polynomial
x^8 + x^4 + x^3 + x + 1 (0x11B). Elements are uint8. Addition is XOR.
Multiplication uses log/exp tables generated once at import time with
numpy (host-side), then captured as jnp constants inside jitted code.

Conventions used throughout the codebase:
  * ``LOG[0]`` is never read on the fast path — multiplication masks zero
    operands explicitly.
  * ``EXP`` is doubled (length 510) so ``EXP[LOG[a] + LOG[b]]`` needs no
    modular reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Table generation (host-side, numpy)
# ---------------------------------------------------------------------------

_POLY = 0x11B  # x^8 + x^4 + x^3 + x + 1
_GENERATOR = 0x03  # 3 is a primitive element for 0x11B


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    exp = np.zeros(510, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        # multiply x by the generator (0x03 = x + 1): x*3 = (x<<1) ^ x
        x = (x << 1) ^ x
        if x & 0x100:
            x ^= _POLY
    exp[255:510] = exp[0:255]
    return exp, log


_EXP_NP, _LOG_NP = _build_tables()

# Full 256x256 multiplication table (64 KiB) — used by the reference paths
# and for building per-matrix lookup tables. Host-side only.
_MUL_NP = np.zeros((256, 256), dtype=np.uint8)
_nz = np.arange(1, 256)
_MUL_NP[1:, 1:] = _EXP_NP[(_LOG_NP[_nz][:, None] + _LOG_NP[_nz][None, :])]

_INV_NP = np.zeros(256, dtype=np.uint8)
_INV_NP[1:] = _EXP_NP[255 - _LOG_NP[_nz]]


# ---------------------------------------------------------------------------
# JAX-facing API
# ---------------------------------------------------------------------------

def exp_table() -> jnp.ndarray:
    return jnp.asarray(_EXP_NP)


def log_table() -> jnp.ndarray:
    return jnp.asarray(_LOG_NP)


def add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Field addition == XOR (also subtraction)."""
    return jnp.bitwise_xor(a, b)


def mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise field multiplication via log/exp tables."""
    a = a.astype(jnp.uint8)
    b = b.astype(jnp.uint8)
    la = jnp.asarray(_LOG_NP)[a.astype(jnp.int32)]
    lb = jnp.asarray(_LOG_NP)[b.astype(jnp.int32)]
    prod = jnp.asarray(_EXP_NP)[la + lb]
    zero = (a == 0) | (b == 0)
    return jnp.where(zero, jnp.uint8(0), prod)


def inv(a: jnp.ndarray) -> jnp.ndarray:
    """Elementwise multiplicative inverse. inv(0) := 0 (never used)."""
    return jnp.asarray(_INV_NP)[a.astype(jnp.int32)]


def pow_(a: int, e: int) -> int:
    """Host-side scalar power (for generator-matrix construction)."""
    if e == 0:
        return 1
    if a == 0:
        return 0
    return int(_EXP_NP[(int(_LOG_NP[a]) * e) % 255])


def mul_scalar_np(a: int, b: int) -> int:
    return int(_MUL_NP[a, b])


@functools.partial(jax.jit, static_argnames=())
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """GF(2^8) matrix multiply: C[i,j] = XOR_k a[i,k]*b[k,j].

    a: (M, K) uint8, b: (..., K, N) uint8 -> (..., M, N) uint8 (batched
    over b's leading dims). Pure-jnp implementation (the Pallas kernel in
    repro.kernels is the TPU-optimized version; this is the oracle / CPU
    fallback).
    """
    a = a.astype(jnp.uint8)
    b = b.astype(jnp.uint8)
    # (M, K, 1) x (..., 1, K, N) -> (..., M, K, N), XOR-reduce over K
    prod = mul(a[:, :, None], b[..., None, :, :])
    return _xor_reduce(prod, axis=-2)


def _xor_reduce(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    return jax.lax.reduce(
        x, jnp.uint8(0), jax.lax.bitwise_xor, dimensions=(axis % x.ndim,)
    )


def xor_reduce(x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """XOR-reduce along ``axis`` (vertical-parity primitive)."""
    return _xor_reduce(x, axis)


# ---------------------------------------------------------------------------
# Host-side matrix helpers over GF(2^8) (numpy; used for generator matrices
# and erasure-decoding matrix inversion — all small: n, k <= a few dozen)
# ---------------------------------------------------------------------------

def np_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Host-side GF matmul for small matrices: (M,K) @ (K,N)."""
    a = a.astype(np.uint8)
    b = b.astype(np.uint8)
    out = np.zeros((a.shape[0], b.shape[1]), dtype=np.uint8)
    for k in range(a.shape[1]):
        out ^= _MUL_NP[a[:, k][:, None], b[k, :][None, :]]
    return out


def np_inv_matrix(m: np.ndarray) -> np.ndarray:
    """Host-side Gauss-Jordan inversion over GF(2^8). Raises if singular."""
    m = m.astype(np.uint8).copy()
    n = m.shape[0]
    assert m.shape == (n, n)
    aug = np.concatenate([m, np.eye(n, dtype=np.uint8)], axis=1)
    for col in range(n):
        pivot = None
        for row in range(col, n):
            if aug[row, col] != 0:
                pivot = row
                break
        if pivot is None:
            raise np.linalg.LinAlgError("singular GF(256) matrix")
        if pivot != col:
            aug[[col, pivot]] = aug[[pivot, col]]
        pinv = _INV_NP[aug[col, col]]
        aug[col] = _MUL_NP[pinv, aug[col]]
        for row in range(n):
            if row != col and aug[row, col] != 0:
                aug[row] ^= _MUL_NP[aug[row, col], aug[col]]
    return aug[:, n:]
