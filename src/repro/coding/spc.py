"""(t+1, t) single parity check code — the paper's *vertical* code.

Over the binary extension field the parity symbol is the XOR of the t
message symbols; any single erasure is repaired by XORing the surviving t.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.coding import gf256
from repro.coding.linear import LinearCode


@functools.lru_cache(maxsize=None)
def make_spc(t: int) -> LinearCode:
    gen = np.concatenate(
        [np.eye(t, dtype=np.uint8), np.ones((1, t), dtype=np.uint8)], axis=0
    )
    return LinearCode(gen=gen)


def parity(blocks: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """XOR parity over ``axis`` of a stack of t blocks."""
    return gf256.xor_reduce(blocks, axis=axis)


def repair(surviving: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Repair the single missing symbol: XOR of the surviving t blocks
    (which may include the parity row itself)."""
    return gf256.xor_reduce(surviving, axis=axis)
