"""(n, k) Local Reconstruction Code per the paper's §3.3 (Azure LRC).

Composition of (i) a systematic global (n-2, k) MDS code contributing
m-2 = n-k-2 global parities and (ii) two local (k/2+1, k/2) single-parity
codes, one per half of the object.

Codeword layout (paper Fig. 2): [o_1, o_2, p_1, p_2, p_g]
  index 0 .. k/2-1   : first data half  (local group 0)
  index k/2 .. k-1   : second data half (local group 1)
  index k            : p_1 (XOR of group 0)
  index k+1          : p_2 (XOR of group 1)
  index k+2 .. n-1   : global parities
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from repro.coding import rs
from repro.coding.linear import LinearCode


@functools.lru_cache(maxsize=None)
def generator_matrix(n: int, k: int) -> np.ndarray:
    if k % 2 != 0:
        raise ValueError("LRC requires even k")
    if n < k + 2:
        raise ValueError("LRC requires n >= k + 2")
    half = k // 2
    gen = np.zeros((n, k), dtype=np.uint8)
    gen[:k] = np.eye(k, dtype=np.uint8)
    gen[k, :half] = 1  # p_1
    gen[k + 1, half:] = 1  # p_2
    if n > k + 2:
        gen[k + 2 :] = rs.parity_matrix(n - 2, k)  # global parities
    return gen


@functools.lru_cache(maxsize=None)
def make_lrc(n: int, k: int) -> "LRC":
    return LRC(gen=generator_matrix(n, k))


@dataclass(frozen=True)
class LRC(LinearCode):
    """LinearCode plus LRC-specific locality metadata and repair planning."""

    def local_group(self, i: int) -> list[int] | None:
        """Blocks participating in i's local parity equation (incl. i),
        or None for global parities (no locality)."""
        half = self.k // 2
        if i < half or i == self.k:
            return list(range(half)) + [self.k]
        if i < self.k or i == self.k + 1:
            return list(range(half, self.k)) + [self.k + 1]
        return None

    def repair_plan(
        self, failed: set[int]
    ) -> list[tuple[str, list[int], list[int]]] | None:
        """Greedy local-first repair plan.

        Returns a list of steps ``(kind, sources, repaired)`` where kind is
        'local' (XOR of k/2 sources) or 'global' (full decode from k
        sources), or None if the pattern is unrecoverable.
        """
        failed = set(failed)
        steps: list[tuple[str, list[int], list[int]]] = []
        while failed:
            progressed = False
            for i in sorted(failed):
                grp = self.local_group(i)
                if grp is None:
                    continue
                missing_in_grp = [g for g in grp if g in failed]
                if len(missing_in_grp) == 1:
                    sources = [g for g in grp if g not in failed]
                    steps.append(("local", sources, [i]))
                    failed.discard(i)
                    progressed = True
                    break
            if progressed:
                continue
            # fall back to one global decode repairing everything at once
            available = [i for i in range(self.n) if i not in failed]
            if not self.decodable(np.asarray(available)):
                return None
            row_ids, _ = self.decode_matrix(np.asarray(available))
            steps.append(("global", [int(r) for r in row_ids], sorted(failed)))
            failed = set()
        return steps

    @staticmethod
    def plan_traffic(steps: list[tuple[str, list[int], list[int]]]) -> int:
        """Number of block transfers implied by a repair plan."""
        return sum(len(src) for _, src, _ in steps)


def avg_single_repair_cost(n: int, k: int) -> float:
    """Paper §3.3: (2kn - k^2 - 2k) / 2n blocks on average."""
    return (2 * k * n - k * k - 2 * k) / (2 * n)
