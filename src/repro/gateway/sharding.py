"""Sharded multi-gateway front door: N ``ObjectGateway`` shards over
one ``BlockStore``/``NetSimulator`` fabric and one ``MetadataPlane``.

This is the horizontal counterpart to the decode megakernel: instead of
one bigger launch, N serving processes. Each shard owns a private data
path — LRU/negative cache, decode/encode engine pool, coalescer,
planner, repair fixer, client-NIC stripe, hedge ledger — while the
namespace (stripe maps, ground truth, tombstones, fault bookkeeping)
lives on the shared metadata plane. Requests route by consistent hash
of the object id (``MetadataPlane.directory``); per-shard SLO admission
runs inside each shard's own flush exactly as standalone.

The merged event loop preserves the single-gateway serve() semantics
over N shards: requests coalesce into per-shard homogeneous batch
windows; cluster events, due repairs and scrub ticks interleave with
the request stream in global time order, with every open window flushed
before an event applies so planning sees pre-event state. A cluster
event is applied ONCE (store/fabric mutations are global; negative-
cache fan-out goes through the plane) and its repair trigger enqueues
on EVERY live shard — each shard repairs only the groups the directory
hashes to it, so N shards split the repair backlog.

Whole-shard death (``ShardFailEvent``) is consumed here, mid-run: the
dead shard's open window drains, its ring points leave the directory
(only ITS ranges move — survivors keep every object they already
owned), its cache leaves the coherence fan-out, and its pending repair
work is redistributed. Storage is untouched, so failover loses zero
blocks; subsequent requests for the dead shard's namespace route to
survivors.

``serve`` returns one ``GatewayReport`` merged across shards
(``GatewayReport.merged``), so existing report consumers and bench
blocks read a sharded run through the same pinned keys;
``last_reports`` keeps the per-shard reports for scaling analysis.
"""

from __future__ import annotations

from repro.core.product_code import CoreCode
from repro.gateway.gateway import GatewayConfig, GatewayReport, ObjectGateway
from repro.gateway.metadata import MetadataPlane
from repro.gateway.workload import Request, ShardFailEvent
from repro.storage.netmodel import ClusterProfile

import numpy as np


class ShardedGateway:
    """N-shard gateway cluster behind one serve() front door."""

    def __init__(
        self,
        code: CoreCode,
        profile: ClusterProfile,
        num_nodes: int,
        num_shards: int,
        config: GatewayConfig | None = None,
        vnodes: int = 64,
    ):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.config = config or GatewayConfig()
        self.meta = MetadataPlane(shard_ids=range(num_shards), vnodes=vnodes)
        # shard 0 constructs the shared store + fabric from the config;
        # the rest attach to them
        first = ObjectGateway(
            code, profile, num_nodes, self.config, meta=self.meta, shard_id=0
        )
        self.store = first.store
        self.sim = first.sim
        self.shards: dict[int, ObjectGateway] = {0: first}
        for sid in range(1, num_shards):
            self.shards[sid] = ObjectGateway(
                code,
                profile,
                num_nodes,
                self.config,
                store=self.store,
                sim=self.sim,
                meta=self.meta,
                shard_id=sid,
            )
        self.dead_shards: set[int] = set()
        # cluster-wide scrub schedule (one scrubber cluster-wide — the
        # lowest live shard runs the tick; running N would N-plicate
        # maintenance reads over one shared store)
        self._scrub_next: float | None = self.config.scrub_interval
        self.last_reports: dict[int, GatewayReport] = {}

    # -- topology ---------------------------------------------------------------
    def live_shards(self) -> list[int]:
        return [sid for sid in self.shards if sid not in self.dead_shards]

    def shard_of(self, object_id: int) -> int:
        """Which live shard serves this object right now."""
        return self.meta.shard_for(object_id)

    def _lead(self) -> ObjectGateway:
        return self.shards[min(self.live_shards())]

    # -- namespace load ---------------------------------------------------------
    def load_objects(self, objects: np.ndarray) -> None:
        """Bulk-load the namespace (shared: any shard can do it)."""
        self._lead().load_objects(objects)

    # -- failover ---------------------------------------------------------------
    def _fail_shard(self, sid: int, at: float, report: GatewayReport) -> None:
        if sid not in self.shards:
            raise ValueError(f"ShardFailEvent for unknown shard {sid}")
        if sid in self.dead_shards:
            return
        dead = self.shards[sid]
        self.dead_shards.add(sid)
        if not self.live_shards():
            raise RuntimeError("ShardFailEvent killed the last live shard")
        # remove ONLY the dead shard's ring points: its ranges fail over
        # to survivors, every other object keeps its owner
        self.meta.directory.remove_shard(sid)
        # its cache leaves the coherence fan-out (nothing to keep fresh)
        self.meta.unregister_cache(dead.cache)
        # pending repair work it owned re-hashes to survivors — hand its
        # due-times to every survivor; a shard that ends up owning none
        # of the missing groups just no-ops the run
        if dead._repair_queue:
            for osid in self.live_shards():
                q = self.shards[osid]._repair_queue
                for entry in dead._repair_queue:
                    if entry not in q:
                        q.append(entry)
                q.sort()
            dead._repair_queue.clear()
        report.metrics.counter("shard_failovers").inc()
        report.metrics.gauge("live_shards").set(len(self.live_shards()))

    # -- serving ----------------------------------------------------------------
    def serve(
        self,
        requests: list[Request],
        failures: list | None = None,
    ) -> GatewayReport:
        """Route and serve a request trace across the live shards.
        Accepts the same event mix as ``ObjectGateway.serve`` plus
        ``ShardFailEvent``. Returns the cross-shard merged report;
        per-shard reports land in ``last_reports``."""
        cfg = self.config
        reports = {
            sid: GatewayReport(record_requests=cfg.record_requests)
            for sid in self.shards
        }
        events = sorted(failures or [], key=lambda f: f.time)
        reqs = sorted(requests, key=lambda r: r.time)

        batches: dict[int, list[Request]] = {sid: [] for sid in self.shards}
        deadlines: dict[int, float | None] = {sid: None for sid in self.shards}
        kinds: dict[int, str | None] = {sid: None for sid in self.shards}
        fi = 0

        def flush_shard(sid: int) -> None:
            batch = batches[sid]
            if batch:
                gw = self.shards[sid]
                if kinds[sid] == "put":
                    gw._flush_puts(batch, reports[sid])
                else:
                    gw._flush(batch, reports[sid])
            batches[sid], deadlines[sid], kinds[sid] = [], None, None

        def flush_all() -> None:
            for sid in self.live_shards():
                flush_shard(sid)

        def boundary_events(now: float | None) -> None:
            """Apply cluster / repair / scrub work due before ``now``
            (None => all remaining), in global time order across every
            live shard — the merged analogue of the single gateway's
            boundary loop."""
            nonlocal fi
            while True:
                next_evt = events[fi].time if fi < len(events) else None
                rep_sid, next_rep = None, None
                for sid in self.live_shards():
                    q = self.shards[sid]._repair_queue
                    if q and (next_rep is None or q[0][0] < next_rep):
                        rep_sid, next_rep = sid, q[0][0]
                next_scrub = self._scrub_next if now is not None else None
                cands = [
                    t for t in (next_evt, next_rep, next_scrub) if t is not None
                ]
                if not cands:
                    return
                t_evt = min(cands)
                if now is not None and t_evt > now:
                    return
                flush_all()
                if next_evt is not None and t_evt == next_evt:
                    evt = events[fi]
                    fi += 1
                    if isinstance(evt, ShardFailEvent):
                        lead = min(self.live_shards())
                        self._fail_shard(evt.shard, evt.time, reports[lead])
                        continue
                    # apply ONCE via the lead shard: store/fabric effects
                    # are global, cache effects fan out through the plane
                    lead = min(self.live_shards())
                    wants_repair = self.shards[lead]._apply_cluster_event(
                        evt, reports[lead]
                    )
                    if wants_repair and cfg.repair_on_failure:
                        # every live shard gets the trigger; ownership
                        # filtering inside _background_repair splits the
                        # actual work by group hash
                        for sid in self.live_shards():
                            q = self.shards[sid]._repair_queue
                            q.append((evt.time + cfg.repair_delay, evt.node))
                            q.sort()
                elif next_rep is not None and t_evt == next_rep:
                    gw = self.shards[rep_sid]
                    t_rep, _node = gw._repair_queue.pop(0)
                    if gw._background_repair(t_rep, reports[rep_sid]):
                        gw._repair_queue.append(
                            (t_rep + cfg.repair_respacing, -1)
                        )
                        gw._repair_queue.sort()
                else:
                    self._scrub_next = t_evt + cfg.scrub_interval
                    lead = min(self.live_shards())
                    self.shards[lead]._run_scrub(t_evt, reports[lead])

        for req in reqs:
            boundary_events(req.time)
            sid = self.meta.shard_for(req.object_id)
            if req.kind == "delete":
                # namespace barrier: every shard's open window must see
                # pre-delete state (any shard may hold reads planned
                # against this object's group)
                flush_all()
                gw = self.shards[sid]
                reports[sid].add_record(gw._handle_delete(req, reports[sid]))
                continue
            kind = "put" if req.kind == "put" else "get"
            # close any shard's window whose deadline passed — keeps
            # fabric submissions near time order across shards, like the
            # single gateway's one-window deadline does
            for osid in self.live_shards():
                if batches[osid] and req.time > deadlines[osid]:
                    flush_shard(osid)
            if batches[sid] and kinds[sid] != kind:
                flush_shard(sid)
            if not batches[sid]:
                deadlines[sid] = req.time + cfg.batch_window
                kinds[sid] = kind
            batches[sid].append(req)
        flush_all()
        boundary_events(None)
        for sid in self.live_shards():
            self.shards[sid]._finalize_report(reports[sid])
        self.last_reports = dict(reports)
        return GatewayReport.merged(list(reports.values()))

    # -- drains / audits (cluster-wide, over the shared namespace) --------------
    def seal_flush(self, at: float = 0.0) -> int:
        """Drain every live shard's open seal buffer; returns total
        groups sealed."""
        return sum(
            self.shards[sid].seal_flush(at) for sid in self.live_shards()
        )

    def audit_durability(self) -> dict:
        """Namespace-wide durability audit (shared store + maps, so any
        live shard computes the same answer)."""
        return self._lead().audit_durability()

    def audit_parity(self) -> dict:
        return self._lead().audit_parity()
