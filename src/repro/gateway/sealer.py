"""Stripe sealing: pack many small tenant objects into one codeword row.

The warehouse-cluster study (Rashmi et al., 1309.0186) shows real object
traffic is dominated by objects far smaller than a stripe — encoding
each one as its own (k, q) row would waste almost the whole codeword on
zero padding and multiply parity overhead per byte. The sealer is the
gateway's packing buffer: small PUT payloads append into an open row of
``k x q`` bytes (journaled for durability the moment they arrive — the
append itself is the PUT's ack point); when the row fills, it SEALS —
becoming one immutable row object the gateway encodes through the same
ragged ENCODE megakernel window as full-row overwrites and places like
any other group row. Extents never span rows (a torn extent would need
two stripes decoded to read one object), so a payload that does not fit
the remaining space seals the open row early with a zero-padded tail —
zero bytes are identity under both codes, and the audit's ground truth
zero-fills the same way.

Each appended extent keeps a sha256 of its payload bytes: the end-to-end
consistency audit (``ObjectGateway.audit_sealed_stripes``) re-reads
every sealed extent through a store-only DEGRADED decode after fault
traces and compares digests — byte-identical or it counts as wrong.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Extent:
    """One small object's location inside a sealed (or open) row."""

    small_id: tuple  # caller's identity for the small object
    row_seq: int  # global sealed-row sequence number
    offset: int  # byte offset into the row's flat k*q payload
    length: int
    digest: str  # sha256 of the payload at append time
    tenant: str


class StripeSealer:
    """Packs small payloads into flat ``k*q``-byte rows, sealing a row
    when it fills (or early, when the next payload would span rows).
    ``append`` returns the rows sealed by that append — zero or one —
    as ``(row_seq, (k, q) row data, extents)`` tuples; ``flush`` seals
    the partial open row, and ``zero_row`` mints an empty filler row so
    the gateway can complete a group at drain time."""

    def __init__(self, k: int, q: int):
        if k < 1 or q < 1:
            raise ValueError(f"need k >= 1 and q >= 1, got ({k}, {q})")
        self.k = k
        self.q = q
        self.row_bytes = k * q
        self._buf = np.zeros(self.row_bytes, dtype=np.uint8)
        self._fill = 0
        self._extents: list[Extent] = []
        self._rows_sealed = 0

    @property
    def pending_bytes(self) -> int:
        return self._fill

    @property
    def pending_extents(self) -> int:
        return len(self._extents)

    @property
    def rows_sealed(self) -> int:
        return self._rows_sealed

    def append(
        self, small_id: tuple, payload: np.ndarray, tenant: str
    ) -> list[tuple[int, np.ndarray, list[Extent]]]:
        payload = np.asarray(payload, dtype=np.uint8).ravel()
        if payload.size < 1 or payload.size > self.row_bytes:
            raise ValueError(
                f"small-object payload must be 1..{self.row_bytes} bytes "
                f"(one row), got {payload.size}"
            )
        sealed = []
        if self._fill + payload.size > self.row_bytes:
            sealed.append(self._seal_row())
        ext = Extent(
            small_id=small_id,
            row_seq=self._rows_sealed,
            offset=self._fill,
            length=int(payload.size),
            digest=hashlib.sha256(payload.tobytes()).hexdigest(),
            tenant=tenant,
        )
        self._buf[self._fill : self._fill + payload.size] = payload
        self._fill += int(payload.size)
        self._extents.append(ext)
        if self._fill == self.row_bytes:
            sealed.append(self._seal_row())
        return sealed

    def flush(self) -> list[tuple[int, np.ndarray, list[Extent]]]:
        """Seal the partial open row (zero-padded tail), if any."""
        if not self._extents:
            return []
        return [self._seal_row()]

    def zero_row(self) -> tuple[int, np.ndarray, list[Extent]]:
        """An all-zero filler row with a fresh sequence number (pads the
        last group of a drain — matches load_objects' zero padding)."""
        assert not self._extents, "zero_row only between sealed rows"
        return self._seal_row()

    def _seal_row(self) -> tuple[int, np.ndarray, list[Extent]]:
        row = self._buf.copy().reshape(self.k, self.q)
        extents = self._extents
        seq = self._rows_sealed
        self._buf.fill(0)
        self._fill = 0
        self._extents = []
        self._rows_sealed += 1
        return (seq, row, extents)
