"""LRU block cache with hit/miss accounting.

Sits between the gateway and the fabric: a hit serves the block from
gateway memory (no network transfer, no reconstruction); a miss goes to
the block store. Decoded (reconstructed) blocks are cached too, so a hot
degraded object pays its reconstruction once per eviction period rather
than once per request — the standard production mitigation for repair
read amplification.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.storage.blockstore import BlockKey


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class LRUBlockCache:
    def __init__(self, capacity_bytes: int):
        self.capacity_bytes = int(capacity_bytes)
        self._blocks: OrderedDict[BlockKey, np.ndarray] = OrderedDict()
        self._bytes = 0
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._blocks)

    @property
    def nbytes(self) -> int:
        return self._bytes

    def __contains__(self, key: BlockKey) -> bool:
        """Membership probe with no stats / LRU side effects (planning)."""
        return key in self._blocks

    def get(self, key: BlockKey) -> np.ndarray | None:
        blk = self._blocks.get(key)
        if blk is None:
            self.stats.misses += 1
            return None
        self._blocks.move_to_end(key)
        self.stats.hits += 1
        return blk

    def put(self, key: BlockKey, block: np.ndarray) -> None:
        if block.nbytes > self.capacity_bytes:
            return
        old = self._blocks.pop(key, None)
        if old is not None:
            self._bytes -= old.nbytes
        self._blocks[key] = block
        self._bytes += block.nbytes
        while self._bytes > self.capacity_bytes:
            _, evicted = self._blocks.popitem(last=False)
            self._bytes -= evicted.nbytes
            self.stats.evictions += 1

    def invalidate(self, key: BlockKey) -> None:
        old = self._blocks.pop(key, None)
        if old is not None:
            self._bytes -= old.nbytes
