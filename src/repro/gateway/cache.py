"""Block cache with hit/miss accounting and a choice of eviction policy.

Sits between the gateway and the fabric: a hit serves the block from
gateway memory (no network transfer, no reconstruction); a miss goes to
the block store. Decoded (reconstructed) blocks are cached too, so a hot
degraded object pays its reconstruction once per eviction period rather
than once per request — the standard production mitigation for repair
read amplification.

Two policies:

  * ``lru``  — plain recency (the PR-1 behavior).
  * ``cost`` — reconstruction-cost-aware (GreedyDual): each entry
    carries a rebuild cost (source blocks needed to regenerate it — 1
    for a directly-fetched block, t for a vertical XOR rebuild, k for a
    horizontal RS decode) and the victim is the entry with the lowest
    recency x cost score. A k-cost horizontal reconstruction outlives
    cheap verticals and plain fetches under pressure, exactly the
    blocks whose re-miss would hurt most. With uniform costs the policy
    degenerates to LRU.

``refresh_cost`` re-prices an entry in place — the gateway calls it when
BlockFixer repairs the underlying block, since a repaired block is a
cheap store read again and should no longer squat on cache capacity at
reconstruction priority.

Negative entries (TTL'd): a negative entry records "this block is known
to be down" with an expiry in simulated time. The gateway inserts them
for every block on a crashed node, so planning skips re-probing known
failures; they are purged eagerly on the node-recover event (the
scenario engine's transient-failure path) and when a repair write-back
heals the block, and they expire on their TTL otherwise — the backstop
that keeps stale failure knowledge from outliving an unobserved
recovery. Negative entries consume no data capacity (they hold no
bytes) and never shadow a positive copy: a cached reconstruction of a
down block still serves hits.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.storage.blockstore import BlockKey


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    negative_hits: int = 0  # availability probes short-circuited
    negative_expired: int = 0  # TTL lapses (stale failure knowledge)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class LRUBlockCache:
    def __init__(self, capacity_bytes: int, policy: str = "lru"):
        if policy not in ("lru", "cost"):
            raise ValueError(f"policy must be 'lru' or 'cost', got {policy!r}")
        self.capacity_bytes = int(capacity_bytes)
        self.policy = policy
        self._blocks: OrderedDict[BlockKey, np.ndarray] = OrderedDict()
        self._bytes = 0
        self.stats = CacheStats()
        # GreedyDual state (policy="cost"): per-entry score H = L + cost,
        # where L is the inflation clock — the score of the last victim.
        # Re-accessing an entry re-inflates it to the current L + cost,
        # so score order is recency order scaled by rebuild cost.
        self._cost: dict[BlockKey, float] = {}
        self._score: dict[BlockKey, float] = {}
        self._clock = 0.0
        # negative entries: key -> expiry in simulated seconds (inf for
        # "until explicitly purged"). Zero-capacity — a tombstone, not a
        # block — so they live outside the eviction loop entirely.
        self._negative: dict[BlockKey, float] = {}

    def __len__(self) -> int:
        return len(self._blocks)

    @property
    def nbytes(self) -> int:
        return self._bytes

    def __contains__(self, key: BlockKey) -> bool:
        """Membership probe with no stats / LRU side effects (planning)."""
        return key in self._blocks

    def get(self, key: BlockKey) -> np.ndarray | None:
        blk = self._blocks.get(key)
        if blk is None:
            self.stats.misses += 1
            return None
        self._blocks.move_to_end(key)
        if self.policy == "cost":
            self._score[key] = self._clock + self._cost[key]
        self.stats.hits += 1
        return blk

    def put(self, key: BlockKey, block: np.ndarray, cost: float = 1.0) -> None:
        if block.nbytes > self.capacity_bytes:
            return
        old = self._blocks.pop(key, None)
        if old is not None:
            self._bytes -= old.nbytes
        self._blocks[key] = block
        self._bytes += block.nbytes
        if self.policy == "cost":
            self._cost[key] = float(cost)
            self._score[key] = self._clock + float(cost)
        while self._bytes > self.capacity_bytes:
            victim = self._pick_victim()
            evicted = self._blocks.pop(victim)
            self._bytes -= evicted.nbytes
            self._drop_meta(victim)
            self.stats.evictions += 1

    def refresh_cost(self, key: BlockKey, cost: float) -> None:
        """Re-price a resident entry (repair made the block cheap again;
        no recency boost — only the cost component changes)."""
        if self.policy != "cost" or key not in self._blocks:
            return
        old_cost = self._cost[key]
        self._cost[key] = float(cost)
        self._score[key] += float(cost) - old_cost

    def invalidate(self, key: BlockKey) -> None:
        old = self._blocks.pop(key, None)
        if old is not None:
            self._bytes -= old.nbytes
            self._drop_meta(key)

    # -- negative / TTL entries -------------------------------------------------
    def put_negative(self, key: BlockKey, now: float, ttl: float = float("inf")) -> None:
        """Record that ``key`` is known-down as of ``now``; the entry
        expires at now + ttl unless purged first (node recover / repair)."""
        self._negative[key] = now + ttl

    def is_negative(self, key: BlockKey, now: float) -> bool:
        """True while a live negative entry covers ``key``. Expired
        entries are dropped lazily here (the TTL backstop: after it, the
        gateway re-probes the store instead of trusting stale failure
        knowledge)."""
        exp = self._negative.get(key)
        if exp is None:
            return False
        if now >= exp:
            del self._negative[key]
            self.stats.negative_expired += 1
            return False
        self.stats.negative_hits += 1
        return True

    def purge_negative(self, keys) -> int:
        """Eagerly drop negative entries (node recovered / block healed);
        returns how many were live."""
        n = 0
        for key in keys:
            if self._negative.pop(key, None) is not None:
                n += 1
        return n

    @property
    def negative_entries(self) -> int:
        return len(self._negative)

    # -- internals -------------------------------------------------------------
    def _pick_victim(self) -> BlockKey:
        if self.policy == "lru":
            return next(iter(self._blocks))
        # least score wins; ties broken LRU-first (the OrderedDict runs
        # LRU -> MRU), so uniform costs degenerate to exact LRU. The
        # linear scan is O(residents) per eviction — fine at this
        # simulation's cache sizes; a real deployment would keep a
        # lazy-invalidation min-heap instead.
        victim, best = None, float("inf")
        for key in self._blocks:
            s = self._score[key]
            if s < best:
                victim, best = key, s
        # inflate the clock to the victim's score: survivors' remaining
        # scores shrink relative to fresh insertions (aging), bounding
        # how long a high-cost entry can squat without re-access. Never
        # let it roll BACKWARDS: refresh_cost can legally demote an
        # entry's score below the current clock, and deflating the clock
        # from such a victim would hand later insertions stale scores.
        self._clock = max(self._clock, best)
        return victim

    def _drop_meta(self, key: BlockKey) -> None:
        self._cost.pop(key, None)
        self._score.pop(key, None)
