"""Request coalescer: execute a window's degraded-read decodes in as few
Pallas launches as the shape mix allows.

Two dataplanes share one interface (``DecodeCoalescer(mode=...)``):

**Ragged megakernel (default, ``mode="ragged"``).** A realistic mixed-
tenant window holds decodes of MIXED shapes — horizontal RS ops with
varying target counts, vertical XOR repairs, ragged byte lengths — and
per-shape launches pay per-launch overhead once per bucket plus up to 2x
batch-ladder filler. The ragged path instead stages the WHOLE window per
kind: every decode row (one output row of one op) is cut into fixed-
width tiles (width autotuned, capped to the longest row), gathered into
a preallocated staging buffer ``(C, K, TN)`` with a per-tile descriptor
(op id, coefficient bit-planes, byte offset, valid length), and decoded
by ONE descriptor-driven kernel launch whose grid walks tiles
(kernels/ragged_decode.py). Flattening to ROWS is what removes the
target count M from the traced shape; its price is that an op with M
targets stages its K source slabs once per target row — accepted
because M > 1 is the rare case (multi-loss rows) and the alternative
(per-tile source indirection in the kernel) needs scalar-prefetch
support (ROADMAP follow-on). The launch tile count C comes from exactly
two rungs (small/big chunk), so the LIVE traced signatures per kind
stay <= 2 no matter how diverse the traffic — ``jit_entries`` is O(1)
per kind — and ``padded_ops`` is 0 by construction: the only filler is
tail tiles and the final chunk's null tiles, reported as
``stats.padded_byte_ratio``. The K axis and tile width are grow-only
caps: a window exceeding a cap retraces once and retires the outgrown
signatures (they can never be launched again); cumulative compile churn
stays visible as ``stats.jit_retraces``.

Staging-buffer contract: buffers are preallocated once per (kind, C)
and reused across windows; the gather writes each source's bytes
straight into its tile slab (no intermediate ``np.stack`` pyramids),
zero-filling K-axis padding and tile tails — zero bytes are the
identity for both GF(256) products and XOR, so the kernel needs no
masking and the host slices each row's valid prefix back out.

**Shape buckets (``mode="bucketed"``, the pre-megakernel baseline).**
One stacked launch per (kind, M, K, blocklen) bucket, batch sizes
padded up a fixed power-of-two ladder (PAD_LADDER) by replicating the
first stripe, buckets beyond the top rung split into top-rung chunks.
Kept as the measured comparison baseline (benchmarks/gateway_load.py
``gateway_megakernel`` rows) and the property-test oracle.

Engine-pool integration: ``execute`` returns a list of ``LaunchUnit``s
— the simulated-compute quanta the gateway dispatches onto its parallel
decode engines. A bucketed launch is one unit owning its batch; a
megakernel launch is SPLIT by tile ranges into one unit per op, each
billed its tile share of the measured launch time, so one physical
launch can still spread across engines. The gateway gates every unit
of a launch on the launch-wide source barrier (the staging buffer
holds all its ops' tiles), keyed by ``launch_id``.

Compute time is measured on the real jitted kernels (block_until_ready)
and scaled by the cluster profile, mirroring BlockFixer's convention.
Each traced signature is billed at its BEST-observed execution time:
the kernel's intrinsic cost is its fastest run, and transient host
stalls (a noisy neighbour during one launch) are not properties of the
simulated hardware — without the floor, one slow wall-clock sample
would skew a whole simulated-latency distribution.
"""

from __future__ import annotations

import bisect
import logging
import time
from collections import Counter, defaultdict
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.gateway.planner import DecodeOp
from repro.kernels import autotune, ops
from repro.kernels import ragged_decode as _rdk
from repro.kernels.gf256_matmul import expand_coeff_bitplanes
from repro.kernels.ops import _next_pow2
from repro.storage.blockstore import BlockKey

_log = logging.getLogger(__name__)

RAGGED = "ragged"
BUCKETED = "bucketed"

# Batch-size rungs for the bucketed baseline: B pads up to the next rung
# (powers of two). Buckets larger than the top rung are SPLIT into
# top-rung launches, so the distinct traced signatures per decode shape
# are truly <= len(PAD_LADDER).
PAD_LADDER = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def ladder_rung(b: int) -> int:
    """Smallest ladder rung >= b. Callers cap b at PAD_LADDER[-1] first
    (the coalescer splits oversized buckets into top-rung chunks)."""
    assert 0 < b <= PAD_LADDER[-1], b
    return PAD_LADDER[bisect.bisect_left(PAD_LADDER, b)]


@dataclass(frozen=True)
class LaunchUnit:
    """One simulated-compute quantum the gateway schedules on its decode
    engine pool. ``op_indices`` are positions in the ``execute`` op
    list; ``fraction`` is this unit's share of its physical launch's
    wall time (1.0 for a bucketed launch; a megakernel launch splits by
    tile ranges, one unit per op), so modeled-cost billing can charge
    ``decode_cost x fraction`` and still sum to one launch."""

    op_indices: tuple[int, ...]
    compute: float  # scaled seconds
    kind: str
    launch_id: int
    fraction: float = 1.0
    tiles: int = 0  # descriptor tiles this unit covers (0 = bucketed)


@dataclass
class CoalescerStats:
    decode_ops: int = 0  # logical reconstructions requested
    decode_calls: int = 0  # actual kernel launches issued
    padded_ops: int = 0  # ladder filler stripes launched (bucketed only)
    max_batch: int = 0  # most ops sharing one launch
    compute_time: float = 0.0  # scaled seconds, cumulative
    windows: int = 0  # execute() calls that had work
    staged_bytes: int = 0  # useful source bytes staged for kernels
    padded_bytes: int = 0  # filler staged alongside (tails, rungs)
    # ops-per-launch histogram. Bounded: at most one key per distinct
    # batch size (<= PAD_LADDER[-1] of them), unlike the unbounded
    # per-launch list it replaced — a week-long scenario run no longer
    # accretes one int per launch.
    batch_hist: dict[int, int] = field(default_factory=dict)
    ops_by_kind: dict[str, int] = field(default_factory=dict)
    sources_by_kind: dict[str, int] = field(default_factory=dict)
    jit_entries: int = 0  # LIVE traced kernel signatures (see below)
    jit_retraces: int = 0  # every trace ever taken (compile churn)
    decode_shapes: int = 0  # distinct decode shape_keys ever executed
    # write-dataplane counters (kinds "EH"/"EV"): kept separate so a
    # read-only run's decode stats stay bit-identical with or without
    # the encode path compiled in
    encode_ops: int = 0  # logical encode ops requested
    encode_calls: int = 0  # encode kernel launches issued
    encode_compute_time: float = 0.0  # scaled seconds, cumulative
    encode_windows: int = 0  # execute_encode() calls that had work

    @property
    def coalescing_ratio(self) -> float:
        """ops per launch; > 1 means batching is happening."""
        return self.decode_ops / self.decode_calls if self.decode_calls else 0.0

    @property
    def launches_per_window(self) -> float:
        return self.decode_calls / self.windows if self.windows else 0.0

    @property
    def padded_byte_ratio(self) -> float:
        """Filler fraction of all bytes staged for decode kernels."""
        total = self.staged_bytes + self.padded_bytes
        return self.padded_bytes / total if total else 0.0

    def record_batch(self, n_ops: int) -> None:
        self.batch_hist[n_ops] = self.batch_hist.get(n_ops, 0) + 1
        self.max_batch = max(self.max_batch, n_ops)

    def sources_per_op(self, kind: str) -> float:
        """Mean source blocks per reconstruction of this kind — the
        paper's Table 1 costs: exactly t for "V", exactly k for "H"."""
        n = self.ops_by_kind.get(kind, 0)
        return self.sources_by_kind.get(kind, 0) / n if n else 0.0


class DecodeCoalescer:
    def __init__(
        self,
        compute_scale: float = 1.0,
        interpret: bool | None = None,
        autotune_kernels: bool = True,
        mode: str = RAGGED,
    ):
        if mode not in (RAGGED, BUCKETED):
            raise ValueError(
                f"mode must be 'ragged' or 'bucketed', got {mode!r}"
            )
        self.compute_scale = compute_scale
        self.interpret = interpret
        self.autotune_kernels = autotune_kernels
        self.mode = mode
        self.stats = CoalescerStats()
        self._warm: set[tuple] = set()  # traced kernel signatures
        self._best: dict[tuple, float] = {}  # per-signature fastest run
        self._tuned: dict[str, autotune.TunedKernel] = {}
        self._shapes: set[tuple] = set()  # distinct op shape_keys seen
        # ragged-path state: grow-only caps (retracing only on growth
        # keeps the signature set at the two chunk rungs for steady
        # traffic) and the reusable staging buffers, keyed (kind, C).
        self._k_cap: dict[str, int] = {}
        self._tile_n: dict[str, int] = {}
        self._staging: dict[tuple, np.ndarray] = {}

    def tiles_for(self, length: int, kind: str = "H") -> int:
        """Descriptor tiles one ``length``-byte output row costs at the
        current tile width (the ratcheted width once seen, else the
        same fit formula ``_execute_ragged_kind`` would pick). Used by
        per-tile modeled billing to price decode work that does not go
        through ``execute`` (background repair's codec)."""
        tn = self._tile_n.get(kind)
        if tn is None:
            tn = min(_rdk.DEFAULT_TILE_N, _next_pow2(max(1, int(length))))
        return -(-int(length) // tn)

    def jit_entries_by_kind(self) -> dict[str, int]:
        """Distinct traced signatures per decode kind — the megakernel's
        O(1)-per-kind guarantee, observable (tests/test_ragged_decode)."""
        out: dict[str, int] = {}
        for sig in self._warm:
            kind = sig[1][0] if sig[0] == BUCKETED else sig[1]
            out[kind] = out.get(kind, 0) + 1
        return out

    def _tuned_for(self, kind: str) -> autotune.TunedKernel | None:
        if not self.autotune_kernels:
            return None
        # encode kinds ("E*") only ever run ragged — there is no
        # bucketed encode baseline (the write-path comparison point is
        # the gateway's per-PUT synchronous billing, not a shape-bucket
        # dataplane) — so they always take the ragged tuners
        mode = RAGGED if kind.startswith("E") else self.mode
        key = f"{mode}:{kind}"
        tuned = self._tuned.get(key)
        if tuned is None:
            if mode == RAGGED:
                tune = (
                    autotune.tuned_ragged_xor
                    if kind in ("V", "EV")
                    else autotune.tuned_ragged_gf256
                )
            else:
                tune = autotune.tuned_xor if kind == "V" else autotune.tuned_gf256
            tuned = tune(self.interpret)
            self._tuned[key] = tuned
        return tuned

    def execute(
        self,
        decode_ops: list[DecodeOp],
        fetch: Callable[[BlockKey], np.ndarray],
    ) -> tuple[list[dict[int, np.ndarray]], list[LaunchUnit]]:
        """Run all ``decode_ops``; returns (results, units).

        ``results[i]`` maps target column -> reconstructed block for
        ``decode_ops[i]``. ``units`` are the simulated-compute quanta of
        the launches actually issued (see LaunchUnit): the gateway
        dispatches each unit onto its engine pool once the unit's ops'
        sources have landed, so one window's decode work can overlap
        other windows' fabric transfers and spread over engines."""
        results: list[dict[int, np.ndarray]] = [dict() for _ in decode_ops]
        units: list[LaunchUnit] = []
        if not decode_ops:
            return results, units
        self.stats.windows += 1
        for op in decode_ops:
            self._shapes.add(op.shape_key)
        if self.mode == RAGGED:
            by_kind: dict[str, list[int]] = defaultdict(list)
            for j, op in enumerate(decode_ops):
                by_kind[op.kind].append(j)
            for kind in sorted(by_kind):
                self._execute_ragged(
                    kind, by_kind[kind], decode_ops, fetch, results, units
                )
        else:
            # buckets split by byte length too (it is a jit shape key
            # anyway), so ragged-length windows stack cleanly
            buckets: dict[tuple, list[int]] = defaultdict(list)
            for i, op in enumerate(decode_ops):
                n = int(np.asarray(fetch(op.sources[0])).shape[-1])
                buckets[(op.shape_key, n)].append(i)
            for (key, _n), all_idxs in buckets.items():
                kind = key[0]
                tuned = self._tuned_for(kind)
                # buckets beyond the top rung split into top-rung launches
                cap = PAD_LADDER[-1]
                chunks = [
                    all_idxs[c : c + cap] for c in range(0, len(all_idxs), cap)
                ]
                for idxs in chunks:
                    self._launch_bucket(
                        key, kind, idxs, tuned, decode_ops, fetch, results, units
                    )
        self.stats.decode_shapes = len(self._shapes)
        return results, units

    def execute_encode(
        self,
        encode_ops: list[DecodeOp],
        fetch: Callable[[BlockKey], np.ndarray],
    ) -> tuple[list[dict[int, np.ndarray]], list[LaunchUnit]]:
        """Run a PUT window's encode work in chunked megakernel launches:
        GF(256) parity-row generation ("EH" ops, coefficient rows from
        coding/rs.py's ``parity_matrix``) and XOR-delta parity folds
        ("EV" ops — stored parity plus any number of old^new row
        contributions, one op per touched parity block per window).

        Same interface and staging contract as ``execute``, but always
        via the ragged path (see ``_tuned_for``) and the separate
        kernels/ragged_encode.py jit entries, so encode signature growth
        is observable per kind and never retraces the decode kernels.
        Source keys are whatever hashables ``fetch`` resolves — the
        gateway feeds host-staged old/new row arrays under synthetic
        tokens. Emitted LaunchUnits are billed on the engine pool by the
        gateway exactly like decode launches (best-observed kernel time,
        modeled-cost override, launch-wide readiness barrier)."""
        results: list[dict[int, np.ndarray]] = [dict() for _ in encode_ops]
        units: list[LaunchUnit] = []
        if not encode_ops:
            return results, units
        self.stats.encode_windows += 1
        by_kind: dict[str, list[int]] = defaultdict(list)
        for j, op in enumerate(encode_ops):
            assert op.kind.startswith("E"), f"not an encode kind: {op.kind!r}"
            by_kind[op.kind].append(j)
        for kind in sorted(by_kind):
            self._execute_ragged(
                kind, by_kind[kind], encode_ops, fetch, results, units
            )
        return results, units

    # -- ragged megakernel path -------------------------------------------------
    def _execute_ragged(
        self, kind, idxs, decode_ops, fetch, results, units
    ) -> None:
        """Stage every op of ``kind`` as descriptor tiles and decode the
        whole set in chunked megakernel launches (see module docstring
        for the staging contract)."""
        tuned = self._tuned_for(kind)
        # fetch each distinct source once, straight into the gather below
        src: dict[BlockKey, np.ndarray] = {}
        # one descriptor row per OUTPUT row: (op_idx, target column,
        # coefficient bit-planes (K, 8) or None for XOR, sources, length)
        rows: list[tuple] = []
        for j in idxs:
            op = decode_ops[j]
            for s in op.sources:
                if s not in src:
                    src[s] = np.asarray(fetch(s))
            length = int(src[op.sources[0]].shape[-1])
            for s in op.sources[1:]:
                assert src[s].shape[-1] == length, (
                    f"ragged decode op sources must share a length: "
                    f"{src[s].shape[-1]} != {length}"
                )
            if kind in ("V", "EV"):
                rows.append((j, op.targets[0], None, op.sources, length))
            else:
                planes = expand_coeff_bitplanes(np.asarray(op.coeffs))
                for m, col in enumerate(op.targets):
                    rows.append((j, col, planes[m], op.sources, length))
        k_max = max(len(r[3]) for r in rows)
        self._k_cap[kind] = max(self._k_cap.get(kind, 0), k_max)
        k_cap = self._k_cap[kind]
        max_len = max(r[4] for r in rows)
        tn_fit = (
            tuned.block_n_for(max_len)
            if tuned is not None
            else min(_rdk.DEFAULT_TILE_N, _next_pow2(max_len))
        )
        self._tile_n[kind] = max(self._tile_n.get(kind, 0), tn_fit)
        tn = self._tile_n[kind]
        # cut rows into fixed-width tiles
        tiles: list[tuple[int, int, int]] = []  # (row index, offset, valid)
        out_rows = [np.empty(r[4], dtype=np.uint8) for r in rows]
        for ri, (_j, _col, _planes, _sources, length) in enumerate(rows):
            off = 0
            while off < length:
                valid = min(tn, length - off)
                tiles.append((ri, off, valid))
                off += valid
        pos = 0
        for c in _rdk.chunk_sizes(len(tiles)):
            self._launch_ragged_chunk(
                kind, c, tiles[pos : pos + c], rows, src, out_rows,
                tn, k_cap, tuned, units,
            )
            pos += c
        for ri, (j, col, _planes, _sources, _length) in enumerate(rows):
            results[j][col] = out_rows[ri]
        if kind.startswith("E"):
            self.stats.encode_ops += len(idxs)
        else:
            self.stats.decode_ops += len(idxs)
        self.stats.ops_by_kind[kind] = (
            self.stats.ops_by_kind.get(kind, 0) + len(idxs)
        )
        self.stats.sources_by_kind[kind] = self.stats.sources_by_kind.get(
            kind, 0
        ) + sum(len(decode_ops[j].sources) for j in idxs)

    def _buffer(self, key: tuple, shape: tuple) -> np.ndarray:
        """Preallocated staging buffer, reused across windows; replaced
        only when a grow-only cap (K, TN) ratchets."""
        buf = self._staging.get(key)
        if buf is None or buf.shape != shape:
            buf = np.zeros(shape, dtype=np.uint8)
            self._staging[key] = buf
        return buf

    def _launch_ragged_chunk(
        self, kind, c, chunk_tiles, rows, src, out_rows, tn, k_cap, tuned, units
    ) -> None:
        """Gather one chunk of tiles into the staging buffers, run ONE
        megakernel launch, scatter outputs, and emit per-op LaunchUnits
        billed by tile share."""
        data = self._buffer((kind, "data", c), (c, k_cap, tn))
        data.fill(0)
        xor_kind = kind in ("V", "EV")
        mc = None
        if not xor_kind:
            mc = self._buffer((kind, "mc", c), (c, k_cap, 8))
            mc.fill(0)
        useful = 0
        for slot, (ri, off, valid) in enumerate(chunk_tiles):
            _j, _col, planes, sources, _length = rows[ri]
            for k, s in enumerate(sources):
                data[slot, k, :valid] = src[s][off : off + valid]
            if mc is not None:
                mc[slot, : planes.shape[0], :] = planes
            useful += valid * len(sources)
        packed = bool(tuned.packed) if (tuned is not None and not xor_kind) else False
        interpret = self.interpret
        # encode kinds route to the separate ragged_encode jit entries,
        # keeping the encode/decode signature pools independently
        # countable (jit_entries_by_kind) and independently retraced
        if kind == "V":
            launch = lambda: ops.xor_ragged(jnp.asarray(data), interpret=interpret)
        elif kind == "EV":
            launch = lambda: ops.xor_ragged_encode(
                jnp.asarray(data), interpret=interpret
            )
        elif kind == "EH":
            launch = lambda: ops.gf256_ragged_encode(
                mc, jnp.asarray(data), interpret=interpret, packed=packed
            )
        else:
            launch = lambda: ops.gf256_ragged(
                mc, jnp.asarray(data), interpret=interpret, packed=packed
            )
        # Untimed warm-up on first sight of a traced signature: chunk
        # rung, K cap and tile width are the only jit shape keys, and
        # the one-off trace/compile cost must not be billed to the
        # window's simulated decode latency.
        sig = (RAGGED, kind, c, k_cap, tn, packed)
        if sig not in self._warm:
            # a grow-only cap ratchet obsoletes this kind's previous
            # signatures — they can never be launched again, so the LIVE
            # set stays at the two chunk rungs per kind; jit_retraces
            # keeps the cumulative trace count for churn visibility
            stale = {
                s
                for s in self._warm
                if s[0] == RAGGED
                and s[1] == kind
                and (s[3], s[4]) != (k_cap, tn)
            }
            self._warm -= stale
            for s in stale:
                self._best.pop(s, None)
            if stale:
                _log.warning(
                    "coalescer: kind %r cap ratchet to (K=%d, TN=%d) "
                    "retired %d traced signature(s)",
                    kind, k_cap, tn, len(stale),
                )
            jax.block_until_ready(launch())
            self._warm.add(sig)
            self.stats.jit_entries = len(self._warm)
            self.stats.jit_retraces += 1
        t0 = time.perf_counter()
        out = launch()
        jax.block_until_ready(out)
        out = np.asarray(out)
        dt = (time.perf_counter() - t0) * self.compute_scale
        best = self._best.get(sig)
        dt = dt if best is None or dt < best else best
        self._best[sig] = dt
        for slot, (ri, off, valid) in enumerate(chunk_tiles):
            out_rows[ri][off : off + valid] = out[slot, :valid]
        # one unit per op, billed its tile share of the launch, so the
        # engine pool can spread this single launch across engines
        # (the gateway still gates all of them on the launch-wide
        # source barrier)
        encode = kind.startswith("E")
        launch_id = self.stats.encode_calls if encode else self.stats.decode_calls
        tiles_per_op = Counter(rows[ri][0] for ri, _off, _valid in chunk_tiles)
        n_valid = len(chunk_tiles)
        for j in sorted(tiles_per_op):
            frac = tiles_per_op[j] / n_valid
            units.append(
                LaunchUnit(
                    (j,), dt * frac, kind, launch_id, frac, tiles_per_op[j]
                )
            )
        if encode:
            self.stats.encode_calls += 1
            self.stats.encode_compute_time += dt
        else:
            self.stats.decode_calls += 1
            self.stats.compute_time += dt
        self.stats.record_batch(len(tiles_per_op))
        self.stats.staged_bytes += useful
        self.stats.padded_bytes += c * k_cap * tn - useful

    # -- bucketed baseline path -------------------------------------------------
    def _launch_bucket(
        self, key, kind, idxs, tuned, decode_ops, fetch, results, units
    ) -> None:
        """One stacked launch for ``idxs`` (all sharing shape ``key``),
        padded up the ladder; emits one LaunchUnit owning the whole
        batch and writes per-op ``results``."""
        b_pad = ladder_rung(len(idxs))
        # ladder padding: replicate the first stripe — same shape,
        # same coefficients, output rows sliced away below
        pad_idxs = idxs + [idxs[0]] * (b_pad - len(idxs))
        kw = {"interpret": self.interpret}
        if kind == "V":
            data = np.stack(
                [np.stack([fetch(s) for s in decode_ops[i].sources]) for i in pad_idxs]
            )  # (B, T, q)
            if tuned is not None:
                kw["block_n"] = tuned.block_n_for(data.shape[-1])
            launch = lambda: ops.xor_parity_batched(jnp.asarray(data), **kw)
        else:
            coefs = np.stack([decode_ops[i].coeffs for i in pad_idxs])  # (B, M, K)
            data = np.stack(
                [np.stack([fetch(s) for s in decode_ops[i].sources]) for i in pad_idxs]
            )  # (B, K, q)
            if tuned is not None:
                kw["block_n"] = tuned.block_n_for(data.shape[-1])
                kw["packed"] = tuned.packed
            launch = lambda: ops.gf256_matmul_batched(coefs, jnp.asarray(data), **kw)
        # Untimed warm-up on first sight of a traced signature: the
        # padded batch size B and byte length are jit shape keys, and
        # the one-off trace/compile cost must not be billed to the
        # window's simulated decode latency.
        sig = (BUCKETED, key, b_pad, data.shape[-1])
        if sig not in self._warm:
            jax.block_until_ready(launch())
            self._warm.add(sig)
            self.stats.jit_entries = len(self._warm)
            self.stats.jit_retraces += 1
        t0 = time.perf_counter()
        out = launch()
        jax.block_until_ready(out)
        out = np.asarray(out)
        if kind == "V":
            for b, i in enumerate(idxs):  # out: (B, q)
                results[i][decode_ops[i].targets[0]] = out[b]
        else:
            for b, i in enumerate(idxs):  # out: (B, M, q)
                for m, col in enumerate(decode_ops[i].targets):
                    results[i][col] = out[b, m]
        dt = (time.perf_counter() - t0) * self.compute_scale
        # bill at the signature's best-observed time (module docstring)
        best = self._best.get(sig)
        dt = dt if best is None or dt < best else best
        self._best[sig] = dt
        units.append(
            LaunchUnit(tuple(idxs), dt, kind, self.stats.decode_calls)
        )
        stripe = int(np.prod(data.shape[1:]))  # bytes per staged stripe
        self.stats.staged_bytes += len(idxs) * stripe
        self.stats.padded_bytes += (b_pad - len(idxs)) * stripe
        self.stats.compute_time += dt
        self.stats.decode_calls += 1
        self.stats.decode_ops += len(idxs)
        self.stats.padded_ops += b_pad - len(idxs)
        self.stats.record_batch(len(idxs))
        self.stats.ops_by_kind[kind] = (
            self.stats.ops_by_kind.get(kind, 0) + len(idxs)
        )
        self.stats.sources_by_kind[kind] = self.stats.sources_by_kind.get(
            kind, 0
        ) + sum(len(decode_ops[i].sources) for i in idxs)
