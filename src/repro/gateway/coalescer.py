"""Request coalescer: batch concurrent degraded-read decodes that share a
decode shape into ONE stacked kernel launch.

Under failures, a popular object's neighbours all degrade the same way
(same (kind, M, K) decode shape, same block size), so a busy gateway sees
many same-shaped decodes per batching window. Dispatching them one by one
pays per-launch overhead B times; the stacked (B, M, K) x (B, K, N)
Pallas entry (kernels/gf256_matmul.py) pays it once. Vertical XOR repairs
batch the same way through the stacked xor_parity kernel.

Compute time is measured on the real jitted kernels (block_until_ready)
and scaled by the cluster profile, mirroring BlockFixer's convention.
"""

from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.gateway.planner import DecodeOp
from repro.kernels import ops
from repro.storage.blockstore import BlockKey


@dataclass
class CoalescerStats:
    decode_ops: int = 0  # logical reconstructions requested
    decode_calls: int = 0  # actual kernel launches issued
    max_batch: int = 0
    compute_time: float = 0.0  # scaled seconds, cumulative
    batch_sizes: list[int] = field(default_factory=list)
    ops_by_kind: dict[str, int] = field(default_factory=dict)
    sources_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def coalescing_ratio(self) -> float:
        """ops per launch; > 1 means batching is happening."""
        return self.decode_ops / self.decode_calls if self.decode_calls else 0.0

    def sources_per_op(self, kind: str) -> float:
        """Mean source blocks per reconstruction of this kind — the
        paper's Table 1 costs: exactly t for "V", exactly k for "H"."""
        n = self.ops_by_kind.get(kind, 0)
        return self.sources_by_kind.get(kind, 0) / n if n else 0.0


class DecodeCoalescer:
    def __init__(self, compute_scale: float = 1.0, interpret: bool | None = None):
        self.compute_scale = compute_scale
        self.interpret = interpret
        self.stats = CoalescerStats()
        self._warm: set[tuple] = set()  # traced (shape, B, q) signatures

    def execute(
        self,
        decode_ops: list[DecodeOp],
        fetch: Callable[[BlockKey], np.ndarray],
    ) -> tuple[list[dict[int, np.ndarray]], float]:
        """Run all ``decode_ops``, batching by shape bucket.

        Returns (results, compute_seconds) where results[i] maps target
        column -> reconstructed block for decode_ops[i], and
        compute_seconds is the scaled wall time of this execution (all
        ops in a window wait on the same launches).
        """
        results: list[dict[int, np.ndarray]] = [dict() for _ in decode_ops]
        if not decode_ops:
            return results, 0.0
        buckets: dict[tuple, list[int]] = defaultdict(list)
        for i, op in enumerate(decode_ops):
            buckets[op.shape_key].append(i)
        window_compute = 0.0
        for key, idxs in buckets.items():
            kind = key[0]
            if kind == "V":
                data = np.stack(
                    [np.stack([fetch(s) for s in decode_ops[i].sources]) for i in idxs]
                )  # (B, T, q)
                launch = lambda: ops.xor_parity_batched(
                    jnp.asarray(data), interpret=self.interpret
                )
            else:
                coefs = np.stack([decode_ops[i].coeffs for i in idxs])  # (B, M, K)
                data = np.stack(
                    [np.stack([fetch(s) for s in decode_ops[i].sources]) for i in idxs]
                )  # (B, K, q)
                launch = lambda: ops.gf256_matmul_batched(
                    coefs, jnp.asarray(data), interpret=self.interpret
                )
            # Untimed warm-up on first sight of a traced signature: the
            # batch size B and byte length are jit shape keys, and the
            # one-off trace/compile cost must not be billed to the
            # window's simulated decode latency.
            sig = (key, data.shape[0], data.shape[-1])
            if sig not in self._warm:
                jax.block_until_ready(launch())
                self._warm.add(sig)
            t0 = time.perf_counter()
            out = launch()
            jax.block_until_ready(out)
            out = np.asarray(out)
            if kind == "V":
                for b, i in enumerate(idxs):  # out: (B, q)
                    results[i][decode_ops[i].targets[0]] = out[b]
            else:
                for b, i in enumerate(idxs):  # out: (B, M, q)
                    for m, col in enumerate(decode_ops[i].targets):
                        results[i][col] = out[b, m]
            dt = (time.perf_counter() - t0) * self.compute_scale
            window_compute += dt
            self.stats.decode_calls += 1
            self.stats.decode_ops += len(idxs)
            self.stats.max_batch = max(self.stats.max_batch, len(idxs))
            self.stats.batch_sizes.append(len(idxs))
            self.stats.ops_by_kind[kind] = (
                self.stats.ops_by_kind.get(kind, 0) + len(idxs)
            )
            self.stats.sources_by_kind[kind] = self.stats.sources_by_kind.get(
                kind, 0
            ) + sum(len(decode_ops[i].sources) for i in idxs)
        self.stats.compute_time += window_compute
        return results, window_compute
