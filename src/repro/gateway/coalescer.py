"""Request coalescer: batch concurrent degraded-read decodes that share a
decode shape into ONE stacked kernel launch.

Under failures, a popular object's neighbours all degrade the same way
(same (kind, M, K) decode shape, same block size), so a busy gateway sees
many same-shaped decodes per batching window. Dispatching them one by one
pays per-launch overhead B times; the stacked (B, M, K) x (B, K, N)
Pallas entry (kernels/gf256_matmul.py) pays it once. Vertical XOR repairs
batch the same way through the stacked xor_parity kernel.

Recompilation control: the batch size B is a jit shape key, and organic
traffic produces a different B almost every window — each one a fresh
trace/compile. Batches are therefore padded up a fixed power-of-two
ladder (PAD_LADDER) by replicating the first stripe, so the distinct
traced signatures per decode shape stay logarithmic in the largest batch
ever seen (<= len(PAD_LADDER)) instead of linear in traffic diversity.
``stats.jit_entries`` counts live signatures so recompilation regressions
are visible in GatewayReport and the benchmarks.

Kernel parameters (block_n, packed u32 variant) come from the measured
per-backend sweep in kernels/autotune.py, capped to the actual block
size so ladder padding never multiplies kernel work.

Compute time is measured on the real jitted kernels (block_until_ready)
and scaled by the cluster profile, mirroring BlockFixer's convention —
reported PER LAUNCH so the gateway's engine dispatcher can spread a
bucket's launches over parallel decode engines. Each traced signature is
billed at its BEST-observed execution time: the kernel's intrinsic cost
is its fastest run, and transient host stalls (a noisy neighbour during
one launch) are not properties of the simulated hardware — without the
floor, one slow wall-clock sample would skew a whole simulated-latency
distribution.
"""

from __future__ import annotations

import bisect
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.gateway.planner import DecodeOp
from repro.kernels import autotune, ops
from repro.storage.blockstore import BlockKey

# Batch-size rungs: B pads up to the next rung (powers of two). Buckets
# larger than the top rung are SPLIT into top-rung launches, so the
# distinct traced signatures per decode shape are truly <= len(PAD_LADDER).
PAD_LADDER = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def ladder_rung(b: int) -> int:
    """Smallest ladder rung >= b. Callers cap b at PAD_LADDER[-1] first
    (the coalescer splits oversized buckets into top-rung chunks)."""
    assert 0 < b <= PAD_LADDER[-1], b
    return PAD_LADDER[bisect.bisect_left(PAD_LADDER, b)]


@dataclass
class CoalescerStats:
    decode_ops: int = 0  # logical reconstructions requested
    decode_calls: int = 0  # actual kernel launches issued
    padded_ops: int = 0  # ladder filler stripes launched (overhead)
    max_batch: int = 0
    compute_time: float = 0.0  # scaled seconds, cumulative
    batch_sizes: list[int] = field(default_factory=list)
    ops_by_kind: dict[str, int] = field(default_factory=dict)
    sources_by_kind: dict[str, int] = field(default_factory=dict)
    jit_entries: int = 0  # distinct traced (shape, B, q) signatures
    decode_shapes: int = 0  # distinct decode shape_keys ever launched

    @property
    def coalescing_ratio(self) -> float:
        """ops per launch; > 1 means batching is happening."""
        return self.decode_ops / self.decode_calls if self.decode_calls else 0.0

    def sources_per_op(self, kind: str) -> float:
        """Mean source blocks per reconstruction of this kind — the
        paper's Table 1 costs: exactly t for "V", exactly k for "H"."""
        n = self.ops_by_kind.get(kind, 0)
        return self.sources_by_kind.get(kind, 0) / n if n else 0.0


class DecodeCoalescer:
    def __init__(
        self,
        compute_scale: float = 1.0,
        interpret: bool | None = None,
        autotune_kernels: bool = True,
    ):
        self.compute_scale = compute_scale
        self.interpret = interpret
        self.autotune_kernels = autotune_kernels
        self.stats = CoalescerStats()
        self._warm: set[tuple] = set()  # traced (shape, B, q) signatures
        self._best: dict[tuple, float] = {}  # per-signature fastest run
        self._tuned: dict[str, autotune.TunedKernel] = {}

    def _tuned_for(self, kind: str) -> autotune.TunedKernel | None:
        if not self.autotune_kernels:
            return None
        tuned = self._tuned.get(kind)
        if tuned is None:
            tune = autotune.tuned_xor if kind == "V" else autotune.tuned_gf256
            tuned = tune(self.interpret)
            self._tuned[kind] = tuned
        return tuned

    def execute(
        self,
        decode_ops: list[DecodeOp],
        fetch: Callable[[BlockKey], np.ndarray],
    ) -> tuple[list[dict[int, np.ndarray]], dict[tuple, list[float]]]:
        """Run all ``decode_ops``, batching by shape bucket.

        Returns (results, bucket_compute) where results[i] maps target
        column -> reconstructed block for decode_ops[i], and
        bucket_compute maps each shape_key to the list of scaled wall
        times of that bucket's launches (top-rung splits produce several
        per key) — per-launch so the gateway's engine dispatcher can
        spread a bucket's launches over parallel decode engines and
        overlap one bucket's decode with another's fabric transfers
        (the serial path just sums all the values).
        """
        results: list[dict[int, np.ndarray]] = [dict() for _ in decode_ops]
        bucket_compute: dict[tuple, list[float]] = {}
        if not decode_ops:
            return results, bucket_compute
        buckets: dict[tuple, list[int]] = defaultdict(list)
        for i, op in enumerate(decode_ops):
            buckets[op.shape_key].append(i)
        for key, all_idxs in buckets.items():
            kind = key[0]
            tuned = self._tuned_for(kind)
            # buckets beyond the top rung split into top-rung launches
            cap = PAD_LADDER[-1]
            chunks = [all_idxs[c : c + cap] for c in range(0, len(all_idxs), cap)]
            for idxs in chunks:
                self._launch_bucket(key, kind, idxs, tuned, decode_ops,
                                    fetch, results, bucket_compute)
        return results, bucket_compute

    def _launch_bucket(
        self, key, kind, idxs, tuned, decode_ops, fetch, results, bucket_compute
    ) -> None:
        """One stacked launch for ``idxs`` (all sharing shape ``key``),
        padded up the ladder; appends its measured compute time to
        ``bucket_compute[key]`` and writes per-op ``results``."""
        b_pad = ladder_rung(len(idxs))
        # ladder padding: replicate the first stripe — same shape,
        # same coefficients, output rows sliced away below
        pad_idxs = idxs + [idxs[0]] * (b_pad - len(idxs))
        kw = {"interpret": self.interpret}
        if kind == "V":
            data = np.stack(
                [np.stack([fetch(s) for s in decode_ops[i].sources]) for i in pad_idxs]
            )  # (B, T, q)
            if tuned is not None:
                kw["block_n"] = tuned.block_n_for(data.shape[-1])
            launch = lambda: ops.xor_parity_batched(jnp.asarray(data), **kw)
        else:
            coefs = np.stack([decode_ops[i].coeffs for i in pad_idxs])  # (B, M, K)
            data = np.stack(
                [np.stack([fetch(s) for s in decode_ops[i].sources]) for i in pad_idxs]
            )  # (B, K, q)
            if tuned is not None:
                kw["block_n"] = tuned.block_n_for(data.shape[-1])
                kw["packed"] = tuned.packed
            launch = lambda: ops.gf256_matmul_batched(coefs, jnp.asarray(data), **kw)
        # Untimed warm-up on first sight of a traced signature: the
        # padded batch size B and byte length are jit shape keys, and
        # the one-off trace/compile cost must not be billed to the
        # window's simulated decode latency.
        sig = (key, b_pad, data.shape[-1])
        if sig not in self._warm:
            jax.block_until_ready(launch())
            self._warm.add(sig)
            self.stats.jit_entries = len(self._warm)
            self.stats.decode_shapes = len({s[0] for s in self._warm})
        t0 = time.perf_counter()
        out = launch()
        jax.block_until_ready(out)
        out = np.asarray(out)
        if kind == "V":
            for b, i in enumerate(idxs):  # out: (B, q)
                results[i][decode_ops[i].targets[0]] = out[b]
        else:
            for b, i in enumerate(idxs):  # out: (B, M, q)
                for m, col in enumerate(decode_ops[i].targets):
                    results[i][col] = out[b, m]
        dt = (time.perf_counter() - t0) * self.compute_scale
        # bill at the signature's best-observed time (module docstring)
        best = self._best.get(sig)
        dt = dt if best is None or dt < best else best
        self._best[sig] = dt
        bucket_compute.setdefault(key, []).append(dt)
        self.stats.compute_time += dt
        self.stats.decode_calls += 1
        self.stats.decode_ops += len(idxs)
        self.stats.padded_ops += b_pad - len(idxs)
        self.stats.max_batch = max(self.stats.max_batch, len(idxs))
        self.stats.batch_sizes.append(len(idxs))
        self.stats.ops_by_kind[kind] = (
            self.stats.ops_by_kind.get(kind, 0) + len(idxs)
        )
        self.stats.sources_by_kind[kind] = self.stats.sources_by_kind.get(
            kind, 0
        ) + sum(len(decode_ops[i].sources) for i in idxs)
