"""Workload synthesis for the serving gateway.

Object popularity is Zipfian (rank-r probability ∝ r^-s over a finite
catalog — the shape measured for blob/photo stores and the warehouse
traces the paper's related work studies), arrivals are Poisson, and node
failures are injected at configurable times. Everything is generated
host-side with numpy from a single seed so runs are reproducible.

Multi-tenant traces: each ``TenantProfile`` describes one tenant's
arrival rate, popularity skew, and fabric weight / latency SLO;
``generate_tenant_requests`` draws an independent Poisson/Zipf stream
per tenant over the shared catalog and merges them by arrival time, so
the gateway sees one interleaved trace of tenant-tagged requests.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

DEFAULT_TENANT = "foreground"


@dataclass(frozen=True)
class Request:
    time: float  # arrival (seconds since epoch 0 of the trace)
    object_id: int
    kind: str = "get"  # get | put | delete
    tenant: str = DEFAULT_TENANT  # fabric/SLO tenant this request bills to
    # PUT payload size in bytes. None => a full-row overwrite of the
    # object's k blocks (the pre-write-dataplane PUT). A value marks a
    # SMALL-object put: the gateway journals the payload and packs it
    # with other small objects into one codeword row (stripe sealing).
    nbytes: int | None = None


@dataclass(frozen=True)
class FailureEvent:
    """Node crash: the node goes dark but its disks survive — a matching
    ``NodeRecoverEvent`` brings the blocks back intact (reboot, network
    partition). The scenario engine (repro.scenario) composes these with
    recoveries, capacity losses and load surges into full fault traces."""

    time: float
    node: int


@dataclass(frozen=True)
class NodeRecoverEvent:
    """Transient failure over: the node rejoins with its blocks intact.
    The gateway purges the node's negative cache entries on this event."""

    time: float
    node: int


@dataclass(frozen=True)
class CapacityLossEvent:
    """Permanent loss: the node's blocks are destroyed (disk failure);
    the node rejoins empty and only repair can restore the data."""

    time: float
    node: int


@dataclass(frozen=True)
class CorruptionEvent:
    """Silent corruption: blocks on ``node`` are damaged in place (bit
    flip or torn write) with their stored checksums left stale — the
    gateway notices nothing until a fetch or scrub verifies the bytes,
    then reclassifies the mismatch as an erasure (tombstone + degraded
    read + repair). ``blocks`` names explicit (group, row, col) victims;
    when empty, the first ``count`` blocks on the node (crc32-ordered,
    process-stable) are hit — ``count=0`` means every block on the node.
    """

    time: float
    node: int
    blocks: tuple = ()  # explicit BlockKey victims, () => derive from node
    mode: str = "bitflip"  # bitflip | torn | erase
    count: int = 1


@dataclass(frozen=True)
class SlowNodeEvent:
    """Fail-slow (gray) degradation: the node stays up and its bytes are
    intact, but every transfer it participates in runs at
    ``rate_factor`` x the healthy bandwidth. ``rate_factor=1.0``
    restores full speed (the recover edge of a flapping-slow pair)."""

    time: float
    node: int
    rate_factor: float = 0.1


@dataclass(frozen=True)
class SlowNicEvent:
    """Directional fail-slow: only the node's send or receive side
    degrades (a half-duplex NIC fault / oversubscribed uplink)."""

    time: float
    node: int
    rate_factor: float = 0.1
    direction: str = "send"  # send | recv


@dataclass(frozen=True)
class ShardFailEvent:
    """Whole-gateway-shard death: the serving process for one namespace
    shard dies mid-run. Storage is untouched (blocks live on the shared
    BlockStore fabric, not in the gateway), so ZERO blocks are lost —
    the sharded front door removes the dead shard's points from the
    consistent-hash directory and its namespace ranges fail over to the
    surviving shards. Consumed by ``ShardedGateway`` only; a standalone
    ``ObjectGateway`` has no shard to kill and rejects the event.
    ``node`` is fixed at -1 so the event can ride the same time-sorted
    cluster-event stream as node-level faults."""

    time: float
    shard: int
    node: int = -1


@dataclass(frozen=True)
class WorkloadConfig:
    num_objects: int
    num_requests: int
    arrival_rate: float = 200.0  # requests/sec (Poisson)
    zipf_s: float = 1.1  # popularity exponent
    put_fraction: float = 0.0  # fraction of requests that are PUTs
    seed: int = 0
    # write-churn shape: deletes tombstone the drawn object; a fraction
    # of PUTs may be SMALL (sealed into shared stripes) instead of
    # full-row overwrites. All three default off, so existing traces are
    # byte-identical (the extra rng draws happen after every preexisting
    # draw in the stream).
    delete_fraction: float = 0.0  # fraction of requests that are DELETEs
    small_put_fraction: float = 0.0  # fraction of PUTs that are small
    small_put_bytes: int = 256  # payload size of a small put


def zipf_probs(num_objects: int, s: float) -> np.ndarray:
    """Finite-catalog Zipf pmf: p(rank r) ∝ r^-s, r = 1..num_objects."""
    ranks = np.arange(1, num_objects + 1, dtype=np.float64)
    w = ranks**-s
    return w / w.sum()


def generate_requests(
    cfg: WorkloadConfig, tenant: str = DEFAULT_TENANT
) -> list[Request]:
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.arrival_rate, size=cfg.num_requests)
    times = np.cumsum(gaps)
    # Popular ranks are mapped to shuffled object ids so popularity is not
    # correlated with placement order.
    perm = rng.permutation(cfg.num_objects)
    ranks = rng.choice(cfg.num_objects, size=cfg.num_requests, p=zipf_probs(cfg.num_objects, cfg.zipf_s))
    kinds = np.where(rng.random(cfg.num_requests) < cfg.put_fraction, "put", "get")
    # churn draws LAST: a zero-fraction config consumes extra rng stream
    # only after every preexisting field is decided, so old traces stay
    # byte-identical
    deletes = rng.random(cfg.num_requests) < cfg.delete_fraction
    smalls = rng.random(cfg.num_requests) < cfg.small_put_fraction
    out = []
    for i in range(cfg.num_requests):
        kind = "delete" if deletes[i] else str(kinds[i])
        nbytes = (
            int(cfg.small_put_bytes)
            if (kind == "put" and smalls[i])
            else None
        )
        out.append(
            Request(
                time=float(times[i]),
                object_id=int(perm[ranks[i]]),
                kind=kind,
                tenant=tenant,
                nbytes=nbytes,
            )
        )
    return out


@dataclass(frozen=True)
class TenantProfile:
    """One tenant's traffic shape and service terms.

    ``weight`` is the fabric's weighted-fair quantum ratio (netmodel
    tenant_weights); ``slo_p99`` is the latency target (seconds) the
    gateway's admission controller enforces for this tenant (None =>
    best-effort, never rejected).
    """

    name: str
    arrival_rate: float  # requests/sec (Poisson)
    weight: float = 1.0
    zipf_s: float = 1.1
    put_fraction: float = 0.0
    slo_p99: float | None = None
    delete_fraction: float = 0.0
    small_put_fraction: float = 0.0
    small_put_bytes: int = 256

    def workload(self, num_objects: int, num_requests: int, seed: int) -> WorkloadConfig:
        return WorkloadConfig(
            num_objects=num_objects,
            num_requests=num_requests,
            arrival_rate=self.arrival_rate,
            zipf_s=self.zipf_s,
            put_fraction=self.put_fraction,
            seed=seed,
            delete_fraction=self.delete_fraction,
            small_put_fraction=self.small_put_fraction,
            small_put_bytes=self.small_put_bytes,
        )


def tenant_weight_map(profiles: list[TenantProfile]) -> dict[str, float]:
    return {p.name: p.weight for p in profiles}


def tenant_slo_map(profiles: list[TenantProfile]) -> dict[str, float]:
    return {p.name: p.slo_p99 for p in profiles if p.slo_p99 is not None}


def generate_tenant_requests(
    profiles: list[TenantProfile],
    num_objects: int,
    num_requests_per_tenant: int,
    seed: int = 0,
) -> list[Request]:
    """Independent Poisson/Zipf stream per tenant over the shared object
    catalog, merged by arrival time. Sub-seeds derive from the tenant
    NAME (not list position), so a tenant's stream stays stable when
    other tenants are added, dropped, or reordered."""
    merged: list[Request] = []
    for prof in profiles:
        sub_seed = (seed * 7919 + zlib.crc32(prof.name.encode())) % (2**31)
        wl = prof.workload(num_objects, num_requests_per_tenant, seed=sub_seed)
        merged.extend(generate_requests(wl, tenant=prof.name))
    merged.sort(key=lambda r: r.time)
    return merged


def plan_failures(
    num_failures: int,
    num_nodes: int,
    at_time: float = 0.0,
    spacing: float = 0.0,
    seed: int = 0,
) -> list[FailureEvent]:
    """Pick ``num_failures`` distinct victim nodes; fail the first at
    ``at_time`` and each subsequent one ``spacing`` seconds later."""
    rng = np.random.default_rng(seed + 7919)
    victims = rng.choice(num_nodes, size=num_failures, replace=False)
    return [
        FailureEvent(time=at_time + i * spacing, node=int(v))
        for i, v in enumerate(victims)
    ]
