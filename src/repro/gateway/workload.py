"""Workload synthesis for the serving gateway.

Object popularity is Zipfian (rank-r probability ∝ r^-s over a finite
catalog — the shape measured for blob/photo stores and the warehouse
traces the paper's related work studies), arrivals are Poisson, and node
failures are injected at configurable times. Everything is generated
host-side with numpy from a single seed so runs are reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Request:
    time: float  # arrival (seconds since epoch 0 of the trace)
    object_id: int
    kind: str = "get"  # get | put


@dataclass(frozen=True)
class FailureEvent:
    time: float
    node: int


@dataclass(frozen=True)
class WorkloadConfig:
    num_objects: int
    num_requests: int
    arrival_rate: float = 200.0  # requests/sec (Poisson)
    zipf_s: float = 1.1  # popularity exponent
    put_fraction: float = 0.0  # fraction of requests that are PUTs
    seed: int = 0


def zipf_probs(num_objects: int, s: float) -> np.ndarray:
    """Finite-catalog Zipf pmf: p(rank r) ∝ r^-s, r = 1..num_objects."""
    ranks = np.arange(1, num_objects + 1, dtype=np.float64)
    w = ranks**-s
    return w / w.sum()


def generate_requests(cfg: WorkloadConfig) -> list[Request]:
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.arrival_rate, size=cfg.num_requests)
    times = np.cumsum(gaps)
    # Popular ranks are mapped to shuffled object ids so popularity is not
    # correlated with placement order.
    perm = rng.permutation(cfg.num_objects)
    ranks = rng.choice(cfg.num_objects, size=cfg.num_requests, p=zipf_probs(cfg.num_objects, cfg.zipf_s))
    kinds = np.where(rng.random(cfg.num_requests) < cfg.put_fraction, "put", "get")
    return [
        Request(time=float(times[i]), object_id=int(perm[ranks[i]]), kind=str(kinds[i]))
        for i in range(cfg.num_requests)
    ]


def plan_failures(
    num_failures: int,
    num_nodes: int,
    at_time: float = 0.0,
    spacing: float = 0.0,
    seed: int = 0,
) -> list[FailureEvent]:
    """Pick ``num_failures`` distinct victim nodes; fail the first at
    ``at_time`` and each subsequent one ``spacing`` seconds later."""
    rng = np.random.default_rng(seed + 7919)
    victims = rng.choice(num_nodes, size=num_failures, replace=False)
    return [
        FailureEvent(time=at_time + i * spacing, node=int(v))
        for i, v in enumerate(victims)
    ]
