"""Namespace metadata plane + consistent-hash shard directory.

The namenode/datanode split, in-process: ``MetadataPlane`` owns
everything about the NAMESPACE — object -> (group, row) stripe maps,
group membership, ground truth, tombstones, fault bookkeeping shared by
every data-path actor, and the object -> shard directory — while
``ObjectGateway`` shards own only data-path state (cache contents,
engine pool, coalescer, repair queue). N gateway shards constructed
over one plane serve one namespace over one ``BlockStore``/fabric;
a single unsharded gateway builds a private plane and behaves exactly
as before.

Routing is CONSISTENT HASHING (the crc32 placement hash from the block
store, lifted to the namespace): each shard projects ``vnodes`` virtual
points onto a 32-bit ring, an object id routes to the first live point
clockwise of its hash. Killing a shard removes only that shard's
points, so exactly the dead shard's ranges move to survivors — the
whole-shard-death failover reassigns namespace WITHOUT reshuffling
objects that never lived there (asserted by the failover test).

Cache coherence: every shard registers its LRU/negative cache with the
plane; invalidation-style events (PUT overwrites, corruption
tombstones, repair heals, node recovers) fan out to ``caches`` so no
shard serves a stale or known-down block another shard learned about
first.
"""

from __future__ import annotations

import zlib

BlockKey = tuple[str, int, int]


def _mix(h: int) -> int:
    """Murmur3 finalizer over a crc32 seed. crc32 alone is GF(2)-LINEAR:
    the points of two shards at the same vnode index differ by a
    constant xor, so whole point sets land in correlated clusters and
    the ring's arcs skew badly (measured: 34 vs 6 of 80 groups on a
    4-shard ring). The finalizer's multiply-xorshift rounds break the
    linearity; the crc32 stays as the stable, process-independent seed.
    """
    h &= 0xFFFFFFFF
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return h


def ring_hash(key: str) -> int:
    """Position of ``key`` on the 32-bit ring (crc32 seed, mixed)."""
    return _mix(zlib.crc32(key.encode()))


class ShardDirectory:
    """Consistent-hash ring over shard ids (crc32-keyed, process-stable).

    ``vnodes`` virtual points per shard smooth the ranges; lookups
    binary-search the sorted point list. ``remove_shard`` deletes only
    the dead shard's points — the minimal-movement property the
    failover test pins."""

    def __init__(self, shard_ids, vnodes: int = 64):
        self.vnodes = int(vnodes)
        self._points: list[tuple[int, int]] = []  # (hash, shard_id), sorted
        self._shards: set[int] = set()
        for sid in shard_ids:
            self.add_shard(sid)

    @property
    def shards(self) -> list[int]:
        return sorted(self._shards)

    def add_shard(self, shard_id: int) -> None:
        sid = int(shard_id)
        if sid in self._shards:
            return
        self._shards.add(sid)
        for v in range(self.vnodes):
            h = ring_hash(f"s{sid}#v{v}")
            self._points.append((h, sid))
        self._points.sort()

    def remove_shard(self, shard_id: int) -> None:
        sid = int(shard_id)
        if sid not in self._shards:
            return
        if len(self._shards) == 1:
            raise ValueError("cannot remove the last shard from the directory")
        self._shards.discard(sid)
        self._points = [(h, s) for h, s in self._points if s != sid]

    def _lookup(self, h: int) -> int:
        pts = self._points
        # first point at/after h, wrapping (bisect over (hash, sid) pairs)
        lo, hi = 0, len(pts)
        while lo < hi:
            mid = (lo + hi) // 2
            if pts[mid][0] < h:
                lo = mid + 1
            else:
                hi = mid
        return pts[lo % len(pts)][1]

    def shard_for(self, object_id: int) -> int:
        """Owning shard of an object id (request routing)."""
        return self._lookup(ring_hash(f"o{int(object_id)}"))

    def shard_for_group(self, group_id: str) -> int:
        """Owning shard of a GROUP (repair ownership): each group's
        background repair runs on exactly one shard, so N shards split
        the repair backlog instead of racing over it."""
        return self._lookup(ring_hash(f"g:{group_id}"))


class MetadataPlane:
    """Shared namespace state for one logical gateway (1..N shards).

    Shards alias these containers directly and mutate them in place —
    the plane is the single source of truth for what exists, what is
    deleted, what is lost/healing/corrupt, and which shard owns what.
    Per-shard state (caches, pools, repair queues, hedge ledgers) stays
    on the shards; the plane only keeps the cache REGISTRY so coherence
    events can fan out."""

    def __init__(self, shard_ids=(0,), vnodes: int = 64):
        self.directory = ShardDirectory(shard_ids, vnodes=vnodes)
        # namespace maps (ObjectGateway.load_objects / PUT path fill these)
        self.objects: dict[int, tuple[str, int]] = {}  # oid -> (gid, row)
        self.groups: dict[str, list[int]] = {}  # gid -> member oids
        self.expected: dict = {}  # oid -> ground-truth (k, q) array
        self.deleted: set[int] = set()  # tombstoned oids
        self.block_bytes: int = 0
        # fault bookkeeping shared by every shard's planner/repair/audit
        self.lost_at: dict[BlockKey, float] = {}
        self.healing: dict[BlockKey, float] = {}
        self.corrupted_at: dict[BlockKey, float] = {}
        self.repair_stuck: dict[str, frozenset] = {}
        self.reprice_on_heal: set[BlockKey] = set()
        # registered per-shard block caches (coherence fan-out targets)
        self.caches: list = []

    # -- cache coherence -------------------------------------------------------
    def register_cache(self, cache) -> None:
        if cache is not None and cache not in self.caches:
            self.caches.append(cache)

    def unregister_cache(self, cache) -> None:
        if cache in self.caches:
            self.caches.remove(cache)

    def put_negative(self, key: BlockKey, now: float, ttl: float) -> None:
        """Tombstone ``key`` in EVERY shard's negative cache."""
        for cache in self.caches:
            cache.put_negative(key, now, ttl)

    def purge_negative(self, keys) -> int:
        """Drop negative entries for ``keys`` across every shard;
        returns how many live entries died cluster-wide."""
        keys = list(keys)
        return sum(cache.purge_negative(keys) for cache in self.caches)

    def invalidate(self, key: BlockKey) -> None:
        """Evict stale bytes for ``key`` from EVERY shard's cache (a PUT
        overwrote the block, or repair rewrote it)."""
        for cache in self.caches:
            cache.invalidate(key)

    def refresh_cost(self, key: BlockKey, cost: float) -> None:
        for cache in self.caches:
            cache.refresh_cost(key, cost)

    # -- routing ---------------------------------------------------------------
    def shard_for(self, object_id: int) -> int:
        return self.directory.shard_for(object_id)

    def owns_group(self, shard_id: int | None, group_id: str) -> bool:
        """Repair-ownership filter. Unsharded gateways (shard_id None)
        own everything; a live shard owns the groups the directory
        hashes to it (redistributed automatically when a shard dies)."""
        if shard_id is None:
            return True
        return self.directory.shard_for_group(group_id) == shard_id
