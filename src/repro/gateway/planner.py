"""Per-request degraded-read planning (the paper's Table 1 cost model,
applied online).

A GET for object ``row`` of a group needs its k data blocks. For each
missing data block the gateway can reconstruct either

  * vertically  — XOR of the t surviving blocks of its COLUMN (needs the
    whole column minus this row intact): t source blocks, and
  * horizontally — RS decode over k surviving blocks of its ROW: k
    source blocks, but ONE decode covers every missing block of the row.

The planner sees the live failure set and picks the cheapest total plan:
all-vertical costs t per missing block; one horizontal decode costs k
for any number of missing blocks; if any column is broken the horizontal
path is forced. Plans carry host-side coefficient matrices so the
coalescer can batch decodes across concurrent requests.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.product_code import CoreCode
from repro.storage.blockstore import BlockKey, BlockStore


class UnreadableObjectError(RuntimeError):
    """Neither the vertical nor the horizontal path can serve the read."""


@dataclass(frozen=True)
class DecodeOp:
    """One reconstruction: targets = coeffs @ sources (GF(256)), or a
    plain XOR over sources when kind == "V" (coeffs is None)."""

    kind: str  # "V" | "H"
    group_id: str
    row: int
    targets: tuple[int, ...]  # data columns this op regenerates
    sources: tuple[BlockKey, ...]
    coeffs: np.ndarray | None  # (len(targets), len(sources)) for "H"

    @property
    def shape_key(self) -> tuple:
        """Decode-shape bucket: ops sharing this key can share one
        batched kernel launch."""
        return (self.kind, len(self.targets), len(self.sources))


@dataclass(frozen=True)
class ReadPlan:
    group_id: str
    row: int
    direct: tuple[BlockKey, ...]  # available data blocks, fetched as-is
    decodes: tuple[DecodeOp, ...]
    # Clock at which the plan was made against the live failure set; the
    # pipelined gateway uses it as the fetch stage's earliest start (a
    # plan is only valid from the moment it was planned).
    planned_at: float = 0.0

    @property
    def degraded(self) -> bool:
        return bool(self.decodes)

    @property
    def source_keys(self) -> tuple[BlockKey, ...]:
        """All distinct blocks the plan touches (direct + decode inputs)."""
        seen: dict[BlockKey, None] = dict.fromkeys(self.direct)
        for op in self.decodes:
            seen.update(dict.fromkeys(op.sources))
        return tuple(seen)

    @property
    def reconstruction_blocks(self) -> int:
        """Source blocks consumed by reconstruction — the paper's Table 1
        traffic figure (t per vertical repair, k per horizontal decode)."""
        return sum(len(op.sources) for op in self.decodes)


class DegradedReadPlanner:
    def __init__(self, store: BlockStore, code: CoreCode, available_fn=None):
        """``available_fn(key) -> bool`` overrides raw store availability —
        the gateway passes "in the store OR in the block cache" so cached
        reconstructions short-circuit replanning."""
        self.store = store
        self.code = code
        self._available = available_fn if available_fn is not None else store.available

    def plan(self, group_id: str, row: int, at: float = 0.0) -> ReadPlan:
        """The Table-1-cheapest viable plan (first candidate)."""
        return self.candidates(group_id, row, at=at)[0]

    def candidates(
        self, group_id: str, row: int, at: float = 0.0
    ) -> tuple[ReadPlan, ...]:
        """Every viable plan for this read against the live failure set,
        Table-1-cheapest first. A healthy object has exactly one (all
        direct); a degraded one has the vertical plan (t sources per
        missing block) and/or the horizontal plan (k sources covering
        the whole row). The gateway's SLO admission controller re-ranks
        these by *estimated completion time* when a request is about to
        bust its tenant's latency target — under a backlogged decode
        engine the Table-1 byte-cheapest plan is not always the
        latency-cheapest one."""
        code = self.code
        k, n = code.k, code.n
        avail_data = [
            c for c in range(k) if self._available((group_id, row, c))
        ]
        missing = [c for c in range(k) if c not in avail_data]
        direct = tuple((group_id, row, c) for c in avail_data)
        if not missing:
            return (ReadPlan(group_id, row, direct, (), planned_at=at),)

        vertical_ok = all(self._column_intact(group_id, row, c) for c in missing)
        avail_row = [
            c for c in range(n) if self._available((group_id, row, c))
        ]
        horizontal_ok = len(avail_row) >= k

        vertical = (
            ReadPlan(
                group_id,
                row,
                direct,
                tuple(self._vertical_op(group_id, row, c) for c in missing),
                planned_at=at,
            )
            if vertical_ok
            else None
        )
        horizontal = (
            ReadPlan(
                group_id,
                row,
                direct,
                (self._horizontal_op(group_id, row, avail_row, missing),),
                planned_at=at,
            )
            if horizontal_ok
            else None
        )
        # Table 1: vertical = t reads per block, horizontal = k reads for
        # the whole row. Prefer vertical on ties (pure XOR vs GF decode).
        v_cost = code.t * len(missing)
        if vertical is not None and horizontal is not None:
            ordered = (
                (vertical, horizontal) if v_cost <= k else (horizontal, vertical)
            )
            return ordered
        if vertical is not None:
            return (vertical,)
        if horizontal is not None:
            return (horizontal,)
        raise UnreadableObjectError(
            f"object ({group_id}, row {row}): columns {missing} broken and "
            f"only {len(avail_row)} < k={k} row blocks survive"
        )

    def recovery_ops(
        self, group_id: str, row: int, col: int
    ) -> tuple[DecodeOp, ...]:
        """Every viable single-block reconstruction of ONE data column,
        Table-1-cheapest first — the hedged-fetch alternate paths: when
        the direct fetch of (group_id, row, col) is stuck behind a
        fail-slow source, the gateway races it against one of these
        instead of waiting. CORE's vertical XOR (t sources) when the
        column survives, RS over the row (k sources) when enough row
        blocks do. The gateway picks among them by PLACEMENT: vertical
        sources share the stuck column's node under column-aligned
        placement, so the byte-cheapest op can be the one op guaranteed
        to lose the race."""
        ops = []
        if self._column_intact(group_id, row, col):
            ops.append(self._vertical_op(group_id, row, col))
        avail_row = [
            c
            for c in range(self.code.n)
            if c != col and self._available((group_id, row, c))
        ]
        if len(avail_row) >= self.code.k:
            ops.append(self._horizontal_op(group_id, row, avail_row, [col]))
        return tuple(ops)

    def recovery_op(self, group_id: str, row: int, col: int) -> DecodeOp | None:
        """Cheapest single-block reconstruction (first of recovery_ops)."""
        ops = self.recovery_ops(group_id, row, col)
        return ops[0] if ops else None

    # -- helpers ---------------------------------------------------------------
    def _column_intact(self, group_id: str, row: int, col: int) -> bool:
        return all(
            self._available((group_id, r, col))
            for r in range(self.code.rows)
            if r != row
        )

    def _vertical_op(self, group_id: str, row: int, col: int) -> DecodeOp:
        sources = tuple(
            (group_id, r, col) for r in range(self.code.rows) if r != row
        )
        return DecodeOp("V", group_id, row, (col,), sources, None)

    def _horizontal_op(
        self, group_id: str, row: int, avail_row: list[int], missing: list[int]
    ) -> DecodeOp:
        # Prefer the available data columns as sources — the GET fetches
        # them anyway, so total distinct blocks stays at k (Table 1).
        preferred = [c for c in avail_row if c < self.code.k] + [
            c for c in avail_row if c >= self.code.k
        ]
        row_ids, coeffs = self.code.horizontal.repair_matrix(
            np.asarray(preferred), np.asarray(missing)
        )
        sources = tuple((group_id, row, int(c)) for c in row_ids)
        return DecodeOp(
            "H", group_id, row, tuple(missing), sources, np.asarray(coeffs)
        )
