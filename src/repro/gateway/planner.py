"""Per-request degraded-read planning (the paper's Table 1 cost model,
applied online).

A GET for object ``row`` of a group needs its k data blocks. For each
missing data block the gateway can reconstruct either

  * vertically  — XOR of the t surviving blocks of its COLUMN (needs the
    whole column minus this row intact): t source blocks, and
  * horizontally — RS decode over k surviving blocks of its ROW: k
    source blocks, but ONE decode covers every missing block of the row.

The planner sees the live failure set and picks the cheapest total plan:
all-vertical costs t per missing block; one horizontal decode costs k
for any number of missing blocks; if any column is broken the horizontal
path is forced. Plans carry host-side coefficient matrices so the
coalescer can batch decodes across concurrent requests.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.coding import lrc as lrc_mod
from repro.coding import rs
from repro.core.product_code import CoreCode, CoreCodec
from repro.storage.blockstore import BlockKey, BlockStore


class UnreadableObjectError(RuntimeError):
    """Neither the vertical nor the horizontal path can serve the read."""


@dataclass(frozen=True)
class DecodeOp:
    """One reconstruction: targets = coeffs @ sources (GF(256)), or a
    plain XOR over sources when kind == "V" (coeffs is None)."""

    kind: str  # "V" | "H"
    group_id: str
    row: int
    targets: tuple[int, ...]  # data columns this op regenerates
    sources: tuple[BlockKey, ...]
    coeffs: np.ndarray | None  # (len(targets), len(sources)) for "H"

    @property
    def shape_key(self) -> tuple:
        """Decode-shape bucket: ops sharing this key can share one
        batched kernel launch."""
        return (self.kind, len(self.targets), len(self.sources))


@dataclass(frozen=True)
class ReadPlan:
    group_id: str
    row: int
    direct: tuple[BlockKey, ...]  # available data blocks, fetched as-is
    decodes: tuple[DecodeOp, ...]
    # Clock at which the plan was made against the live failure set; the
    # pipelined gateway uses it as the fetch stage's earliest start (a
    # plan is only valid from the moment it was planned).
    planned_at: float = 0.0

    @property
    def degraded(self) -> bool:
        return bool(self.decodes)

    @property
    def source_keys(self) -> tuple[BlockKey, ...]:
        """All distinct blocks the plan touches (direct + decode inputs)."""
        seen: dict[BlockKey, None] = dict.fromkeys(self.direct)
        for op in self.decodes:
            seen.update(dict.fromkeys(op.sources))
        return tuple(seen)

    @property
    def reconstruction_blocks(self) -> int:
        """Source blocks consumed by reconstruction — the paper's Table 1
        traffic figure (t per vertical repair, k per horizontal decode)."""
        return sum(len(op.sources) for op in self.decodes)


class CodeFamily:
    """A code family as a per-namespace property (ROADMAP bake-off item).

    Everything the serving and repair planes need to know about an
    erasure code lives behind this interface, so RS, CORE, and LRC all
    run through the SAME gateway, tenant workload, and fault traces:

      * geometry — how many block rows a group matrix has, how many
        objects pack into one group, and the storage stretch;
      * the encode path (``encode_group``);
      * degraded-read candidate enumeration (``candidates`` /
        ``recovery_ops``) producing coalescer-ready :class:`DecodeOp`
        uops ("V" = plain XOR over any source count, "H" = GF(256)
        matmul with a host-side coefficient plane);
      * the repair cost surface (``single_repair_cost`` /
        ``avg_repair_cost`` in source blocks per repaired block, and
        ``repair_plan`` for the row-coded families) that
        :class:`repro.storage.repair.BlockFixer` and the bake-off bench
        price against;
      * ``tolerance`` — the number of concurrent node failures the
        family survives under anti-colocated placement, which bounds
        scenario admission (``ScenarioConfig.max_concurrent_failures``).

    ``available(key) -> bool`` arguments are the planner's liveness
    oracle (store OR cache), so families never touch the store directly.
    """

    name = "?"

    # -- geometry -----------------------------------------------------------
    rows: int
    n: int
    k: int
    objects_per_group: int

    @property
    def tolerance(self) -> int:
        """Concurrent node failures always survivable (anti-colocated)."""
        raise NotImplementedError

    @property
    def storage_overhead(self) -> float:
        """Stored bytes per data byte (the paper's stretch factor)."""
        raise NotImplementedError

    @property
    def degraded_fetch_blocks(self) -> int:
        """Pessimistic distinct-block count of one degraded GET — the
        admission controller's foreground-pressure unit."""
        raise NotImplementedError

    def encode_group(self, objects):
        """objects (objects_per_group, k, q) -> group matrix (rows, n, q)."""
        raise NotImplementedError

    def group_recoverable(self, available) -> bool:
        """Whole-group durability check for the audit plane.

        ``available(key) -> bool``; keys range over (row, col) pairs of
        one group with group_id "" (the oracle closes over the gid)."""
        raise NotImplementedError

    # -- degraded-read candidate enumeration --------------------------------
    def candidates(
        self, available, group_id: str, row: int, at: float = 0.0
    ) -> tuple[ReadPlan, ...]:
        raise NotImplementedError

    def recovery_ops(
        self, available, group_id: str, row: int, col: int
    ) -> tuple[DecodeOp, ...]:
        raise NotImplementedError

    # -- repair cost surface ------------------------------------------------
    def single_repair_cost(self, col: int) -> int:
        """Source blocks to regenerate one lost block in column ``col``."""
        raise NotImplementedError

    @property
    def avg_repair_cost(self) -> float:
        """Mean single-block repair traffic over all n columns."""
        return sum(self.single_repair_cost(c) for c in range(self.n)) / self.n

    def repair_plan(
        self, failed: set[int]
    ) -> list[tuple[str, list[int], list[int]]] | None:
        """Row-coded families (rows == 1): ordered steps
        ``(kind, sources, repaired)`` with kind 'local' (XOR) or 'global'
        (GF decode), or None when unrecoverable. CORE repairs through the
        two-dimensional scheduler in storage/repair.py instead."""
        raise NotImplementedError(f"{self.name} repairs via BlockFixer schedulers")


class CoreFamily(CodeFamily):
    """The (n, k, t) CORE product code — the default namespace family.

    Candidate enumeration is the paper's Table 1 applied online: t
    sources per missing block vertically, k sources for the whole row
    horizontally, vertical preferred on ties (pure XOR vs GF decode)."""

    name = "core"

    def __init__(self, code: CoreCode):
        self.code = code
        self.rows = code.rows
        self.n = code.n
        self.k = code.k
        self.objects_per_group = code.t
        self._codec = CoreCodec(code)

    @property
    def tolerance(self) -> int:
        # Any <= m erasures leave every row with >= k survivors, so the
        # horizontal code alone guarantees recovery; vertical XOR only
        # ever makes repairs cheaper.
        return self.code.m

    @property
    def storage_overhead(self) -> float:
        return self.code.stretch

    @property
    def degraded_fetch_blocks(self) -> int:
        return self.code.k + self.code.t

    def encode_group(self, objects):
        return self._codec.encode(objects)

    def group_recoverable(self, available) -> bool:
        # Row-wise horizontal sufficiency matches ``tolerance``: the
        # fixer's 2D scheduler can always do at least this well.
        return all(
            sum(1 for c in range(self.n) if available((r, c))) >= self.k
            for r in range(self.rows)
        )

    def candidates(
        self, available, group_id: str, row: int, at: float = 0.0
    ) -> tuple[ReadPlan, ...]:
        code = self.code
        k, n = code.k, code.n
        avail_data = [c for c in range(k) if available((group_id, row, c))]
        missing = [c for c in range(k) if c not in avail_data]
        direct = tuple((group_id, row, c) for c in avail_data)
        if not missing:
            return (ReadPlan(group_id, row, direct, (), planned_at=at),)

        vertical_ok = all(
            self._column_intact(available, group_id, row, c) for c in missing
        )
        avail_row = [c for c in range(n) if available((group_id, row, c))]
        horizontal_ok = len(avail_row) >= k

        vertical = (
            ReadPlan(
                group_id,
                row,
                direct,
                tuple(self._vertical_op(group_id, row, c) for c in missing),
                planned_at=at,
            )
            if vertical_ok
            else None
        )
        horizontal = (
            ReadPlan(
                group_id,
                row,
                direct,
                (self._horizontal_op(group_id, row, avail_row, missing),),
                planned_at=at,
            )
            if horizontal_ok
            else None
        )
        # Table 1: vertical = t reads per block, horizontal = k reads for
        # the whole row. Prefer vertical on ties (pure XOR vs GF decode).
        v_cost = code.t * len(missing)
        if vertical is not None and horizontal is not None:
            ordered = (
                (vertical, horizontal) if v_cost <= k else (horizontal, vertical)
            )
            return ordered
        if vertical is not None:
            return (vertical,)
        if horizontal is not None:
            return (horizontal,)
        raise UnreadableObjectError(
            f"object ({group_id}, row {row}): columns {missing} broken and "
            f"only {len(avail_row)} < k={k} row blocks survive"
        )

    def recovery_ops(
        self, available, group_id: str, row: int, col: int
    ) -> tuple[DecodeOp, ...]:
        ops = []
        if self._column_intact(available, group_id, row, col):
            ops.append(self._vertical_op(group_id, row, col))
        avail_row = [
            c
            for c in range(self.code.n)
            if c != col and available((group_id, row, c))
        ]
        if len(avail_row) >= self.code.k:
            ops.append(self._horizontal_op(group_id, row, avail_row, [col]))
        return tuple(ops)

    def single_repair_cost(self, col: int) -> int:
        return self.code.t  # vertical XOR of the column's survivors

    def repair_plan(self, failed):
        raise NotImplementedError("core repairs via BlockFixer 2D schedulers")

    # -- helpers ------------------------------------------------------------
    def _column_intact(self, available, group_id: str, row: int, col: int) -> bool:
        return all(
            available((group_id, r, col))
            for r in range(self.code.rows)
            if r != row
        )

    def _vertical_op(self, group_id: str, row: int, col: int) -> DecodeOp:
        sources = tuple(
            (group_id, r, col) for r in range(self.code.rows) if r != row
        )
        return DecodeOp("V", group_id, row, (col,), sources, None)

    def _horizontal_op(
        self, group_id: str, row: int, avail_row: list[int], missing: list[int]
    ) -> DecodeOp:
        # Prefer the available data columns as sources — the GET fetches
        # them anyway, so total distinct blocks stays at k (Table 1).
        preferred = [c for c in avail_row if c < self.code.k] + [
            c for c in avail_row if c >= self.code.k
        ]
        row_ids, coeffs = self.code.horizontal.repair_matrix(
            np.asarray(preferred), np.asarray(missing)
        )
        sources = tuple((group_id, row, int(c)) for c in row_ids)
        return DecodeOp(
            "H", group_id, row, tuple(missing), sources, np.asarray(coeffs)
        )


class RowCodeFamily(CodeFamily):
    """Shared machinery for the single-row (rows == 1) families: one
    object per group stored as one (n,) codeword row. Degraded reads are
    one "H" decode over >= k survivors; subclasses add locality."""

    rows = 1
    objects_per_group = 1

    def __init__(self, code):
        self.code = code
        self.n = code.n
        self.k = code.k

    @property
    def storage_overhead(self) -> float:
        return self.n / self.k

    @property
    def degraded_fetch_blocks(self) -> int:
        return self.k

    def encode_group(self, objects):
        return self.code.encode(objects)  # (1, k, q) -> (1, n, q)

    def group_recoverable(self, available) -> bool:
        avail = [c for c in range(self.n) if available((0, c))]
        return self.code.decodable(np.asarray(avail))

    def candidates(
        self, available, group_id: str, row: int, at: float = 0.0
    ) -> tuple[ReadPlan, ...]:
        avail_data = [c for c in range(self.k) if available((group_id, row, c))]
        missing = [c for c in range(self.k) if c not in avail_data]
        direct = tuple((group_id, row, c) for c in avail_data)
        if not missing:
            return (ReadPlan(group_id, row, direct, (), planned_at=at),)
        plans = self._degraded_plans(available, group_id, row, direct, missing, at)
        if not plans:
            raise UnreadableObjectError(
                f"object ({group_id}, row {row}): columns {missing} broken "
                f"and fewer than k={self.k} row blocks survive"
            )
        return tuple(plans)

    def recovery_ops(
        self, available, group_id: str, row: int, col: int
    ) -> tuple[DecodeOp, ...]:
        ops = []
        local = self._local_op(available, group_id, row, col)
        if local is not None:
            ops.append(local)
        avail_row = [
            c
            for c in range(self.n)
            if c != col and available((group_id, row, c))
        ]
        if self.code.decodable(np.asarray(avail_row)):
            ops.append(self._global_op(group_id, row, avail_row, [col]))
        return tuple(ops)

    def single_repair_cost(self, col: int) -> int:
        return self.k

    def repair_plan(self, failed):
        failed = sorted(set(failed))
        available = [c for c in range((self.n)) if c not in failed]
        if not self.code.decodable(np.asarray(available)):
            return None
        row_ids, _ = self.code.repair_matrix(
            np.asarray(available), np.asarray(failed)
        )
        return [("global", [int(r) for r in row_ids], list(failed))]

    # -- hooks --------------------------------------------------------------
    def _degraded_plans(self, available, group_id, row, direct, missing, at):
        plans = []
        avail_row = [c for c in range(self.n) if available((group_id, row, c))]
        if len(avail_row) >= self.k and self.code.decodable(np.asarray(avail_row)):
            plans.append(
                ReadPlan(
                    group_id,
                    row,
                    direct,
                    (self._global_op(group_id, row, avail_row, missing),),
                    planned_at=at,
                )
            )
        return plans

    def _local_op(self, available, group_id, row, col) -> DecodeOp | None:
        return None  # plain MDS codes have no locality

    def _global_op(
        self, group_id: str, row: int, avail_row: list[int], missing: list[int]
    ) -> DecodeOp:
        # Prefer data columns as sources, same rationale as CORE's
        # horizontal op: the GET fetches them anyway.
        preferred = [c for c in avail_row if c < self.k] + [
            c for c in avail_row if c >= self.k
        ]
        row_ids, coeffs = self.code.repair_matrix(
            np.asarray(preferred), np.asarray(missing)
        )
        sources = tuple((group_id, row, int(c)) for c in row_ids)
        return DecodeOp(
            "H", group_id, row, tuple(missing), sources, np.asarray(coeffs)
        )


class RSFamily(RowCodeFamily):
    """Plain (n, k) Reed-Solomon — the paper's "traditional erasure
    code" baseline: every repair and every degraded read costs k source
    blocks, storage stretch n/k."""

    name = "rs"

    def __init__(self, n: int, k: int):
        super().__init__(rs.make_rs(n, k))

    @property
    def tolerance(self) -> int:
        return self.n - self.k  # MDS


class LRCFamily(RowCodeFamily):
    """(n, k) Azure-style Local Reconstruction Code (coding/lrc.py).

    Single-block loss inside a local group repairs from the k/2
    surviving group members by plain XOR (a "V" uop — the coalescer's
    XOR path takes any source count); multi-loss patterns fall back to
    one global "H" decode over >= k independent survivors."""

    name = "lrc"

    def __init__(self, n: int, k: int):
        super().__init__(lrc_mod.make_lrc(n, k))

    @property
    def tolerance(self) -> int:
        # d = n - k: any n-k-1 erasures decode (many n-k patterns do
        # too, but admission bounds on the guarantee).
        return self.n - self.k - 1

    def single_repair_cost(self, col: int) -> int:
        return self.k // 2 if self.code.local_group(col) is not None else self.k

    @property
    def avg_repair_cost(self) -> float:
        return lrc_mod.avg_single_repair_cost(self.n, self.k)

    def repair_plan(self, failed):
        return self.code.repair_plan(set(failed))

    def _degraded_plans(self, available, group_id, row, direct, missing, at):
        plans = []
        local_ops = []
        for col in missing:
            op = self._local_op(available, group_id, row, col)
            if op is None:
                break
            local_ops.append(op)
        if len(local_ops) == len(missing):
            plans.append(
                ReadPlan(group_id, row, direct, tuple(local_ops), planned_at=at)
            )
        plans.extend(
            super()._degraded_plans(available, group_id, row, direct, missing, at)
        )
        # Order by traffic: local XOR costs k/2 per missing block, the
        # global decode k for the whole row. Prefer local on ties.
        plans.sort(key=lambda p: p.reconstruction_blocks)
        return plans

    def _local_op(self, available, group_id, row, col) -> DecodeOp | None:
        grp = self.code.local_group(col)
        if grp is None:
            return None
        sources = [g for g in grp if g != col]
        if not all(available((group_id, row, g)) for g in sources):
            return None
        return DecodeOp(
            "V",
            group_id,
            row,
            (col,),
            tuple((group_id, row, g) for g in sources),
            None,
        )


FAMILY_NAMES = ("core", "rs", "lrc")


def make_family(code: CoreCode, name: str = "core") -> CodeFamily:
    """Build the named family on the shared (n, k) geometry of ``code``.

    RS and LRC derive (n, k) from the CORE parameters so all three
    families stripe the same row shape — the bake-off comparison and the
    GatewayConfig plumbing both key off one CoreCode."""
    if name == "core":
        return CoreFamily(code)
    if name == "rs":
        return RSFamily(code.n, code.k)
    if name == "lrc":
        return LRCFamily(code.n, code.k)
    raise ValueError(f"unknown code family {name!r} (want one of {FAMILY_NAMES})")


class DegradedReadPlanner:
    def __init__(
        self,
        store: BlockStore,
        code: CoreCode,
        available_fn=None,
        family: CodeFamily | None = None,
    ):
        """``available_fn(key) -> bool`` overrides raw store availability —
        the gateway passes "in the store OR in the block cache" so cached
        reconstructions short-circuit replanning. ``family`` selects the
        code family (default: the CORE product code on ``code``)."""
        self.store = store
        self.code = code
        self.family = family if family is not None else CoreFamily(code)
        self._available = available_fn if available_fn is not None else store.available

    def plan(self, group_id: str, row: int, at: float = 0.0) -> ReadPlan:
        """The cost-model-cheapest viable plan (first candidate)."""
        return self.candidates(group_id, row, at=at)[0]

    def candidates(
        self, group_id: str, row: int, at: float = 0.0
    ) -> tuple[ReadPlan, ...]:
        """Every viable plan for this read against the live failure set,
        family-cost-cheapest first (the paper's Table 1 for CORE). A
        healthy object has exactly one (all direct). The gateway's SLO
        admission controller re-ranks these by *estimated completion
        time* when a request is about to bust its tenant's latency
        target — under a backlogged decode engine the byte-cheapest plan
        is not always the latency-cheapest one."""
        return self.family.candidates(self._available, group_id, row, at=at)

    def recovery_ops(
        self, group_id: str, row: int, col: int
    ) -> tuple[DecodeOp, ...]:
        """Every viable single-block reconstruction of ONE data column,
        cheapest first — the hedged-fetch alternate paths: when the
        direct fetch of (group_id, row, col) is stuck behind a fail-slow
        source, the gateway races it against one of these instead of
        waiting. The gateway picks among them by PLACEMENT: a
        reconstruction whose sources share the stuck node loses the
        race, so the byte-cheapest op is not always the winner."""
        return self.family.recovery_ops(self._available, group_id, row, col)

    def recovery_op(self, group_id: str, row: int, col: int) -> DecodeOp | None:
        """Cheapest single-block reconstruction (first of recovery_ops)."""
        ops = self.recovery_ops(group_id, row, col)
        return ops[0] if ops else None
