# Client-facing object-storage serving layer over the simulated CORE
# cluster: Zipf/Poisson workloads, per-request degraded-read planning
# (paper Table 1), shape-bucketed batched GF(256) decode, LRU block
# caching, and foreground/background fabric sharing with repair.
from repro.gateway.cache import CacheStats, LRUBlockCache
from repro.gateway.coalescer import CoalescerStats, DecodeCoalescer
from repro.gateway.gateway import (
    GatewayConfig,
    GatewayReport,
    ObjectGateway,
    RequestRecord,
)
from repro.gateway.planner import (
    DecodeOp,
    DegradedReadPlanner,
    ReadPlan,
    UnreadableObjectError,
)
from repro.gateway.workload import (
    FailureEvent,
    Request,
    WorkloadConfig,
    generate_requests,
    plan_failures,
    zipf_probs,
)

__all__ = [
    "CacheStats",
    "LRUBlockCache",
    "CoalescerStats",
    "DecodeCoalescer",
    "GatewayConfig",
    "GatewayReport",
    "ObjectGateway",
    "RequestRecord",
    "DecodeOp",
    "DegradedReadPlanner",
    "ReadPlan",
    "UnreadableObjectError",
    "FailureEvent",
    "Request",
    "WorkloadConfig",
    "generate_requests",
    "plan_failures",
    "zipf_probs",
]
