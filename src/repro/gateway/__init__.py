# Client-facing object-storage serving layer over the simulated CORE
# cluster: Zipf/Poisson workloads, per-request degraded-read planning
# (paper Table 1), a pipelined fetch->decode->verify dataplane with
# shape-bucketed batched GF(256) decode (ladder-padded, autotuned,
# bounded jit cache), rebuild-cost-aware block caching, and preemptive
# quantum fabric sharing between foreground reads and background repair.
from repro.gateway.cache import CacheStats, LRUBlockCache
from repro.gateway.coalescer import PAD_LADDER, CoalescerStats, DecodeCoalescer
from repro.gateway.gateway import (
    GatewayConfig,
    GatewayReport,
    ObjectGateway,
    RequestRecord,
)
from repro.gateway.planner import (
    DecodeOp,
    DegradedReadPlanner,
    ReadPlan,
    UnreadableObjectError,
)
from repro.gateway.workload import (
    FailureEvent,
    Request,
    WorkloadConfig,
    generate_requests,
    plan_failures,
    zipf_probs,
)

__all__ = [
    "CacheStats",
    "LRUBlockCache",
    "PAD_LADDER",
    "CoalescerStats",
    "DecodeCoalescer",
    "GatewayConfig",
    "GatewayReport",
    "ObjectGateway",
    "RequestRecord",
    "DecodeOp",
    "DegradedReadPlanner",
    "ReadPlan",
    "UnreadableObjectError",
    "FailureEvent",
    "Request",
    "WorkloadConfig",
    "generate_requests",
    "plan_failures",
    "zipf_probs",
]
