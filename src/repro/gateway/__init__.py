# Client-facing object-storage serving layer over the simulated CORE
# cluster: Zipf/Poisson workloads, per-request degraded-read planning
# (paper Table 1), a pipelined fetch->decode->verify dataplane whose
# decode stage is the ragged MEGAKERNEL (GatewayConfig.coalesce,
# default "ragged"): a window's whole mixed-shape decode set — H and V
# ops of any (M, K, blocklen) — is staged as fixed-width descriptor
# tiles and decoded in ONE Pallas launch per kind, with <= 2 traced
# signatures per kind and only tail-tile padding; the measured launch
# time is split by tile ranges into per-op LaunchUnits so the engine
# pool spreads one launch across engines. coalesce="bucketed" keeps
# the per-shape stacked launches (ladder-padded, autotuned) as the
# measured baseline. Plus rebuild-cost-aware block caching and
# weighted-fair quantum fabric sharing between any number of tenants.
#
# Tenancy and SLOs: every request is tagged with a tenant; each tenant's
# fabric traffic is shaped by its weighted-fair quantum ratio
# (GatewayConfig.tenant_weights — background repair is just the "repair"
# tenant, whose weight defaults to background_share), and tenants may
# declare a p99 latency target (tenant_slo_p99). The admission
# controller estimates each arriving GET's completion time from the
# client-NIC fetch serialization, the decode-engine backlog, and the
# measured per-launch decode cost; requests that would bust their
# tenant's SLO are rejected up front (admission="reject") or first
# degraded to the latency-cheapest viable plan (admission="degrade").
# Decode runs on num_engines parallel simulated engine timelines with
# least-loaded dispatch under per-tenant engine shares (EnginePool:
# full-weight tenants dispatch tenant-blind; a share-w tenant is
# rate-capped at w of the pool's throughput), so decode-bound degraded
# workloads scale with the engine pool while throttled tenants cannot
# crowd it. Per-tenant latency, rejection, starvation, and
# deadline-miss accounting surface in GatewayReport and NetSimulator.
#
# Fault scenarios + closed-loop repair (see repro.scenario for the
# trace DSL): serve() consumes node-level cluster events mid-run —
# FailureEvent (transient crash), NodeRecoverEvent (blocks return
# intact; negative cache entries purged), CapacityLossEvent (blocks
# destroyed; only repair restores them). Blocks on down nodes are
# negative-cached with a TTL (GatewayConfig.negative_ttl) so planning
# skips re-probing known failures; MTTR is sampled per healed block
# (GatewayReport.mttr_samples / restored_samples) and
# audit_durability() reports provable data loss. Gray failures ride the
# same event stream: CorruptionEvent flips bits in place (silent until a
# digest check catches it), SlowNode/SlowNicEvent degrade a node's
# effective link rate. The integrity plane (verify_checksums, default
# on) checks every store fetch and decode output against the crc32
# digest recorded at PUT, reclassifies mismatches as erasures (replan ->
# CORE parity first, RS fallback; corrupt replica quarantined,
# tombstoned, queued for repair), and a paced background scrubber
# (scrub_interval) bounds detection latency for data no read touches.
# hedge=True races direct fetches stuck past a healthy-fabric deadline
# against the cheapest alternate reconstruction, under a per-tenant
# speculative-byte budget (hedge_budget). repair_pacing=True
# closes the SLO loop: a PacingController (storage/repair.py) maps
# observed foreground p99 headroom against tenant_slo_p99 — plus MTTR
# urgency as a repair drags — to the "repair" tenant's fabric weight
# and engine share before every group repair (GatewayReport.pacing).
#
# Write dataplane (GatewayConfig.write_coalesce, default "ragged"):
# PUT windows mirror the decode megakernel — a batch's RS parity-row
# generations (kind "EH") and XOR-delta vertical-parity folds (kind
# "EV", one fold op per touched parity block via XOR associativity)
# each run as ONE ragged ENCODE launch (kernels/ragged_encode.py),
# billed on the same engine pool decodes ride; client transfers start
# only after the billed encodes land. write_coalesce="sync" is the
# per-PUT launch baseline. Small PUTs (Request.nbytes set) journal for
# an instant ack and pack into shared codeword rows via StripeSealer;
# deletes tombstone in place. audit_parity() / audit_sealed_stripes()
# are the end-to-end churn consistency audits (zero stale parity, every
# sealed extent byte-identical through degraded decode).
#
# Multi-gateway scale-out (metadata.py + sharding.py): the namespace
# metadata plane (stripe maps, object->shard consistent-hash directory,
# ground truth, tombstones, fault bookkeeping, cache-coherence fan-out)
# is split from the per-shard data path, so N ObjectGateway shards run
# over ONE shared BlockStore/NetSimulator. ShardedGateway is the front
# door: requests route by crc32 consistent hash (vnodes per shard),
# each shard keeps its own cache/engine pool/admission/repair fixer
# (fabric lanes tagged "tenant@s<id>", weights inherited from the base
# tenant), cluster events apply once with repair ownership split by
# group hash, and ShardFailEvent kills a shard mid-run — storage is
# untouched, so its namespace ranges fail over to survivors with zero
# lost blocks. serve() returns GatewayReport.merged across shards.
from repro.gateway.cache import CacheStats, LRUBlockCache
from repro.gateway.coalescer import (
    PAD_LADDER,
    CoalescerStats,
    DecodeCoalescer,
    LaunchUnit,
)
from repro.gateway.gateway import (
    EnginePool,
    GatewayConfig,
    GatewayReport,
    ObjectGateway,
    RequestRecord,
)
from repro.gateway.planner import (
    DecodeOp,
    DegradedReadPlanner,
    ReadPlan,
    UnreadableObjectError,
)
from repro.gateway.metadata import MetadataPlane, ShardDirectory
from repro.gateway.sealer import Extent, StripeSealer
from repro.gateway.sharding import ShardedGateway
from repro.gateway.workload import (
    CapacityLossEvent,
    CorruptionEvent,
    DEFAULT_TENANT,
    FailureEvent,
    NodeRecoverEvent,
    Request,
    ShardFailEvent,
    SlowNicEvent,
    SlowNodeEvent,
    TenantProfile,
    WorkloadConfig,
    generate_requests,
    generate_tenant_requests,
    plan_failures,
    tenant_slo_map,
    tenant_weight_map,
    zipf_probs,
)

__all__ = [
    "DEFAULT_TENANT",
    "TenantProfile",
    "generate_tenant_requests",
    "tenant_slo_map",
    "tenant_weight_map",
    "CacheStats",
    "CapacityLossEvent",
    "CorruptionEvent",
    "SlowNicEvent",
    "SlowNodeEvent",
    "EnginePool",
    "LRUBlockCache",
    "NodeRecoverEvent",
    "PAD_LADDER",
    "CoalescerStats",
    "DecodeCoalescer",
    "LaunchUnit",
    "GatewayConfig",
    "GatewayReport",
    "MetadataPlane",
    "ObjectGateway",
    "RequestRecord",
    "ShardDirectory",
    "ShardFailEvent",
    "ShardedGateway",
    "DecodeOp",
    "DegradedReadPlanner",
    "Extent",
    "ReadPlan",
    "StripeSealer",
    "UnreadableObjectError",
    "FailureEvent",
    "Request",
    "WorkloadConfig",
    "generate_requests",
    "plan_failures",
    "zipf_probs",
]
