# Client-facing object-storage serving layer over the simulated CORE
# cluster: Zipf/Poisson workloads, per-request degraded-read planning
# (paper Table 1), a pipelined fetch->decode->verify dataplane with
# shape-bucketed batched GF(256) decode (ladder-padded, autotuned,
# bounded jit cache), rebuild-cost-aware block caching, and weighted-fair
# quantum fabric sharing between any number of tenants.
#
# Tenancy and SLOs: every request is tagged with a tenant; each tenant's
# fabric traffic is shaped by its weighted-fair quantum ratio
# (GatewayConfig.tenant_weights — background repair is just the "repair"
# tenant, whose weight defaults to background_share), and tenants may
# declare a p99 latency target (tenant_slo_p99). The admission
# controller estimates each arriving GET's completion time from the
# client-NIC fetch serialization, the decode-engine backlog, and the
# measured per-launch decode cost; requests that would bust their
# tenant's SLO are rejected up front (admission="reject") or first
# degraded to the latency-cheapest viable plan (admission="degrade").
# Decode runs on num_engines parallel simulated engine timelines with
# least-loaded dispatch, so decode-bound degraded workloads scale with
# the engine pool. Per-tenant latency, rejection, starvation, and
# deadline-miss accounting surface in GatewayReport and NetSimulator.
from repro.gateway.cache import CacheStats, LRUBlockCache
from repro.gateway.coalescer import PAD_LADDER, CoalescerStats, DecodeCoalescer
from repro.gateway.gateway import (
    GatewayConfig,
    GatewayReport,
    ObjectGateway,
    RequestRecord,
)
from repro.gateway.planner import (
    DecodeOp,
    DegradedReadPlanner,
    ReadPlan,
    UnreadableObjectError,
)
from repro.gateway.workload import (
    DEFAULT_TENANT,
    FailureEvent,
    Request,
    TenantProfile,
    WorkloadConfig,
    generate_requests,
    generate_tenant_requests,
    plan_failures,
    tenant_slo_map,
    tenant_weight_map,
    zipf_probs,
)

__all__ = [
    "DEFAULT_TENANT",
    "TenantProfile",
    "generate_tenant_requests",
    "tenant_slo_map",
    "tenant_weight_map",
    "CacheStats",
    "LRUBlockCache",
    "PAD_LADDER",
    "CoalescerStats",
    "DecodeCoalescer",
    "GatewayConfig",
    "GatewayReport",
    "ObjectGateway",
    "RequestRecord",
    "DecodeOp",
    "DegradedReadPlanner",
    "ReadPlan",
    "UnreadableObjectError",
    "FailureEvent",
    "Request",
    "WorkloadConfig",
    "generate_requests",
    "plan_failures",
    "zipf_probs",
]
