"""The object-storage serving gateway: request-driven PUT/GET over the
simulated CORE cluster, end to end.

Requests (Poisson arrivals) are grouped into small batching windows; each
window's GETs are planned against the live failure set (planner.py) and
their reconstructions coalesced into batched kernel launches
(coalescer.py). Every byte moved rides the shared NetSimulator fabric —
where background repair traffic (BlockFixer as the "repair" tenant)
contends with foreground reads, instead of running in a separate
universe. Block contents are real; every degraded GET is verified
against ground truth.

Multi-tenant QoS: every request carries a tenant tag, and each tenant's
fabric transfers ride the quantum scheduler under that tenant's
weighted-fair ratio (``GatewayConfig.tenant_weights`` — repair is just
another tenant whose weight defaults to ``background_share``). Tenants
may declare a p99 latency SLO (``tenant_slo_p99``); the admission
controller estimates an arriving GET's completion time (client-NIC fetch
serialization + decode-engine backlog + measured per-launch decode cost)
and, when the estimate busts the tenant's SLO, either rejects the
request up front (``admission="reject"``) or first degrades it to the
latency-cheapest viable plan (``admission="degrade"``, re-ranking the
planner's candidates by estimated time instead of Table-1 bytes) and
rejects only if even that plan busts the target. Rejections are tracked
per tenant in ``GatewayReport.rejections``.

Pipeline stages (config.pipeline):

  1. **fetch**   — every source block of the window's plans is scheduled
     on the fabric at the request's plan time (``ReadPlan.planned_at``);
     cache hits are ready immediately. Under the quantum fabric
     (config.fabric) these transfers preempt long background repair
     transfers at quantum granularity instead of queueing behind them.
  2. **decode**  — reconstructions are deduped across the window and
     executed by the ragged megakernel dataplane
     (``config.coalesce="ragged"``, the default): the whole window's
     mixed-shape decode set is staged as fixed-width descriptor tiles
     and decoded in ONE Pallas launch per kind (two chunk rungs bound
     the traced signatures at <= 2 per kind; see gateway/coalescer.py).
     The coalescer returns LaunchUnits — a megakernel launch is split
     by tile ranges into one unit per op — and each unit is dispatched
     least-loaded-first onto ``num_engines`` parallel simulated
     decode-engine timelines once its LAUNCH's source transfers have
     all completed (a physical launch's staging buffer holds every one
     of its ops' tiles) and an engine frees, so a single physical
     launch still spreads across the pool. ``coalesce="bucketed"`` keeps the
     pre-megakernel shape-bucketed dataplane (one stacked launch per
     (kind, M, K, blocklen) bucket, ladder-padded) as the measured
     baseline.
  3. **verify / deliver** — each GET completes at the max of its direct
     fetches and the decode launches it depends on; contents are checked
     against ground truth host-side (zero simulated cost).

In ``pipelined`` mode (default) the stages overlap across windows:
window N+1's fabric transfers proceed while window N's decode launches
occupy the engine, and the engine drains buckets in source-arrival
order. ``serial`` mode is the comparison baseline: it charges the
serialization a synchronous flush-per-batch loop actually implies — a
window's transfers may not start before the previous window fully
completed, no launch is issued before ALL the window's transfers land,
the launches run back-to-back, and every degraded GET of the window
waits for the last of them. (The PR-1 loop executed stages strictly in
sequence but its simulated timestamps let them overlap optimistically;
serial mode prices that loop honestly rather than reproducing its
accounting.)

Fabric quantum model (storage/netmodel.py): transfers are scheduled in
fixed full-rate quanta; a priority class with share s may claim one
quantum per quantum/s of wall time per port, so the holes a throttled
background class leaves are real preemption points for foreground reads
— ``background_share`` is a weighted-fair quantum ratio, not a rate cap.

Latency model per request: arrival -> (cache | fabric transfers to the
request's client port) -> per-bucket decode on the shared engine ->
completion. Decode compute is measured on the real jitted kernels
(autotuned per backend, batch sizes padded up a fixed ladder so the jit
cache stays bounded — GatewayReport.jit_cache_entries) and scaled by the
cluster profile.

Fault scenarios (repro.scenario): ``serve`` consumes node-level cluster
events mid-run — transient crashes (FailureEvent), recoveries
(NodeRecoverEvent: blocks return intact, negative cache entries purged)
and capacity losses (CapacityLossEvent: blocks destroyed, only repair
restores them). Blocks on down nodes are negative-cached with a TTL so
planning skips re-probing known failures; loss times feed MTTR samples
when repair heals (``GatewayReport.mttr_samples``) or the node recovers
(``restored_samples``), and ``audit_durability`` reports provable data
loss for traces beyond the code's tolerance.

Closed-loop repair pacing (``repair_pacing=True``): before each group
repair, a PacingController (storage/repair.py) maps the protected
tier's recent p99 headroom against ``tenant_slo_p99`` — plus an MTTR
urgency term as the repair drags — to the "repair" tenant's fabric
weight AND decode-engine share, applied via
``NetSimulator.set_tenant_weight`` and ``EnginePool.set_weight``:
repair backs off while foreground latency is at risk and accelerates
toward the MTTR target when idle. Decisions land in
``GatewayReport.pacing``. Repair decode compute itself is billed on the
shared engine pool as the "repair" tenant, so engine shares bite both
ways.
"""

from __future__ import annotations

import hashlib
import zlib
from collections import deque
from dataclasses import dataclass, field, replace

import numpy as np

from repro.coding import rs
from repro.coding.gf256 import np_matmul
from repro.core.failure_matrix import independent_clusters
from repro.core.product_code import CoreCode, CoreCodec
from repro.core.recoverability import is_recoverable
from repro.gateway.cache import LRUBlockCache
from repro.gateway.coalescer import DecodeCoalescer
from repro.gateway.metadata import MetadataPlane
from repro.gateway.planner import (
    DecodeOp,
    DegradedReadPlanner,
    ReadPlan,
    UnreadableObjectError,
    make_family,
)
from repro.gateway.sealer import Extent, StripeSealer
from repro.gateway.workload import (
    CapacityLossEvent,
    CorruptionEvent,
    DEFAULT_TENANT,
    FailureEvent,
    NodeRecoverEvent,
    Request,
    SlowNicEvent,
    SlowNodeEvent,
)
from repro.kernels import autotune
from repro.obs.metrics import BoundedLog, BoundedSamples, MetricsRegistry
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.storage.blockstore import BlockKey, BlockStore
from repro.storage.netmodel import (
    ClusterProfile,
    FOREGROUND_TENANT,
    NetSimulator,
    REPAIR_TENANT,
    PortTimeline,
    Transfer,
    shard_tenant,
)
from repro.storage.repair import BlockFixer, PacingController, Scrubber

PIPELINED = "pipelined"
SERIAL = "serial"

# Sealed-stripe rows register as synthetic objects above this id, so
# they can never collide with workload-drawn tenant object ids.
SEAL_OID_BASE = 1 << 40

# Admission-control policies (GatewayConfig.admission):
#   off     — admit everything (SLOs are observed, never enforced)
#   reject  — refuse a GET whose estimated completion busts its SLO
#   degrade — first re-rank the planner's candidate plans by estimated
#             completion time and take the cheapest; reject only if even
#             that plan busts the SLO
ADMIT_OFF = "off"
ADMIT_REJECT = "reject"
ADMIT_DEGRADE = "degrade"


@dataclass(frozen=True)
class GatewayConfig:
    batch_window: float = 0.002  # seconds of arrival coalescing
    cache_bytes: int = 0  # 0 disables the block cache
    cache_policy: str = "cost"  # "cost" (rebuild-cost-aware) | "lru"
    num_client_ports: int = 32  # parallel client-side NICs
    background_share: float = 0.5  # repair's weighted-fair quantum ratio
    fabric: str = "quantum"  # "quantum" (preemptive) | "fifo"
    repair_on_failure: bool = False  # run BlockFixer after detection
    repair_delay: float = 5.0  # failure-detection lag (seconds)
    verify: bool = True  # check every GET against ground truth
    interpret: bool | None = None  # kernel backend override
    pipeline: str = PIPELINED  # "pipelined" | "serial" (PR-1 loop)
    autotune: bool = True  # measured kernel-parameter sweep at first use
    # decode dataplane: "ragged" = one descriptor-driven megakernel
    # launch per (window, kind); "bucketed" = the pre-megakernel
    # per-shape stacked launches (kept as the measured baseline)
    coalesce: str = "ragged"
    record_payloads: bool = False  # sha256 of every GET payload in records
    # -- multi-tenant QoS ------------------------------------------------------
    tenant_weights: dict | None = None  # tenant -> fabric quantum ratio
    tenant_slo_p99: dict | None = None  # tenant -> p99 latency target (s)
    admission: str = ADMIT_OFF  # "off" | "reject" | "degrade"
    num_engines: int = 1  # parallel simulated decode engines
    # tenant -> decode-engine share in (0, 1]. Independent of the fabric
    # weights: a throttled tenant's launches are rate-capped at
    # share x pool throughput; unlisted tenants dispatch at full weight
    # (identical to the tenant-blind least-loaded behavior).
    engine_weights: dict | None = None
    # Modeled decode cost: when set, every decode launch (and each
    # repaired block's codec work) is billed this many scaled seconds
    # instead of the measured kernel wall time. Payload bytes still come
    # off the real kernels — only the TIMING model changes — so a run
    # becomes bit-for-bit replayable (golden traces, paced-vs-fixed
    # comparisons) with no cold-vs-warm-jit sensitivity. None (default):
    # measured, best-observed-per-signature billing.
    decode_cost: float | None = None
    # Modeled decode cost PER DESCRIPTOR TILE: bills each megakernel
    # launch unit ``cost x its tile count``, so billed compute scales
    # with the work actually launched instead of the launch count.
    # decode_cost (per launch) models a fixed-cost accelerator
    # dispatch; per-tile models a throughput-bound accelerator — the
    # right replayable model when comparing configurations that split
    # the SAME op stream into DIFFERENT window sizes (the sharded
    # scale-out bench: N shards cut windows ~N ways, and per-launch
    # billing would charge the cluster N times for the same tiles).
    # Requires coalesce="ragged" (bucketed units carry no tile counts)
    # and is mutually exclusive with decode_cost.
    decode_cost_per_tile: float | None = None
    # -- write dataplane -------------------------------------------------------
    # Modeled ENCODE cost per launch (same semantics as decode_cost);
    # None falls back to decode_cost, and to the coalescer's measured
    # encode history when both are None. Encode launches are billed on
    # the SAME engine pool decodes ride, so PUT latency reflects the
    # engine backlog and writes push back on degraded reads.
    encode_cost: float | None = None
    # write dataplane shape: "ragged" = one descriptor-driven encode
    # megakernel window per PUT batch (EH parity-row generation + EV
    # XOR-delta parity folds, one launch per kind); "sync" = one
    # launch pair PER PUT (the synchronous write baseline the bench
    # compares against).
    write_coalesce: str = "ragged"
    # -- fault scenarios / closed-loop repair ---------------------------------
    negative_ttl: float = 5.0  # seconds a known-down block stays negative-cached
    repair_pacing: bool = False  # SLO-aware closed-loop repair pacing
    repair_min_share: float = 0.5  # pacer floor (fabric + engine share)
    repair_max_share: float = 1.0  # pacer ceiling (idle / healthy)
    repair_mttr_target: float | None = None  # urgency override threshold (s)
    pacing_window: float = 1.0  # seconds of latency history the pacer observes
    # Incremental repair drain: at most this many groups repair per
    # boundary event, with the remainder requeued repair_respacing
    # seconds later (None => the whole backlog in one shot, the
    # pre-scenario behavior). Spreading the drain is what lets the
    # pacer RE-OBSERVE foreground latency between batches — the loop
    # cannot close inside one atomic repair event.
    repair_groups_per_run: int | None = None
    repair_respacing: float = 0.05
    # -- integrity / gray-failure hardening -----------------------------------
    # Verify every store fetch's crc32 digest (and every decode output
    # against its target's reference digest). A mismatch is reclassified
    # as an ERASURE: quarantine + negative-cache tombstone + replan as a
    # degraded read + repair queue. Zero simulated cost (checksumming is
    # local disk-speed work on each node), so enabling it on a clean
    # cluster changes no timings.
    verify_checksums: bool = True
    # Hedged fetches: when a direct data-block fetch is going to land
    # later than hedge_threshold x its healthy-fabric estimate (fair-
    # share serialization + the tenant's own committed backlog), launch
    # the cheapest single-block recovery plan (CORE vertical XOR first,
    # RS row fallback) speculatively and take the first verified winner.
    hedge: bool = False
    hedge_threshold: float = 2.0
    hedge_max_retries: int = 2  # speculative attempts per request
    hedge_backoff: float = 2.0  # deadline multiplier per extra attempt
    # Per-tenant hedge-byte budget: cumulative speculative fabric bytes
    # may not exceed this fraction of the tenant's primary fetch bytes —
    # the structural cap that keeps hedging from stampeding the fabric.
    hedge_budget: float = 0.05
    # Background scrubber: every scrub_interval simulated seconds, verify
    # up to scrub_blocks_per_run stored blocks (paced down by the repair
    # PacingController when foreground SLOs are at risk) so latent
    # corruption is found before reads trip over it. None disables.
    scrub_interval: float | None = None
    scrub_blocks_per_run: int = 64
    # -- observability (repro.obs) --------------------------------------------
    tracing: bool = False  # emit sim-time spans into a bounded Tracer
    # sampling policy: "always" | "head:N" | "tail:SECONDS" | comma-combos
    # (keep a trace if ANY matches — slow requests are never dropped)
    trace_sample: str = "always"
    trace_capacity: int = 65536  # span ring-buffer size
    # False => streaming mode: GatewayReport keeps NO per-request list
    # (records stays empty; aggregates come from the bounded metrics
    # registry) so resident memory is O(1) in trace length
    record_requests: bool = True
    # -- code family (per-namespace property) ----------------------------------
    # "core" (the (n,k,t) product code, default), "rs" (plain (n,k)
    # Reed-Solomon rows — the paper's traditional-EC baseline), or "lrc"
    # ((n,k) Azure-style Local Reconstruction Code rows). RS/LRC derive
    # (n,k) from the gateway's CoreCode so all families stripe the same
    # row geometry; planner candidates, repair plans, PUT re-encode, and
    # the durability audit all go through repro.gateway.planner.CodeFamily.
    code_family: str = "core"
    # -- placement / scale-out -------------------------------------------------
    # Rack size for failure-domain-aware placement: nodes [i*r, (i+1)*r)
    # form rack i, and stripe placement guarantees any single rack
    # failure costs each row and each column at most one block (XORing
    # Elephants, 1301.3791). None keeps node-level anti-colocation only.
    nodes_per_rack: int | None = None


@dataclass
class RequestRecord:
    time: float
    object_id: int
    kind: str
    latency: float | None  # None => unrecoverable or rejected
    degraded: bool
    bytes_read: int  # fabric bytes moved for this request
    reconstruction_blocks: int  # planner's Table-1 traffic
    cache_hits: int
    payload_digest: str | None = None  # sha256 (record_payloads=True)
    tenant: str = DEFAULT_TENANT
    rejected: bool = False  # refused by SLO admission control


# Completed GETs the repair pacer can observe: (arrival, tenant,
# latency), last RECENT_CAP only — the trailing pacing_window never
# needs more, and the cap is what keeps the pacer's input bounded.
RECENT_CAP = 4096


@dataclass
class GatewayReport:
    """Per-``serve()`` outcome report: a snapshot over the streaming
    ``metrics`` registry plus (by default) the raw per-request records.

    Every sample container here is BOUNDED: ``mttr_samples`` /
    ``restored_samples`` keep exact streaming count/mean/max plus a
    capped prefix of raw samples, ``pacing`` keeps the last decisions,
    ``recent`` the trailing completed GETs the repair pacer reads, and
    the registry's histograms are fixed-bin sketches — so with
    ``GatewayConfig.record_requests=False`` (streaming mode, ``records``
    stays empty) resident memory is O(1) in trace length. The aggregate
    accessors fall back from exact record scans to the registry in that
    mode; only WINDOWED percentiles (``since``/``until``) require
    records."""

    records: list[RequestRecord] = field(default_factory=list)
    repair_reports: list = field(default_factory=list)
    jit_cache_entries: int = 0  # coalescer's traced-signature count
    decode_launches: int = 0  # physical kernel launches (cumulative)
    launches_per_window: float = 0.0  # decode launches per batching window
    padded_byte_ratio: float = 0.0  # filler fraction of staged decode bytes
    rejections: dict = field(default_factory=dict)  # tenant -> refused GETs
    put_rejections: dict = field(default_factory=dict)  # tenant -> refused PUTs
    # time from block loss to repair-heal completion, one sample per
    # block healed by BlockFixer during this serve() call
    mttr_samples: BoundedSamples = field(default_factory=BoundedSamples)
    # time from block loss to availability restoration via a
    # NodeRecoverEvent (transient failure over — no repair bytes moved)
    restored_samples: BoundedSamples = field(default_factory=BoundedSamples)
    # time from silent-corruption injection to checksum detection (fetch
    # verify or scrub), one sample per corrupt block detected
    corruption_latency: BoundedSamples = field(default_factory=BoundedSamples)
    # closed-loop repair pacing decisions: (simulated time, share)
    pacing: BoundedLog = field(default_factory=BoundedLog)
    # streaming metrics registry: labeled counters / gauges / histograms
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)
    recent: deque = field(default_factory=lambda: deque(maxlen=RECENT_CAP))
    record_requests: bool = True  # False => streaming mode (records empty)
    _first_arrival: float = float("inf")
    _last_completion: float = 0.0

    def add_record(self, rec: RequestRecord) -> None:
        """Route one finished request into the report: the raw record
        list (unless streaming mode), the metrics registry, and the
        pacer's bounded ``recent`` window."""
        if self.record_requests:
            self.records.append(rec)
        m = self.metrics
        m.counter("requests", kind=rec.kind, tenant=rec.tenant).inc()
        if rec.rejected:
            m.counter("rejected_requests", tenant=rec.tenant).inc()
        if rec.latency is None:
            return
        m.counter("completed", kind=rec.kind, tenant=rec.tenant).inc()
        m.histogram("latency", kind=rec.kind, tenant=rec.tenant).observe(
            max(rec.latency, 1e-9)
        )
        m.counter("bytes_read", tenant=rec.tenant).inc(rec.bytes_read)
        self._first_arrival = min(self._first_arrival, rec.time)
        self._last_completion = max(self._last_completion, rec.time + rec.latency)
        if rec.kind == "get":
            self.recent.append((rec.time, rec.tenant, rec.latency))
            if rec.degraded:
                m.counter("degraded_gets").inc()
                m.counter("degraded_bytes").inc(rec.bytes_read)
                m.counter("degraded_recon_blocks").inc(rec.reconstruction_blocks)

    def resident_samples(self) -> int:
        """Total retained entries across every sample container — the
        number the long-trace benchmark gates on staying bounded."""
        return (
            len(self.records)
            + len(self.recent)
            + self.mttr_samples.resident()
            + self.restored_samples.resident()
            + self.corruption_latency.resident()
            + self.pacing.resident()
            + self.metrics.resident_samples()
        )

    @property
    def mttr_mean(self) -> float:
        return self.mttr_samples.mean

    @property
    def mttr_max(self) -> float:
        return self.mttr_samples.max

    # -- aggregates -----------------------------------------------------------
    @property
    def completed(self) -> list[RequestRecord]:
        return [r for r in self.records if r.latency is not None]

    @property
    def degraded_gets(self) -> list[RequestRecord]:
        return [r for r in self.completed if r.kind == "get" and r.degraded]

    @property
    def rejected(self) -> list[RequestRecord]:
        return [r for r in self.records if r.rejected]

    def latency_percentile(
        self, q: float, since: float = 0.0, until: float = float("inf")
    ) -> float:
        """Latency percentile over requests ARRIVING in [since, until) —
        the one quantile definition every window statistic delegates to.
        Streaming mode answers WHOLE-trace quantiles from the registry's
        merged latency sketch; windowed quantiles need records."""
        if not self.records and since == 0.0 and until == float("inf"):
            h = self.metrics.merged_histogram("latency")
            return h.quantile(q / 100.0) if h is not None else 0.0
        lats = [r.latency for r in self.completed if since <= r.time < until]
        return float(np.percentile(lats, q)) if lats else 0.0

    # -- per-tenant aggregates -------------------------------------------------
    def tenant_completed(self, tenant: str) -> list[RequestRecord]:
        return [r for r in self.completed if r.tenant == tenant]

    def tenant_latency_percentile(
        self,
        tenant: str,
        q: float,
        since: float = 0.0,
        until: float = float("inf"),
    ) -> float:
        if not self.records and since == 0.0 and until == float("inf"):
            h = self.metrics.merged_histogram("latency", tenant=tenant)
            return h.quantile(q / 100.0) if h is not None else 0.0
        lats = [
            r.latency
            for r in self.completed
            if r.tenant == tenant and since <= r.time < until
        ]
        return float(np.percentile(lats, q)) if lats else 0.0

    def slo_violation_rate(self, tenant: str, slo: float) -> float:
        """Fraction of this tenant's completed GETs that finished over
        the target — measured over ADMITTED traffic, so rejections trade
        availability for the survivors' latency."""
        gets = [r for r in self.tenant_completed(tenant) if r.kind == "get"]
        if not gets and not self.records:
            h = self.metrics.merged_histogram("latency", kind="get", tenant=tenant)
            return 1.0 - h.cdf(slo) if h is not None and h.count else 0.0
        if not gets:
            return 0.0
        return sum(1 for r in gets if r.latency > slo) / len(gets)

    @property
    def throughput(self) -> float:
        """Completed requests per second of simulated trace time."""
        n = self.metrics.counter_total("completed")
        if not n:
            return 0.0
        span = self._last_completion - self._first_arrival
        return n / span if span > 0 else float("inf")

    @property
    def bytes_per_degraded_get(self) -> float:
        deg = self.metrics.counter_total("degraded_gets")
        return (
            self.metrics.counter_total("degraded_bytes") / deg if deg else 0.0
        )

    @property
    def reconstruction_blocks_per_degraded_get(self) -> float:
        deg = self.metrics.counter_total("degraded_gets")
        return (
            self.metrics.counter_total("degraded_recon_blocks") / deg
            if deg
            else 0.0
        )

    # -- cross-shard aggregation ------------------------------------------------
    @classmethod
    def merged(cls, reports: list["GatewayReport"]) -> "GatewayReport":
        """One logical report over N shard reports: records are replayed
        through ``add_record`` in (time, object, kind) order so every
        derived aggregate — metrics counters, latency sketches, the
        throughput window, the pacer's ``recent`` deque — is rebuilt
        exactly as a single gateway would have built it; sample
        containers and rejection maps are summed. Existing bench blocks
        read the merged report through the same pinned keys."""
        for r in reports:
            if not r.record_requests:
                raise ValueError(
                    "GatewayReport.merged needs per-request records; "
                    "run shards with record_requests=True"
                )
        out = cls(record_requests=True)
        for rec in sorted(
            (rec for r in reports for rec in r.records),
            key=lambda rec: (rec.time, rec.object_id, rec.kind),
        ):
            out.add_record(rec)
        for r in reports:
            out.repair_reports.extend(r.repair_reports)
            # jit entries: shards run private coalescers over identical
            # kernels — the MAX is the per-process signature footprint
            out.jit_cache_entries = max(out.jit_cache_entries, r.jit_cache_entries)
            out.decode_launches += r.decode_launches
            for t, n in r.rejections.items():
                out.rejections[t] = out.rejections.get(t, 0) + n
            for t, n in r.put_rejections.items():
                out.put_rejections[t] = out.put_rejections.get(t, 0) + n
            for s in r.mttr_samples:
                out.mttr_samples.append(s)
            for s in r.restored_samples:
                out.restored_samples.append(s)
            for s in r.corruption_latency:
                out.corruption_latency.append(s)
            for p in r.pacing:
                out.pacing.append(p)
        n_windows = sum(
            r.decode_launches / r.launches_per_window
            for r in reports
            if r.launches_per_window > 0
        )
        if n_windows > 0:
            out.launches_per_window = out.decode_launches / n_windows
        return out


class EnginePool:
    """``num_engines`` parallel simulated decode-engine timelines with
    least-loaded dispatch and per-tenant weighted admission.

    Full-weight tenants dispatch exactly as the tenant-blind pool did:
    earliest-free engine, start at max(ready, engine_free). A tenant with
    share w < 1 additionally respects a virtual-clock cursor spaced at
    duration / (w x pool_size) per launch, rate-capping it at w of the
    pool's aggregate throughput — so a throttled repair tenant's decode
    work cannot crowd foreground reconstructions off the engines, and
    the SLO pacer can modulate that share mid-run (``set_weight``).

    Engines keep interval timelines (the fabric's PortTimeline), not
    just a high-water mark: the idle gap a throttled tenant's cursor
    wait leaves on an engine is a real hole later full-weight launches
    backfill — throttling yields capacity to other tenants instead of
    reserving dead time (mirroring the quantum fabric's preemptible
    holes). On hole-free timelines earliest-fit placement coincides
    with least-loaded dispatch, so all-full-weight workloads are
    schedule-identical to the tenant-blind pool."""

    def __init__(self, num_engines: int, weights: dict | None = None):
        self.free = [0.0] * num_engines  # per-engine last-end high-water mark
        self._timelines = [PortTimeline() for _ in range(num_engines)]
        self._weights: dict = dict(weights or {})
        for tenant, w in self._weights.items():
            self._check_weight(tenant, w)
        self._cursor: dict = {}
        self.tracer = NULL_TRACER  # engine-track span sink (repro.obs)
        self._tracks = [("engine", f"engine{e}") for e in range(num_engines)]

    @staticmethod
    def _check_weight(tenant, w) -> None:
        if not 0.0 < w <= 1.0:
            raise ValueError(
                f"engine weight must be in (0, 1], got {tenant!r}: {w}"
            )

    def weight_of(self, tenant) -> float:
        return self._weights.get(tenant, 1.0)

    def set_weight(self, tenant, w: float) -> None:
        self._check_weight(tenant, w)
        self._weights[tenant] = w

    def earliest_start(self, now: float) -> float:
        """Earliest instant at/after ``now`` any engine could begin new
        work, holes included — the admission estimator's view of decode
        queueing. (The per-engine high-water marks in ``free`` are NOT
        usable for this: a throttled tenant's cursor-delayed booking
        pushes them far out while the timeline before it stays idle.)
        Probes for a 1 us hole — anything above the timeline's float
        tolerance, below which zero-length gaps are accepted."""
        return min(tl.next_fit(now, 1e-6) for tl in self._timelines)

    def dispatch(
        self, ready: float, dur: float, tenant=None, ctx: tuple | None = None
    ) -> tuple[float, float]:
        """Schedule one launch; returns (start, end). ``ctx`` is an
        optional (trace_id, parent_id, attrs) observability context —
        when given (and tracing is on) the launch emits an engine-track
        span into that trace. Purely observational: the schedule is
        identical with or without it."""
        share = 1.0 if tenant is None else self.weight_of(tenant)
        if share < 1.0:
            ready = max(ready, self._cursor.get(tenant, 0.0))
        # earliest-fit across engines (holes included); ties break on the
        # lowest index, which on hole-free timelines is least-loaded
        best_e, best_start = 0, float("inf")
        for e, tl in enumerate(self._timelines):
            s = tl.next_fit(ready, dur) if dur > 0.0 else max(ready, self.free[e])
            if s < best_start:
                best_e, best_start = e, s
        end = best_start + dur
        if dur > 0.0:
            self._timelines[best_e].occupy(best_start, end)
        self.free[best_e] = max(self.free[best_e], end)
        if share < 1.0 and dur > 0.0:
            spacing = dur / (share * len(self.free))
            self._cursor[tenant] = max(
                self._cursor.get(tenant, 0.0) + spacing, best_start + spacing
            )
        if ctx is not None and self.tracer.enabled and dur > 0.0:
            tid, pid, attrs = ctx
            self.tracer.span(
                "engine.launch",
                best_start,
                end,
                tid,
                pid,
                track=self._tracks[best_e],
                tenant=tenant,
                **attrs,
            )
        return best_start, end


class ObjectGateway:
    """Serves a trace of PUT/GET requests over a BlockStore cluster.

    Standalone by default: constructs its own store, fabric and
    (private) metadata plane. Under ``ShardedGateway`` N instances are
    built over ONE shared ``store``/``sim``/``meta`` with distinct
    ``shard_id``s: namespace maps and fault bookkeeping alias the
    plane's shared containers, fabric submissions are tagged with the
    shard's tenant lane, and cache-coherence events fan out to every
    registered shard cache through the plane."""

    def __init__(
        self,
        code: CoreCode,
        profile: ClusterProfile,
        num_nodes: int,
        config: GatewayConfig | None = None,
        *,
        store: BlockStore | None = None,
        sim: NetSimulator | None = None,
        meta: MetadataPlane | None = None,
        shard_id: int | None = None,
    ):
        self.code = code
        self.codec = CoreCodec(code)
        self.profile = profile
        self.config = config or GatewayConfig()
        # the namespace's code family: geometry + encode + degraded-read
        # candidates + repair cost surface (raises on unknown names)
        self.family = make_family(code, self.config.code_family)
        if self.config.pipeline not in (PIPELINED, SERIAL):
            raise ValueError(
                f"pipeline must be 'pipelined' or 'serial', got "
                f"{self.config.pipeline!r}"
            )
        if self.config.admission not in (ADMIT_OFF, ADMIT_REJECT, ADMIT_DEGRADE):
            raise ValueError(
                f"admission must be 'off', 'reject' or 'degrade', got "
                f"{self.config.admission!r}"
            )
        if self.config.num_engines < 1:
            raise ValueError(
                f"num_engines must be >= 1, got {self.config.num_engines}"
            )
        if self.config.coalesce not in ("ragged", "bucketed"):
            raise ValueError(
                f"coalesce must be 'ragged' or 'bucketed', got "
                f"{self.config.coalesce!r}"
            )
        if self.config.decode_cost is not None and self.config.decode_cost <= 0:
            raise ValueError(
                f"decode_cost must be positive or None (measured), got "
                f"{self.config.decode_cost}"
            )
        if self.config.encode_cost is not None and self.config.encode_cost <= 0:
            raise ValueError(
                f"encode_cost must be positive or None, got "
                f"{self.config.encode_cost}"
            )
        if self.config.decode_cost_per_tile is not None:
            if self.config.decode_cost_per_tile <= 0:
                raise ValueError(
                    f"decode_cost_per_tile must be positive or None, got "
                    f"{self.config.decode_cost_per_tile}"
                )
            if self.config.decode_cost is not None:
                raise ValueError(
                    "decode_cost and decode_cost_per_tile are mutually "
                    "exclusive timing models"
                )
            if self.config.coalesce != "ragged":
                raise ValueError(
                    "decode_cost_per_tile requires coalesce='ragged' "
                    "(bucketed launch units carry no tile counts)"
                )
        if self.config.write_coalesce not in ("ragged", "sync"):
            raise ValueError(
                f"write_coalesce must be 'ragged' or 'sync', got "
                f"{self.config.write_coalesce!r}"
            )
        if (
            self.config.repair_groups_per_run is not None
            and self.config.repair_groups_per_run < 1
        ):
            # a zero budget would requeue a continuation that never
            # repairs anything — serve() would spin forever
            raise ValueError(
                f"repair_groups_per_run must be >= 1 or None, got "
                f"{self.config.repair_groups_per_run}"
            )
        if self.config.hedge_threshold <= 0:
            raise ValueError(
                f"hedge_threshold must be positive, got "
                f"{self.config.hedge_threshold}"
            )
        if self.config.hedge_max_retries < 0:
            raise ValueError(
                f"hedge_max_retries must be >= 0, got "
                f"{self.config.hedge_max_retries}"
            )
        if self.config.hedge_backoff < 1.0:
            raise ValueError(
                f"hedge_backoff must be >= 1 (deadlines may not shrink "
                f"across retries), got {self.config.hedge_backoff}"
            )
        if self.config.hedge_budget <= 0:
            raise ValueError(
                f"hedge_budget must be positive, got {self.config.hedge_budget}"
            )
        if (
            self.config.scrub_interval is not None
            and self.config.scrub_interval <= 0
        ):
            raise ValueError(
                f"scrub_interval must be positive or None, got "
                f"{self.config.scrub_interval}"
            )
        if self.config.scrub_blocks_per_run < 1:
            raise ValueError(
                f"scrub_blocks_per_run must be >= 1, got "
                f"{self.config.scrub_blocks_per_run}"
            )
        if self.config.pipeline == SERIAL and self.config.num_engines != 1:
            # the serial baseline prices the PR-1 synchronous loop, which
            # had exactly one decode engine — extra engines would sit
            # idle while still skewing the admission estimator
            raise ValueError(
                "pipeline='serial' models a single-engine synchronous "
                f"loop; num_engines must be 1, got {self.config.num_engines}"
            )
        # sim-time observability plane (repro.obs): one tracer threaded
        # through the fabric, engine pool and repair engine. NULL_TRACER
        # when disabled, so emission sites cost one attribute check.
        self.tracer = (
            Tracer(self.config.trace_sample, self.config.trace_capacity)
            if self.config.tracing
            else NULL_TRACER
        )
        # scale-out wiring: shard_id tags this gateway's fabric tenants
        # and scopes its repair ownership; store/sim/meta may be shared
        # across N shards (ShardedGateway) or private (standalone).
        self.shard_id = shard_id
        self.meta = meta if meta is not None else MetadataPlane()
        self.store = (
            store
            if store is not None
            else BlockStore(
                num_nodes=num_nodes, nodes_per_rack=self.config.nodes_per_rack
            )
        )
        if sim is not None:
            self.sim = sim
        else:
            self.sim = NetSimulator(
                profile,
                background_share=self.config.background_share,
                mode=self.config.fabric,
                tenant_weights=self.config.tenant_weights,
            )
        if sim is None or self.tracer.enabled:
            # don't clobber a shared fabric's tracer with a shard's
            # NULL_TRACER; a tracing shard may claim it explicitly
            self.sim.tracer = self.tracer
        # this shard's fabric lane for background repair ("repair@s2";
        # plain "repair" standalone). The per-shard ENGINE pool keeps
        # the base name — pools are private, lanes only matter on the
        # shared fabric.
        self._repair_tenant = shard_tenant(REPAIR_TENANT, shard_id)
        self.cache = (
            LRUBlockCache(self.config.cache_bytes, policy=self.config.cache_policy)
            if self.config.cache_bytes
            else None
        )
        self.meta.register_cache(self.cache)
        self.planner = DegradedReadPlanner(
            self.store, code, available_fn=self._available, family=self.family
        )
        self.coalescer = DecodeCoalescer(
            compute_scale=profile.compute_scale,
            interpret=self.config.interpret,
            autotune_kernels=self.config.autotune,
            mode=self.config.coalesce,
        )
        self.fixer = BlockFixer(
            self.store,
            code,
            profile,
            mode="core",
            sim=self.sim,
            priority=self._repair_tenant,
            on_block_repaired=self._on_block_repaired,
            family=self.family,
        )
        self.fixer.tracer = self.tracer
        # namespace maps + fault bookkeeping ALIAS the metadata plane's
        # containers (mutated in place, never rebound): every shard over
        # one plane sees one namespace. A standalone gateway's private
        # plane makes these its own state, exactly as before.
        self._objects = self.meta.objects  # object -> (group, row)
        self._groups = self.meta.groups
        self._expected = self.meta.expected  # ground truth (k, q)
        # Repaired blocks become visible only once the repair's fabric
        # transfers complete: key -> completion time of its write-back.
        self._healing = self.meta.healing
        # Cache entries to re-price once their block's heal completes —
        # re-pricing at repair time would demote a reconstruction that is
        # still the only copy reads dated before heal completion can use.
        self._reprice_on_heal = self.meta.reprice_on_heal
        # Simulated time at which each cached block came into existence
        # (fetch completion / decode completion). A cache hit may not be
        # served before it: blocks are cached at host flush time, and
        # without this gate a later window's request dated before an
        # engine-backlogged decode would read a block that does not exist
        # yet in simulated time.
        self._cache_ready: dict[BlockKey, float] = {}
        self._clock = 0.0  # logical time of the request being planned
        # Simulated decode engines: each runs one batched launch at a
        # time; launches dispatch to the least-loaded engine under the
        # owning tenant's engine share. The pool persists across windows
        # so pipelined windows overlap on it; repair decode compute is
        # billed on it too (as the "repair" tenant), so repair and
        # foreground reconstruction contend for the same engines.
        self._pool = EnginePool(
            self.config.num_engines, weights=self.config.engine_weights
        )
        self._pool.tracer = self.tracer
        # Serial-mode barrier: completion time of the previous window.
        self._window_free = 0.0
        # Scenario bookkeeping: when each currently-unavailable block was
        # lost (feeds MTTR samples on heal/recover), persisted across
        # serve() calls like _healing. Shared: a loss is a cluster fact.
        self._lost_at = self.meta.lost_at
        # groups whose missing set repair provably cannot shrink right
        # now (unrecoverable clusters): skipped by continuation runs
        # until their failure set changes
        self._repair_stuck = self.meta.repair_stuck
        # SLO-aware repair pacing: observed foreground p99 headroom
        # modulates the repair tenant's fabric weight and engine share.
        self._pacer = (
            PacingController(
                min_share=self.config.repair_min_share,
                max_share=self.config.repair_max_share,
                mttr_target=self.config.repair_mttr_target,
            )
            if self.config.repair_pacing
            else None
        )
        slos = self.config.tenant_slo_p99 or {}
        # the tier the pacer protects: the tightest declared SLO
        self._pacing_slo = min(slos.values()) if slos else None
        # -- integrity plane state ---------------------------------------------
        # background scrubber over the store (paced via the same
        # PacingController share repair uses)
        self._scrubber = Scrubber(
            self.store, blocks_per_run=self.config.scrub_blocks_per_run
        )
        self._scrub_next: float | None = self.config.scrub_interval
        # when each still-undetected silent corruption was injected —
        # omniscient metrics-only bookkeeping (detection latency); the
        # serving path itself only ever learns of corruption via verify
        self._corrupted_at = self.meta.corrupted_at
        # per-tenant hedge budget ledger: cumulative speculative fabric
        # bytes vs cumulative primary fetch bytes (the <= hedge_budget
        # structural cap), persisted across windows and serve() calls
        self._hedge_bytes: dict = {}
        self._fetch_bytes: dict = {}
        # pending detection-triggered / event-triggered repairs:
        # (due time, node | -1 continuation | -2 corruption detection)
        self._repair_queue: list[tuple[float, int]] = []
        # -- write dataplane state ---------------------------------------------
        # tombstoned objects: blocks and ground truth stay resident (the
        # group parity remains a consistent codeword — eager block
        # removal would force a parity RMW per delete) until a future GC
        # reclaims whole groups; GETs answer not-found.
        self._deleted = self.meta.deleted
        # per-tenant in-flight write work: (completion time, bytes) of
        # every PUT fabric transfer still unfinished — the admission
        # estimator's view of write pressure (GETs and PUTs both pay it)
        self._put_inflight: dict[str, list[tuple[float, float]]] = {}
        # small-object packing: lazily built (needs _block_bytes), plus
        # sealed rows awaiting a full group and the registry the sealed-
        # stripe audit walks
        self._sealer: StripeSealer | None = None
        self._pending_rows: list[tuple[int, np.ndarray, list[Extent]]] = []
        self._sealed_extents: list[Extent] = []
        self._sealed_rows: dict[int, int] = {}  # row_seq -> object id
        self._seal_group_seq = 0
        # sealed groups/objects register in the SHARED namespace, so a
        # shard's mints must not collide with a sibling's: group ids get
        # a shard infix ("w1.3") and synthetic oids a per-shard stripe
        # of the id space above SEAL_OID_BASE. Standalone stays "w3" /
        # SEAL_OID_BASE + seq exactly as before.
        self._seal_tag = "" if shard_id is None else f"{shard_id}."
        self._seal_oid_base = SEAL_OID_BASE + (
            0 if shard_id is None else shard_id << 24
        )
        # per-tile modeled billing history (admission estimator input)
        self._pt_tiles = 0
        self._pt_launches = 0

    # -- scale-out plumbing ----------------------------------------------------
    @property
    def _block_bytes(self) -> int:
        # namespace-wide (an object's geometry doesn't depend on which
        # shard serves it), so it lives on the metadata plane
        return self.meta.block_bytes

    @_block_bytes.setter
    def _block_bytes(self, value: int) -> None:
        self.meta.block_bytes = value

    def _fab_tenant(self, tenant):
        """This shard's fabric lane for a workload tenant: "gold@s1"
        under sharding, identity standalone — per-shard accounting and
        pacing on the shared fabric without changing effective weights
        (``NetSimulator.weight_of`` falls back to the base name)."""
        return shard_tenant(tenant, self.shard_id)

    # -- availability: store OR cache, gated on repair completion --------------
    def _available(self, key: BlockKey) -> bool:
        if self.cache is not None and self.cache.is_negative(key, self._clock):
            # known-down: skip the store probe entirely (negative entries
            # are purged the moment a recover event or repair write-back
            # brings the block back, and TTL-expire as a backstop); a
            # cached reconstruction still serves
            return key in self.cache
        if self.store.available(key):
            healed_at = self._healing.get(key)
            if healed_at is not None:
                if self._clock < healed_at:
                    # the repair wrote the block, but its transfers are
                    # still in flight at this request's time
                    return self.cache is not None and key in self.cache
                del self._healing[key]
                self._apply_heal_reprice(key)
            return True
        return self.cache is not None and key in self.cache

    def _on_block_repaired(self, key: BlockKey) -> None:
        # BlockFixer wrote the block back; once the write-back's fabric
        # transfers complete (the _healing gate) it is a cheap store
        # read again and any cached copy stops deserving reconstruction
        # priority. The re-price (and negative-entry purge) is deferred
        # to that simulated moment.
        self._reprice_on_heal.add(key)
        # the tombstone dies with the repair WRITE, not with the
        # node-down condition that keyed it: a corrupt-then-repaired
        # block never crashed a node, so without this purge its
        # negative entry would outlive the repair and shadow the
        # healthy store copy until TTL expiry (the _healing gate
        # keeps it invisible until the write-back lands regardless).
        # Fans out to EVERY shard's cache: a heal is a cluster fact.
        self.meta.purge_negative([key])
        # the rewrite replaces the bytes, so any still-undetected silent
        # damage is gone with them
        self._corrupted_at.pop(key, None)

    def _apply_heal_reprice(self, key: BlockKey) -> None:
        self.meta.purge_negative([key])
        if key in self._reprice_on_heal:
            self._reprice_on_heal.discard(key)
            self.meta.refresh_cost(key, 1.0)

    # -- bulk load (trace setup; not metered on the fabric) --------------------
    def load_objects(self, objects: np.ndarray) -> None:
        """objects: (num_objects, k, q) uint8. Packs objects_per_group
        objects per group (t for CORE, 1 for the row families, zero-
        padding the last group) and places all groups."""
        num, k, q = objects.shape
        if k != self.code.k:
            raise ValueError(f"objects must have k={self.code.k} blocks")
        self._block_bytes = int(q)
        t = self.family.objects_per_group
        for g0 in range(0, num, t):
            chunk = objects[g0 : g0 + t]
            if chunk.shape[0] < t:
                pad = np.zeros((t - chunk.shape[0], k, q), dtype=np.uint8)
                chunk = np.concatenate([chunk, pad], axis=0)
            gid = f"g{g0 // t}"
            matrix = np.asarray(self.family.encode_group(chunk))
            self.store.put_group(gid, matrix)
            members = []
            for r in range(min(t, num - g0)):
                oid = g0 + r
                self._objects[oid] = (gid, r)
                self._expected[oid] = np.asarray(objects[oid])
                members.append(oid)
            self._groups[gid] = members

    # -- serving ----------------------------------------------------------------
    def serve(
        self,
        requests: list[Request],
        failures: list | None = None,
    ) -> GatewayReport:
        """``failures`` accepts any mix of cluster events — FailureEvent
        (crash), NodeRecoverEvent, CapacityLossEvent — e.g. a
        ScenarioTrace's ``cluster_events()``. Events apply mid-run, in
        time order interleaved with the request stream, so the planner,
        negative cache, and admission controller see availability change
        between requests."""
        report = GatewayReport(record_requests=self.config.record_requests)
        cfg = self.config
        events = sorted(failures or [], key=lambda f: f.time)
        reqs = sorted(requests, key=lambda r: r.time)
        # (time, node) — on self so detection paths (_note_corrupt, fired
        # from fetch verify and scrub mid-window) can queue repairs too
        repair_queue = self._repair_queue

        fi = 0
        batch: list[Request] = []
        batch_deadline = None
        batch_kind = None  # "get" | "put" — windows are homogeneous

        def flush_open():
            nonlocal batch, batch_deadline, batch_kind
            if batch:
                if batch_kind == "put":
                    self._flush_puts(batch, report)
                else:
                    self._flush(batch, report)
            batch, batch_deadline, batch_kind = [], None, None

        def boundary_events(now: float | None):
            """Apply cluster / repair / scrub events due before ``now``
            (None => all remaining; scrub ticks stop with the request
            stream — a final drain must not scrub forever), flushing the
            open batch first."""
            nonlocal fi
            while True:
                next_evt = events[fi].time if fi < len(events) else None
                next_rep = repair_queue[0][0] if repair_queue else None
                next_scrub = self._scrub_next if now is not None else None
                cands = [
                    t for t in (next_evt, next_rep, next_scrub) if t is not None
                ]
                if not cands:
                    return
                t_evt = min(cands)
                if now is not None and t_evt > now:
                    return
                if batch and batch_deadline is not None:
                    flush_open()
                if next_evt is not None and t_evt == next_evt:
                    evt = events[fi]
                    fi += 1
                    wants_repair = self._apply_cluster_event(evt, report)
                    if wants_repair and cfg.repair_on_failure:
                        repair_queue.append((evt.time + cfg.repair_delay, evt.node))
                        repair_queue.sort()
                elif next_rep is not None and t_evt == next_rep:
                    t_rep, _node = repair_queue.pop(0)
                    if self._background_repair(t_rep, report):
                        # budgeted run left groups pending: drain the
                        # rest after the respacing interval (-1: a
                        # continuation, not a fresh failure)
                        repair_queue.append((t_rep + cfg.repair_respacing, -1))
                        repair_queue.sort()
                else:
                    self._scrub_next = t_evt + cfg.scrub_interval
                    self._run_scrub(t_evt, report)

        for req in reqs:
            boundary_events(req.time)
            if req.kind == "delete":
                # a delete is an instant metadata barrier: flush the open
                # window first so its planned (cache-pinned) reads see
                # pre-delete state, then tombstone
                flush_open()
                report.add_record(self._handle_delete(req, report))
                continue
            kind = "put" if req.kind == "put" else "get"
            # windows are HOMOGENEOUS: a kind switch closes the open
            # window (a PUT mutates blocks and parity, which must not
            # interleave with an open window's planned reads — and
            # arrival-ordered flushing is what keeps read-after-write)
            if batch and (batch_kind != kind or req.time > batch_deadline):
                flush_open()
            if not batch:
                batch_deadline = req.time + cfg.batch_window
                batch_kind = kind
            batch.append(req)
        flush_open()
        boundary_events(None)
        self._finalize_report(report)
        return report

    def _finalize_report(self, report: GatewayReport) -> None:
        """Stamp end-of-serve coalescer/autotune/tracer statistics into
        the report — shared by ``serve`` and the sharded front door's
        merged loop (which finalizes each shard's report at drain)."""
        st = self.coalescer.stats
        report.jit_cache_entries = st.jit_entries
        report.decode_launches = st.decode_calls
        report.launches_per_window = st.launches_per_window
        report.padded_byte_ratio = st.padded_byte_ratio
        # surface kernel-compile churn and autotune cache behavior as
        # first-class metrics (they were only visible as raw counters)
        m = report.metrics
        m.gauge("jit_entries").set(st.jit_entries)
        m.gauge("jit_retraces").set(st.jit_retraces)
        m.gauge("encode_launches").set(st.encode_calls)
        m.gauge("encode_ops").set(st.encode_ops)
        m.gauge("encode_windows").set(st.encode_windows)
        for name, v in autotune.cache_stats().items():
            m.gauge(f"autotune_{name}").set(v)
        if self.tracer.enabled:
            for name, v in self.tracer.stats().items():
                if isinstance(v, (int, float)):
                    m.gauge(f"traces_{name}").set(v)

    # -- request batch execution ------------------------------------------------
    def _flush(self, batch: list[Request], report: GatewayReport) -> None:
        serial = self.config.pipeline == SERIAL
        tracer = self.tracer
        gets: list[tuple[Request, ReadPlan]] = []
        tids: list[int] = []  # per-get trace id, parallel to ``gets``
        # Blocks whose plans depend on the CACHE copy (store copy is
        # gone) are pinned at plan time — later fetches in this window
        # may otherwise evict them before their request executes.
        pinned: dict[BlockKey, np.ndarray] = {}
        slos = self.config.tenant_slo_p99 or {}
        for req in batch:
            # serve() handles PUTs as window barriers before batching;
            # a PUT inside a window would break the pin/plan invariants
            assert req.kind == "get", f"batch may only hold GETs, got {req.kind}"
            if (
                req.object_id not in self._objects
                or req.object_id in self._deleted
            ):
                report.add_record(
                    RequestRecord(
                        req.time, req.object_id, "get", None, False, 0, 0, 0,
                        tenant=req.tenant,
                    )
                )
                continue
            gid, row = self._objects[req.object_id]
            self._clock = req.time
            try:
                plan = self.planner.plan(gid, row, at=req.time)
            except UnreadableObjectError:
                report.add_record(
                    RequestRecord(
                        req.time, req.object_id, "get", None, True, 0, 0, 0,
                        tenant=req.tenant,
                    )
                )
                continue
            # SLO admission: estimate queue + transfer + decode time for
            # the plan; degrade mode first re-ranks the planner's
            # candidates by that estimate (a backlogged engine can make
            # the Table-1 byte-cheapest plan the latency-dearest one).
            slo = slos.get(req.tenant)
            if slo is not None and self.config.admission != ADMIT_OFF:
                est = self._estimate_service_time(plan, req.time, req.tenant)
                if est > slo and self.config.admission == ADMIT_DEGRADE:
                    plan, est = min(
                        (
                            (p, self._estimate_service_time(p, req.time, req.tenant))
                            for p in self.planner.candidates(gid, row, at=req.time)
                        ),
                        key=lambda pe: pe[1],
                    )
                if est > slo:
                    report.rejections[req.tenant] = (
                        report.rejections.get(req.tenant, 0) + 1
                    )
                    report.add_record(
                        RequestRecord(
                            req.time, req.object_id, "get", None,
                            plan.degraded, 0, 0, 0,
                            tenant=req.tenant, rejected=True,
                        )
                    )
                    continue
            if self.cache is not None:
                for key in plan.source_keys:
                    if key not in pinned and not self.store.available(key):
                        blk = self.cache.get(key)
                        if blk is not None:
                            pinned[key] = blk
            tid = 0
            if tracer.enabled:
                tid = tracer.begin_trace()
                tracer.instant(
                    "plan",
                    req.time,
                    tid,
                    tid,
                    track=("tenant", req.tenant),
                    degraded=plan.degraded,
                    sources=len(plan.source_keys),
                    decodes=len(plan.decodes),
                )
            gets.append((req, plan))
            tids.append(tid)
        if not gets:
            return

        # 1) fetch: every needed block rides the fabric to the request's
        # client port, and every store fetch's crc32 digest is verified
        # on landing (config.verify_checksums). A mismatch is
        # reclassified as an ERASURE at the fetch's completion time —
        # quarantine + tombstone + repair queue — and the request
        # REPLANS against the shrunken source set (CORE parity first, RS
        # fallback), so wrong bytes never reach a payload. Direct data
        # fetches stuck behind a fail-slow source may hedge
        # (config.hedge): past the deadline derived from the healthy-
        # fabric estimate, the cheapest single-block recovery plan races
        # the primary and the first verified winner serves the column.
        # Serial mode gates the whole window's transfers on the previous
        # window's completion (the synchronous loop cannot start
        # fetching window N+1 while window N is still decoding);
        # pipelined mode starts them at plan time.
        verify_ck = self.config.verify_checksums
        ready: list[dict[BlockKey, float]] = []
        bytes_read: list[int] = []
        cache_hits: list[int] = []
        fetch_ats: list[float] = []
        alive: list[bool] = []
        fetched: dict[BlockKey, np.ndarray] = {}
        for i, (req, plan) in enumerate(gets):
            client = self._client_port(req)
            tid = tids[i]
            gid, row = self._objects[req.object_id]
            fetch_at0 = fetch_at = (
                max(plan.planned_at, self._window_free)
                if serial
                else plan.planned_at
            )
            # SLO tenants stamp their fabric transfers with a deadline so
            # the simulator's per-tenant miss counters line up with the
            # report's violation rates.
            deadline = (
                req.time + slos[req.tenant] if req.tenant in slos else None
            )
            key_ready: dict[BlockKey, float] = {}
            nbytes = 0
            hits = 0
            hedges = 0
            n_store = 0  # store fetches scheduled for THIS request
            extra_ops: list = []
            dropped_direct: set[BlockKey] = set()
            ok_request = True
            trk = ("tenant", req.tenant)
            # Replan loop: terminates because every corruption detection
            # permanently quarantines a source (the replan never picks it
            # again); the attempt cap is pure defense in depth.
            for _attempt in range(self.code.n * self.family.rows + 1):
                corrupt: list[tuple[BlockKey, float]] = []
                stale = False
                # direct fetches eligible to hedge; the DECISION is
                # deferred until every primary of this attempt is booked,
                # so the alternate path can reuse the whole in-flight
                # fetch set for free
                h_cands: list[tuple[BlockKey, float, int, float]] = []
                for key in plan.source_keys:
                    if key in key_ready:
                        continue
                    blk = pinned.get(key)
                    if blk is None and self.cache is not None:
                        blk = self.cache.get(key)
                    if blk is not None:
                        # cache copies were digest-verified when they
                        # entered (fetch path) or checked post-decode —
                        # no re-verify: checksumming models DISK reads
                        key_ready[key] = max(
                            fetch_at, self._cache_ready.get(key, 0.0)
                        )
                        hits += 1
                        if tracer.enabled:
                            tracer.instant(
                                "cache.hit",
                                key_ready[key],
                                tid,
                                tid,
                                track=trk,
                                key=key,
                            )
                        fetched[key] = blk
                        continue
                    if not self.store.available(key):
                        # quarantined by an earlier request of this same
                        # window: nothing to fetch, the replan below
                        # routes around it
                        stale = True
                        continue
                    blk = self.store.get(key)
                    src_node = self.store.node_of(key)
                    # committed backlog BEFORE this transfer books its
                    # own reservation: the hedge deadline must measure
                    # the fabric as the request found it
                    pre_backlog = (
                        self.sim.send_backlog(
                            src_node, self._fab_tenant(req.tenant), fetch_at
                        )
                        if self.config.hedge and key in plan.direct
                        else None
                    )
                    n_store += 1
                    end = self.sim.transfer(
                        Transfer(
                            src_node,
                            client,
                            blk.nbytes,
                            fetch_at,
                            tenant=self._fab_tenant(req.tenant),
                            deadline=deadline,
                            ctx=(tid, tid) if tracer.enabled else None,
                        )
                    )
                    nbytes += blk.nbytes
                    self._fetch_bytes[req.tenant] = (
                        self._fetch_bytes.get(req.tenant, 0) + blk.nbytes
                    )
                    if verify_ck and not self.store.verify(key):
                        # corrupt bytes crossed the fabric and failed
                        # the digest check on landing — never cached,
                        # never delivered
                        corrupt.append((key, end))
                        continue
                    if pre_backlog is not None:
                        h_cands.append((key, pre_backlog, n_store, end))
                    key_ready[key] = end
                    fetched[key] = blk
                    if self.cache is not None:
                        self.cache.put(key, blk)
                        self._cache_ready[key] = end
                    if tracer.enabled:
                        # request-side view: includes fabric queueing
                        # (the port-track xfer span shows the transfer
                        # itself, from its first byte)
                        tracer.span(
                            "fetch",
                            fetch_at,
                            end,
                            tid,
                            tid,
                            track=trk,
                            key=key,
                            src=src_node,
                            bytes=blk.nbytes,
                        )
                # Deadline baseline: the LEAST-backlogged source this
                # request fetched from. A fail-slow port's own committed
                # queue is stretched by the very slowness being detected,
                # so pricing each candidate against its own backlog would
                # let a gray source re-baseline its own deadline into
                # oblivion; the cross-source differential is the signal.
                base_b = min((b for _, b, _, _ in h_cands), default=0.0)
                for h_key, _pre_b, n_at, h_end in h_cands:
                    if hedges >= self.config.hedge_max_retries:
                        break
                    h_op, h_bytes, h_hits, launched = self._maybe_hedge(
                        req, h_key, fetch_at, base_b, n_at, h_end, hedges,
                        client, deadline, key_ready, fetched, pinned,
                        report, tid, trk,
                    )
                    nbytes += h_bytes
                    hits += h_hits
                    if launched:
                        hedges += 1
                    if h_op is not None:
                        extra_ops.append(h_op)
                        dropped_direct.add(h_key)
                if not corrupt and not stale:
                    break
                detect_at = max((e for _, e in corrupt), default=fetch_at)
                for key, at in corrupt:
                    self._note_corrupt(
                        key,
                        at,
                        report,
                        source="read",
                        ctx=(tid, tid, trk) if tracer.enabled else None,
                    )
                # the degraded replan starts when the LAST bad fetch of
                # this round landed — detection costs real latency
                self._clock = fetch_at = max(detect_at, fetch_at)
                try:
                    plan = self.planner.plan(gid, row, at=fetch_at)
                except UnreadableObjectError:
                    ok_request = False
                    break
            if ok_request and (extra_ops or dropped_direct):
                plan = replace(
                    plan,
                    direct=tuple(
                        k for k in plan.direct if k not in dropped_direct
                    ),
                    decodes=plan.decodes + tuple(extra_ops),
                )
            gets[i] = (req, plan)
            if not ok_request:
                # corruption detections mid-window pushed the object past
                # tolerance: fail the read (bytes already moved are real)
                report.add_record(
                    RequestRecord(
                        req.time, req.object_id, "get", None, True,
                        nbytes, 0, hits, tenant=req.tenant,
                    )
                )
                if tracer.enabled:
                    tracer.end_trace(tid)
            alive.append(ok_request)
            ready.append(key_ready)
            bytes_read.append(nbytes)
            cache_hits.append(hits)
            fetch_ats.append(fetch_at0)

        # 2) decode: dedup identical reconstructions (a hot degraded
        # object appears once per window, not once per request), then one
        # stacked launch per shape bucket, scheduled on the simulated
        # serial decode engine.
        unique_idx: dict[tuple, int] = {}
        uops = []
        owners: list[list[int]] = []
        for i, (_req, plan) in enumerate(gets):
            if not alive[i]:
                continue
            for op in plan.decodes:
                okey = (op.group_id, op.row, op.kind, op.targets, op.sources)
                j = unique_idx.get(okey)
                if j is None:
                    j = len(uops)
                    unique_idx[okey] = j
                    uops.append(op)
                    owners.append([])
                owners[j].append(i)
        results, units = self.coalescer.execute(uops, lambda k: fetched[k])
        if verify_ck:
            # end-to-end integrity: a reconstruction must reproduce the
            # digest stored at PUT. Sources are verified at fetch time,
            # so a mismatch here means the decode pipeline itself (or an
            # unverified path feeding it) produced wrong bytes — a bug,
            # not a modeled fault.
            for j, op in enumerate(uops):
                for col, out in results[j].items():
                    if self.store.checksum_ok((op.group_id, op.row, col), out) is False:
                        raise AssertionError(
                            "decode output digest mismatch for block "
                            f"({op.group_id}, {op.row}, {col})"
                        )
        if self.config.decode_cost_per_tile is not None:
            # throughput-bound modeled billing: a unit costs its tile
            # count, so splitting the op stream into more/smaller
            # launches does not change the cluster's total billed work
            units = [
                replace(u, compute=self.config.decode_cost_per_tile * u.tiles)
                for u in units
            ]
            # rolling tiles-per-launch average for the admission
            # estimator (billed work, not measured wall time)
            self._pt_tiles += sum(u.tiles for u in units)
            self._pt_launches += len({(u.kind, u.launch_id) for u in units})
        elif self.config.decode_cost is not None:
            # modeled-cost mode: deterministic billing — each unit gets
            # its FRACTION of one modeled launch, so a launch's units
            # still sum to exactly decode_cost regardless of dataplane
            units = [
                replace(u, compute=self.config.decode_cost * u.fraction)
                for u in units
            ]
        # a unit bills its engine time to the tenant of the earliest
        # request that owns one of its ops (a unit has exactly one
        # engine reservation, so it needs exactly one payer)
        op_ready: list[float] = [
            max(ready[i][s] for i in owners[j] for s in op.sources)
            for j, op in enumerate(uops)
        ]
        op_tenant: list[str] = [
            gets[owners[j][0]][0].tenant for j in range(len(uops))
        ]
        op_done: list[float] = [0.0] * len(uops)
        # per-op launch attribution for the critical-path analyzer: the
        # dispatch interval of the unit that COMPLETED the op (its max
        # end), plus the launch-wide source barrier it waited behind
        op_meta: list[dict | None] = [None] * len(uops)
        if serial:
            # strict staging: no launch before ALL the window's transfers
            # (even direct-only fetches) complete; launches back-to-back
            # on ONE engine (the synchronous loop this baseline prices
            # had no decode parallelism); the whole window waits for the
            # last launch.
            window_net = max(
                (t for key_ready in ready for t in key_ready.values()),
                default=self._window_free,
            )
            if units:
                total = sum(u.compute for u in units)
                start, end = self._pool.dispatch(
                    window_net,
                    total,
                    ctx=(
                        (tids[0], tids[0], {"kind": "serial", "launch_id": -1})
                        if tracer.enabled
                        else None
                    ),
                )
                op_done = [end] * len(uops)
                op_meta = [
                    {
                        "start": start,
                        "end": end,
                        "ready": window_net,
                        "kind": "serial",
                        "launch_id": -1,
                        "fraction": 1.0,
                        "tiles": 0,
                    }
                ] * len(uops)
        else:
            # pipelined: a PHYSICAL launch cannot start before every
            # source staged into it lands (its buffer holds all its
            # ops' tiles), so all units sharing a launch_id wait for
            # the launch-wide barrier; past it they dispatch
            # independently, in arrival order, onto the least-loaded
            # decode engine under the owning tenant's engine share —
            # windows (and one megakernel launch's per-op tile ranges)
            # overlap across the engine pool
            launch_ready: dict[int, float] = {}
            for u in units:
                r = max(op_ready[j] for j in u.op_indices)
                launch_ready[u.launch_id] = max(
                    launch_ready.get(u.launch_id, 0.0), r
                )
            for u in sorted(units, key=lambda u: launch_ready[u.launch_id]):
                ctx = None
                if tracer.enabled:
                    # bill the engine-track span to the trace of the
                    # earliest request owning this unit's first op (the
                    # same owner the engine time is billed to)
                    ctx = (
                        tids[owners[u.op_indices[0]][0]],
                        tids[owners[u.op_indices[0]][0]],
                        {"kind": u.kind, "launch_id": u.launch_id},
                    )
                start, end = self._pool.dispatch(
                    launch_ready[u.launch_id], u.compute,
                    tenant=op_tenant[u.op_indices[0]],
                    ctx=ctx,
                )
                for j in u.op_indices:
                    if end >= op_done[j]:
                        op_done[j] = end
                        op_meta[j] = {
                            "start": start,
                            "end": end,
                            "ready": launch_ready[u.launch_id],
                            "kind": u.kind,
                            "launch_id": u.launch_id,
                            "fraction": u.fraction,
                            "tiles": u.tiles,
                        }

        # 3) verify + deliver
        decoded_per_req: list[dict[int, np.ndarray]] = [dict() for _ in gets]
        for j, op in enumerate(uops):
            for i in owners[j]:
                decoded_per_req[i].update(results[j])
        # rebuild cost of a decoded block = source blocks its op consumed
        # (t vertical, k horizontal) — the cache's eviction currency
        decode_cost: dict[int, dict[int, int]] = {}
        for j, op in enumerate(uops):
            for i in owners[j]:
                costs = decode_cost.setdefault(i, {})
                for col in op.targets:
                    costs[col] = len(op.sources)
        window_end = self._window_free
        for i, (req, plan) in enumerate(gets):
            if not alive[i]:
                continue
            done = req.time
            for key in plan.direct:
                done = max(done, ready[i][key])
            for op in plan.decodes:
                okey = (op.group_id, op.row, op.kind, op.targets, op.sources)
                done = max(done, op_done[unique_idx[okey]])
            digest = None
            if self.config.verify or self.config.record_payloads:
                payload = self._assemble_payload(req, plan, fetched, decoded_per_req[i])
                if self.config.verify:
                    self._verify_get(req, payload)
                    report.metrics.counter("verified_gets").inc()
                if self.config.record_payloads:
                    digest = hashlib.sha256(payload.tobytes()).hexdigest()
            if self.cache is not None:
                gid, row = self._objects[req.object_id]
                costs = decode_cost.get(i, {})
                col_done = {
                    col: op_done[
                        unique_idx[
                            (op.group_id, op.row, op.kind, op.targets, op.sources)
                        ]
                    ]
                    for op in plan.decodes
                    for col in op.targets
                }
                for col, blk in decoded_per_req[i].items():
                    ckey = (gid, row, col)
                    self.cache.put(ckey, blk, cost=costs.get(col, 1.0))
                    self._cache_ready[ckey] = col_done.get(col, done)
            if tracer.enabled:
                tid = tids[i]
                for op in plan.decodes:
                    okey = (op.group_id, op.row, op.kind, op.targets, op.sources)
                    j = unique_idx[okey]
                    meta = op_meta[j]
                    if meta is None:
                        continue
                    tracer.span(
                        "decode",
                        meta["start"],
                        meta["end"],
                        tid,
                        tid,
                        track=("tenant", req.tenant),
                        op=j,
                        shared=len(owners[j]),
                        op_ready=max(ready[i][s] for s in op.sources),
                        **{
                            k: meta[k]
                            for k in ("ready", "kind", "launch_id", "fraction", "tiles")
                        },
                    )
                if self.config.verify:
                    tracer.instant(
                        "verify", done, tid, tid, track=("tenant", req.tenant)
                    )
                tracer.root_span(
                    "request",
                    req.time,
                    done,
                    tid,
                    track=("tenant", req.tenant),
                    object_id=req.object_id,
                    kind="get",
                    tenant=req.tenant,
                    degraded=plan.degraded,
                    bytes=bytes_read[i],
                    cache_hits=cache_hits[i],
                    fetch_at=fetch_ats[i],
                )
                tracer.end_trace(tid, latency=done - req.time)
            report.add_record(
                RequestRecord(
                    req.time,
                    req.object_id,
                    "get",
                    done - req.time,
                    plan.degraded,
                    bytes_read[i],
                    plan.reconstruction_blocks,
                    cache_hits[i],
                    payload_digest=digest,
                    tenant=req.tenant,
                )
            )
            window_end = max(window_end, done)
        if serial:
            self._window_free = window_end

    # -- integrity plane ---------------------------------------------------------
    def _note_corrupt(
        self,
        key: BlockKey,
        at: float,
        report: GatewayReport,
        source: str,
        ctx=None,
        queue_repair: bool = True,
    ) -> None:
        """Reclassify a detected corruption as an ERASURE: quarantine the
        replica (placement and the trusted digest survive — repair can
        verify its own rebuild), tombstone it in the negative cache so
        planners stop probing it, and queue a repair pass. ``source``
        labels the detector (read | scrub | write | repair)."""
        self.store.quarantine(key)
        self._lost_at.setdefault(key, at)
        # any in-flight heal write-back raced the corruption; distrust it
        self._healing.pop(key, None)
        # tombstone in EVERY shard's negative cache — another shard may
        # hold this block's key in a read plan it has yet to execute
        self.meta.put_negative(key, at, self.config.negative_ttl)
        report.metrics.counter("corruption_detected", source=source).inc()
        t0 = self._corrupted_at.pop(key, None)
        if t0 is not None:
            # injection-to-detection gap: the integrity plane's MTTD
            report.corruption_latency.append(at - t0)
        if queue_repair and self.config.repair_on_failure:
            self._repair_queue.append((at + self.config.repair_delay, -2))
            self._repair_queue.sort()
        if ctx is not None:
            tid, pid, trk = ctx
            self.tracer.instant(
                "corrupt", at, tid, pid, track=trk, key=key, source=source
            )

    def _run_scrub(self, at: float, report: GatewayReport) -> None:
        """One background scrub tick: verify a budget's worth of resident
        blocks against their stored digests, reclassifying mismatches as
        erasures. The budget rides the repair pacer's share so scrubbing
        backs off exactly when foreground latency is under pressure."""
        share = 1.0
        if self._pacer is not None:
            observed = self._observed_p99(report, at)
            pressure = self._foreground_pressure(at)
            if pressure > 0.0:
                observed = max(observed or 0.0, pressure)
            share = self._pacer.share(observed, self._pacing_slo)
        budget = max(1, int(self.config.scrub_blocks_per_run * share))
        bad = self._scrubber.scan(budget)
        report.metrics.counter("scrub_blocks").inc(budget)
        tracer = self.tracer
        stid = 0
        if tracer.enabled:
            stid = tracer.begin_trace()
        for key in bad:
            self._note_corrupt(
                key,
                at,
                report,
                source="scrub",
                ctx=(stid, stid, ("repair", "repair")) if stid else None,
            )
        if stid:
            tracer.root_span(
                "scrub.run",
                at,
                at,
                stid,
                track=("repair", "repair"),
                scanned=min(budget, len(self.store.blocks)),
                found=len(bad),
            )
            tracer.end_trace(stid)

    def _maybe_hedge(
        self,
        req,
        key: BlockKey,
        fetch_at: float,
        pre_backlog: float,
        n_store: int,
        end: float,
        hedges: int,
        client: int,
        deadline: float | None,
        key_ready: dict,
        fetched: dict,
        pinned: dict,
        report: GatewayReport,
        tid: int,
        trk,
    ):
        """Race a slow direct fetch against the planner's cheapest
        single-block recovery op. Returns ``(op, bytes, hits, launched)``
        — ``op`` is the winning DecodeOp to splice into the plan (None:
        deadline not hit, no viable op, out of budget, or the primary
        won the race anyway).

        The hedge deadline is ``hedge_threshold x`` the HEALTHY-fabric
        estimate: ``pre_backlog`` is the committed backlog of the
        request's LEAST-backlogged source (the caller computes the min
        across its fetch set), plus serialization at the tenant's
        guaranteed rate. A fail-slow port's own queue is stretched by
        the very slowness being detected, so the estimate never reads
        the lagging source's backlog — the degraded fetch shows up as
        ``end >> estimate`` instead of quietly re-baselining its own
        deadline. Speculative bytes are capped
        by a per-tenant ledger at ``hedge_budget`` of the tenant's
        cumulative primary fetch bytes — the extra-fabric-traffic bound
        is structural, not observed."""
        cfg = self.config
        tenant = req.tenant
        # expected completion of THIS fetch on a healthy fabric: source
        # backlog + the request's own client-NIC serialization so far
        # (n_store store fetches, this one included, share the client
        # port) — self-inflicted queueing is NOT gray failure and must
        # not trip the hedge
        est = pre_backlog + n_store * self._block_bytes / (
            self.sim.weight_of(tenant) * self.profile.node_bandwidth
        )
        h_at = fetch_at + cfg.hedge_threshold * (cfg.hedge_backoff ** hedges) * est
        if end <= h_at:
            return None, 0, 0, False
        gid, row, col = key
        self._clock = h_at
        # Rank alternate paths by NEW fetch bytes, not Table-1 totals: a
        # horizontal op whose row sources are already riding this
        # request's fabric costs one parity fetch, while the "cheaper"
        # vertical op fetches t fresh column blocks. Disqualify any path
        # that routes new fetches through the lagging source's node —
        # under column-aligned placement the vertical sources can share
        # the stuck column's node, making the byte-cheapest op the one
        # op guaranteed to lose the race.
        lagging = self.store.node_of(key)
        op = None
        h_cost = 0
        for cand in self.planner.recovery_ops(gid, row, col):
            fresh = [
                s
                for s in cand.sources
                if s not in key_ready
                and s not in pinned
                and not (self.cache is not None and s in self.cache)
            ]
            if any(self.store.node_of(s) == lagging for s in fresh):
                continue
            cost = len(fresh) * self._block_bytes
            if op is None or cost < h_cost:
                op, h_cost = cand, cost
        if op is None:
            return None, 0, 0, False
        spent = self._hedge_bytes.get(tenant, 0)
        if spent + h_cost > cfg.hedge_budget * self._fetch_bytes.get(tenant, 0):
            report.metrics.counter("hedge_budget_denied", tenant=tenant).inc()
            return None, 0, 0, False
        report.metrics.counter("hedge_launched", tenant=tenant).inc()
        nbytes = 0
        hits = 0
        h_ready = h_at
        ok = True
        for s in op.sources:
            if s in key_ready:
                # already riding the fabric for this request — free
                h_ready = max(h_ready, key_ready[s])
                continue
            sblk = pinned.get(s)
            if sblk is None and self.cache is not None:
                sblk = self.cache.get(s)
            if sblk is not None:
                r = max(h_at, self._cache_ready.get(s, 0.0))
                key_ready[s] = r
                fetched[s] = sblk
                hits += 1
                h_ready = max(h_ready, r)
                continue
            if not self.store.available(s):
                ok = False
                break
            sblk = self.store.get(s)
            s_end = self.sim.transfer(
                Transfer(
                    self.store.node_of(s),
                    client,
                    sblk.nbytes,
                    h_at,
                    tenant=self._fab_tenant(tenant),
                    deadline=deadline,
                    ctx=(tid, tid) if self.tracer.enabled else None,
                )
            )
            nbytes += sblk.nbytes
            self._hedge_bytes[tenant] = (
                self._hedge_bytes.get(tenant, 0) + sblk.nbytes
            )
            if cfg.verify_checksums and not self.store.verify(s):
                # the speculation tripped over latent damage: quarantine
                # it and abandon this hedge (the primary still serves)
                self._note_corrupt(
                    s,
                    s_end,
                    report,
                    source="read",
                    ctx=(tid, tid, trk) if self.tracer.enabled else None,
                )
                ok = False
                break
            key_ready[s] = s_end
            fetched[s] = sblk
            if self.cache is not None:
                self.cache.put(s, sblk)
                self._cache_ready[s] = s_end
            h_ready = max(h_ready, s_end)
        won = ok and (h_ready + self._decode_launch_estimate() < end)
        report.metrics.counter(
            "hedge_wins" if won else "hedge_losses", tenant=tenant
        ).inc()
        if nbytes:
            report.metrics.counter("hedge_bytes", tenant=tenant).inc(nbytes)
        if self.tracer.enabled:
            self.tracer.span(
                "hedge",
                h_at,
                max(h_ready, h_at),
                tid,
                tid,
                track=trk,
                key=key,
                kind=op.kind,
                won=won,
                attempt=hedges + 1,
            )
        return (op if won else None), nbytes, hits, True

    # -- write dataplane ---------------------------------------------------------
    def _handle_delete(
        self, req: Request, report: GatewayReport
    ) -> RequestRecord:
        """Tombstone an object. Blocks and ground truth stay resident
        (the group parity remains a consistent codeword — eager block
        removal would force a parity RMW per delete); a later overwrite
        PUT resurrects the object in place. A delete is pure metadata:
        zero fabric traffic, acknowledged instantly."""
        oid = req.object_id
        known = oid in self._objects and oid not in self._deleted
        if known:
            self._deleted.add(oid)
            report.metrics.counter("deletes", tenant=req.tenant).inc()
        return RequestRecord(
            req.time, oid, "delete", 0.0 if known else None, False, 0, 0, 0,
            tenant=req.tenant,
        )

    def _flush_puts(self, batch: list[Request], report: GatewayReport) -> None:
        """One PUT window: admission, small-object journaling/sealing,
        then the window's encodes — ONE ragged ENCODE megakernel window
        for the whole batch (``write_coalesce="ragged"``) or one per PUT
        (``"sync"``, the synchronous write baseline)."""
        cfg = self.config
        slos = cfg.tenant_slo_p99 or {}
        full_reqs: list[Request] = []
        small_reqs: list[Request] = []
        for req in batch:
            assert req.kind == "put", f"put batch may only hold PUTs, got {req.kind}"
            self._clock = req.time
            if req.nbytes is None and req.object_id not in self._objects:
                report.add_record(
                    RequestRecord(
                        req.time, req.object_id, "put", None, False, 0, 0, 0,
                        tenant=req.tenant,
                    )
                )
                continue
            # SLO admission: writes are admitted against the tenant's
            # in-flight write backlog + this PUT's own bytes + (full
            # overwrites) the encode-engine wait — the same currency the
            # GET estimator charges, so writes and reads push back on
            # each other instead of writes riding for free
            slo = slos.get(req.tenant)
            if slo is not None and cfg.admission != ADMIT_OFF:
                est = self._estimate_put_time(req, req.time)
                if est > slo:
                    report.put_rejections[req.tenant] = (
                        report.put_rejections.get(req.tenant, 0) + 1
                    )
                    report.add_record(
                        RequestRecord(
                            req.time, req.object_id, "put", None, False, 0,
                            0, 0, tenant=req.tenant, rejected=True,
                        )
                    )
                    continue
            (small_reqs if req.nbytes is not None else full_reqs).append(req)
        seal_groups = self._append_small(small_reqs, report)
        jobs: list[dict] = []
        cur: dict[int, np.ndarray] = {}  # same-oid overwrite chains
        for req in full_reqs:
            oid = req.object_id
            gid, row = self._objects[oid]
            rng = np.random.default_rng(
                (oid * 1_000_003 + int(req.time * 1e6)) % (2**63)
            )
            new_data = rng.integers(
                0, 256, (self.code.k, self._block_bytes), dtype=np.uint8
            )
            # Delta against the re-encoded OLD row (ground truth), not
            # the stored block — a lost old block must still contribute
            # its delta or the vertical parity goes stale for the whole
            # column. Within a window, chained overwrites of one object
            # delta against the PREVIOUS overwrite in arrival order.
            old_data = cur.get(oid, self._expected[oid])
            cur[oid] = new_data
            jobs.append(
                {
                    "req": req,
                    "oid": oid,
                    "gid": gid,
                    "row": row,
                    "new_data": new_data,
                    "old_data": old_data,
                    "enc_done": req.time,
                }
            )
        if cfg.write_coalesce == "ragged":
            windows = [(jobs, seal_groups)] if (jobs or seal_groups) else []
        else:
            windows = [([j], []) for j in jobs]
            windows += [([], [g]) for g in seal_groups]
        for wjobs, wseals in windows:
            self._encode_window(wjobs, wseals, report)

    def _append_small(
        self, reqs: list[Request], report: GatewayReport
    ) -> list[dict]:
        """Journal and pack small PUTs (stripe sealing). The journal
        append IS the ack: the payload rides the fabric to a
        deterministic journal node and the PUT completes when it lands —
        sealing and encoding happen behind the ack. Returns the seal
        groups (``objects_per_group`` sealed rows each) this window
        completed, ready for _encode_window."""
        groups: list[dict] = []
        t = self.family.objects_per_group
        tracer = self.tracer
        for req in reqs:
            if self._sealer is None:
                self._sealer = StripeSealer(self.code.k, self._block_bytes)
            nb = max(1, min(int(req.nbytes), self._sealer.row_bytes))
            rng = np.random.default_rng(
                (req.object_id * 1_000_003 + int(req.time * 1e6) + nb)
                % (2**63)
            )
            payload = rng.integers(0, 256, nb, dtype=np.uint8)
            small_id = (req.object_id, round(req.time, 9))
            self._pending_rows.extend(
                self._sealer.append(small_id, payload, req.tenant)
            )
            while len(self._pending_rows) >= t:
                rows = self._pending_rows[:t]
                del self._pending_rows[:t]
                gid = f"w{self._seal_tag}{self._seal_group_seq}"
                self._seal_group_seq += 1
                groups.append(
                    {
                        "gid": gid,
                        "rows": rows,
                        "time": req.time,
                        "tenant": req.tenant,
                        "enc_done": req.time,
                    }
                )
            jnode = zlib.crc32(repr(small_id).encode()) % self.store.num_nodes
            tid = tracer.begin_trace() if tracer.enabled else 0
            end = self.sim.transfer(
                Transfer(
                    self._client_port(req),
                    jnode,
                    nb,
                    req.time,
                    tenant=self._fab_tenant(req.tenant),
                    ctx=(tid, tid) if tracer.enabled else None,
                )
            )
            self._put_inflight.setdefault(req.tenant, []).append(
                (end, float(nb))
            )
            report.metrics.counter("small_puts", tenant=req.tenant).inc()
            if tracer.enabled:
                tracer.root_span(
                    "request",
                    req.time,
                    end,
                    tid,
                    track=("tenant", req.tenant),
                    object_id=req.object_id,
                    kind="put",
                    tenant=req.tenant,
                    degraded=False,
                    bytes=nb,
                    cache_hits=0,
                    fetch_at=req.time,
                )
                tracer.end_trace(tid, latency=end - req.time)
            report.add_record(
                RequestRecord(
                    req.time, req.object_id, "put", end - req.time, False,
                    nb, 0, 0, tenant=req.tenant,
                )
            )
        return groups

    def _dispatch_encode_units(
        self, units, op_ready, op_tenant, op_tid, model_cost
    ) -> list[float]:
        """Dispatch one encode phase's LaunchUnits on the shared engine
        pool under the decode path's exact conventions: modeled-cost
        override scaled by each unit's launch fraction, launch-wide
        readiness barrier (a physical launch's staging buffer holds
        every op's tiles), owner-tenant billing. Returns per-op
        completion times."""
        op_done = list(op_ready)
        if not units:
            return op_done
        if model_cost is not None:
            units = [
                replace(u, compute=model_cost * u.fraction) for u in units
            ]
        launch_ready: dict[int, float] = {}
        for u in units:
            r = max(op_ready[j] for j in u.op_indices)
            launch_ready[u.launch_id] = max(
                launch_ready.get(u.launch_id, 0.0), r
            )
        tracer = self.tracer
        for u in sorted(units, key=lambda u: launch_ready[u.launch_id]):
            j0 = u.op_indices[0]
            ctx = None
            if tracer.enabled and op_tid[j0]:
                ctx = (
                    op_tid[j0],
                    op_tid[j0],
                    {"kind": u.kind, "launch_id": u.launch_id},
                )
            _start, end = self._pool.dispatch(
                launch_ready[u.launch_id],
                u.compute,
                tenant=op_tenant[j0],
                ctx=ctx,
            )
            for j in u.op_indices:
                op_done[j] = max(op_done[j], end)
        return op_done

    def _encode_window(
        self, jobs: list[dict], seals: list[dict], report: GatewayReport
    ) -> None:
        """Execute one write ENCODE window end to end.

        Phase EH (ops.gf256_ragged_encode): every full overwrite
        re-encodes its NEW data and re-derives its OLD row's parity
        columns through the RS generator, and every sealing row
        generates its parity columns — all in ONE ragged megakernel
        launch. Phase EV (ops.xor_ragged_encode): ONE fold op per parity
        block the window touches (XOR associativity folds every
        contributing PUT's old^new delta and the stored parity in a
        single op) plus the sealing groups' vertical parity columns —
        again one launch. Both phases are billed on the SHARED engine
        pool (modeled ``encode_cost`` / ``decode_cost`` or measured
        best-observed kernel time, exactly like decode), and each PUT's
        client transfers start only once its encodes land — encoded
        bytes cannot ride the fabric before they exist.

        The parity read-modify-write verifies the stored digest BEFORE
        folding: XOR-ing into silently-corrupt bytes and restamping
        would LAUNDER the corruption under a fresh valid checksum. A
        corrupt parity block is treated like an unavailable one —
        quarantined and reconciled by repair."""
        if not jobs and not seals:
            return
        cfg = self.config
        n, k, q = self.code.n, self.code.k, self._block_bytes
        has_parity = self.family.rows > 1
        parity_row = self.family.rows - 1
        model_cost = (
            cfg.encode_cost if cfg.encode_cost is not None else cfg.decode_cost
        )
        tracer = self.tracer
        pool: dict = {}  # staging tokens -> host arrays (the fetch oracle)
        for job in jobs:
            job["tid"] = tracer.begin_trace() if tracer.enabled else 0
        for seal in seals:
            seal["tid"] = tracer.begin_trace() if tracer.enabled else 0
            seal["matrix"] = np.zeros(
                (self.family.rows, n, q), dtype=np.uint8
            )
            for r, (_seq, row_data, _exts) in enumerate(seal["rows"]):
                seal["matrix"][r, :k] = row_data

        # ---- phase EH: RS parity-row generation ------------------------------
        eh_ops: list[DecodeOp] = []
        eh_owner: list[tuple] = []
        eh_ready: list[float] = []
        eh_tenant: list[str] = []
        eh_tid: list[int] = []
        if has_parity:
            pmat = rs.parity_matrix(n, k)
            par_targets = tuple(range(k, n))

            def stage_eh(tok0, data, gid, row, owner, at, tenant, tid):
                srcs = []
                for i in range(k):
                    tok = tok0 + (i,)
                    pool[tok] = data[i]
                    srcs.append(tok)
                eh_ops.append(
                    DecodeOp("EH", gid, row, par_targets, tuple(srcs), pmat)
                )
                eh_owner.append(owner)
                eh_ready.append(at)
                eh_tenant.append(tenant)
                eh_tid.append(tid)

            for ji, job in enumerate(jobs):
                for tag in ("new", "old"):
                    stage_eh(
                        ("j", ji, tag),
                        job[f"{tag}_data"],
                        job["gid"],
                        job["row"],
                        ("job", ji, tag),
                        job["req"].time,
                        job["req"].tenant,
                        job["tid"],
                    )
            for si, seal in enumerate(seals):
                for r in range(len(seal["rows"])):
                    stage_eh(
                        ("s", si, r),
                        seal["matrix"][r, :k],
                        seal["gid"],
                        r,
                        ("seal", si, r),
                        seal["time"],
                        seal["tenant"],
                        seal["tid"],
                    )
        eh_results, eh_units = self.coalescer.execute_encode(
            eh_ops, pool.__getitem__
        )
        eh_done = self._dispatch_encode_units(
            eh_units, eh_ready, eh_tenant, eh_tid, model_cost
        )
        for oi, owner in enumerate(eh_owner):
            out = eh_results[oi]
            if owner[0] == "job":
                _o, ji, tag = owner
                job = jobs[ji]
                rowbuf = np.empty((n, q), dtype=np.uint8)
                rowbuf[:k] = job[f"{tag}_data"]
                for col, arr in out.items():
                    rowbuf[col] = arr
                job[f"{tag}_row"] = rowbuf
                job["enc_done"] = max(job["enc_done"], eh_done[oi])
            else:
                _o, si, r = owner
                for col, arr in out.items():
                    seals[si]["matrix"][r, col] = arr
                seals[si]["enc_done"] = max(
                    seals[si]["enc_done"], eh_done[oi]
                )
        if has_parity and cfg.verify:
            # kernel-vs-oracle: the ragged EH output must equal the host
            # generator exactly — wrong encodes may never reach a disk
            for job in jobs:
                want = np.asarray(self.code.horizontal.encode(job["new_data"]))
                if not np.array_equal(job["new_row"], want):
                    raise AssertionError(
                        f"ragged encode mismatch for object {job['oid']}"
                    )
        if not has_parity:
            # row families (rs / lrc): the object IS the whole codeword
            # row — encode through the family generator host-side and
            # bill one modeled launch per overwrite / seal on the pool
            dur = (
                model_cost
                if model_cost is not None
                else self._encode_launch_estimate()
            )
            for job in jobs:
                job["new_row"] = np.asarray(
                    self.family.encode_group(job["new_data"][None])
                )[0]
                job["old_row"] = None
                if dur > 0.0:
                    _s, end = self._pool.dispatch(
                        job["req"].time, dur, tenant=job["req"].tenant
                    )
                    job["enc_done"] = max(job["enc_done"], end)
            for seal in seals:
                objs = np.stack([rd for (_sq, rd, _x) in seal["rows"]])
                seal["matrix"] = np.asarray(self.family.encode_group(objs))
                if dur > 0.0:
                    _s, end = self._pool.dispatch(
                        seal["time"], dur, tenant=seal["tenant"]
                    )
                    seal["enc_done"] = max(seal["enc_done"], end)

        # ---- phase EV: XOR-delta folds + seal vertical parity ----------------
        ev_ops: list[DecodeOp] = []
        ev_owner: list[tuple] = []
        ev_ready: list[float] = []
        ev_tenant: list[str] = []
        ev_tid: list[int] = []
        if has_parity:
            par_state: dict = {}
            folds: dict = {}
            for ji, job in enumerate(jobs):
                gid = job["gid"]
                cols = []
                for c in range(n):
                    par_key = (gid, parity_row, c)
                    ok = par_state.get(par_key)
                    if ok is None:
                        # a lost parity column is reconciled later by
                        # repair instead
                        ok = self.store.available(par_key)
                        if (
                            ok
                            and cfg.verify_checksums
                            and not self.store.verify(par_key)
                        ):
                            self._note_corrupt(
                                par_key,
                                job["req"].time,
                                report,
                                source="write",
                            )
                            ok = False
                        par_state[par_key] = ok
                    if not ok:
                        continue
                    ent = folds.get(par_key)
                    if ent is None:
                        tok = ("p",) + par_key
                        pool[tok] = self.store.blocks[par_key]
                        ent = folds[par_key] = {
                            "sources": [tok],
                            "jobs": [],
                            "ready": 0.0,
                        }
                    otok = ("o", ji, c)
                    ntok = ("n", ji, c)
                    pool[otok] = job["old_row"][c]
                    pool[ntok] = job["new_row"][c]
                    ent["sources"] += [otok, ntok]
                    if ji not in ent["jobs"]:
                        ent["jobs"].append(ji)
                    ent["ready"] = max(ent["ready"], job["enc_done"])
                    cols.append(c)
                job["par_cols"] = cols
            for par_key, ent in folds.items():
                gidp, prow, c = par_key
                ev_ops.append(
                    DecodeOp(
                        "EV", gidp, prow, (c,), tuple(ent["sources"]), None
                    )
                )
                ev_owner.append(("fold", par_key, tuple(ent["jobs"])))
                ev_ready.append(ent["ready"])
                j0 = ent["jobs"][0]
                ev_tenant.append(jobs[j0]["req"].tenant)
                ev_tid.append(jobs[j0]["tid"])
            for si, seal in enumerate(seals):
                mat = seal["matrix"]
                for c in range(n):
                    srcs = []
                    for r in range(len(seal["rows"])):
                        tok = ("v", si, r, c)
                        pool[tok] = mat[r, c]
                        srcs.append(tok)
                    ev_ops.append(
                        DecodeOp(
                            "EV",
                            seal["gid"],
                            parity_row,
                            (c,),
                            tuple(srcs),
                            None,
                        )
                    )
                    ev_owner.append(("seal", si, c))
                    ev_ready.append(seal["enc_done"])
                    ev_tenant.append(seal["tenant"])
                    ev_tid.append(seal["tid"])
        ev_results, ev_units = self.coalescer.execute_encode(
            ev_ops, pool.__getitem__
        )
        ev_done = self._dispatch_encode_units(
            ev_units, ev_ready, ev_tenant, ev_tid, model_cost
        )
        par_final: dict = {}
        for oi, owner in enumerate(ev_owner):
            val = ev_results[oi][ev_ops[oi].targets[0]]
            if owner[0] == "fold":
                par_final[owner[1]] = val
                for ji in owner[2]:
                    jobs[ji]["enc_done"] = max(
                        jobs[ji]["enc_done"], ev_done[oi]
                    )
            else:
                _o, si, c = owner
                seals[si]["matrix"][parity_row, c] = val
                seals[si]["enc_done"] = max(
                    seals[si]["enc_done"], ev_done[oi]
                )

        # ---- commit: store writes, client transfers, housekeeping ------------
        for par_key, val in par_final.items():
            # each parity block is written ONCE with the window's fully
            # folded value (the write re-digests it over its new bytes)
            self.store.put_block(par_key, val)
            self._corrupted_at.pop(par_key, None)
            # fresh parity bytes: stale cached copies die EVERYWHERE, and
            # only a parity block actually WRITTEN sheds its known-down
            # tombstone; an unavailable one stays negative until repair
            # or recovery brings it back
            self.meta.invalidate(par_key)
            self.meta.purge_negative([par_key])
        for job in jobs:
            self._commit_overwrite(job, report)
        for seal in seals:
            self._commit_seal(seal, report)

    def _commit_overwrite(self, job: dict, report: GatewayReport) -> None:
        """Write one full-row overwrite's blocks and bill its client
        transfers — starting at max(arrival, encode completion): the
        fabric carries ENCODED bytes, which cannot exist before the
        billed encode launches land."""
        req = job["req"]
        gid, row, oid = job["gid"], job["row"], job["oid"]
        q = self._block_bytes
        new_row = job["new_row"]
        parity_row = self.family.rows - 1
        client = self._client_port(req)
        tid = job["tid"]
        tracer = self.tracer
        xfer_at = max(req.time, job["enc_done"])
        inflight = self._put_inflight.setdefault(req.tenant, [])
        done = xfer_at
        nbytes = 0
        par_cols = set(job.get("par_cols") or ())
        for c in range(self.code.n):
            old_key = (gid, row, c)
            par_key = (gid, parity_row, c)
            if c in par_cols:
                end = self.sim.transfer(
                    Transfer(
                        client,
                        self.store.node_of(par_key),
                        int(q),
                        xfer_at,
                        tenant=self._fab_tenant(req.tenant),
                        ctx=(tid, tid) if tracer.enabled else None,
                    )
                )
                inflight.append((end, float(q)))
                done = max(done, end)
                nbytes += q
            self.store.put_block(old_key, new_row[c])
            # a full overwrite wipes any undetected silent damage
            self._corrupted_at.pop(old_key, None)
            end = self.sim.transfer(
                Transfer(
                    client,
                    self.store.node_of(old_key),
                    int(q),
                    xfer_at,
                    tenant=self._fab_tenant(req.tenant),
                    ctx=(tid, tid) if tracer.enabled else None,
                )
            )
            inflight.append((end, float(q)))
            done = max(done, end)
            nbytes += q
            # PUT invalidations propagate to EVERY shard's cache: a
            # routed overwrite must not leave pre-write bytes servable
            # from a sibling shard that cached them for a vertical read
            self.meta.invalidate(old_key)
            self.meta.invalidate(par_key)
            # the data write re-placed its block on an alive node:
            # that tombstone is stale (the parity one is handled at
            # the fold commit, only when actually written)
            self.meta.purge_negative([old_key])
            # a client write supersedes any in-flight repair write-back
            self._healing.pop(old_key, None)
            self._healing.pop(par_key, None)
            self._reprice_on_heal.discard(old_key)
            self._reprice_on_heal.discard(par_key)
            self._lost_at.pop(old_key, None)
            if self.store.available(par_key):
                self._lost_at.pop(par_key, None)
        self._expected[oid] = job["new_data"]
        self._deleted.discard(oid)  # an overwrite resurrects a tombstone
        if tracer.enabled:
            tracer.root_span(
                "request",
                req.time,
                done,
                tid,
                track=("tenant", req.tenant),
                object_id=oid,
                kind="put",
                tenant=req.tenant,
                degraded=False,
                bytes=nbytes,
                cache_hits=0,
                fetch_at=xfer_at,
            )
            tracer.end_trace(tid, latency=done - req.time)
        report.add_record(
            RequestRecord(
                req.time, oid, "put", done - req.time, False, nbytes, 0, 0,
                tenant=req.tenant,
            )
        )

    def _commit_seal(self, seal: dict, report: GatewayReport) -> None:
        """Place one sealed group (rows x n blocks) and register its
        rows as synthetic objects above SEAL_OID_BASE, so sealed small
        objects serve/plan/repair like any other group row."""
        gid = seal["gid"]
        mat = seal["matrix"]
        q = self._block_bytes
        if self.config.verify:
            objs = np.stack([rd for (_sq, rd, _x) in seal["rows"]])
            want = np.asarray(self.family.encode_group(objs))
            if not np.array_equal(mat, want):
                raise AssertionError(
                    f"sealed-stripe encode mismatch for group {gid}"
                )
        self.store.put_group(gid, mat)
        client = -(
            1
            + (self.shard_id or 0) * self.config.num_client_ports
            + zlib.crc32(gid.encode()) % self.config.num_client_ports
        )
        xfer_at = max(seal["time"], seal["enc_done"])
        inflight = self._put_inflight.setdefault(seal["tenant"], [])
        tid = seal["tid"]
        tracer = self.tracer
        done = xfer_at
        nbytes = 0
        for r in range(mat.shape[0]):
            for c in range(self.code.n):
                end = self.sim.transfer(
                    Transfer(
                        client,
                        self.store.node_of((gid, r, c)),
                        int(q),
                        xfer_at,
                        tenant=self._fab_tenant(seal["tenant"]),
                        ctx=(tid, tid) if tracer.enabled else None,
                    )
                )
                inflight.append((end, float(q)))
                done = max(done, end)
                nbytes += q
        members = []
        for r, (seq, row_data, exts) in enumerate(seal["rows"]):
            oid = self._seal_oid_base + seq
            self._objects[oid] = (gid, r)
            self._expected[oid] = row_data
            self._sealed_rows[seq] = oid
            self._sealed_extents.extend(exts)
            members.append(oid)
        self._groups[gid] = members
        report.metrics.counter("stripes_sealed").inc()
        report.metrics.counter("seal_bytes").inc(nbytes)
        if tracer.enabled:
            tracer.root_span(
                "request",
                seal["time"],
                done,
                tid,
                track=("tenant", seal["tenant"]),
                object_id=-1,
                kind="seal",
                tenant=seal["tenant"],
                degraded=False,
                bytes=nbytes,
                cache_hits=0,
                fetch_at=xfer_at,
            )
            tracer.end_trace(tid, latency=done - seal["time"])

    def seal_flush(
        self, at: float, report: GatewayReport | None = None
    ) -> int:
        """Drain the small-object packer: seal the partial open row
        (zero-padded tail), pad out the last group with zero filler rows
        (zero bytes are identity under both codes — mirrors
        load_objects' padding), and encode/place what remains. Returns
        the number of groups sealed."""
        if self._sealer is None:
            return 0
        report = report if report is not None else GatewayReport()
        self._pending_rows.extend(self._sealer.flush())
        t = self.family.objects_per_group
        if self._pending_rows:
            while len(self._pending_rows) % t:
                self._pending_rows.append(self._sealer.zero_row())
        groups = []
        while self._pending_rows:
            rows = self._pending_rows[:t]
            del self._pending_rows[:t]
            gid = f"w{self._seal_tag}{self._seal_group_seq}"
            self._seal_group_seq += 1
            groups.append(
                {
                    "gid": gid,
                    "rows": rows,
                    "time": at,
                    "tenant": DEFAULT_TENANT,
                    "enc_done": at,
                }
            )
        self._encode_window([], groups, report)
        return len(groups)

    # -- cluster fault events (scenario engine) ----------------------------------
    def _apply_cluster_event(self, evt, report: GatewayReport) -> bool:
        """Apply one node-level fault event; returns True when the event
        creates missing blocks that background repair should chase.

        Gray-failure events ride the same stream: SlowNode/SlowNicEvent
        degrade the fabric model's per-node rate (no blocks lost — repair
        is not triggered), and CorruptionEvent flips bits in place. A
        silent corruption (bitflip / torn) creates NO missing block yet:
        the damage surfaces only when a digest check — fetch, scrub, or
        repair-source verify — catches it, which is exactly the
        detection-latency gap the integrity plane measures."""
        if isinstance(evt, (SlowNodeEvent, SlowNicEvent)):
            direction = getattr(evt, "direction", "both")
            self.sim.set_node_rate(evt.node, evt.rate_factor, direction=direction)
            report.metrics.counter(
                "slow_events", node=str(evt.node), direction=direction
            ).inc()
            return False
        if isinstance(evt, CorruptionEvent):
            if evt.blocks:
                keys = [tuple(k) for k in evt.blocks]
            else:
                # deterministic victim pick: crc32-keyed order over the
                # node's resident blocks (stable across runs and immune
                # to dict-insertion order)
                keys = sorted(
                    (k for k in self.store.keys_on_node(evt.node)
                     if k in self.store.blocks),
                    key=lambda k: zlib.crc32(repr(k).encode()),
                )
                if evt.count > 0:
                    keys = keys[: evt.count]
            wants_repair = False
            for key in keys:
                if not self.store.corrupt_block(key, mode=evt.mode):
                    continue
                report.metrics.counter("blocks_corrupted", mode=evt.mode).inc()
                if evt.mode == "erase":
                    # hard loss, like a test's drop_block: visible to the
                    # planner immediately, chased by repair immediately
                    self._lost_at.setdefault(key, evt.time)
                    self._healing.pop(key, None)
                    wants_repair = True
                else:
                    # SILENT: the store still serves the block; only the
                    # stale digest knows. Stamp the injection time so
                    # detection latency is measurable.
                    self._corrupted_at.setdefault(key, evt.time)
            return wants_repair
        if isinstance(evt, NodeRecoverEvent):
            keys = self.store.keys_on_node(evt.node)
            self.store.heal_node(evt.node)
            # transient failure over: the node's blocks are back, so
            # their negative entries expire NOW, not at their TTL —
            # in every shard's cache, not just the one applying the event
            self.meta.purge_negative(keys)
            for key in keys:
                if self.store.available(key):
                    t0 = self._lost_at.pop(key, None)
                    if t0 is not None:
                        report.restored_samples.append(evt.time - t0)
            # a recovery can restore the SOURCES a stuck group was
            # waiting on (its missing set changes, clearing the stuck
            # memo) — with no failure event left to queue a repair, the
            # recovery itself must trigger a re-scan when losses remain
            return bool(self._lost_at or self._repair_stuck)
        if isinstance(evt, CapacityLossEvent):
            # capture keys BEFORE the store drops their placement
            lost = self.store.lose_node_blocks(evt.node)
            for key in lost:
                self._lost_at.setdefault(key, evt.time)
                # data destroyed: any in-flight heal of this key is moot
                self._healing.pop(key, None)
                self.meta.put_negative(key, evt.time, self.config.negative_ttl)
            return bool(lost)
        # FailureEvent: transient crash — disks survive, the node may
        # recover with its blocks intact
        assert isinstance(evt, FailureEvent), f"unknown cluster event {evt!r}"
        keys = [
            k for k in self.store.keys_on_node(evt.node) if k in self.store.blocks
        ]
        self.store.fail_nodes([evt.node])
        for key in keys:
            self._lost_at.setdefault(key, evt.time)
            self.meta.put_negative(key, evt.time, self.config.negative_ttl)
        return True

    # -- background repair -------------------------------------------------------
    def _observed_p99(self, report: GatewayReport, at_time: float) -> float | None:
        """Recent foreground p99 the pacer reacts to: completed GETs of
        SLO-declaring tenants (all tenants when none declare) arriving in
        the trailing ``pacing_window``. None => idle (no recent traffic)."""
        slos = self.config.tenant_slo_p99 or {}
        since = at_time - self.config.pacing_window
        # report.recent holds the trailing completed GETs (bounded deque)
        # — the pacer's observation window no longer needs the unbounded
        # per-request record list, so streaming mode paces identically
        lats = [
            lat
            for (t, tenant, lat) in report.recent
            if since <= t <= at_time and (not slos or tenant in slos)
        ]
        if not lats:
            return None
        # same interpolating definition as GatewayReport.latency_percentile
        # — an index quantile would degenerate to the window MAX below
        # 100 samples and let one outlier throttle repair
        return float(np.percentile(lats, 99))

    def _foreground_pressure(self, at_time: float) -> float:
        """The pacer's fast signal: the estimated completion time of a
        degraded GET arriving right now — worst committed foreground
        backlog on any send port plus the k + t source-block
        serialization such a read pays on its client NIC. Completed-
        request p99 lags by exactly the queueing it should prevent (a
        request hurt by repair is only OBSERVED after it finishes
        waiting); port backlog reflects full-weight repair reservations
        the moment they are booked, so the loop reacts before the
        damage reaches the latency records. Zero while no port is
        backlogged: an idle fabric is no reason to slow repair.

        The backlog is read per SLO-declaring tenant (their fair-share
        cursors differ when they ride at different fabric weights);
        without declared SLOs it falls back to the default foreground
        tenant."""
        slos = self.config.tenant_slo_p99 or {}
        tenants = tuple(slos) or (FOREGROUND_TENANT,)
        backlog = max(
            (
                self.sim.send_backlog(node, self._fab_tenant(tenant), at_time)
                for node in self.store.alive_nodes()
                for tenant in tenants
            ),
            default=0.0,
        )
        if backlog <= 0.0:
            return 0.0
        serialization = (
            self.family.degraded_fetch_blocks
            * self._block_bytes
            / self.profile.node_bandwidth
        )
        return backlog + serialization

    def _background_repair(self, at_time: float, report: GatewayReport) -> bool:
        """Repair up to ``repair_groups_per_run`` groups; returns True
        when pending groups remain (the caller requeues a continuation).
        Groups whose missing set provably cannot shrink (fix_group ran
        and left it unchanged) are skipped until their failure set
        changes — a continuation loop must not spin on data loss."""
        self.fixer.not_before = at_time
        pending: list[tuple[str, list[BlockKey]]] = []
        for gid in self._groups:
            if not self.meta.owns_group(self.shard_id, gid):
                # under sharding each group's repair runs on exactly one
                # shard (directory-hashed), so N shards split the
                # backlog; a dead shard's groups re-hash to survivors
                continue
            missing = [
                (gid, r, c)
                for r in range(self.family.rows)
                for c in range(self.code.n)
                if not self.store.available((gid, r, c))
            ]
            if not missing:
                self._repair_stuck.pop(gid, None)
                continue
            if self.config.verify_checksums:
                # the rebuild reads this group's surviving blocks as
                # decode sources — verify them first so a silently-
                # corrupt source joins the missing set instead of
                # poisoning the regenerated blocks (which would carry a
                # fresh digest over wrong bytes)
                bad = [
                    (gid, r, c)
                    for r in range(self.family.rows)
                    for c in range(self.code.n)
                    if (gid, r, c) in self.store.blocks
                    and not self.store.verify((gid, r, c))
                ]
                for key in bad:
                    self._note_corrupt(
                        key, at_time, report, source="repair",
                        queue_repair=False,
                    )
                    missing.append(key)
            if self._repair_stuck.get(gid) == frozenset(missing):
                continue
            pending.append((gid, missing))
        budget = self.config.repair_groups_per_run
        if budget is None:
            budget = len(pending)
        tracer = self.tracer
        rtid = 0
        run_end = at_time
        healed = 0
        if tracer.enabled and pending:
            rtid = tracer.begin_trace()
            self.fixer.trace_ctx = (rtid, rtid)
        for gid, missing in pending[:budget]:
            if self._pacer is not None:
                # closed loop: re-evaluate per group, so within one long
                # repair the share tracks mounting MTTR urgency (the
                # repair tenant's own makespan is "how long this repair
                # has been dragging")
                elapsed_anchor = max(
                    at_time, self.sim.class_makespan.get(self._repair_tenant, 0.0)
                )
                oldest = min(
                    (self._lost_at.get(k, at_time) for k in missing),
                    default=at_time,
                )
                observed = self._observed_p99(report, at_time)
                pressure = self._foreground_pressure(at_time)
                if pressure > 0.0:
                    observed = max(observed or 0.0, pressure)
                share = self._pacer.share(
                    observed,
                    self._pacing_slo,
                    outstanding_for=elapsed_anchor - oldest,
                )
                # fabric pacing acts on this shard's repair LANE (other
                # shards' repairs pace independently); the engine pool
                # is private, so the base name suffices there
                self.sim.set_tenant_weight(self._repair_tenant, share)
                self._pool.set_weight(REPAIR_TENANT, share)
                report.pacing.append((round(elapsed_anchor, 6), round(share, 4)))
                if rtid:
                    tracer.instant(
                        "pacing",
                        elapsed_anchor,
                        rtid,
                        rtid,
                        track=("repair", "repair"),
                        share=round(share, 4),
                        observed_p99=observed,
                        pressure=round(pressure, 6),
                    )
            rep = self.fixer.fix_group(gid)
            report.repair_reports.append(rep)
            # repaired blocks stay invisible to reads until the repair's
            # background transfers complete on the fabric AND its decode
            # compute clears the (shared, weighted) engine pool
            done = self.sim.class_makespan.get(self._repair_tenant, at_time)
            compute = rep.compute_time
            if self.config.decode_cost is not None:
                compute = self.config.decode_cost * rep.blocks_repaired
            elif self.config.decode_cost_per_tile is not None:
                # throughput model: each repaired block is one decoded
                # row of block_bytes, priced at the coalescer tile width
                compute = (
                    self.config.decode_cost_per_tile
                    * rep.blocks_repaired
                    * self.coalescer.tiles_for(self._block_bytes)
                )
            if compute > 0.0:
                # fetch -> decode -> write-back: the decode cannot start
                # before the repair's fabric transfers deliver its inputs
                _, eng_done = self._pool.dispatch(
                    done,
                    compute,
                    tenant=REPAIR_TENANT,
                    ctx=(
                        (rtid, rtid, {"kind": "repair.decode", "group": gid})
                        if rtid
                        else None
                    ),
                )
                done = max(done, eng_done)
            run_end = max(run_end, done)
            still_missing = []
            for key in missing:
                if self.store.available(key):
                    self._healing[key] = done
                    # the block is no longer known-down; the _healing
                    # gate (not the tombstone) hides it until its
                    # write-back transfers land — purged cluster-wide
                    self.meta.purge_negative([key])
                    t0 = self._lost_at.pop(key, None)
                    if t0 is not None:
                        report.mttr_samples.append(done - t0)
                        healed += 1
                        if rtid:
                            tracer.instant(
                                "repair.heal",
                                done,
                                rtid,
                                rtid,
                                track=("repair", "repair"),
                                key=str(key),
                                mttr=round(done - t0, 6),
                            )
                else:
                    still_missing.append(key)
            if still_missing:
                # fix_group repaired everything it could: what's left is
                # stuck until the failure set changes (data loss, or a
                # recovery event restoring sources)
                self._repair_stuck[gid] = frozenset(still_missing)
            else:
                self._repair_stuck.pop(gid, None)
        if rtid:
            tracer.root_span(
                "repair.run",
                at_time,
                max(run_end, at_time),
                rtid,
                track=("repair", "repair"),
                groups=min(budget, len(pending)),
                healed=healed,
            )
            tracer.end_trace(rtid)
            self.fixer.trace_ctx = None
        return len(pending) > budget

    # -- durability audit ---------------------------------------------------------
    def audit_durability(self) -> dict:
        """Ground-truth durability snapshot against the RAW store (cache
        copies don't count — a reconstruction in gateway memory is not a
        durable replica): blocks currently missing, blocks in clusters
        the code provably cannot rebuild (``blocks_lost`` — data loss),
        and objects no read plan can serve right now."""
        missing_blocks = 0
        blocks_lost = 0
        for gid in self._groups:
            fm = self.store.failure_matrix(gid, self.family.rows, self.code.n)
            missing_blocks += int(fm.sum())
            if self.family.name == "core":
                for cluster in independent_clusters(fm):
                    if not is_recoverable(self.code, cluster):
                        blocks_lost += int(cluster.sum())
            elif not self.family.group_recoverable(
                lambda rc, g=gid: self.store.available((g, rc[0], rc[1]))
            ):
                missing_blocks_in_group = int(fm.sum())
                blocks_lost += missing_blocks_in_group
        store_planner = DegradedReadPlanner(
            self.store, self.code, family=self.family
        )
        unreadable = 0
        for oid, (gid, row) in self._objects.items():
            try:
                store_planner.plan(gid, row)
            except UnreadableObjectError:
                unreadable += 1
        return {
            "missing_blocks": missing_blocks,
            "blocks_lost": blocks_lost,
            "unreadable_objects": unreadable,
        }

    # -- write consistency audits -------------------------------------------------
    def audit_parity(self) -> dict:
        """Ground-truth parity freshness audit: re-encode every group
        from the gateway's expected object contents and compare each
        RESIDENT stored block byte-for-byte. A block whose stored digest
        fails (silent corruption awaiting detection) counts as
        ``corrupt``, NOT ``stale`` — staleness means the write path
        forgot a delta; corruption is a modeled fault the integrity
        plane will catch and repair. Zero ``stale`` after any churn
        trace is the write dataplane's consistency contract."""
        checked = stale = corrupt = 0
        t = self.family.objects_per_group
        k, q = self.code.k, self._block_bytes
        for gid, members in self._groups.items():
            objs = np.zeros((t, k, q), dtype=np.uint8)
            for oid in members:
                _g, r = self._objects[oid]
                objs[r] = self._expected[oid]
            want = np.asarray(self.family.encode_group(objs))
            for r in range(self.family.rows):
                for c in range(self.code.n):
                    key = (gid, r, c)
                    blk = self.store.blocks.get(key)
                    if blk is None:
                        continue
                    checked += 1
                    if not self.store.verify(key):
                        corrupt += 1
                    elif not np.array_equal(blk, want[r, c]):
                        stale += 1
        return {
            "blocks_checked": checked,
            "stale_blocks": stale,
            "corrupt_blocks": corrupt,
        }

    def audit_sealed_stripes(self) -> dict:
        """End-to-end sealed-extent audit through DEGRADED paths: plan
        every sealed row against the RAW store (cache copies don't
        count), host-execute the plan's reconstructions, and compare
        each extent's bytes against the sha256 recorded at append time.
        Run after a fault trace: zero ``extents_wrong`` means every
        sealed byte decodes identically through whatever degraded path
        the failure set forces."""
        planner = DegradedReadPlanner(self.store, self.code, family=self.family)
        rows_checked = rows_unreadable = rows_degraded = 0
        extents = wrong = 0
        rows_of: dict[int, list[Extent]] = {}
        for ext in self._sealed_extents:
            rows_of.setdefault(ext.row_seq, []).append(ext)
        for seq, exts in sorted(rows_of.items()):
            oid = self._sealed_rows.get(seq)
            if oid is None:
                continue  # row sealed but its group not yet placed
            gid, row = self._objects[oid]
            rows_checked += 1
            try:
                plan = planner.plan(gid, row)
            except UnreadableObjectError:
                rows_unreadable += 1
                continue
            if plan.degraded:
                rows_degraded += 1
            decoded: dict[int, np.ndarray] = {}
            for op in plan.decodes:
                decoded.update(self._host_decode(op))
            flat = np.concatenate(
                [
                    np.asarray(
                        decoded[c]
                        if c in decoded
                        else self.store.blocks[(gid, row, c)]
                    ).ravel()
                    for c in range(self.code.k)
                ]
            )
            for ext in exts:
                extents += 1
                chunk = flat[ext.offset : ext.offset + ext.length]
                if hashlib.sha256(chunk.tobytes()).hexdigest() != ext.digest:
                    wrong += 1
        return {
            "rows_checked": rows_checked,
            "rows_unreadable": rows_unreadable,
            "rows_degraded": rows_degraded,
            "extents_checked": extents,
            "extents_wrong": wrong,
            "extents_pending": (
                self._sealer.pending_extents if self._sealer else 0
            ),
        }

    def _host_decode(self, op: DecodeOp) -> dict[int, np.ndarray]:
        """Execute one reconstruction host-side (audit path only — zero
        simulated cost, raw store sources)."""
        srcs = np.stack([self.store.blocks[s] for s in op.sources])
        if op.coeffs is None:
            out = srcs[0].copy()
            for s in srcs[1:]:
                np.bitwise_xor(out, s, out=out)
            return {op.targets[0]: out}
        out = np_matmul(np.asarray(op.coeffs, dtype=np.uint8), srcs)
        return {col: out[i] for i, col in enumerate(op.targets)}

    # -- SLO admission estimator -------------------------------------------------
    def _decode_launch_estimate(self) -> float:
        """Expected scaled wall time of one batched decode launch, from
        the coalescer's measured history (0 until the first launch —
        optimistic, so cold-start traffic is admitted). Modeled-cost mode
        returns the modeled cost exactly; per-tile mode prices the
        rolling billed tiles-per-launch average."""
        if self.config.decode_cost is not None:
            return self.config.decode_cost
        if self.config.decode_cost_per_tile is not None:
            if not self._pt_launches:
                return 0.0
            return (
                self.config.decode_cost_per_tile
                * self._pt_tiles
                / self._pt_launches
            )
        st = self.coalescer.stats
        return st.compute_time / st.decode_calls if st.decode_calls else 0.0

    def _encode_launch_estimate(self) -> float:
        """Expected scaled wall time of one encode launch: the modeled
        cost when set (``encode_cost``, falling back to ``decode_cost``),
        else the coalescer's measured encode history, else the decode
        estimate (optimistic cold start — admit early traffic)."""
        cfg = self.config
        if cfg.encode_cost is not None:
            return cfg.encode_cost
        if cfg.decode_cost is not None:
            return cfg.decode_cost
        st = self.coalescer.stats
        if st.encode_calls:
            return st.encode_compute_time / st.encode_calls
        return self._decode_launch_estimate()

    def _estimate_put_time(self, req: Request, now: float) -> float:
        """Admission estimate for a PUT arriving ``now``: the tenant's
        own in-flight write bytes + this PUT's write bytes serializing
        at the tenant's guaranteed fair-share rate, plus (full
        overwrites) the encode-engine wait and the window's two encode
        launches (EH + EV). O(1) on purpose, like
        ``_estimate_service_time`` — admission may not re-run the
        simulation."""
        tenant = req.tenant
        pending = self._put_inflight.get(tenant)
        live: list[tuple[float, float]] = []
        if pending:
            live = [e for e in pending if e[0] > now]
            self._put_inflight[tenant] = live
        rate = self.sim.weight_of(tenant) * self.profile.node_bandwidth
        if req.nbytes is not None:
            write_bytes = float(req.nbytes)
        else:
            per_col = 2 if self.family.rows > 1 else 1
            write_bytes = float(self.code.n * per_col * self._block_bytes)
        est = (sum(b for _e, b in live) + write_bytes) / rate
        if req.nbytes is None:
            est += max(0.0, self._pool.earliest_start(now) - now)
            est += 2 * self._encode_launch_estimate()
        return est

    def _estimate_service_time(
        self, plan: ReadPlan, now: float, tenant: str
    ) -> float:
        """Estimated completion time for a GET arriving ``now``: source
        blocks not in cache serialize into the request's single client
        NIC at the tenant's GUARANTEED fair-share rate, behind the
        tenant's own most-backlogged source-port cursor (reservations of
        lighter tenants are preemptible under the quantum fabric, so
        they don't count against it), and a degraded plan then waits for
        the least-loaded decode engine's backlog plus its own launches.
        O(plan) on purpose — an admission decision may not re-run the
        simulation — so it uses the simulator's per-(port, tenant)
        cursors rather than exact timeline search."""
        fetch_bytes = 0
        net_backlog = 0.0
        for key in plan.source_keys:
            if self.cache is not None and key in self.cache:
                continue
            fetch_bytes += self._block_bytes
            net_backlog = max(
                net_backlog,
                self.sim.send_backlog(
                    self.store.node_of(key), self._fab_tenant(tenant), now
                ),
            )
        share = self.sim.weight_of(tenant)
        est = net_backlog + fetch_bytes / (share * self.profile.node_bandwidth)
        # write pressure: the tenant's in-flight PUT bytes share the same
        # fair-share pipe its fetches ride — reads queue behind committed
        # writes, so admission must charge them (no puts => term is 0 and
        # read-only traces price identically to the pre-write estimator)
        pending = self._put_inflight.get(tenant)
        if pending:
            live = [e for e in pending if e[0] > now]
            self._put_inflight[tenant] = live
            est += sum(b for _e, b in live) / (
                share * self.profile.node_bandwidth
            )
        if self.config.pipeline == SERIAL:
            # serial mode gates every fetch on the previous window's
            # completion — under load that barrier IS the latency
            est += max(0.0, self._window_free - now)
        if plan.decodes:
            est += max(0.0, self._pool.earliest_start(now) - now)
            est += self._decode_launch_estimate() * len(plan.decodes)
        return est

    # -- helpers ----------------------------------------------------------------
    def _client_port(self, req: Request) -> int:
        # negative node ids: client NICs outside the storage cluster.
        # Hashed per REQUEST, not per object: a popular object is popular
        # because many distinct clients want it, so its traffic spreads
        # over client NICs instead of melting one artificial hot port.
        h = (req.object_id * 1_000_003 + int(req.time * 1e7)) % (2**31)
        # each shard gets a private client-NIC stripe: shard 1's port -33
        # is not shard 0's port -1, so shards don't serialize on fake
        # shared client hardware (the whole point of scale-out)
        base = (self.shard_id or 0) * self.config.num_client_ports
        return -(1 + base + h % self.config.num_client_ports)

    def _assemble_payload(self, req, plan, fetched, decoded) -> np.ndarray:
        """The GET's (k, q) payload: direct blocks + reconstructions."""
        gid, row = self._objects[req.object_id]
        got = []
        for c in range(self.code.k):
            key = (gid, row, c)
            if key in fetched and c not in decoded:
                got.append(fetched[key])
            else:
                got.append(decoded[c])
        return np.stack(got)

    def _verify_get(self, req, payload: np.ndarray) -> None:
        want = self._expected[req.object_id]
        if not np.array_equal(payload, want):
            raise AssertionError(
                f"GET integrity failure for object {req.object_id}"
            )
