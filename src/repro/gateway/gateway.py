"""The object-storage serving gateway: request-driven PUT/GET over the
simulated CORE cluster, end to end.

Requests (Poisson arrivals) are grouped into small batching windows; each
window's GETs are planned against the live failure set (planner.py) and
their reconstructions coalesced into batched kernel launches
(coalescer.py). Every byte moved rides the shared NetSimulator fabric —
where background repair traffic (BlockFixer as the "repair" tenant)
contends with foreground reads, instead of running in a separate
universe. Block contents are real; every degraded GET is verified
against ground truth.

Multi-tenant QoS: every request carries a tenant tag, and each tenant's
fabric transfers ride the quantum scheduler under that tenant's
weighted-fair ratio (``GatewayConfig.tenant_weights`` — repair is just
another tenant whose weight defaults to ``background_share``). Tenants
may declare a p99 latency SLO (``tenant_slo_p99``); the admission
controller estimates an arriving GET's completion time (client-NIC fetch
serialization + decode-engine backlog + measured per-launch decode cost)
and, when the estimate busts the tenant's SLO, either rejects the
request up front (``admission="reject"``) or first degrades it to the
latency-cheapest viable plan (``admission="degrade"``, re-ranking the
planner's candidates by estimated time instead of Table-1 bytes) and
rejects only if even that plan busts the target. Rejections are tracked
per tenant in ``GatewayReport.rejections``.

Pipeline stages (config.pipeline):

  1. **fetch**   — every source block of the window's plans is scheduled
     on the fabric at the request's plan time (``ReadPlan.planned_at``);
     cache hits are ready immediately. Under the quantum fabric
     (config.fabric) these transfers preempt long background repair
     transfers at quantum granularity instead of queueing behind them.
  2. **decode**  — reconstructions are deduped across the window and
     executed by the ragged megakernel dataplane
     (``config.coalesce="ragged"``, the default): the whole window's
     mixed-shape decode set is staged as fixed-width descriptor tiles
     and decoded in ONE Pallas launch per kind (two chunk rungs bound
     the traced signatures at <= 2 per kind; see gateway/coalescer.py).
     The coalescer returns LaunchUnits — a megakernel launch is split
     by tile ranges into one unit per op — and each unit is dispatched
     least-loaded-first onto ``num_engines`` parallel simulated
     decode-engine timelines once its LAUNCH's source transfers have
     all completed (a physical launch's staging buffer holds every one
     of its ops' tiles) and an engine frees, so a single physical
     launch still spreads across the pool. ``coalesce="bucketed"`` keeps the
     pre-megakernel shape-bucketed dataplane (one stacked launch per
     (kind, M, K, blocklen) bucket, ladder-padded) as the measured
     baseline.
  3. **verify / deliver** — each GET completes at the max of its direct
     fetches and the decode launches it depends on; contents are checked
     against ground truth host-side (zero simulated cost).

In ``pipelined`` mode (default) the stages overlap across windows:
window N+1's fabric transfers proceed while window N's decode launches
occupy the engine, and the engine drains buckets in source-arrival
order. ``serial`` mode is the comparison baseline: it charges the
serialization a synchronous flush-per-batch loop actually implies — a
window's transfers may not start before the previous window fully
completed, no launch is issued before ALL the window's transfers land,
the launches run back-to-back, and every degraded GET of the window
waits for the last of them. (The PR-1 loop executed stages strictly in
sequence but its simulated timestamps let them overlap optimistically;
serial mode prices that loop honestly rather than reproducing its
accounting.)

Fabric quantum model (storage/netmodel.py): transfers are scheduled in
fixed full-rate quanta; a priority class with share s may claim one
quantum per quantum/s of wall time per port, so the holes a throttled
background class leaves are real preemption points for foreground reads
— ``background_share`` is a weighted-fair quantum ratio, not a rate cap.

Latency model per request: arrival -> (cache | fabric transfers to the
request's client port) -> per-bucket decode on the shared engine ->
completion. Decode compute is measured on the real jitted kernels
(autotuned per backend, batch sizes padded up a fixed ladder so the jit
cache stays bounded — GatewayReport.jit_cache_entries) and scaled by the
cluster profile.

Fault scenarios (repro.scenario): ``serve`` consumes node-level cluster
events mid-run — transient crashes (FailureEvent), recoveries
(NodeRecoverEvent: blocks return intact, negative cache entries purged)
and capacity losses (CapacityLossEvent: blocks destroyed, only repair
restores them). Blocks on down nodes are negative-cached with a TTL so
planning skips re-probing known failures; loss times feed MTTR samples
when repair heals (``GatewayReport.mttr_samples``) or the node recovers
(``restored_samples``), and ``audit_durability`` reports provable data
loss for traces beyond the code's tolerance.

Closed-loop repair pacing (``repair_pacing=True``): before each group
repair, a PacingController (storage/repair.py) maps the protected
tier's recent p99 headroom against ``tenant_slo_p99`` — plus an MTTR
urgency term as the repair drags — to the "repair" tenant's fabric
weight AND decode-engine share, applied via
``NetSimulator.set_tenant_weight`` and ``EnginePool.set_weight``:
repair backs off while foreground latency is at risk and accelerates
toward the MTTR target when idle. Decisions land in
``GatewayReport.pacing``. Repair decode compute itself is billed on the
shared engine pool as the "repair" tenant, so engine shares bite both
ways.
"""

from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.failure_matrix import independent_clusters
from repro.core.product_code import CoreCode, CoreCodec
from repro.core.recoverability import is_recoverable
from repro.gateway.cache import LRUBlockCache
from repro.gateway.coalescer import DecodeCoalescer
from repro.gateway.planner import (
    DegradedReadPlanner,
    ReadPlan,
    UnreadableObjectError,
)
from repro.gateway.workload import (
    CapacityLossEvent,
    DEFAULT_TENANT,
    FailureEvent,
    NodeRecoverEvent,
    Request,
)
from repro.kernels import autotune
from repro.obs.metrics import BoundedLog, BoundedSamples, MetricsRegistry
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.storage.blockstore import BlockKey, BlockStore
from repro.storage.netmodel import (
    ClusterProfile,
    FOREGROUND_TENANT,
    NetSimulator,
    REPAIR_TENANT,
    PortTimeline,
    Transfer,
)
from repro.storage.repair import BlockFixer, PacingController

PIPELINED = "pipelined"
SERIAL = "serial"

# Admission-control policies (GatewayConfig.admission):
#   off     — admit everything (SLOs are observed, never enforced)
#   reject  — refuse a GET whose estimated completion busts its SLO
#   degrade — first re-rank the planner's candidate plans by estimated
#             completion time and take the cheapest; reject only if even
#             that plan busts the SLO
ADMIT_OFF = "off"
ADMIT_REJECT = "reject"
ADMIT_DEGRADE = "degrade"


@dataclass(frozen=True)
class GatewayConfig:
    batch_window: float = 0.002  # seconds of arrival coalescing
    cache_bytes: int = 0  # 0 disables the block cache
    cache_policy: str = "cost"  # "cost" (rebuild-cost-aware) | "lru"
    num_client_ports: int = 32  # parallel client-side NICs
    background_share: float = 0.5  # repair's weighted-fair quantum ratio
    fabric: str = "quantum"  # "quantum" (preemptive) | "fifo"
    repair_on_failure: bool = False  # run BlockFixer after detection
    repair_delay: float = 5.0  # failure-detection lag (seconds)
    verify: bool = True  # check every GET against ground truth
    interpret: bool | None = None  # kernel backend override
    pipeline: str = PIPELINED  # "pipelined" | "serial" (PR-1 loop)
    autotune: bool = True  # measured kernel-parameter sweep at first use
    # decode dataplane: "ragged" = one descriptor-driven megakernel
    # launch per (window, kind); "bucketed" = the pre-megakernel
    # per-shape stacked launches (kept as the measured baseline)
    coalesce: str = "ragged"
    record_payloads: bool = False  # sha256 of every GET payload in records
    # -- multi-tenant QoS ------------------------------------------------------
    tenant_weights: dict | None = None  # tenant -> fabric quantum ratio
    tenant_slo_p99: dict | None = None  # tenant -> p99 latency target (s)
    admission: str = ADMIT_OFF  # "off" | "reject" | "degrade"
    num_engines: int = 1  # parallel simulated decode engines
    # tenant -> decode-engine share in (0, 1]. Independent of the fabric
    # weights: a throttled tenant's launches are rate-capped at
    # share x pool throughput; unlisted tenants dispatch at full weight
    # (identical to the tenant-blind least-loaded behavior).
    engine_weights: dict | None = None
    # Modeled decode cost: when set, every decode launch (and each
    # repaired block's codec work) is billed this many scaled seconds
    # instead of the measured kernel wall time. Payload bytes still come
    # off the real kernels — only the TIMING model changes — so a run
    # becomes bit-for-bit replayable (golden traces, paced-vs-fixed
    # comparisons) with no cold-vs-warm-jit sensitivity. None (default):
    # measured, best-observed-per-signature billing.
    decode_cost: float | None = None
    # -- fault scenarios / closed-loop repair ---------------------------------
    negative_ttl: float = 5.0  # seconds a known-down block stays negative-cached
    repair_pacing: bool = False  # SLO-aware closed-loop repair pacing
    repair_min_share: float = 0.5  # pacer floor (fabric + engine share)
    repair_max_share: float = 1.0  # pacer ceiling (idle / healthy)
    repair_mttr_target: float | None = None  # urgency override threshold (s)
    pacing_window: float = 1.0  # seconds of latency history the pacer observes
    # Incremental repair drain: at most this many groups repair per
    # boundary event, with the remainder requeued repair_respacing
    # seconds later (None => the whole backlog in one shot, the
    # pre-scenario behavior). Spreading the drain is what lets the
    # pacer RE-OBSERVE foreground latency between batches — the loop
    # cannot close inside one atomic repair event.
    repair_groups_per_run: int | None = None
    repair_respacing: float = 0.05
    # -- observability (repro.obs) --------------------------------------------
    tracing: bool = False  # emit sim-time spans into a bounded Tracer
    # sampling policy: "always" | "head:N" | "tail:SECONDS" | comma-combos
    # (keep a trace if ANY matches — slow requests are never dropped)
    trace_sample: str = "always"
    trace_capacity: int = 65536  # span ring-buffer size
    # False => streaming mode: GatewayReport keeps NO per-request list
    # (records stays empty; aggregates come from the bounded metrics
    # registry) so resident memory is O(1) in trace length
    record_requests: bool = True


@dataclass
class RequestRecord:
    time: float
    object_id: int
    kind: str
    latency: float | None  # None => unrecoverable or rejected
    degraded: bool
    bytes_read: int  # fabric bytes moved for this request
    reconstruction_blocks: int  # planner's Table-1 traffic
    cache_hits: int
    payload_digest: str | None = None  # sha256 (record_payloads=True)
    tenant: str = DEFAULT_TENANT
    rejected: bool = False  # refused by SLO admission control


# Completed GETs the repair pacer can observe: (arrival, tenant,
# latency), last RECENT_CAP only — the trailing pacing_window never
# needs more, and the cap is what keeps the pacer's input bounded.
RECENT_CAP = 4096


@dataclass
class GatewayReport:
    """Per-``serve()`` outcome report: a snapshot over the streaming
    ``metrics`` registry plus (by default) the raw per-request records.

    Every sample container here is BOUNDED: ``mttr_samples`` /
    ``restored_samples`` keep exact streaming count/mean/max plus a
    capped prefix of raw samples, ``pacing`` keeps the last decisions,
    ``recent`` the trailing completed GETs the repair pacer reads, and
    the registry's histograms are fixed-bin sketches — so with
    ``GatewayConfig.record_requests=False`` (streaming mode, ``records``
    stays empty) resident memory is O(1) in trace length. The aggregate
    accessors fall back from exact record scans to the registry in that
    mode; only WINDOWED percentiles (``since``/``until``) require
    records."""

    records: list[RequestRecord] = field(default_factory=list)
    repair_reports: list = field(default_factory=list)
    jit_cache_entries: int = 0  # coalescer's traced-signature count
    decode_launches: int = 0  # physical kernel launches (cumulative)
    launches_per_window: float = 0.0  # decode launches per batching window
    padded_byte_ratio: float = 0.0  # filler fraction of staged decode bytes
    rejections: dict = field(default_factory=dict)  # tenant -> refused GETs
    # time from block loss to repair-heal completion, one sample per
    # block healed by BlockFixer during this serve() call
    mttr_samples: BoundedSamples = field(default_factory=BoundedSamples)
    # time from block loss to availability restoration via a
    # NodeRecoverEvent (transient failure over — no repair bytes moved)
    restored_samples: BoundedSamples = field(default_factory=BoundedSamples)
    # closed-loop repair pacing decisions: (simulated time, share)
    pacing: BoundedLog = field(default_factory=BoundedLog)
    # streaming metrics registry: labeled counters / gauges / histograms
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)
    recent: deque = field(default_factory=lambda: deque(maxlen=RECENT_CAP))
    record_requests: bool = True  # False => streaming mode (records empty)
    _first_arrival: float = float("inf")
    _last_completion: float = 0.0

    def add_record(self, rec: RequestRecord) -> None:
        """Route one finished request into the report: the raw record
        list (unless streaming mode), the metrics registry, and the
        pacer's bounded ``recent`` window."""
        if self.record_requests:
            self.records.append(rec)
        m = self.metrics
        m.counter("requests", kind=rec.kind, tenant=rec.tenant).inc()
        if rec.rejected:
            m.counter("rejected_requests", tenant=rec.tenant).inc()
        if rec.latency is None:
            return
        m.counter("completed", kind=rec.kind, tenant=rec.tenant).inc()
        m.histogram("latency", kind=rec.kind, tenant=rec.tenant).observe(
            max(rec.latency, 1e-9)
        )
        m.counter("bytes_read", tenant=rec.tenant).inc(rec.bytes_read)
        self._first_arrival = min(self._first_arrival, rec.time)
        self._last_completion = max(self._last_completion, rec.time + rec.latency)
        if rec.kind == "get":
            self.recent.append((rec.time, rec.tenant, rec.latency))
            if rec.degraded:
                m.counter("degraded_gets").inc()
                m.counter("degraded_bytes").inc(rec.bytes_read)
                m.counter("degraded_recon_blocks").inc(rec.reconstruction_blocks)

    def resident_samples(self) -> int:
        """Total retained entries across every sample container — the
        number the long-trace benchmark gates on staying bounded."""
        return (
            len(self.records)
            + len(self.recent)
            + self.mttr_samples.resident()
            + self.restored_samples.resident()
            + self.pacing.resident()
            + self.metrics.resident_samples()
        )

    @property
    def mttr_mean(self) -> float:
        return self.mttr_samples.mean

    @property
    def mttr_max(self) -> float:
        return self.mttr_samples.max

    # -- aggregates -----------------------------------------------------------
    @property
    def completed(self) -> list[RequestRecord]:
        return [r for r in self.records if r.latency is not None]

    @property
    def degraded_gets(self) -> list[RequestRecord]:
        return [r for r in self.completed if r.kind == "get" and r.degraded]

    @property
    def rejected(self) -> list[RequestRecord]:
        return [r for r in self.records if r.rejected]

    def latency_percentile(
        self, q: float, since: float = 0.0, until: float = float("inf")
    ) -> float:
        """Latency percentile over requests ARRIVING in [since, until) —
        the one quantile definition every window statistic delegates to.
        Streaming mode answers WHOLE-trace quantiles from the registry's
        merged latency sketch; windowed quantiles need records."""
        if not self.records and since == 0.0 and until == float("inf"):
            h = self.metrics.merged_histogram("latency")
            return h.quantile(q / 100.0) if h is not None else 0.0
        lats = [r.latency for r in self.completed if since <= r.time < until]
        return float(np.percentile(lats, q)) if lats else 0.0

    # -- per-tenant aggregates -------------------------------------------------
    def tenant_completed(self, tenant: str) -> list[RequestRecord]:
        return [r for r in self.completed if r.tenant == tenant]

    def tenant_latency_percentile(
        self,
        tenant: str,
        q: float,
        since: float = 0.0,
        until: float = float("inf"),
    ) -> float:
        if not self.records and since == 0.0 and until == float("inf"):
            h = self.metrics.merged_histogram("latency", tenant=tenant)
            return h.quantile(q / 100.0) if h is not None else 0.0
        lats = [
            r.latency
            for r in self.completed
            if r.tenant == tenant and since <= r.time < until
        ]
        return float(np.percentile(lats, q)) if lats else 0.0

    def slo_violation_rate(self, tenant: str, slo: float) -> float:
        """Fraction of this tenant's completed GETs that finished over
        the target — measured over ADMITTED traffic, so rejections trade
        availability for the survivors' latency."""
        gets = [r for r in self.tenant_completed(tenant) if r.kind == "get"]
        if not gets and not self.records:
            h = self.metrics.merged_histogram("latency", kind="get", tenant=tenant)
            return 1.0 - h.cdf(slo) if h is not None and h.count else 0.0
        if not gets:
            return 0.0
        return sum(1 for r in gets if r.latency > slo) / len(gets)

    @property
    def throughput(self) -> float:
        """Completed requests per second of simulated trace time."""
        n = self.metrics.counter_total("completed")
        if not n:
            return 0.0
        span = self._last_completion - self._first_arrival
        return n / span if span > 0 else float("inf")

    @property
    def bytes_per_degraded_get(self) -> float:
        deg = self.metrics.counter_total("degraded_gets")
        return (
            self.metrics.counter_total("degraded_bytes") / deg if deg else 0.0
        )

    @property
    def reconstruction_blocks_per_degraded_get(self) -> float:
        deg = self.metrics.counter_total("degraded_gets")
        return (
            self.metrics.counter_total("degraded_recon_blocks") / deg
            if deg
            else 0.0
        )


class EnginePool:
    """``num_engines`` parallel simulated decode-engine timelines with
    least-loaded dispatch and per-tenant weighted admission.

    Full-weight tenants dispatch exactly as the tenant-blind pool did:
    earliest-free engine, start at max(ready, engine_free). A tenant with
    share w < 1 additionally respects a virtual-clock cursor spaced at
    duration / (w x pool_size) per launch, rate-capping it at w of the
    pool's aggregate throughput — so a throttled repair tenant's decode
    work cannot crowd foreground reconstructions off the engines, and
    the SLO pacer can modulate that share mid-run (``set_weight``).

    Engines keep interval timelines (the fabric's PortTimeline), not
    just a high-water mark: the idle gap a throttled tenant's cursor
    wait leaves on an engine is a real hole later full-weight launches
    backfill — throttling yields capacity to other tenants instead of
    reserving dead time (mirroring the quantum fabric's preemptible
    holes). On hole-free timelines earliest-fit placement coincides
    with least-loaded dispatch, so all-full-weight workloads are
    schedule-identical to the tenant-blind pool."""

    def __init__(self, num_engines: int, weights: dict | None = None):
        self.free = [0.0] * num_engines  # per-engine last-end high-water mark
        self._timelines = [PortTimeline() for _ in range(num_engines)]
        self._weights: dict = dict(weights or {})
        for tenant, w in self._weights.items():
            self._check_weight(tenant, w)
        self._cursor: dict = {}
        self.tracer = NULL_TRACER  # engine-track span sink (repro.obs)
        self._tracks = [("engine", f"engine{e}") for e in range(num_engines)]

    @staticmethod
    def _check_weight(tenant, w) -> None:
        if not 0.0 < w <= 1.0:
            raise ValueError(
                f"engine weight must be in (0, 1], got {tenant!r}: {w}"
            )

    def weight_of(self, tenant) -> float:
        return self._weights.get(tenant, 1.0)

    def set_weight(self, tenant, w: float) -> None:
        self._check_weight(tenant, w)
        self._weights[tenant] = w

    def earliest_start(self, now: float) -> float:
        """Earliest instant at/after ``now`` any engine could begin new
        work, holes included — the admission estimator's view of decode
        queueing. (The per-engine high-water marks in ``free`` are NOT
        usable for this: a throttled tenant's cursor-delayed booking
        pushes them far out while the timeline before it stays idle.)
        Probes for a 1 us hole — anything above the timeline's float
        tolerance, below which zero-length gaps are accepted."""
        return min(tl.next_fit(now, 1e-6) for tl in self._timelines)

    def dispatch(
        self, ready: float, dur: float, tenant=None, ctx: tuple | None = None
    ) -> tuple[float, float]:
        """Schedule one launch; returns (start, end). ``ctx`` is an
        optional (trace_id, parent_id, attrs) observability context —
        when given (and tracing is on) the launch emits an engine-track
        span into that trace. Purely observational: the schedule is
        identical with or without it."""
        share = 1.0 if tenant is None else self.weight_of(tenant)
        if share < 1.0:
            ready = max(ready, self._cursor.get(tenant, 0.0))
        # earliest-fit across engines (holes included); ties break on the
        # lowest index, which on hole-free timelines is least-loaded
        best_e, best_start = 0, float("inf")
        for e, tl in enumerate(self._timelines):
            s = tl.next_fit(ready, dur) if dur > 0.0 else max(ready, self.free[e])
            if s < best_start:
                best_e, best_start = e, s
        end = best_start + dur
        if dur > 0.0:
            self._timelines[best_e].occupy(best_start, end)
        self.free[best_e] = max(self.free[best_e], end)
        if share < 1.0 and dur > 0.0:
            spacing = dur / (share * len(self.free))
            self._cursor[tenant] = max(
                self._cursor.get(tenant, 0.0) + spacing, best_start + spacing
            )
        if ctx is not None and self.tracer.enabled and dur > 0.0:
            tid, pid, attrs = ctx
            self.tracer.span(
                "engine.launch",
                best_start,
                end,
                tid,
                pid,
                track=self._tracks[best_e],
                tenant=tenant,
                **attrs,
            )
        return best_start, end


class ObjectGateway:
    """Serves a trace of PUT/GET requests over a BlockStore cluster."""

    def __init__(
        self,
        code: CoreCode,
        profile: ClusterProfile,
        num_nodes: int,
        config: GatewayConfig | None = None,
    ):
        self.code = code
        self.codec = CoreCodec(code)
        self.profile = profile
        self.config = config or GatewayConfig()
        if self.config.pipeline not in (PIPELINED, SERIAL):
            raise ValueError(
                f"pipeline must be 'pipelined' or 'serial', got "
                f"{self.config.pipeline!r}"
            )
        if self.config.admission not in (ADMIT_OFF, ADMIT_REJECT, ADMIT_DEGRADE):
            raise ValueError(
                f"admission must be 'off', 'reject' or 'degrade', got "
                f"{self.config.admission!r}"
            )
        if self.config.num_engines < 1:
            raise ValueError(
                f"num_engines must be >= 1, got {self.config.num_engines}"
            )
        if self.config.coalesce not in ("ragged", "bucketed"):
            raise ValueError(
                f"coalesce must be 'ragged' or 'bucketed', got "
                f"{self.config.coalesce!r}"
            )
        if self.config.decode_cost is not None and self.config.decode_cost <= 0:
            raise ValueError(
                f"decode_cost must be positive or None (measured), got "
                f"{self.config.decode_cost}"
            )
        if (
            self.config.repair_groups_per_run is not None
            and self.config.repair_groups_per_run < 1
        ):
            # a zero budget would requeue a continuation that never
            # repairs anything — serve() would spin forever
            raise ValueError(
                f"repair_groups_per_run must be >= 1 or None, got "
                f"{self.config.repair_groups_per_run}"
            )
        if self.config.pipeline == SERIAL and self.config.num_engines != 1:
            # the serial baseline prices the PR-1 synchronous loop, which
            # had exactly one decode engine — extra engines would sit
            # idle while still skewing the admission estimator
            raise ValueError(
                "pipeline='serial' models a single-engine synchronous "
                f"loop; num_engines must be 1, got {self.config.num_engines}"
            )
        # sim-time observability plane (repro.obs): one tracer threaded
        # through the fabric, engine pool and repair engine. NULL_TRACER
        # when disabled, so emission sites cost one attribute check.
        self.tracer = (
            Tracer(self.config.trace_sample, self.config.trace_capacity)
            if self.config.tracing
            else NULL_TRACER
        )
        self.store = BlockStore(num_nodes=num_nodes)
        self.sim = NetSimulator(
            profile,
            background_share=self.config.background_share,
            mode=self.config.fabric,
            tenant_weights=self.config.tenant_weights,
        )
        self.sim.tracer = self.tracer
        self.cache = (
            LRUBlockCache(self.config.cache_bytes, policy=self.config.cache_policy)
            if self.config.cache_bytes
            else None
        )
        self.planner = DegradedReadPlanner(
            self.store, code, available_fn=self._available
        )
        self.coalescer = DecodeCoalescer(
            compute_scale=profile.compute_scale,
            interpret=self.config.interpret,
            autotune_kernels=self.config.autotune,
            mode=self.config.coalesce,
        )
        self.fixer = BlockFixer(
            self.store,
            code,
            profile,
            mode="core",
            sim=self.sim,
            priority=REPAIR_TENANT,
            on_block_repaired=self._on_block_repaired,
        )
        self.fixer.tracer = self.tracer
        self._objects: dict[int, tuple[str, int]] = {}  # object -> (group, row)
        self._groups: dict[str, list[int]] = {}
        self._expected: dict[int, np.ndarray] = {}  # ground truth (k, q)
        self._block_bytes = 0
        # Repaired blocks become visible only once the repair's fabric
        # transfers complete: key -> completion time of its write-back.
        self._healing: dict[BlockKey, float] = {}
        # Cache entries to re-price once their block's heal completes —
        # re-pricing at repair time would demote a reconstruction that is
        # still the only copy reads dated before heal completion can use.
        self._reprice_on_heal: set[BlockKey] = set()
        # Simulated time at which each cached block came into existence
        # (fetch completion / decode completion). A cache hit may not be
        # served before it: blocks are cached at host flush time, and
        # without this gate a later window's request dated before an
        # engine-backlogged decode would read a block that does not exist
        # yet in simulated time.
        self._cache_ready: dict[BlockKey, float] = {}
        self._clock = 0.0  # logical time of the request being planned
        # Simulated decode engines: each runs one batched launch at a
        # time; launches dispatch to the least-loaded engine under the
        # owning tenant's engine share. The pool persists across windows
        # so pipelined windows overlap on it; repair decode compute is
        # billed on it too (as the "repair" tenant), so repair and
        # foreground reconstruction contend for the same engines.
        self._pool = EnginePool(
            self.config.num_engines, weights=self.config.engine_weights
        )
        self._pool.tracer = self.tracer
        # Serial-mode barrier: completion time of the previous window.
        self._window_free = 0.0
        # Scenario bookkeeping: when each currently-unavailable block was
        # lost (feeds MTTR samples on heal/recover), persisted across
        # serve() calls like _healing.
        self._lost_at: dict[BlockKey, float] = {}
        # groups whose missing set repair provably cannot shrink right
        # now (unrecoverable clusters): skipped by continuation runs
        # until their failure set changes
        self._repair_stuck: dict[str, frozenset] = {}
        # SLO-aware repair pacing: observed foreground p99 headroom
        # modulates the repair tenant's fabric weight and engine share.
        self._pacer = (
            PacingController(
                min_share=self.config.repair_min_share,
                max_share=self.config.repair_max_share,
                mttr_target=self.config.repair_mttr_target,
            )
            if self.config.repair_pacing
            else None
        )
        slos = self.config.tenant_slo_p99 or {}
        # the tier the pacer protects: the tightest declared SLO
        self._pacing_slo = min(slos.values()) if slos else None

    # -- availability: store OR cache, gated on repair completion --------------
    def _available(self, key: BlockKey) -> bool:
        if self.cache is not None and self.cache.is_negative(key, self._clock):
            # known-down: skip the store probe entirely (negative entries
            # are purged the moment a recover event or repair write-back
            # brings the block back, and TTL-expire as a backstop); a
            # cached reconstruction still serves
            return key in self.cache
        if self.store.available(key):
            healed_at = self._healing.get(key)
            if healed_at is not None:
                if self._clock < healed_at:
                    # the repair wrote the block, but its transfers are
                    # still in flight at this request's time
                    return self.cache is not None and key in self.cache
                del self._healing[key]
                self._apply_heal_reprice(key)
            return True
        return self.cache is not None and key in self.cache

    def _on_block_repaired(self, key: BlockKey) -> None:
        # BlockFixer wrote the block back; once the write-back's fabric
        # transfers complete (the _healing gate) it is a cheap store
        # read again and any cached copy stops deserving reconstruction
        # priority. The re-price (and negative-entry purge) is deferred
        # to that simulated moment.
        if self.cache is not None:
            self._reprice_on_heal.add(key)

    def _apply_heal_reprice(self, key: BlockKey) -> None:
        if self.cache is not None:
            self.cache.purge_negative([key])
        if key in self._reprice_on_heal:
            self._reprice_on_heal.discard(key)
            if self.cache is not None:
                self.cache.refresh_cost(key, 1.0)

    # -- bulk load (trace setup; not metered on the fabric) --------------------
    def load_objects(self, objects: np.ndarray) -> None:
        """objects: (num_objects, k, q) uint8. Packs t objects per CORE
        group (zero-padding the last group) and places all groups."""
        num, k, q = objects.shape
        if k != self.code.k:
            raise ValueError(f"objects must have k={self.code.k} blocks")
        self._block_bytes = int(q)
        t = self.code.t
        for g0 in range(0, num, t):
            chunk = objects[g0 : g0 + t]
            if chunk.shape[0] < t:
                pad = np.zeros((t - chunk.shape[0], k, q), dtype=np.uint8)
                chunk = np.concatenate([chunk, pad], axis=0)
            gid = f"g{g0 // t}"
            matrix = np.asarray(self.codec.encode(chunk))
            self.store.put_group(gid, matrix)
            members = []
            for r in range(min(t, num - g0)):
                oid = g0 + r
                self._objects[oid] = (gid, r)
                self._expected[oid] = np.asarray(objects[oid])
                members.append(oid)
            self._groups[gid] = members

    # -- serving ----------------------------------------------------------------
    def serve(
        self,
        requests: list[Request],
        failures: list | None = None,
    ) -> GatewayReport:
        """``failures`` accepts any mix of cluster events — FailureEvent
        (crash), NodeRecoverEvent, CapacityLossEvent — e.g. a
        ScenarioTrace's ``cluster_events()``. Events apply mid-run, in
        time order interleaved with the request stream, so the planner,
        negative cache, and admission controller see availability change
        between requests."""
        report = GatewayReport(record_requests=self.config.record_requests)
        cfg = self.config
        events = sorted(failures or [], key=lambda f: f.time)
        reqs = sorted(requests, key=lambda r: r.time)
        repair_queue: list[tuple[float, int]] = []  # (time, node)

        fi = 0
        batch: list[Request] = []
        batch_deadline = None

        def boundary_events(now: float | None):
            """Apply cluster / repair events due before ``now`` (None =>
            all remaining), flushing the open batch first."""
            nonlocal fi, batch, batch_deadline
            while True:
                next_evt = events[fi].time if fi < len(events) else None
                next_rep = repair_queue[0][0] if repair_queue else None
                cands = [t for t in (next_evt, next_rep) if t is not None]
                if not cands:
                    return
                t_evt = min(cands)
                if now is not None and t_evt > now:
                    return
                if batch and batch_deadline is not None:
                    self._flush(batch, report)
                    batch, batch_deadline = [], None
                if next_evt is not None and t_evt == next_evt:
                    evt = events[fi]
                    fi += 1
                    wants_repair = self._apply_cluster_event(evt, report)
                    if wants_repair and cfg.repair_on_failure:
                        repair_queue.append((evt.time + cfg.repair_delay, evt.node))
                        repair_queue.sort()
                else:
                    t_rep, _node = repair_queue.pop(0)
                    if self._background_repair(t_rep, report):
                        # budgeted run left groups pending: drain the
                        # rest after the respacing interval (-1: a
                        # continuation, not a fresh failure)
                        repair_queue.append((t_rep + cfg.repair_respacing, -1))
                        repair_queue.sort()

        for req in reqs:
            boundary_events(req.time)
            if req.kind == "put":
                # PUT is a window barrier: it mutates blocks and parity,
                # which must not interleave with an open window's planned
                # (and cache-pinned) reads.
                if batch:
                    self._flush(batch, report)
                    batch, batch_deadline = [], None
                report.add_record(self._handle_put(req))
                continue
            if batch and req.time > batch_deadline:
                self._flush(batch, report)
                batch, batch_deadline = [], None
            if not batch:
                batch_deadline = req.time + cfg.batch_window
            batch.append(req)
        if batch:
            self._flush(batch, report)
            batch, batch_deadline = [], None
        boundary_events(None)
        st = self.coalescer.stats
        report.jit_cache_entries = st.jit_entries
        report.decode_launches = st.decode_calls
        report.launches_per_window = st.launches_per_window
        report.padded_byte_ratio = st.padded_byte_ratio
        # surface kernel-compile churn and autotune cache behavior as
        # first-class metrics (they were only visible as raw counters)
        m = report.metrics
        m.gauge("jit_entries").set(st.jit_entries)
        m.gauge("jit_retraces").set(st.jit_retraces)
        for name, v in autotune.cache_stats().items():
            m.gauge(f"autotune_{name}").set(v)
        if self.tracer.enabled:
            for name, v in self.tracer.stats().items():
                if isinstance(v, (int, float)):
                    m.gauge(f"traces_{name}").set(v)
        return report

    # -- request batch execution ------------------------------------------------
    def _flush(self, batch: list[Request], report: GatewayReport) -> None:
        serial = self.config.pipeline == SERIAL
        tracer = self.tracer
        gets: list[tuple[Request, ReadPlan]] = []
        tids: list[int] = []  # per-get trace id, parallel to ``gets``
        # Blocks whose plans depend on the CACHE copy (store copy is
        # gone) are pinned at plan time — later fetches in this window
        # may otherwise evict them before their request executes.
        pinned: dict[BlockKey, np.ndarray] = {}
        slos = self.config.tenant_slo_p99 or {}
        for req in batch:
            # serve() handles PUTs as window barriers before batching;
            # a PUT inside a window would break the pin/plan invariants
            assert req.kind == "get", f"batch may only hold GETs, got {req.kind}"
            if req.object_id not in self._objects:
                report.add_record(
                    RequestRecord(
                        req.time, req.object_id, "get", None, False, 0, 0, 0,
                        tenant=req.tenant,
                    )
                )
                continue
            gid, row = self._objects[req.object_id]
            self._clock = req.time
            try:
                plan = self.planner.plan(gid, row, at=req.time)
            except UnreadableObjectError:
                report.add_record(
                    RequestRecord(
                        req.time, req.object_id, "get", None, True, 0, 0, 0,
                        tenant=req.tenant,
                    )
                )
                continue
            # SLO admission: estimate queue + transfer + decode time for
            # the plan; degrade mode first re-ranks the planner's
            # candidates by that estimate (a backlogged engine can make
            # the Table-1 byte-cheapest plan the latency-dearest one).
            slo = slos.get(req.tenant)
            if slo is not None and self.config.admission != ADMIT_OFF:
                est = self._estimate_service_time(plan, req.time, req.tenant)
                if est > slo and self.config.admission == ADMIT_DEGRADE:
                    plan, est = min(
                        (
                            (p, self._estimate_service_time(p, req.time, req.tenant))
                            for p in self.planner.candidates(gid, row, at=req.time)
                        ),
                        key=lambda pe: pe[1],
                    )
                if est > slo:
                    report.rejections[req.tenant] = (
                        report.rejections.get(req.tenant, 0) + 1
                    )
                    report.add_record(
                        RequestRecord(
                            req.time, req.object_id, "get", None,
                            plan.degraded, 0, 0, 0,
                            tenant=req.tenant, rejected=True,
                        )
                    )
                    continue
            if self.cache is not None:
                for key in plan.source_keys:
                    if key not in pinned and not self.store.available(key):
                        blk = self.cache.get(key)
                        if blk is not None:
                            pinned[key] = blk
            tid = 0
            if tracer.enabled:
                tid = tracer.begin_trace()
                tracer.instant(
                    "plan",
                    req.time,
                    tid,
                    tid,
                    track=("tenant", req.tenant),
                    degraded=plan.degraded,
                    sources=len(plan.source_keys),
                    decodes=len(plan.decodes),
                )
            gets.append((req, plan))
            tids.append(tid)
        if not gets:
            return

        # 1) fetch: every needed block rides the fabric to the request's
        # client port. Serial mode gates the whole window's transfers on
        # the previous window's completion (the synchronous loop cannot
        # start fetching window N+1 while window N is still decoding);
        # pipelined mode starts them at plan time.
        ready: list[dict[BlockKey, float]] = []
        bytes_read: list[int] = []
        cache_hits: list[int] = []
        fetch_ats: list[float] = []
        fetched: dict[BlockKey, np.ndarray] = {}
        for i, (req, plan) in enumerate(gets):
            client = self._client_port(req)
            tid = tids[i]
            fetch_at = (
                max(plan.planned_at, self._window_free)
                if serial
                else plan.planned_at
            )
            # SLO tenants stamp their fabric transfers with a deadline so
            # the simulator's per-tenant miss counters line up with the
            # report's violation rates.
            deadline = (
                req.time + slos[req.tenant] if req.tenant in slos else None
            )
            key_ready: dict[BlockKey, float] = {}
            nbytes = 0
            hits = 0
            trk = ("tenant", req.tenant)
            for key in plan.source_keys:
                blk = pinned.get(key)
                if blk is None and self.cache is not None:
                    blk = self.cache.get(key)
                if blk is not None:
                    key_ready[key] = max(fetch_at, self._cache_ready.get(key, 0.0))
                    hits += 1
                    if tracer.enabled:
                        tracer.instant(
                            "cache.hit",
                            key_ready[key],
                            tid,
                            tid,
                            track=trk,
                            key=key,
                        )
                else:
                    blk = self.store.get(key)
                    src_node = self.store.node_of(key)
                    end = self.sim.transfer(
                        Transfer(
                            src_node,
                            client,
                            blk.nbytes,
                            fetch_at,
                            tenant=req.tenant,
                            deadline=deadline,
                            ctx=(tid, tid) if tracer.enabled else None,
                        )
                    )
                    key_ready[key] = end
                    nbytes += blk.nbytes
                    if self.cache is not None:
                        self.cache.put(key, blk)
                        self._cache_ready[key] = end
                    if tracer.enabled:
                        # request-side view: includes fabric queueing
                        # (the port-track xfer span shows the transfer
                        # itself, from its first byte)
                        tracer.span(
                            "fetch",
                            fetch_at,
                            end,
                            tid,
                            tid,
                            track=trk,
                            key=key,
                            src=src_node,
                            bytes=blk.nbytes,
                        )
                fetched[key] = blk
            ready.append(key_ready)
            bytes_read.append(nbytes)
            cache_hits.append(hits)
            fetch_ats.append(fetch_at)

        # 2) decode: dedup identical reconstructions (a hot degraded
        # object appears once per window, not once per request), then one
        # stacked launch per shape bucket, scheduled on the simulated
        # serial decode engine.
        unique_idx: dict[tuple, int] = {}
        uops = []
        owners: list[list[int]] = []
        for i, (_req, plan) in enumerate(gets):
            for op in plan.decodes:
                okey = (op.group_id, op.row, op.kind, op.targets, op.sources)
                j = unique_idx.get(okey)
                if j is None:
                    j = len(uops)
                    unique_idx[okey] = j
                    uops.append(op)
                    owners.append([])
                owners[j].append(i)
        results, units = self.coalescer.execute(uops, lambda k: fetched[k])
        if self.config.decode_cost is not None:
            # modeled-cost mode: deterministic billing — each unit gets
            # its FRACTION of one modeled launch, so a launch's units
            # still sum to exactly decode_cost regardless of dataplane
            units = [
                replace(u, compute=self.config.decode_cost * u.fraction)
                for u in units
            ]
        # a unit bills its engine time to the tenant of the earliest
        # request that owns one of its ops (a unit has exactly one
        # engine reservation, so it needs exactly one payer)
        op_ready: list[float] = [
            max(ready[i][s] for i in owners[j] for s in op.sources)
            for j, op in enumerate(uops)
        ]
        op_tenant: list[str] = [
            gets[owners[j][0]][0].tenant for j in range(len(uops))
        ]
        op_done: list[float] = [0.0] * len(uops)
        # per-op launch attribution for the critical-path analyzer: the
        # dispatch interval of the unit that COMPLETED the op (its max
        # end), plus the launch-wide source barrier it waited behind
        op_meta: list[dict | None] = [None] * len(uops)
        if serial:
            # strict staging: no launch before ALL the window's transfers
            # (even direct-only fetches) complete; launches back-to-back
            # on ONE engine (the synchronous loop this baseline prices
            # had no decode parallelism); the whole window waits for the
            # last launch.
            window_net = max(
                (t for key_ready in ready for t in key_ready.values()),
                default=self._window_free,
            )
            if units:
                total = sum(u.compute for u in units)
                start, end = self._pool.dispatch(
                    window_net,
                    total,
                    ctx=(
                        (tids[0], tids[0], {"kind": "serial", "launch_id": -1})
                        if tracer.enabled
                        else None
                    ),
                )
                op_done = [end] * len(uops)
                op_meta = [
                    {
                        "start": start,
                        "end": end,
                        "ready": window_net,
                        "kind": "serial",
                        "launch_id": -1,
                        "fraction": 1.0,
                        "tiles": 0,
                    }
                ] * len(uops)
        else:
            # pipelined: a PHYSICAL launch cannot start before every
            # source staged into it lands (its buffer holds all its
            # ops' tiles), so all units sharing a launch_id wait for
            # the launch-wide barrier; past it they dispatch
            # independently, in arrival order, onto the least-loaded
            # decode engine under the owning tenant's engine share —
            # windows (and one megakernel launch's per-op tile ranges)
            # overlap across the engine pool
            launch_ready: dict[int, float] = {}
            for u in units:
                r = max(op_ready[j] for j in u.op_indices)
                launch_ready[u.launch_id] = max(
                    launch_ready.get(u.launch_id, 0.0), r
                )
            for u in sorted(units, key=lambda u: launch_ready[u.launch_id]):
                ctx = None
                if tracer.enabled:
                    # bill the engine-track span to the trace of the
                    # earliest request owning this unit's first op (the
                    # same owner the engine time is billed to)
                    ctx = (
                        tids[owners[u.op_indices[0]][0]],
                        tids[owners[u.op_indices[0]][0]],
                        {"kind": u.kind, "launch_id": u.launch_id},
                    )
                start, end = self._pool.dispatch(
                    launch_ready[u.launch_id], u.compute,
                    tenant=op_tenant[u.op_indices[0]],
                    ctx=ctx,
                )
                for j in u.op_indices:
                    if end >= op_done[j]:
                        op_done[j] = end
                        op_meta[j] = {
                            "start": start,
                            "end": end,
                            "ready": launch_ready[u.launch_id],
                            "kind": u.kind,
                            "launch_id": u.launch_id,
                            "fraction": u.fraction,
                            "tiles": u.tiles,
                        }

        # 3) verify + deliver
        decoded_per_req: list[dict[int, np.ndarray]] = [dict() for _ in gets]
        for j, op in enumerate(uops):
            for i in owners[j]:
                decoded_per_req[i].update(results[j])
        # rebuild cost of a decoded block = source blocks its op consumed
        # (t vertical, k horizontal) — the cache's eviction currency
        decode_cost: dict[int, dict[int, int]] = {}
        for j, op in enumerate(uops):
            for i in owners[j]:
                costs = decode_cost.setdefault(i, {})
                for col in op.targets:
                    costs[col] = len(op.sources)
        window_end = self._window_free
        for i, (req, plan) in enumerate(gets):
            done = req.time
            for key in plan.direct:
                done = max(done, ready[i][key])
            for op in plan.decodes:
                okey = (op.group_id, op.row, op.kind, op.targets, op.sources)
                done = max(done, op_done[unique_idx[okey]])
            digest = None
            if self.config.verify or self.config.record_payloads:
                payload = self._assemble_payload(req, plan, fetched, decoded_per_req[i])
                if self.config.verify:
                    self._verify_get(req, payload)
                if self.config.record_payloads:
                    digest = hashlib.sha256(payload.tobytes()).hexdigest()
            if self.cache is not None:
                gid, row = self._objects[req.object_id]
                costs = decode_cost.get(i, {})
                col_done = {
                    col: op_done[
                        unique_idx[
                            (op.group_id, op.row, op.kind, op.targets, op.sources)
                        ]
                    ]
                    for op in plan.decodes
                    for col in op.targets
                }
                for col, blk in decoded_per_req[i].items():
                    ckey = (gid, row, col)
                    self.cache.put(ckey, blk, cost=costs.get(col, 1.0))
                    self._cache_ready[ckey] = col_done.get(col, done)
            if tracer.enabled:
                tid = tids[i]
                for op in plan.decodes:
                    okey = (op.group_id, op.row, op.kind, op.targets, op.sources)
                    j = unique_idx[okey]
                    meta = op_meta[j]
                    if meta is None:
                        continue
                    tracer.span(
                        "decode",
                        meta["start"],
                        meta["end"],
                        tid,
                        tid,
                        track=("tenant", req.tenant),
                        op=j,
                        shared=len(owners[j]),
                        op_ready=max(ready[i][s] for s in op.sources),
                        **{
                            k: meta[k]
                            for k in ("ready", "kind", "launch_id", "fraction", "tiles")
                        },
                    )
                if self.config.verify:
                    tracer.instant(
                        "verify", done, tid, tid, track=("tenant", req.tenant)
                    )
                tracer.root_span(
                    "request",
                    req.time,
                    done,
                    tid,
                    track=("tenant", req.tenant),
                    object_id=req.object_id,
                    kind="get",
                    tenant=req.tenant,
                    degraded=plan.degraded,
                    bytes=bytes_read[i],
                    cache_hits=cache_hits[i],
                    fetch_at=fetch_ats[i],
                )
                tracer.end_trace(tid, latency=done - req.time)
            report.add_record(
                RequestRecord(
                    req.time,
                    req.object_id,
                    "get",
                    done - req.time,
                    plan.degraded,
                    bytes_read[i],
                    plan.reconstruction_blocks,
                    cache_hits[i],
                    payload_digest=digest,
                    tenant=req.tenant,
                )
            )
            window_end = max(window_end, done)
        if serial:
            self._window_free = window_end

    # -- PUT --------------------------------------------------------------------
    def _handle_put(self, req: Request) -> RequestRecord:
        """Overwrite one object (one CORE row) in place: re-encode the row
        RS codeword and XOR-delta the vertical parity row (linearity of
        both codes — no other row is touched)."""
        oid = req.object_id
        if oid not in self._objects:
            return RequestRecord(
                req.time, oid, "put", None, False, 0, 0, 0, tenant=req.tenant
            )
        gid, row = self._objects[oid]
        q = self._block_bytes
        tracer = self.tracer
        tid = tracer.begin_trace() if tracer.enabled else 0
        rng = np.random.default_rng((oid * 1_000_003 + int(req.time * 1e6)) % (2**63))
        new_data = rng.integers(0, 256, (self.code.k, q), dtype=np.uint8)
        new_row = np.asarray(self.code.horizontal.encode(new_data))  # (n, q)
        # Delta against the re-encoded OLD row (ground truth), not the
        # stored block — a lost old block must still contribute its delta
        # or the vertical parity goes stale for the whole column.
        old_row = np.asarray(self.code.horizontal.encode(self._expected[oid]))
        client = self._client_port(req)
        nbytes = 0
        done = req.time
        parity_row = self.code.rows - 1
        for c in range(self.code.n):
            old_key = (gid, row, c)
            par_key = (gid, parity_row, c)
            # a lost parity column is reconciled later by repair instead
            if self.store.available(par_key):
                delta = np.bitwise_xor(old_row[c], new_row[c])
                self.store.put_block(
                    par_key, np.bitwise_xor(self.store.blocks[par_key], delta)
                )
                if self.cache is not None:
                    # only a parity block actually WRITTEN sheds its
                    # known-down tombstone; an unavailable one stays
                    # negative until repair or recovery brings it back
                    self.cache.purge_negative([par_key])
                end = self.sim.transfer(
                    Transfer(
                        client,
                        self.store.node_of(par_key),
                        int(q),
                        req.time,
                        tenant=req.tenant,
                        ctx=(tid, tid) if tracer.enabled else None,
                    )
                )
                done = max(done, end)
                nbytes += q
            self.store.put_block(old_key, new_row[c])
            end = self.sim.transfer(
                Transfer(
                    client,
                    self.store.node_of(old_key),
                    int(q),
                    req.time,
                    tenant=req.tenant,
                    ctx=(tid, tid) if tracer.enabled else None,
                )
            )
            done = max(done, end)
            nbytes += q
            if self.cache is not None:
                self.cache.invalidate(old_key)
                self.cache.invalidate(par_key)
                # the data write re-placed its block on an alive node:
                # that tombstone is stale (the parity one is handled in
                # the write branch above, only when actually written)
                self.cache.purge_negative([old_key])
            # a client write supersedes any in-flight repair write-back
            self._healing.pop(old_key, None)
            self._healing.pop(par_key, None)
            self._reprice_on_heal.discard(old_key)
            self._reprice_on_heal.discard(par_key)
            self._lost_at.pop(old_key, None)
            if self.store.available(par_key):
                self._lost_at.pop(par_key, None)
        self._expected[oid] = new_data
        if tracer.enabled:
            tracer.root_span(
                "request",
                req.time,
                done,
                tid,
                track=("tenant", req.tenant),
                object_id=oid,
                kind="put",
                tenant=req.tenant,
                degraded=False,
                bytes=nbytes,
                cache_hits=0,
                fetch_at=req.time,
            )
            tracer.end_trace(tid, latency=done - req.time)
        return RequestRecord(
            req.time, oid, "put", done - req.time, False, nbytes, 0, 0,
            tenant=req.tenant,
        )

    # -- cluster fault events (scenario engine) ----------------------------------
    def _apply_cluster_event(self, evt, report: GatewayReport) -> bool:
        """Apply one node-level fault event; returns True when the event
        creates missing blocks that background repair should chase."""
        if isinstance(evt, NodeRecoverEvent):
            keys = self.store.keys_on_node(evt.node)
            self.store.heal_node(evt.node)
            if self.cache is not None:
                # transient failure over: the node's blocks are back, so
                # their negative entries expire NOW, not at their TTL
                self.cache.purge_negative(keys)
            for key in keys:
                if self.store.available(key):
                    t0 = self._lost_at.pop(key, None)
                    if t0 is not None:
                        report.restored_samples.append(evt.time - t0)
            # a recovery can restore the SOURCES a stuck group was
            # waiting on (its missing set changes, clearing the stuck
            # memo) — with no failure event left to queue a repair, the
            # recovery itself must trigger a re-scan when losses remain
            return bool(self._lost_at or self._repair_stuck)
        if isinstance(evt, CapacityLossEvent):
            # capture keys BEFORE the store drops their placement
            lost = self.store.lose_node_blocks(evt.node)
            for key in lost:
                self._lost_at.setdefault(key, evt.time)
                # data destroyed: any in-flight heal of this key is moot
                self._healing.pop(key, None)
                if self.cache is not None:
                    self.cache.put_negative(
                        key, evt.time, self.config.negative_ttl
                    )
            return bool(lost)
        # FailureEvent: transient crash — disks survive, the node may
        # recover with its blocks intact
        assert isinstance(evt, FailureEvent), f"unknown cluster event {evt!r}"
        keys = [
            k for k in self.store.keys_on_node(evt.node) if k in self.store.blocks
        ]
        self.store.fail_nodes([evt.node])
        for key in keys:
            self._lost_at.setdefault(key, evt.time)
            if self.cache is not None:
                self.cache.put_negative(key, evt.time, self.config.negative_ttl)
        return True

    # -- background repair -------------------------------------------------------
    def _observed_p99(self, report: GatewayReport, at_time: float) -> float | None:
        """Recent foreground p99 the pacer reacts to: completed GETs of
        SLO-declaring tenants (all tenants when none declare) arriving in
        the trailing ``pacing_window``. None => idle (no recent traffic)."""
        slos = self.config.tenant_slo_p99 or {}
        since = at_time - self.config.pacing_window
        # report.recent holds the trailing completed GETs (bounded deque)
        # — the pacer's observation window no longer needs the unbounded
        # per-request record list, so streaming mode paces identically
        lats = [
            lat
            for (t, tenant, lat) in report.recent
            if since <= t <= at_time and (not slos or tenant in slos)
        ]
        if not lats:
            return None
        # same interpolating definition as GatewayReport.latency_percentile
        # — an index quantile would degenerate to the window MAX below
        # 100 samples and let one outlier throttle repair
        return float(np.percentile(lats, 99))

    def _foreground_pressure(self, at_time: float) -> float:
        """The pacer's fast signal: the estimated completion time of a
        degraded GET arriving right now — worst committed foreground
        backlog on any send port plus the k + t source-block
        serialization such a read pays on its client NIC. Completed-
        request p99 lags by exactly the queueing it should prevent (a
        request hurt by repair is only OBSERVED after it finishes
        waiting); port backlog reflects full-weight repair reservations
        the moment they are booked, so the loop reacts before the
        damage reaches the latency records. Zero while no port is
        backlogged: an idle fabric is no reason to slow repair.

        The backlog is read per SLO-declaring tenant (their fair-share
        cursors differ when they ride at different fabric weights);
        without declared SLOs it falls back to the default foreground
        tenant."""
        slos = self.config.tenant_slo_p99 or {}
        tenants = tuple(slos) or (FOREGROUND_TENANT,)
        backlog = max(
            (
                self.sim.send_backlog(node, tenant, at_time)
                for node in self.store.alive_nodes()
                for tenant in tenants
            ),
            default=0.0,
        )
        if backlog <= 0.0:
            return 0.0
        serialization = (
            (self.code.k + self.code.t)
            * self._block_bytes
            / self.profile.node_bandwidth
        )
        return backlog + serialization

    def _background_repair(self, at_time: float, report: GatewayReport) -> bool:
        """Repair up to ``repair_groups_per_run`` groups; returns True
        when pending groups remain (the caller requeues a continuation).
        Groups whose missing set provably cannot shrink (fix_group ran
        and left it unchanged) are skipped until their failure set
        changes — a continuation loop must not spin on data loss."""
        self.fixer.not_before = at_time
        pending: list[tuple[str, list[BlockKey]]] = []
        for gid in self._groups:
            missing = [
                (gid, r, c)
                for r in range(self.code.rows)
                for c in range(self.code.n)
                if not self.store.available((gid, r, c))
            ]
            if not missing:
                self._repair_stuck.pop(gid, None)
                continue
            if self._repair_stuck.get(gid) == frozenset(missing):
                continue
            pending.append((gid, missing))
        budget = self.config.repair_groups_per_run
        if budget is None:
            budget = len(pending)
        tracer = self.tracer
        rtid = 0
        run_end = at_time
        healed = 0
        if tracer.enabled and pending:
            rtid = tracer.begin_trace()
            self.fixer.trace_ctx = (rtid, rtid)
        for gid, missing in pending[:budget]:
            if self._pacer is not None:
                # closed loop: re-evaluate per group, so within one long
                # repair the share tracks mounting MTTR urgency (the
                # repair tenant's own makespan is "how long this repair
                # has been dragging")
                elapsed_anchor = max(
                    at_time, self.sim.class_makespan.get(REPAIR_TENANT, 0.0)
                )
                oldest = min(
                    (self._lost_at.get(k, at_time) for k in missing),
                    default=at_time,
                )
                observed = self._observed_p99(report, at_time)
                pressure = self._foreground_pressure(at_time)
                if pressure > 0.0:
                    observed = max(observed or 0.0, pressure)
                share = self._pacer.share(
                    observed,
                    self._pacing_slo,
                    outstanding_for=elapsed_anchor - oldest,
                )
                self.sim.set_tenant_weight(REPAIR_TENANT, share)
                self._pool.set_weight(REPAIR_TENANT, share)
                report.pacing.append((round(elapsed_anchor, 6), round(share, 4)))
                if rtid:
                    tracer.instant(
                        "pacing",
                        elapsed_anchor,
                        rtid,
                        rtid,
                        track=("repair", "repair"),
                        share=round(share, 4),
                        observed_p99=observed,
                        pressure=round(pressure, 6),
                    )
            rep = self.fixer.fix_group(gid)
            report.repair_reports.append(rep)
            # repaired blocks stay invisible to reads until the repair's
            # background transfers complete on the fabric AND its decode
            # compute clears the (shared, weighted) engine pool
            done = self.sim.class_makespan.get(REPAIR_TENANT, at_time)
            compute = rep.compute_time
            if self.config.decode_cost is not None:
                compute = self.config.decode_cost * rep.blocks_repaired
            if compute > 0.0:
                # fetch -> decode -> write-back: the decode cannot start
                # before the repair's fabric transfers deliver its inputs
                _, eng_done = self._pool.dispatch(
                    done,
                    compute,
                    tenant=REPAIR_TENANT,
                    ctx=(
                        (rtid, rtid, {"kind": "repair.decode", "group": gid})
                        if rtid
                        else None
                    ),
                )
                done = max(done, eng_done)
            run_end = max(run_end, done)
            still_missing = []
            for key in missing:
                if self.store.available(key):
                    self._healing[key] = done
                    if self.cache is not None:
                        # the block is no longer known-down; the _healing
                        # gate (not the tombstone) hides it until its
                        # write-back transfers land
                        self.cache.purge_negative([key])
                    t0 = self._lost_at.pop(key, None)
                    if t0 is not None:
                        report.mttr_samples.append(done - t0)
                        healed += 1
                        if rtid:
                            tracer.instant(
                                "repair.heal",
                                done,
                                rtid,
                                rtid,
                                track=("repair", "repair"),
                                key=str(key),
                                mttr=round(done - t0, 6),
                            )
                else:
                    still_missing.append(key)
            if still_missing:
                # fix_group repaired everything it could: what's left is
                # stuck until the failure set changes (data loss, or a
                # recovery event restoring sources)
                self._repair_stuck[gid] = frozenset(still_missing)
            else:
                self._repair_stuck.pop(gid, None)
        if rtid:
            tracer.root_span(
                "repair.run",
                at_time,
                max(run_end, at_time),
                rtid,
                track=("repair", "repair"),
                groups=min(budget, len(pending)),
                healed=healed,
            )
            tracer.end_trace(rtid)
            self.fixer.trace_ctx = None
        return len(pending) > budget

    # -- durability audit ---------------------------------------------------------
    def audit_durability(self) -> dict:
        """Ground-truth durability snapshot against the RAW store (cache
        copies don't count — a reconstruction in gateway memory is not a
        durable replica): blocks currently missing, blocks in clusters
        the code provably cannot rebuild (``blocks_lost`` — data loss),
        and objects no read plan can serve right now."""
        missing_blocks = 0
        blocks_lost = 0
        for gid in self._groups:
            fm = self.store.failure_matrix(gid, self.code.rows, self.code.n)
            missing_blocks += int(fm.sum())
            for cluster in independent_clusters(fm):
                if not is_recoverable(self.code, cluster):
                    blocks_lost += int(cluster.sum())
        store_planner = DegradedReadPlanner(self.store, self.code)
        unreadable = 0
        for oid, (gid, row) in self._objects.items():
            try:
                store_planner.plan(gid, row)
            except UnreadableObjectError:
                unreadable += 1
        return {
            "missing_blocks": missing_blocks,
            "blocks_lost": blocks_lost,
            "unreadable_objects": unreadable,
        }

    # -- SLO admission estimator -------------------------------------------------
    def _decode_launch_estimate(self) -> float:
        """Expected scaled wall time of one batched decode launch, from
        the coalescer's measured history (0 until the first launch —
        optimistic, so cold-start traffic is admitted). Modeled-cost mode
        returns the modeled cost exactly."""
        if self.config.decode_cost is not None:
            return self.config.decode_cost
        st = self.coalescer.stats
        return st.compute_time / st.decode_calls if st.decode_calls else 0.0

    def _estimate_service_time(
        self, plan: ReadPlan, now: float, tenant: str
    ) -> float:
        """Estimated completion time for a GET arriving ``now``: source
        blocks not in cache serialize into the request's single client
        NIC at the tenant's GUARANTEED fair-share rate, behind the
        tenant's own most-backlogged source-port cursor (reservations of
        lighter tenants are preemptible under the quantum fabric, so
        they don't count against it), and a degraded plan then waits for
        the least-loaded decode engine's backlog plus its own launches.
        O(plan) on purpose — an admission decision may not re-run the
        simulation — so it uses the simulator's per-(port, tenant)
        cursors rather than exact timeline search."""
        fetch_bytes = 0
        net_backlog = 0.0
        for key in plan.source_keys:
            if self.cache is not None and key in self.cache:
                continue
            fetch_bytes += self._block_bytes
            net_backlog = max(
                net_backlog,
                self.sim.send_backlog(self.store.node_of(key), tenant, now),
            )
        share = self.sim.weight_of(tenant)
        est = net_backlog + fetch_bytes / (share * self.profile.node_bandwidth)
        if self.config.pipeline == SERIAL:
            # serial mode gates every fetch on the previous window's
            # completion — under load that barrier IS the latency
            est += max(0.0, self._window_free - now)
        if plan.decodes:
            est += max(0.0, self._pool.earliest_start(now) - now)
            est += self._decode_launch_estimate() * len(plan.decodes)
        return est

    # -- helpers ----------------------------------------------------------------
    def _client_port(self, req: Request) -> int:
        # negative node ids: client NICs outside the storage cluster.
        # Hashed per REQUEST, not per object: a popular object is popular
        # because many distinct clients want it, so its traffic spreads
        # over client NICs instead of melting one artificial hot port.
        h = (req.object_id * 1_000_003 + int(req.time * 1e7)) % (2**31)
        return -(1 + h % self.config.num_client_ports)

    def _assemble_payload(self, req, plan, fetched, decoded) -> np.ndarray:
        """The GET's (k, q) payload: direct blocks + reconstructions."""
        gid, row = self._objects[req.object_id]
        got = []
        for c in range(self.code.k):
            key = (gid, row, c)
            if key in fetched and c not in decoded:
                got.append(fetched[key])
            else:
                got.append(decoded[c])
        return np.stack(got)

    def _verify_get(self, req, payload: np.ndarray) -> None:
        want = self._expected[req.object_id]
        if not np.array_equal(payload, want):
            raise AssertionError(
                f"GET integrity failure for object {req.object_id}"
            )
