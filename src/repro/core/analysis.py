"""Analytical + Monte-Carlo evaluation of CORE vs MDS vs LRC (paper §5).

All closed forms are from §5.1; the Monte-Carlo engines mirror §5.2/§5.3
("measured numerically using a Monte-Carlo experiment"). Traffic is
normalized by the object size (k blocks); repair time by the time to pull
a whole object from a single node (k block-times).

NOTE on the paper's π_C formula: the paper prints
``π_C >= Σ C(n,i) θ^i (1-θ)^{n-i}`` with θ = Pr(column has ≤1 failure);
as printed this sums the probability that at most m columns are *good*,
which is clearly a typo (it would vanish for small p). The intended
quantity is Pr(#bad columns ≤ m) with a column bad w.p. 1-θ, which is
what we implement: a good column vertically repairs its ≤1 missing block,
and with ≥ k fully-repaired columns every row decodes horizontally.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.coding import lrc as lrc_mod
from repro.core.product_code import CoreCode
from repro.core.recoverability import is_recoverable
from repro.core.scheduling import schedule_rgs

# ---------------------------------------------------------------------------
# §5.1 static resilience (closed forms)
# ---------------------------------------------------------------------------


def _binom_pmf(n: int, i: int, p: float) -> float:
    return math.comb(n, i) * (p**i) * ((1.0 - p) ** (n - i))


def _binom_cdf(n: int, m: int, p: float) -> float:
    return sum(_binom_pmf(n, i, p) for i in range(0, m + 1))


def resilience_mds(n: int, k: int, p: float) -> float:
    """π_E = Pr(B(n,p) <= n-k)."""
    return _binom_cdf(n, n - k, p)


def resilience_lrc(n: int, k: int, p: float) -> float:
    """π_L per §5.1 (Pr of global-decodable plus the local-repair terms)."""
    m = n - k
    theta = (k / 2 + 1) * p * (1.0 - p) ** (k / 2)
    return (
        _binom_cdf(n, m - 2, p)
        + _binom_pmf(n, m - 1, p) * 2.0 * theta * (1.0 - theta)
        + _binom_pmf(n, m, p) * (1.0 - theta) ** 2
    )


def resilience_core_lower(n: int, k: int, t: int, p: float) -> float:
    """Lower bound on π_C: Pr(#bad columns <= n-k), bad = >1 failure in
    the (t+1)-block column. (Paper's formula with the typo corrected —
    see module docstring.)"""
    theta_good = (1.0 - p) ** (t + 1) + (t + 1) * p * (1.0 - p) ** t
    return _binom_cdf(n, n - k, 1.0 - theta_good)


def nines(pi: float) -> float:
    """π -> 'number of nines' = log10(1/(1-π)), capped for π == 1."""
    if pi >= 1.0:
        return float("inf")
    return math.log10(1.0 / (1.0 - pi))


# ---------------------------------------------------------------------------
# §5.2 Monte-Carlo repair traffic & repair time
# ---------------------------------------------------------------------------


@dataclass
class MCResult:
    mean_traffic: float  # E(W | Π), normalized by k blocks
    var_traffic: float  # Var(W | Π)
    mean_time: float  # E(T | Π), normalized by k block-times
    var_time: float
    resilience: float  # empirical Pr(Π)
    samples: int


def _simulate_makespan(steps: list, k: int) -> float:
    """Repair makespan under the §5.2 network model.

    Congestion-free fabric; each node has unit send/receive bandwidth of
    one block per block-time. Each step executes at a distinct receiver
    and must pull ``len(sources)`` blocks (receiver-bound: c block-times),
    and can only start after every source block exists. Source-node send
    contention is modeled by tracking a next-free time per source cell.
    Normalized by k block-times.
    """
    ready: dict[tuple[int, int], float] = {}
    send_free: dict[tuple[int, int], float] = {}
    makespan = 0.0
    for step in steps:
        start = 0.0
        for src in step.sources:
            start = max(start, ready.get(src, 0.0))
        # receiver pulls c blocks serially; sources also serialize sends
        finish = start
        for src in step.sources:
            s = max(finish if False else start, send_free.get(src, 0.0))
            send_free[src] = s + 1.0
        finish = start + len(step.sources)
        for cell in step.repairs:
            ready[cell] = finish
        makespan = max(makespan, finish)
    return makespan / k


def _ec_repair_steps(fm_row: np.ndarray, n: int, k: int) -> list:
    """Classic MDS repair of one object: one decode from k survivors
    fixes every failure in the row (Opt1+Opt2 semantics)."""
    from repro.core.scheduling import RepairStep

    failed = np.flatnonzero(fm_row)
    avail = np.flatnonzero(~fm_row)[:k]
    return [
        RepairStep(
            "H",
            0,
            tuple((0, int(c)) for c in failed),
            tuple((0, int(c)) for c in avail),
        )
    ]


def mc_repair_mds(n: int, k: int, p: float, samples: int, seed: int = 0) -> MCResult:
    rng = np.random.default_rng(seed)
    traffics, times = [], []
    ok = 0
    for _ in range(samples):
        fm = rng.random(n) < p
        nf = int(fm.sum())
        if nf == 0:
            continue
        if nf > n - k:
            continue  # unrecoverable -> excluded by conditioning on Π
        ok += 1
        steps = _ec_repair_steps(fm, n, k)
        traffics.append(sum(len(s.sources) for s in steps) / k)
        times.append(_simulate_makespan(steps, k))
    return _finalize(traffics, times, ok, samples)


def mc_repair_lrc(n: int, k: int, p: float, samples: int, seed: int = 0) -> MCResult:
    code = lrc_mod.make_lrc(n, k)
    rng = np.random.default_rng(seed)
    traffics, times = [], []
    ok = 0
    for _ in range(samples):
        fm = rng.random(n) < p
        failed = set(int(i) for i in np.flatnonzero(fm))
        if not failed:
            continue
        plan = code.repair_plan(set(failed))
        if plan is None:
            continue
        ok += 1
        from repro.core.scheduling import RepairStep

        steps = []
        for kind, sources, repaired in plan:
            steps.append(
                RepairStep(
                    "V" if kind == "local" else "H",
                    0,
                    tuple((0, int(r)) for r in repaired),
                    tuple((0, int(s)) for s in sources),
                )
            )
        traffics.append(sum(len(s.sources) for s in steps) / k)
        times.append(_simulate_makespan(steps, k))
    return _finalize(traffics, times, ok, samples)


def mc_repair_core(
    n: int, k: int, t: int, p: float, samples: int, seed: int = 0
) -> MCResult:
    code = CoreCode(n=n, k=k, t=t)
    rng = np.random.default_rng(seed)
    traffics, times = [], []
    ok = 0
    for _ in range(samples):
        fm = rng.random((t + 1, n)) < p
        nf = int(fm.sum())
        if nf == 0:
            continue
        if not is_recoverable(code, fm):
            continue
        sched = schedule_rgs(code, fm)
        assert sched is not None
        ok += 1
        affected = max(1, int((fm.sum(axis=1) > 0).sum()))
        traffics.append(sched.traffic / (k * affected))
        times.append(_simulate_makespan(sched.steps, k))
    return _finalize(traffics, times, ok, samples)


def _finalize(traffics, times, ok, samples) -> MCResult:
    if not traffics:
        return MCResult(0.0, 0.0, 0.0, 0.0, 0.0, samples)
    tr = np.asarray(traffics)
    tm = np.asarray(times)
    return MCResult(
        mean_traffic=float(tr.mean()),
        var_traffic=float(tr.var()),
        mean_time=float(tm.mean()),
        var_time=float(tm.var()),
        resilience=ok / samples,
        samples=samples,
    )


# ---------------------------------------------------------------------------
# §5.3 degraded reads
# ---------------------------------------------------------------------------


def degraded_read_mds(n: int, k: int, p: float, samples: int, seed: int = 0,
                      distributed: bool = False) -> float:
    """Normalized traffic to read one object under unavailability p.

    Centralized: the reader needs the whole object — k systematic reads if
    all available, else any-k decode (still k, + re-reads of what it
    already pulled are not double counted: decode subsumes the read).
    Distributed: k readers, one systematic block each; a reader whose
    block is missing pulls k blocks to decode it.
    """
    rng = np.random.default_rng(seed)
    total, cnt = 0.0, 0
    for _ in range(samples):
        fm = rng.random(n) < p
        if int(fm.sum()) > n - k:
            continue
        cnt += 1
        miss_sys = int(fm[:k].sum())
        if not distributed:
            total += k / k  # decode-or-read is k blocks either way
        else:
            total += ((k - miss_sys) + miss_sys * k) / k
    return total / max(cnt, 1)


def degraded_read_lrc(n: int, k: int, p: float, samples: int, seed: int = 0,
                      distributed: bool = False) -> float:
    code = lrc_mod.make_lrc(n, k)
    rng = np.random.default_rng(seed)
    total, cnt = 0.0, 0
    for _ in range(samples):
        fm = rng.random(n) < p
        failed = set(int(i) for i in np.flatnonzero(fm))
        miss_sys = [i for i in range(k) if i in failed]
        if failed and code.repair_plan(set(failed)) is None:
            continue
        cnt += 1
        if not distributed:
            if not miss_sys:
                total += 1.0
                continue
            # repair missing systematic blocks (local first), then read rest
            plan = code.repair_plan(set(failed))
            repair_traffic = 0
            covered: set[int] = set()
            for kind, sources, repaired in plan:
                if any(r in miss_sys for r in repaired) or kind == "global":
                    repair_traffic += len(sources)
                    covered.update(repaired)
                if all(ms in covered for ms in miss_sys):
                    break
            total += ((k - len(miss_sys)) + repair_traffic) / k
        else:
            tr = 0
            for i in range(k):
                if i not in failed:
                    tr += 1
                else:
                    grp = code.local_group(i)
                    if sum(1 for g in grp if g in failed) == 1:
                        tr += len(grp) - 1  # k/2 local reads
                    else:
                        tr += k  # global decode
            total += tr / k
    return total / max(cnt, 1)


def degraded_read_core(n: int, k: int, t: int, p: float, samples: int,
                       seed: int = 0, distributed: bool = False) -> float:
    code = CoreCode(n=n, k=k, t=t)
    rng = np.random.default_rng(seed)
    total, cnt = 0.0, 0
    for _ in range(samples):
        fm = rng.random((t + 1, n)) < p
        if not is_recoverable(code, fm):
            continue
        cnt += 1
        # read object = row 0 (w.l.o.g. — rows are exchangeable)
        row = 0
        miss_sys = [c for c in range(k) if fm[row, c]]
        if not distributed:
            if not miss_sys:
                total += 1.0
                continue
            tr = k - len(miss_sys)  # direct reads of the available blocks
            horiz_needed = False
            for c in miss_sys:
                if fm[:, c].sum() == 1:
                    tr += t  # vertical repair
                else:
                    horiz_needed = True
            if horiz_needed:
                # one horizontal decode replaces everything: k reads total
                tr = min(tr + k, 2 * k)
                tr = k if int(fm[row].sum()) <= n - k else tr
            total += tr / k
        else:
            tr = 0
            for c in range(k):
                if not fm[row, c]:
                    tr += 1
                elif fm[:, c].sum() == 1:
                    tr += t
                else:
                    tr += k  # degraded reader falls back to row decode
            total += tr / k
    return total / max(cnt, 1)


# ---------------------------------------------------------------------------
# parameter sweeps (§5.2 "for each stretch factor choose the best")
# ---------------------------------------------------------------------------


def core_params_for_stretch(stretch: float, tol: float = 0.08) -> list[tuple[int, int, int]]:
    """Enumerate (n, k, t) with stretch factor ~= requested."""
    out = []
    for k in range(2, 17):
        for n in range(k + 1, min(k + 7, 26)):
            for t in range(2, 11):
                s = (n * (t + 1)) / (k * t)
                if abs(s - stretch) <= tol:
                    out.append((n, k, t))
    return out


def ec_params_for_stretch(stretch: float, tol: float = 0.08) -> list[tuple[int, int]]:
    out = []
    for k in range(2, 17):
        for n in range(k + 1, min(k + 9, 26)):
            if abs(n / k - stretch) <= tol:
                out.append((n, k))
    return out


def lrc_params_for_stretch(stretch: float, tol: float = 0.08) -> list[tuple[int, int]]:
    out = []
    for k in range(2, 17, 2):
        for n in range(k + 2, min(k + 9, 26)):
            if abs(n / k - stretch) <= tol:
                out.append((n, k))
    return out
