# The paper's primary contribution: the (n, k, t) CORE product code and
# its failure-handling algorithms (clustering, recoverability, repair
# scheduling) — see DESIGN.md §1.
from repro.core.product_code import CoreCode, CoreCodec
from repro.core.failure_matrix import (
    independent_clusters,
    num_clusters,
    plus_pattern,
    random_failure_matrix,
    step_pattern,
)
from repro.core.recoverability import (
    fast_classify,
    irrecoverability_lower_bound,
    is_recoverable,
    recoverability_upper_bound,
)
from repro.core.scheduling import (
    SCHEDULERS,
    RepairStep,
    Schedule,
    schedule_column_first,
    schedule_rgs,
    schedule_row_first,
)

__all__ = [
    "CoreCode",
    "CoreCodec",
    "independent_clusters",
    "num_clusters",
    "plus_pattern",
    "random_failure_matrix",
    "step_pattern",
    "fast_classify",
    "irrecoverability_lower_bound",
    "is_recoverable",
    "recoverability_upper_bound",
    "SCHEDULERS",
    "RepairStep",
    "Schedule",
    "schedule_column_first",
    "schedule_rgs",
    "schedule_row_first",
]
